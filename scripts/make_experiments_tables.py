"""Generate the EXPERIMENTS.md dry-run + roofline tables from
experiments/dryrun/*.json.

    PYTHONPATH=src python scripts/make_experiments_tables.py > /tmp/tables.md
"""
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"

ARCH_ORDER = ["gemma_7b", "qwen25_32b", "gemma3_4b", "stablelm_3b",
              "hymba_15b", "llama32_vision_90b", "whisper_small",
              "mamba2_370m", "mixtral_8x7b", "deepseek_v2_236b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load():
    recs = {}
    for f in ROOT.glob("*.json"):
        r = json.loads(f.read_text())
        tag = r.get("tag", "baseline")
        recs[(r["arch"], r["shape"], r["mesh"], tag)] = r
    return recs


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    return f"{x*1e3:.1f}ms"


def gb(x):
    return f"{x/1e9:.1f}" if x is not None else "-"


def main():
    recs = load()
    print("### Dry-run matrix (status per cell; both meshes)\n")
    print("| arch | shape | pod(256) | multipod(512) | HBM GB/dev "
          "(pod) | note |")
    print("|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            rp = recs.get((a, s, "pod", "baseline"))
            rm = recs.get((a, s, "multipod", "baseline"))
            if rp is None and rm is None:
                continue
            stat = lambda r: (r or {}).get("status", "missing")
            note = ""
            if stat(rp) == "skipped":
                note = rp["reason"][:46]
            mem = "-"
            if rp and rp.get("memory_analysis"):
                mem = gb(rp["memory_analysis"].get("total_bytes_per_device"))
            print(f"| {a} | {s} | {stat(rp)} | {stat(rm)} | {mem} | {note} |")

    print("\n### Roofline (single-pod 16x16, per production step)\n")
    print("memF/fracF = memory term with attention/SSD tile traffic fused "
          "in VMEM (the Pallas-kernel execution path).\n")
    print("| arch | shape | compute | memory | memF | collective | dom "
          "(fused) | useful | frac | fracF |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, "pod", "baseline"))
            if not r or r.get("status") != "ok":
                continue
            print(f"| {a} | {s} | {fmt_s(r['compute_s'])} "
                  f"| {fmt_s(r['memory_s'])} | {fmt_s(r.get('memory_fused_s'))} "
                  f"| {fmt_s(r['collective_s'])} "
                  f"| {r.get('dominant_fused', r['dominant'])} "
                  f"| {r['useful_ratio']:.2f} "
                  f"| {r['roofline_fraction']:.3f} "
                  f"| {r.get('roofline_fraction_fused', 0):.3f} |")

    print("\n### Collective inventory (pod mesh, counts x executed trips)\n")
    print("| arch | shape | all-reduce | all-gather | reduce-scatter | "
          "all-to-all | permute | coll GB/dev |")
    print("|---|---|---|---|---|---|---|---|")
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, "pod", "baseline"))
            if not r or r.get("status") != "ok":
                continue
            c = r.get("collective_counts", {})
            g = lambda k: int(c.get(k, 0))
            print(f"| {a} | {s} | {g('all-reduce')} | {g('all-gather')} "
                  f"| {g('reduce-scatter')} | {g('all-to-all')} "
                  f"| {g('collective-permute')} | {gb(r['collective_bytes'])} |")

    # failures
    fails = [(k, r) for k, r in recs.items() if r.get("status") == "failed"]
    if fails:
        print("\n### FAILURES (bugs)\n")
        for k, r in sorted(fails):
            print(f"- {k}: {r.get('error', '')[:140]}")


if __name__ == "__main__":
    main()
