#!/usr/bin/env python
"""Documentation gate (run by scripts/check.sh before the test suite).

Two checks, both plain AST/regex — no third-party linter needed:

1. **Public-API docstring audit.** Every name in ``__all__`` of the audited
   modules (the public projection/serving API surface) must resolve to a
   top-level function or class carrying a docstring that includes a
   one-line ``>>>`` usage example (the shapes/dtypes contract lives in the
   prose; the example line is the mechanically checkable part). Public
   methods and properties of audited classes must carry docstrings too
   (no example required at method granularity).

2. **Anchor/link staleness.** Docstrings and READMEs point into DESIGN.md
   by section number (``DESIGN.md §7``); if a section is renumbered or
   removed those pointers rot silently. This check greps every
   ``DESIGN.md §N`` / ``§§A–B`` reference under src/, tests/, benchmarks/,
   examples/ and the top-level *.md files and requires a matching
   ``## §N`` heading in DESIGN.md. Relative markdown links in README.md /
   benchmarks/README.md must name files that exist.

Exit code 0 = clean; nonzero prints every violation.
"""
from __future__ import annotations

import ast
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

AUDITED_MODULES = [
    "src/repro/core/engine.py",
    "src/repro/core/families.py",
    "src/repro/core/constraints.py",
    "src/repro/core/l12.py",
    "src/repro/core/hoyer.py",
    "src/repro/dist/projection.py",
    "src/repro/sae/serve.py",
    "src/repro/serve/compact.py",
    "src/repro/serve/refresh.py",
    "src/repro/serve/engine.py",
    "src/repro/kernels/fused_step/ops.py",
]

ANCHOR_SCAN_GLOBS = [
    "src/**/*.py", "tests/**/*.py", "benchmarks/**/*.py", "examples/**/*.py",
    "*.md", "benchmarks/README.md",
]

LINKED_READMES = ["README.md", "benchmarks/README.md"]


def _module_all(tree: ast.Module):
    """Names in a literal ``__all__`` list/tuple, or None. A computed
    ``__all__`` (concatenation, augmented assignment, ...) also returns
    None — audited modules must keep it a plain literal so the audit
    cannot silently skip exports."""
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "__all__":
                    try:
                        names = ast.literal_eval(node.value)
                    except (ValueError, SyntaxError):
                        return None
                    return list(names) if isinstance(names, (list, tuple)) \
                        else None
    return None


def audit_module(relpath: str) -> list[str]:
    path = ROOT / relpath
    tree = ast.parse(path.read_text(), filename=str(path))
    names = _module_all(tree)
    errors = []
    if names is None:
        return [f"{relpath}: no literal __all__ (audited modules must "
                f"declare a plain list/tuple of strings)"]
    defs = {n.name: n for n in tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.ClassDef))}
    for name in names:
        node = defs.get(name)
        if node is None:
            errors.append(f"{relpath}: exported {name!r} is not a top-level "
                          f"def/class in this module")
            continue
        doc = ast.get_docstring(node)
        if not doc:
            errors.append(f"{relpath}:{node.lineno}: {name} has no docstring")
            continue
        if ">>>" not in doc:
            errors.append(f"{relpath}:{node.lineno}: {name} docstring has no "
                          f">>> usage example")
        if isinstance(node, ast.ClassDef):
            for item in node.body:
                if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and not item.name.startswith("_") \
                        and not ast.get_docstring(item):
                    errors.append(f"{relpath}:{item.lineno}: public method "
                                  f"{name}.{item.name} has no docstring")
    return errors


def check_anchors() -> list[str]:
    design = (ROOT / "DESIGN.md").read_text()
    sections = set(int(m) for m in re.findall(r"^## §(\d+)", design, re.M))
    errors = []
    seen = set()
    for glob in ANCHOR_SCAN_GLOBS:
        for path in ROOT.glob(glob):
            if not path.is_file() or path in seen:
                continue
            seen.add(path)
            text = path.read_text(errors="ignore")
            rel = path.relative_to(ROOT)
            refs = set()
            for m in re.finditer(r"DESIGN\.md §(\d+)", text):
                refs.add(int(m.group(1)))
            for m in re.finditer(r"DESIGN\.md §§(\d+)[–-](\d+)", text):
                refs.update(range(int(m.group(1)), int(m.group(2)) + 1))
            for sec in sorted(refs - sections):
                errors.append(f"{rel}: references DESIGN.md §{sec} but "
                              f"DESIGN.md has no '## §{sec}' heading")
    return errors


def check_links() -> list[str]:
    errors = []
    for rel in LINKED_READMES:
        path = ROOT / rel
        if not path.exists():
            errors.append(f"{rel}: missing (README set incomplete)")
            continue
        text = path.read_text()
        for m in re.finditer(r"\[[^\]]+\]\(([^)#]+)(?:#[^)]*)?\)", text):
            target = m.group(1).strip()
            if re.match(r"^[a-z]+://", target) or target.startswith("mailto:"):
                continue
            if not (path.parent / target).exists():
                errors.append(f"{rel}: link target {target!r} does not exist")
    return errors


def main() -> int:
    errors = []
    for mod in AUDITED_MODULES:
        errors += audit_module(mod)
    errors += check_anchors()
    errors += check_links()
    if errors:
        print(f"docs check FAILED ({len(errors)} violation(s)):")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"docs check OK: {len(AUDITED_MODULES)} audited modules, "
          f"anchors and links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
