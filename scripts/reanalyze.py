"""Re-run roofline analysis offline from archived HLO (.hlo.zst) files,
rewriting the JSON records — lets the parser evolve without recompiling.

    PYTHONPATH=src python scripts/reanalyze.py [pattern]
"""
import json
import pathlib
import sys

import zstandard as zstd

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.configs import get_config
from repro.models.zoo import build
from repro.roofline.analysis import analyze, model_flops_for, active_params

ROOT = pathlib.Path(__file__).resolve().parents[1] / "experiments"
pattern = sys.argv[1] if len(sys.argv) > 1 else ""

n_params_cache = {}
for hf in sorted((ROOT / "hlo").glob(f"*{pattern}*.hlo.zst")):
    name = hf.name[: -len(".hlo.zst")]
    parts = name.split("__")
    arch, shape, mesh_kind = parts[0], parts[1], parts[2]
    tag = parts[3] if len(parts) > 3 else "baseline"
    jf = ROOT / "dryrun" / f"{name}.json"
    old = json.loads(jf.read_text()) if jf.exists() else {}
    if old.get("status") not in (None, "ok"):
        continue
    hlo = zstd.ZstdDecompressor().decompress(hf.read_bytes()).decode()
    cfg = get_config(arch)
    if arch not in n_params_cache:
        n_params_cache[arch] = build(cfg).n_params()
    n_total = n_params_cache[arch]
    n_active = active_params(cfg, n_total)
    n_chips = 512 if mesh_kind == "multipod" else 256
    cost = {"flops": old.get("flops_xla_raw", 0.0),
            "bytes accessed": old.get("bytes_xla_raw", 0.0)}
    rf = analyze(arch, shape, mesh_kind, n_chips, cost, hlo,
                 model_flops_for(cfg, shape, n_total, n_active),
                 memory_analysis=old.get("memory_analysis"))
    rec = rf.to_json()
    for k in ("status", "kind", "tag", "n_params_total", "n_params_active",
              "lower_s", "compile_s", "hlo_bytes"):
        if k in old:
            rec[k] = old[k]
    rec.setdefault("status", "ok")
    jf.write_text(json.dumps(rec, indent=1, default=str))
    print(f"{name}: compute={rf.compute_s:.3f}s memory={rf.memory_s:.3f}s "
          f"coll={rf.collective_s:.3f}s dom={rf.dominant} "
          f"useful={rf.useful_ratio:.2f} frac={rf.roofline_fraction:.3f}")
