#!/usr/bin/env bash
# Tier-1 verification: collect must be clean, then the full suite on CPU.
#
#   scripts/check.sh               # docs check + collect check + full suite
#   scripts/check.sh --fast        # skip the slow subprocess multi-device tests
#   scripts/check.sh --bench-smoke # quick projection-engine benchmark gate:
#                                  # runs benchmarks/run.py --quick, emits
#                                  # BENCH_proj.json + BENCH_families.json +
#                                  # BENCH_dist_proj.json + BENCH_fused_step
#                                  # .json + BENCH_serve.json
#                                  # + BENCH_zoo_serve.json
#                                  # + BENCH_fleet_serve.json
#                                  # + BENCH_dist_fused.json (CI uploads all
#                                  # as artifacts), fails if the packed-batch
#                                  # path is >1.15x slower than per-matrix,
#                                  # the sharded engine is >1.15x the
#                                  # replicated solve on the 8-way host mesh,
#                                  # the bilevel family is >1.0x plain at the
#                                  # high-sparsity regime, the compacted SAE
#                                  # serving step costs >0.25x the dense
#                                  # encoder GEMM FLOPs at the ~99%
#                                  # column-sparsity regime, the zoo
#                                  # compact decode is <2x dense tokens/sec,
#                                  # not exact to 1e-4, or retraces across
#                                  # hot refresh / live re-compaction, the
#                                  # fused two-pass projected step is >0.8x
#                                  # the unfused one (wall time), touches
#                                  # more XLA-costed bytes, or diverges from
#                                  # the unfused params, or the fused_sharded
#                                  # step is >0.85x the unfused sharded one
#                                  # on the 8-way host mesh, gathers a weight
#                                  # shard, or diverges >1e-5 from it, or the
#                                  # continuous-batching fleet engine fails
#                                  # its gates (continuous < 2x cohort
#                                  # sustained tok/s under churn at the ~99%
#                                  # regime, any retrace across the
#                                  # admit/evict/refresh/recompact lifecycle,
#                                  # or any token mismatch vs dense / solo
#                                  # serving)
#
# The docs check (scripts/check_docs.py) enforces the public-API docstring
# contract (every exported symbol of the audited modules carries a
# docstring with a one-line example) and fails on stale DESIGN.md section
# anchors / broken local links referenced from docstrings and READMEs.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

if [[ "${1:-}" == "--bench-smoke" ]]; then
    echo "== bench smoke: projection engine (local + sharded) =="
    # benchmarks.run swallows per-bench failures (prints an ERROR row,
    # exits 0); removing the artifacts first guarantees the gate below
    # reads THIS run's numbers or fails loudly — never stale files
    rm -f BENCH_proj.json BENCH_families.json BENCH_dist_proj.json \
          BENCH_fused_step.json BENCH_serve.json BENCH_zoo_serve.json \
          BENCH_fleet_serve.json BENCH_dist_fused.json
    python -m benchmarks.run --quick --only proj_
    python -m benchmarks.run --quick --only dist_fused
    python -m benchmarks.run --quick --only fused_step
    python -m benchmarks.run --quick --only serve
    python -m benchmarks.run --quick --only zoo_serve
    python -m benchmarks.run --quick --only fleet_serve
    python - <<'PYEOF'
import json
d = json.load(open("BENCH_proj.json"))
ratio = d["packed"]["ratio_packed_vs_per_matrix"]
warm = d["warm_start"]["steady_state_newton_steps"]
diff = d["packed"]["max_abs_diff"]
assert ratio <= 1.15, (
    f"packed-batch path is {ratio:.2f}x the per-matrix time (>1.15x gate)")
assert diff <= 1e-4, f"packed != per-matrix (max abs diff {diff:.3e})"
# measured median is ~1.5-2; gate at 3 for fp/platform headroom (a broken
# warm start regresses to the cold ~5-8)
assert warm <= 3, f"steady-state warm Newton steps {warm} > 3"
print(f"bench smoke OK: packed/per-matrix {ratio:.2f}x, "
      f"steady-state warm Newton steps {warm}, packed max diff {diff:.2e}")

fd = json.load(open("BENCH_families.json"))
hi = [r for r in fd["regimes"] if r["C_frac"] == 0.01][0]
bratio = hi["ratio_bilevel_vs_plain"]
# the bi-level solve carries no per-column sort and O(m) iteration state —
# at high sparsity it must never lose to the exact solver. The 1.0 bound
# is not a zero-margin gate: measured ~0.02-0.07x on the quick CPU shape,
# so it holds >10x headroom against timing noise
assert bratio <= 1.0, (
    f"bilevel is {bratio:.2f}x plain at high sparsity (>1.0x gate)")
# the l1,2 solve (PR 10) is the same sort-free bilevel machinery on column
# energies — measured ~0.01x on the quick CPU shape, gated at 1.0 with the
# same >10x noise headroom as the bilevel gate above
lratio = hi["ratio_l12_vs_plain"]
assert lratio <= 1.0, (
    f"l12 is {lratio:.2f}x plain at high sparsity (>1.0x gate)")
assert fd["mixed"]["one_launch_per_family"], fd["mixed"]["launches"]
fdiff = fd["mixed"]["max_abs_diff_vs_per_leaf"]
assert fdiff <= 1e-4, f"mixed packed != per-leaf (max abs diff {fdiff:.3e})"
# the PR 10 fused l1,2 claim: the scale-mode two-pass fold rides the PR-7
# fused step unchanged — it must beat the unfused adam -> pack -> solve ->
# unpack step like the clip families do. Measured ~0.3x on the quick CPU
# shape, so the 0.85 gate keeps real headroom; exactness is gated tight
# (both solvers run the same Newton on the same energies — measured 0.0)
lf = fd["l12_fused"]
assert lf["ratio"] <= 0.85, (
    f"fused l12 step is {lf['ratio']:.3f}x the unfused step (>0.85x gate)")
assert lf["max_abs_diff"] <= 1e-5, (
    f"fused l12 != unfused params (max abs diff {lf['max_abs_diff']:.3e})")
print(f"families bench smoke OK: bilevel/plain {bratio:.2f}x, l12/plain "
      f"{lratio:.2f}x at high sparsity, one launch per family, mixed max "
      f"diff {fdiff:.2e}, fused l12 {lf['ratio']:.2f}x unfused")

dd = json.load(open("BENCH_dist_proj.json"))
dratio = dd["ratio_sharded_vs_replicated"]
ddiff = dd["max_abs_diff"]
ag = dd["collectives"]["sharded"]["all-gather"]
# measured ~0.3x on the 8-way host mesh; gate at 1.15 for platform headroom
assert dratio <= 1.15, (
    f"sharded engine is {dratio:.2f}x the replicated solve (>1.15x gate)")
assert ddiff <= 1e-4, f"sharded != replicated (max abs diff {ddiff:.3e})"
assert ag == 0, f"sharded projection HLO contains {ag} all-gather(s)"
print(f"dist bench smoke OK: sharded/replicated {dratio:.2f}x, "
      f"0 all-gathers, max diff {ddiff:.2e}")

sd = json.load(open("BENCH_serve.json"))
colsp = sd["regime"]["column_sparsity_pct"]
fratio = sd["flops"]["ratio_compact_vs_dense_encoder"]
sz = sd["exactness"]["max_abs_diff_z"]
sx = sd["exactness"]["max_abs_diff_xhat_on_support"]
# the paper's serving claim: at the ~99% column-sparsity regime the
# compacted encoder GEMM is ~0.01x the dense one. The 0.25 bound keeps
# ~25x headroom while still failing loudly if compaction silently stops
# dropping columns; the regime assertion keeps the gate honest (a bench
# that drifted to low sparsity would pass 0.25 vacuously)
assert colsp >= 95.0, f"serve bench regime drifted: colsp {colsp:.1f}% < 95%"
assert fratio <= 0.25, (
    f"compact encoder GEMM is {fratio:.3f}x dense (>0.25x gate)")
assert sz <= 1e-4 and sx <= 1e-4, (
    f"compact serve != dense on support (z {sz:.2e}, xhat {sx:.2e})")
print(f"serve bench smoke OK: colsp {colsp:.1f}%, compact/dense encoder "
      f"FLOPs {fratio:.4f}x, max diff {max(sz, sx):.2e}")

fsd = json.load(open("BENCH_fused_step.json"))
fs_ratio = fsd["worst_ratio"]
fs_bytes = fsd["worst_bytes_ratio"]
fs_diff = fsd["worst_abs_diff"]
# the PR-7 fused-step claim: the two-HBM-pass projected step (pass 1
# streams Adam + per-column stats, Newton on O(num_segments) state, pass 2
# recomputes and clip-writes; no physical packed buffer) beats the unfused
# adam -> pack -> solve -> unpack step at every sparsity regime. Measured
# ~0.4-0.6x on the quick CPU shape (the axis=1 decoder entry is where the
# packer's physical transpose hurts most), so the 0.8 gate keeps real
# headroom against timing noise. The bytes gate confirms the structural
# claim independently of the clock: the fused step's XLA-costed "bytes
# accessed" must be strictly below the unfused step's at every regime
# (measured ~0.64x). Exactness is gated bit-tight — both solvers run the
# same Newton on the same statistics, so the params must match to fp32
# roundoff, not just "close".
assert fs_ratio <= 0.8, (
    f"fused step is {fs_ratio:.3f}x the unfused step (>0.8x gate)")
assert fs_bytes is not None and fs_bytes < 1.0, (
    f"fused step bytes ratio {fs_bytes} not < 1.0 (two-pass claim broken)")
assert fs_diff <= 1e-5, f"fused != unfused params (max abs diff {fs_diff:.3e})"
print(f"fused step bench smoke OK: fused/unfused {fs_ratio:.2f}x wall, "
      f"{fs_bytes:.2f}x bytes, max diff {fs_diff:.2e}")

dfd = json.load(open("BENCH_dist_fused.json"))
df_ratio = dfd["ratio_fused_vs_sharded"]
df_diff = dfd["max_abs_diff"]
df_ag = dfd["collectives"]["fused_sharded"]["all-gather"]
# the PR-8 tentpole claim: the fused two-pass step run rank-local inside
# shard_map (no packed buffer, one stacked (2,G) f32 psum per Newton
# evaluation) beats the unfused sharded step (adam -> pack -> shard_map
# Newton -> unpack) on the same column-sharded inputs. Measured ~0.42-0.44x
# on the 8-way quick host mesh, so the 0.85 gate keeps ~2x headroom against
# timing noise. Exactness is gated tight: both solvers run the same Newton
# on the same per-column statistics (measured diff 0.0 — bit-identical fp
# order per rank), and no path may gather a weight shard.
assert df_ratio <= 0.85, (
    f"fused_sharded is {df_ratio:.3f}x the unfused sharded step "
    f"(>0.85x gate)")
assert df_diff <= 1e-5, (
    f"fused_sharded != sharded params (max abs diff {df_diff:.3e})")
assert df_ag == 0, (
    f"fused_sharded HLO contains {df_ag} all-gather(s)")
print(f"dist fused bench smoke OK: fused_sharded/sharded {df_ratio:.2f}x "
      f"wall, 0 all-gathers, max diff {df_diff:.2e}")

zd = json.load(open("BENCH_zoo_serve.json"))
zcolsp = zd["regime"]["column_sparsity_pct"]
speedup = zd["throughput"]["speedup_compact_vs_dense"]
zdiff = zd["exactness"]["max_abs_diff_logits"]
retr = zd["recompiles"]["extra_after_refresh_and_recompact"]
# the PR-6 zoo serving claim: at the ~99% column-sparsity regime the
# compact decode step (MLP-dominated shape) is >= 2x dense tokens/sec —
# measured ~5-7x on the quick CPU shape, so the 2x gate keeps headroom
# against timing noise; the regime assertion keeps it honest. Scatter-back
# is on the measured path, so the 1e-4 exactness gate covers it (measured
# ~1e-8: the gathered GEMMs sum the same nonzero terms). Hot refresh and
# live re-compaction are shape-preserving by the slot design — any extra
# trace is a contract violation, gated at exactly zero.
assert zcolsp >= 95.0, (
    f"zoo serve regime drifted: colsp {zcolsp:.1f}% < 95%")
assert speedup >= 2.0, (
    f"zoo compact decode is {speedup:.2f}x dense (<2x gate)")
assert zdiff <= 1e-4, f"zoo compact forward != dense ({zdiff:.3e})"
assert retr == 0, (
    f"{retr} retrace(s) across hot refresh + live re-compaction")
print(f"zoo serve bench smoke OK: colsp {zcolsp:.1f}%, compact "
      f"{speedup:.1f}x dense tok/s, max diff {zdiff:.2e}, 0 retraces")

fld = json.load(open("BENCH_fleet_serve.json"))
fcolsp = fld["regime"]["column_sparsity_pct"]
fspeed = fld["throughput"]["speedup_continuous_vs_cohort"]
fretr = fld["churn"]["extra_traces"]
fex = fld["exactness"]
# the PR-9 fleet serving claim: under open-loop churn (heavy-tailed
# generation lengths, one long request per cohort) continuous batching
# sustains >= 2x the cohort baseline's tok/s at the ~99% regime on the
# SAME compiled step — the cohort barrier idles finished slots (slot
# efficiency ~0.18 measured) while the engine re-admits them. Measured
# ~2.3-3x on the quick CPU shape. The lifecycle (mid-stream refresh +
# live recompact via the scheduler) must reuse the one trace, and every
# request's tokens must match dense and solo serving exactly (structural
# zeros + per-slot positions: bit-identical, gated at zero mismatches).
assert fcolsp >= 95.0, (
    f"fleet serve regime drifted: colsp {fcolsp:.1f}% < 95%")
assert fspeed >= 2.0, (
    f"continuous batching is {fspeed:.2f}x cohort tok/s (<2x gate)")
assert fretr == 0, (
    f"{fretr} retrace(s) across the admit/refresh/recompact lifecycle")
mism = (fex["token_mismatches_vs_dense"] + fex["token_mismatches_vs_solo"]
        + fex["token_mismatches_vs_cohort"])
assert mism == 0, f"{mism} token mismatch(es) across serving modes"
print(f"fleet serve bench smoke OK: colsp {fcolsp:.1f}%, continuous "
      f"{fspeed:.2f}x cohort tok/s, 0 retraces, 0 token mismatches")
PYEOF
    exit 0
fi

echo "== docs check (public-API docstrings + anchor targets) =="
python scripts/check_docs.py

echo "== collect check (must be 0 errors) =="
python -m pytest -q --collect-only >/dev/null

FAST_DESELECT=()
if [[ "${1:-}" == "--fast" ]]; then
    FAST_DESELECT=(--ignore=tests/test_multidevice.py
                   --ignore=tests/test_moe_and_serve.py
                   --ignore=tests/test_pipeline_compression.py)
fi

echo "== tier-1: pytest =="
# ${arr[@]+...} guard: empty-array expansion under `set -u` aborts on bash<4.4
python -m pytest -x -q ${FAST_DESELECT[@]+"${FAST_DESELECT[@]}"}
