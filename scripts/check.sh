#!/usr/bin/env bash
# Tier-1 verification: collect must be clean, then the full suite on CPU.
#
#   scripts/check.sh            # collect check + full suite
#   scripts/check.sh --fast     # skip the slow subprocess multi-device tests
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== collect check (must be 0 errors) =="
python -m pytest -q --collect-only >/dev/null

FAST_DESELECT=()
if [[ "${1:-}" == "--fast" ]]; then
    FAST_DESELECT=(--ignore=tests/test_multidevice.py
                   --ignore=tests/test_moe_and_serve.py
                   --ignore=tests/test_pipeline_compression.py)
fi

echo "== tier-1: pytest =="
# ${arr[@]+...} guard: empty-array expansion under `set -u` aborts on bash<4.4
python -m pytest -x -q ${FAST_DESELECT[@]+"${FAST_DESELECT[@]}"}
