#!/usr/bin/env bash
# Tier-1 verification: collect must be clean, then the full suite on CPU.
#
#   scripts/check.sh               # collect check + full suite
#   scripts/check.sh --fast        # skip the slow subprocess multi-device tests
#   scripts/check.sh --bench-smoke # quick projection-engine benchmark gate:
#                                  # runs benchmarks/run.py --quick, emits
#                                  # BENCH_proj.json (CI uploads it as an
#                                  # artifact), fails if the packed-batch
#                                  # path is >1.15x slower than per-matrix
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

if [[ "${1:-}" == "--bench-smoke" ]]; then
    echo "== bench smoke: projection engine =="
    python -m benchmarks.run --quick --only proj_engine
    python - <<'PYEOF'
import json
d = json.load(open("BENCH_proj.json"))
ratio = d["packed"]["ratio_packed_vs_per_matrix"]
warm = d["warm_start"]["steady_state_newton_steps"]
diff = d["packed"]["max_abs_diff"]
assert ratio <= 1.15, (
    f"packed-batch path is {ratio:.2f}x the per-matrix time (>1.15x gate)")
assert diff <= 1e-4, f"packed != per-matrix (max abs diff {diff:.3e})"
# measured median is ~1.5-2; gate at 3 for fp/platform headroom (a broken
# warm start regresses to the cold ~5-8)
assert warm <= 3, f"steady-state warm Newton steps {warm} > 3"
print(f"bench smoke OK: packed/per-matrix {ratio:.2f}x, "
      f"steady-state warm Newton steps {warm}, packed max diff {diff:.2e}")
PYEOF
    exit 0
fi

echo "== collect check (must be 0 errors) =="
python -m pytest -q --collect-only >/dev/null

FAST_DESELECT=()
if [[ "${1:-}" == "--fast" ]]; then
    FAST_DESELECT=(--ignore=tests/test_multidevice.py
                   --ignore=tests/test_moe_and_serve.py
                   --ignore=tests/test_pipeline_compression.py)
fi

echo "== tier-1: pytest =="
# ${arr[@]+...} guard: empty-array expansion under `set -u` aborts on bash<4.4
python -m pytest -x -q ${FAST_DESELECT[@]+"${FAST_DESELECT[@]}"}
