import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Probe: compile one cell and list the largest buffers in the optimized HLO
(debugging memory blowups)."""
import re
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from collections import Counter

from repro.configs import get_config
from repro.models.zoo import build
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import lower_cell

DT = {"pred": 1, "s8": 1, "u8": 1, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
      "f32": 4, "s64": 8, "f64": 8, "u16": 2, "s16": 2}

arch, shape = sys.argv[1], sys.argv[2]
cfg = get_config(arch)
model = build(cfg)
mesh = make_production_mesh(multi_pod=False)
cell = lower_cell(model, shape, mesh, False)
compiled = cell.compile()
print(compiled.memory_analysis())
hlo = compiled.as_text()

sizes = Counter()
for m in re.finditer(r"\b(bf16|f32|f16|s32|u32|pred|s8|u8)\[([0-9,]+)\]", hlo):
    n = 1
    for d in m.group(2).split(","):
        n *= int(d)
    b = n * DT[m.group(1)]
    if b > 100_000_000:
        sizes[f"{m.group(1)}[{m.group(2)}]"] += 1

for shape_s, count in sorted(sizes.items(),
                             key=lambda kv: -eval(kv[0].split('[')[1][:-1].replace(',', '*')) ):
    dt = shape_s.split("[")[0]
    n = 1
    for d in shape_s.split("[")[1][:-1].split(","):
        n *= int(d)
    print(f"{n*DT[dt]/1e9:8.2f} GB  x{count:4d}  {shape_s}")
