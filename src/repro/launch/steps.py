"""Production step builders + per-cell sharding rule selection.

``build_train_step``  — loss + grads + the engine's projected-update core
                        (Adam + the paper's l1,inf projection, warm-started:
                        theta state threads through the step signature; on a
                        real mesh the sharded solver keeps weight shards
                        resident — no projection all-gather). Full production
                        step: optimizer state included so memory analysis
                        reflects reality; params/opt/proj-state donated.
``build_prefill_step``— full forward, returns last-token logits.
``build_decode_step`` — one-token serve step against a donated KV cache.

``rules_for_cell`` picks the parallelism layout per (arch, shape, mesh):
  train/prefill: DP(+pod) x TP(model) with FSDP-over-data weights;
  decode:        DP over data, KV-cache sequence over model (flash-decoding
                 style partial-softmax all-reduce);
  long-context:  batch=1 -> cache sequence sharded over EVERY axis.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..dist.sharding import default_rules, axis_rules, logical_spec, fit_spec
from ..models.zoo import Model, SHAPES
from ..models.transformer import ArchConfig
from ..optim import AdamConfig, AdamState, adam_init
from ..core import ProjectionEngine


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------

def rules_for_cell(cfg: ArchConfig, shape_name: str, multi_pod: bool) -> dict:
    sh = SHAPES[shape_name]
    kind = sh["kind"]
    rules = default_rules(multi_pod=multi_pod)
    if kind == "decode":
        if sh["batch"] == 1:
            # long-context: all parallelism goes to the cache sequence
            rules["batch"] = None
            rules["cache_batch"] = None
            rules["cache_seq"] = (("pod", "data", "model") if multi_pod
                                  else ("data", "model"))
            rules["kv_heads"] = None
        else:
            rules["cache_seq"] = "model"
            rules["kv_heads"] = None      # seq took the model axis
    rules.update(dict(cfg.rules_overrides))
    return rules


def _named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def batch_shardings(batch_abstract: Dict[str, Any], mesh: Mesh, rules: dict):
    """Sharding tree for a train/prefill batch dict."""
    b = rules["batch"]
    out = {}
    for k, v in batch_abstract.items():
        if k in ("tokens", "labels"):
            out[k] = _named(mesh, P(b, None))
        else:  # frames / image_embeds: (B, S, d)
            out[k] = _named(mesh, P(b, None, None))
    return out


def cache_shardings(cache_abstract, mesh: Mesh, rules: dict):
    """Sharding tree for a decode cache, by leaf name. Leaves under the
    scanned 'blocks' subtree carry a leading layers dim (never sharded);
    every dim is divisibility-checked against the mesh."""
    cb, cs = rules["cache_batch"], rules["cache_seq"]

    def one(path, leaf):
        name = None
        for p in reversed(path):
            if hasattr(p, "key"):
                name = str(p.key)
                break
        if name in ("k", "v"):          # (B, S, KV, hd)
            axes = [cb, cs, rules.get("kv_heads"), None]
        elif name in ("ck", "cv"):      # (B, Sm, H, hd) — cross memory
            axes = [cb, None, rules.get("heads"), None]
        elif name in ("c", "kr"):       # MLA compressed (B, S, dim)
            axes = [cb, cs, None]
        elif name == "state":           # SSM (B, H, P, N)
            axes = [cb, rules.get("mlp"), None, None]
        elif name and name.startswith("conv"):  # (B, W-1, D)
            axes = [cb, None, None]
        else:
            axes = [None] * leaf.ndim
        if leaf.ndim == len(axes) + 1:  # stacked (cycles, ...) under blocks
            axes = [None] + axes
        return _named(mesh, fit_spec(mesh, axes, leaf.shape))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_abstract)
    return jax.tree_util.tree_unflatten(treedef,
                                        [one(p, l) for p, l in flat])


def param_shardings(model: Model, mesh: Mesh, rules: dict):
    specs = model.param_specs(rules)
    return jax.tree_util.tree_map(lambda s: _named(mesh, s), specs)


def opt_shardings(param_sh, mesh: Mesh):
    return AdamState(count=_named(mesh, P()),
                     mu=param_sh, nu=param_sh)


# ---------------------------------------------------------------------------
# steps
# ---------------------------------------------------------------------------

def projection_engine_for(cfg: ArchConfig, mesh: Optional[Mesh],
                          with_projection: bool = True) -> ProjectionEngine:
    """The production engine policy: the fused two-HBM-pass step everywhere
    it exists. On a >1-device mesh that is ``solver="fused_sharded"`` — the
    PR-7 megakernel runs rank-local inside shard_map (weight shards stay
    put, one stacked (2, num_segments) psum per Newton evaluation,
    DESIGN.md §12) and plans the megakernel cannot take fall back to the
    shard_map Newton of ``solver="sharded"``, bit-identically. On one
    device it is ``solver="fused"`` with the single-buffer Newton as the
    per-plan fallback."""
    specs = cfg.projection_specs if with_projection else ()
    if mesh is not None and mesh.size > 1:
        return ProjectionEngine(specs, solver="fused_sharded", mesh=mesh)
    return ProjectionEngine(specs, solver="fused")


def build_train_step(model: Model, mesh: Optional[Mesh], rules: dict,
                     acfg: AdamConfig = AdamConfig(),
                     with_projection: bool = True):
    """Production train step: loss + grads + the engine's projected-update
    core (Adam, packed projection, every_k gate). The theta warm-start state
    threads through the signature — (params, opt, proj_state, batch) ->
    (loss, metrics, params, opt, proj_state) — so the production step (and
    the dry-run shardings, see lower_cell) is warm-started exactly like the
    runner loop; metrics carries the per-step Newton eval count."""
    engine = projection_engine_for(model.cfg, mesh, with_projection)

    def train_step(params, opt_state, proj_state, batch):
        with axis_rules(mesh, rules):
            (loss, metrics), grads = jax.value_and_grad(
                model.loss, has_aux=True)(params, batch)
            new_params, new_opt, new_proj, stats = engine.projected_update(
                grads, opt_state, params, acfg, state=proj_state,
                with_stats=True)
            metrics = dict(metrics)
            # warm-start health on the bench's accounting scale: Eq.-(19)
            # evaluations beyond the 2-eval bootstrap floor (0-1 steady
            # state when theta threads correctly, ~4-12 cold)
            metrics["proj_newton_extra_evals"] = (
                jnp.max(jnp.stack([jnp.asarray(v) - 2
                                   for v in stats.values()]))
                if stats else jnp.zeros((), jnp.int32))
        return loss, metrics, new_params, new_opt, new_proj

    return train_step


def build_prefill_step(model: Model, mesh: Optional[Mesh], rules: dict):
    def prefill_step(params, batch):
        with axis_rules(mesh, rules):
            logits, _ = model.forward(params, batch)
        return logits[:, -1, :]

    return prefill_step


def build_decode_step(model: Model, mesh: Optional[Mesh], rules: dict):
    def serve_step(params, cache, tokens, pos):
        with axis_rules(mesh, rules):
            logits, new_cache = model.decode(params, cache, tokens, pos)
        return logits[:, -1, :], new_cache

    return serve_step


# ---------------------------------------------------------------------------
# lowering helper (dry-run + real launch share this)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LoweredCell:
    kind: str
    lowered: Any
    compiled: Any = None

    def compile(self):
        self.compiled = self.lowered.compile()
        return self.compiled


def lower_cell(model: Model, shape_name: str, mesh: Mesh, multi_pod: bool,
               dtype=jnp.bfloat16, with_optimizer: bool = True,
               with_projection: bool = True,
               extra_rules: Optional[dict] = None) -> LoweredCell:
    """jit(...).lower(...) for one (arch x shape x mesh) cell using abstract
    inputs only — nothing is allocated."""
    from ..models.zoo import input_specs

    cfg = model.cfg
    sh = SHAPES[shape_name]
    rules = rules_for_cell(cfg, shape_name, multi_pod)
    if extra_rules:
        rules.update(extra_rules)

    params_abs = model.abstract_params(dtype)
    p_sh = param_shardings(model, mesh, rules)
    specs = input_specs(cfg, shape_name, dtype)

    if sh["kind"] == "train":
        acfg = AdamConfig(moment_dtype=jnp.float32)
        opt_abs = jax.eval_shape(functools.partial(adam_init, cfg=acfg),
                                 params_abs)
        o_sh = opt_shardings(p_sh, mesh)
        b_sh = batch_shardings(specs, mesh, rules)
        engine = projection_engine_for(cfg, mesh, with_projection)
        # theta warm-start state: tiny per-plan vectors, replicated
        proj_abs = jax.eval_shape(engine.init_state, params_abs)
        proj_sh = jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P()), proj_abs)
        step = build_train_step(model, mesh, rules, acfg,
                                with_projection=with_projection)
        jitted = jax.jit(
            step,
            in_shardings=(p_sh, o_sh, proj_sh, b_sh),
            out_shardings=(NamedSharding(mesh, P()),
                           None, p_sh, o_sh, proj_sh),
            donate_argnums=(0, 1, 2),
        )
        with mesh:
            lowered = jitted.lower(params_abs, opt_abs, proj_abs, specs)
        return LoweredCell("train", lowered)

    if sh["kind"] == "prefill":
        b_sh = batch_shardings(specs, mesh, rules)
        step = build_prefill_step(model, mesh, rules)
        jitted = jax.jit(
            step, in_shardings=(p_sh, b_sh),
            out_shardings=NamedSharding(
                mesh, logical_spec(("batch", "vocab"), rules)))
        with mesh:
            lowered = jitted.lower(params_abs, specs)
        return LoweredCell("prefill", lowered)

    # decode
    cache_abs = specs["cache"]
    c_sh = cache_shardings(cache_abs, mesh, rules)
    tok_sh = NamedSharding(mesh, P(rules["batch"], None))
    pos_sh = NamedSharding(mesh, P())
    step = build_decode_step(model, mesh, rules)
    jitted = jax.jit(
        step,
        in_shardings=(p_sh, c_sh, tok_sh, pos_sh),
        out_shardings=(NamedSharding(
            mesh, logical_spec(("batch", "vocab"), rules)), c_sh),
        donate_argnums=(1,),
    )
    with mesh:
        lowered = jitted.lower(params_abs, cache_abs,
                               specs["tokens"], specs["pos"])
    return LoweredCell("decode", lowered)
