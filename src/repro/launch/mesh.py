"""Production meshes (assignment spec).

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run sets XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests."""
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))
