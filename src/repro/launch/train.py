"""Training launcher.

CPU-scale real runs (examples, CI) and production-mesh launches share this
entry point; on a real cluster each host runs the same command and jax
initializes the distributed runtime from the environment.

    python -m repro.launch.train --arch gemma_7b --reduced --steps 200
    python -m repro.launch.train --arch mamba2_370m --reduced --resume auto
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, get_config, get_reduced
from ..models.zoo import build
from ..data.pipeline import SyntheticLM, LMBatcher
from ..train.loop import TrainConfig, train
from .mesh import make_local_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-scale reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="auto", choices=["auto", "none"])
    ap.add_argument("--no-projection", action="store_true")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build(cfg)
    print(f"[launch] {cfg.name}: {model.n_params()/1e6:.1f}M params, "
          f"{len(jax.devices())} device(s)")

    batcher = LMBatcher(SyntheticLM(cfg.vocab), args.batch, args.seq)
    tcfg = TrainConfig(steps=args.steps, lr=args.lr,
                       microbatches=args.microbatches,
                       ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                       with_projection=not args.no_projection)
    out = train(model, batcher, tcfg, resume=(args.resume == "auto"))
    print(f"[launch] final loss {out['losses'][-1]:.4f}; "
          f"first loss {out['losses'][0]:.4f}")
    wd = out["watchdog"]
    print(f"[launch] step time EWMA {wd['step_time_ewma_s']*1e3:.0f} ms; "
          f"{int(wd['straggler_events_total'])} straggler step(s)")
    for s, dt, ew in out["straggler_events"][:5]:
        print(f"[launch]   straggler step {s}: {dt:.3f}s "
              f"(EWMA was {ew:.3f}s)")
    if out["sparsity"]:
        for k, v in out["sparsity"].items():
            print(f"[sparsity] {k}: {v:.1f}% columns zero")


if __name__ == "__main__":
    main()
