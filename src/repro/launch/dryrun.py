import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). Everything below is ordinary.
#
# Multi-pod dry-run: .lower().compile() every (arch x shape x mesh) cell on
# the production meshes, record memory analysis, cost analysis, and the
# roofline terms parsed from the optimized HLO.
#
# Usage:
#   python -m repro.launch.dryrun --arch gemma_7b --shape train_4k --mesh pod
#   python -m repro.launch.dryrun --all            # every runnable cell
#   python -m repro.launch.dryrun --list           # show the cell matrix
#
# One JSON per cell is written to experiments/dryrun/<cell>.json; failures
# are recorded with the exception text (they are bugs — the sweep continues).
# (no `from __future__` here: the XLA_FLAGS lines must be first)
import argparse
import json
import pathlib
import sys
import time
import traceback

import jax

from ..configs import ARCH_IDS, get_config
from ..models.zoo import build, SHAPES, cell_supported
from ..roofline.analysis import (analyze, model_flops_for, active_params)
from .mesh import make_production_mesh
from .steps import lower_cell

OUT_DIR = pathlib.Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _mem_dict(ma) -> dict:
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes"]
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    out["total_bytes_per_device"] = (
        out.get("argument_size_in_bytes", 0)
        + out.get("output_size_in_bytes", 0)
        + out.get("temp_size_in_bytes", 0)
        - out.get("alias_size_in_bytes", 0))
    return out


def run_cell(arch: str, shape: str, mesh_kind: str,
             extra_rules: dict | None = None,
             config_overrides: dict | None = None,
             tag: str = "") -> dict:
    import dataclasses as _dc
    cfg = get_config(arch)
    if config_overrides:
        cfg = _dc.replace(cfg, **config_overrides)
    ok, why = cell_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "mesh": mesh_kind,
                "status": "skipped", "reason": why}
    multi_pod = mesh_kind == "multipod"
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = 512 if multi_pod else 256
    model = build(cfg)
    t0 = time.time()
    cell = lower_cell(model, shape, mesh, multi_pod,
                      extra_rules=extra_rules)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = cell.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    print(ma)  # proves it fits (bytes per device)
    cost_list = compiled.cost_analysis()
    cost = cost_list[0] if isinstance(cost_list, (list, tuple)) else cost_list
    print({k: cost[k] for k in ("flops", "bytes accessed") if k in cost})

    hlo = compiled.as_text()
    # archive compressed HLO so roofline analysis can be re-run offline
    try:
        import zstandard as zstd
        hlo_dir = OUT_DIR.parent / "hlo"
        hlo_dir.mkdir(parents=True, exist_ok=True)
        name = f"{arch}__{shape}__{mesh_kind}"
        if tag and tag != "baseline":
            name += f"__{tag}"
        (hlo_dir / f"{name}.hlo.zst").write_bytes(
            zstd.ZstdCompressor(level=6).compress(hlo.encode()))
    except Exception as e:
        print(f"[warn] HLO archive failed: {e}")
    n_total = model.n_params()
    n_active = active_params(cfg, n_total)
    rf = analyze(arch, shape, mesh_kind, n_chips, cost, hlo,
                 model_flops_for(cfg, shape, n_total, n_active),
                 memory_analysis=_mem_dict(ma))
    rec = rf.to_json()
    rec.update({
        "status": "ok", "kind": cell.kind, "tag": tag,
        "n_params_total": n_total, "n_params_active": n_active,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "hlo_bytes": len(hlo),
    })
    return rec


def cell_list():
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, why = cell_supported(cfg, shape)
            for mesh_kind in ("pod", "multipod"):
                cells.append((arch, shape, mesh_kind, ok, why))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--rules", default=None,
                    help="JSON dict of logical-rule overrides (perf sweeps)")
    ap.add_argument("--config-overrides", default=None,
                    help="JSON dict of ArchConfig field overrides")
    args = ap.parse_args()

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    if args.list:
        for arch, shape, mesh_kind, ok, why in cell_list():
            print(f"{arch:22s} {shape:12s} {mesh_kind:9s} "
                  f"{'RUN' if ok else 'SKIP: ' + why}")
        return

    extra = json.loads(args.rules) if args.rules else None
    cfg_over = (json.loads(args.config_overrides)
                if args.config_overrides else None)
    todo = ([(args.arch, args.shape, args.mesh)] if not args.all else
            [(a, s, m) for a, s, m, ok, _ in cell_list()])
    n_fail = 0
    for arch, shape, mesh_kind in todo:
        name = f"{arch}__{shape}__{mesh_kind}"
        if args.tag != "baseline":
            name += f"__{args.tag}"
        out_path = OUT_DIR / f"{name}.json"
        print(f"=== {name} ===", flush=True)
        try:
            rec = run_cell(arch, shape, mesh_kind, extra_rules=extra,
                           config_overrides=cfg_over, tag=args.tag)
        except Exception as e:  # a failure here is a bug; record and continue
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                   "status": "failed", "error": f"{type(e).__name__}: {e}",
                   "tag": args.tag}
            n_fail += 1
        out_path.write_text(json.dumps(rec, indent=1, default=str))
        print(json.dumps({k: rec.get(k) for k in
                          ("status", "dominant", "compute_s", "memory_s",
                           "collective_s", "roofline_fraction",
                           "compile_s")}, default=str), flush=True)
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
