"""JAX version compatibility shims, installed on ``import repro``.

The codebase targets the current jax mesh API (``jax.make_mesh(...,
axis_types=...)`` with ``jax.sharding.AxisType``); pinned containers may
carry an older jax (0.4.x) where ``AxisType`` does not exist and
``make_mesh`` rejects the ``axis_types`` kwarg. On such versions — and only
there — this module backfills:

  * ``jax.sharding.AxisType`` — an enum with Auto/Explicit/Manual members.
    Old jax has no explicit-sharding mode, so the value is accepted and
    ignored (Auto is old jax's only behavior, and Auto is all this codebase
    uses).
  * ``jax.make_mesh(..., axis_types=...)`` — a wrapper dropping the kwarg.

Nothing is touched when the running jax already provides the API. Import
order does not matter for device initialization: only attributes are set,
no backend is touched.
"""
from __future__ import annotations

import enum
import functools
import inspect

import jax
import jax.sharding as _jsharding

_installed = False


def install() -> None:
    global _installed
    if _installed:
        return

    if not hasattr(_jsharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        _jsharding.AxisType = AxisType

    orig_make_mesh = getattr(jax, "make_mesh", None)
    if orig_make_mesh is None:
        # pre-0.4.35 jax: build the mesh directly
        def make_mesh(axis_shapes, axis_names, *, devices=None,
                      axis_types=None):
            from jax.experimental import mesh_utils
            dev = mesh_utils.create_device_mesh(tuple(axis_shapes),
                                                devices=devices)
            return _jsharding.Mesh(dev, tuple(axis_names))

        jax.make_mesh = make_mesh
    else:
        try:
            params = inspect.signature(orig_make_mesh).parameters
        except (TypeError, ValueError):  # pragma: no cover - exotic builds
            params = {}
        if "axis_types" not in params:
            @functools.wraps(orig_make_mesh)
            def make_mesh(axis_shapes, axis_names, *, devices=None,
                          axis_types=None):
                # axis_types ignored: old jax is Auto-only, which is what
                # the callers request.
                return orig_make_mesh(axis_shapes, axis_names,
                                      devices=devices)

            jax.make_mesh = make_mesh

    _installed = True
