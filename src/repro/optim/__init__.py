from .adam import (AdamConfig, AdamState, adam_init, adam_update,
                   adam_scalars, adam_leaf_update,
                   global_norm, clip_by_global_norm, clip_scale)
from .schedule import constant, cosine_with_warmup, step_decay
