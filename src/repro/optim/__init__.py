from .adam import (AdamConfig, AdamState, adam_init, adam_update,
                   global_norm, clip_by_global_norm)
from .schedule import constant, cosine_with_warmup, step_decay
