"""Minimal-but-production Adam/AdamW on pytrees (no external deps).

Features needed at scale: fp32 moments regardless of param dtype (or bf16
moments for memory-tight configs), decoupled weight decay, global-norm
clipping, bias correction, masked updates (the paper's Algorithm 3), and a
post-update projection hook (projected gradient descent).

The update math is factored into scalar helpers (``adam_scalars``,
``clip_scale``) and a per-leaf kernel (``adam_leaf_update``) so the fused
optimizer+projection megakernel (``kernels/fused_step``, DESIGN.md §11)
computes the EXACT same update in-register — any change to the step
formula here must be mirrored in ``kernels/fused_step/ref.py``.

Mask semantics (Algorithm 3's support freeze): ``mask`` zeroes the WHOLE
step for masked-out entries — gradients before the moment update AND the
decoupled weight-decay term. (Decay is not a gradient; gating only the
grads would let ``lr_t * weight_decay * p`` keep shrinking frozen params,
silently violating the freeze.) A frozen entry is bit-identical across
steps for any ``weight_decay``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

__all__ = ["AdamConfig", "AdamState", "adam_init", "adam_update",
           "adam_scalars", "adam_leaf_update",
           "global_norm", "clip_by_global_norm", "clip_scale"]


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = 1.0
    moment_dtype: Any = jnp.float32    # bf16 for memory-tight giant configs


class AdamState(NamedTuple):
    count: jnp.ndarray
    mu: Any
    nu: Any


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_scale(tree: Any, max_norm: float) -> jnp.ndarray:
    """The scalar multiplier of global-norm clipping: min(1, max_norm/||g||).

    Split out of ``clip_by_global_norm`` so fused paths can compute the
    scale once (one reduction over the grad tree) and fold the multiply
    into their first pass over each leaf."""
    norm = global_norm(tree)
    return jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))


def clip_by_global_norm(tree: Any, max_norm: float) -> Any:
    scale = clip_scale(tree, max_norm)
    return jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype), tree)


def adam_init(params: Any, cfg: AdamConfig = AdamConfig()) -> AdamState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return AdamState(
        count=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def adam_scalars(cfg: AdamConfig, count: jnp.ndarray, lr=None):
    """(lr_t, b1c, b2c) at the POST-increment optimizer count.

    ``lr`` overrides ``cfg.lr`` (schedules); b1c/b2c are the bias-correction
    denominators 1 - b^t. These are the only traced scalars the per-leaf
    update needs, which is what lets ``kernels/fused_step`` ship them to the
    kernel as one tiny prefetched vector."""
    lr_t = cfg.lr if lr is None else lr
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)
    return lr_t, b1c, b2c


def adam_leaf_update(g, m, v, p, cfg: AdamConfig, lr_t, b1c, b2c,
                     *, mask=None, scale=None):
    """One leaf of the Adam update: (p_new, m_new, v_new).

    fp32 math regardless of input dtypes; moments stored back in
    ``cfg.moment_dtype``; ``scale`` is the optional global-norm clip
    multiplier (applied exactly as ``clip_by_global_norm`` does:
    ``(g * scale).astype(g.dtype)``); ``mask`` ({0,1}, broadcastable)
    freezes masked-out entries — it zeroes the gradient before the moment
    update AND the whole step (weight decay included), so a frozen entry
    is bit-identical across steps.
    """
    if scale is not None:
        g = (g * scale).astype(g.dtype)
    if mask is not None:
        g = g * mask.astype(g.dtype)
    g32 = g.astype(jnp.float32)
    m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
    v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
    mhat = m_new / b1c
    vhat = v_new / b2c
    step = lr_t * mhat / (jnp.sqrt(vhat) + cfg.eps)
    if cfg.weight_decay:
        step = step + lr_t * cfg.weight_decay * p.astype(jnp.float32)
    if mask is not None:
        step = step * mask.astype(jnp.float32)
    return ((p.astype(jnp.float32) - step).astype(p.dtype),
            m_new.astype(cfg.moment_dtype), v_new.astype(cfg.moment_dtype))


def adam_update(grads: Any, state: AdamState, params: Any,
                cfg: AdamConfig = AdamConfig(),
                lr: Optional[jnp.ndarray] = None,
                mask: Any = None):
    """Returns (new_params, new_state). `lr` overrides cfg.lr (schedules).
    `mask` (same treedef, {0,1}) freezes masked-out entries (Algorithm 3):
    the whole step — grads and decoupled weight decay — is zeroed under it.
    """
    count = state.count + 1
    lr_t, b1c, b2c = adam_scalars(cfg, count, lr)
    scale = (clip_scale(grads, cfg.clip_norm)
             if cfg.clip_norm is not None else None)

    def upd(p, g, m, v, mk=None):
        return adam_leaf_update(g, m, v, p, cfg, lr_t, b1c, b2c,
                                mask=mk, scale=scale)

    # one pass over the tree: each leaf maps to its (p, m, v) triple, then
    # a single tree_transpose splits the triples back into three trees
    if mask is None:
        out = jax.tree_util.tree_map(upd, params, grads, state.mu, state.nu)
    else:
        out = jax.tree_util.tree_map(upd, params, grads, state.mu, state.nu,
                                     mask)
    treedef = jax.tree_util.tree_structure(params)
    new_p, new_m, new_v = jax.tree_util.tree_transpose(
        treedef, jax.tree_util.tree_structure((0, 0, 0)), out)
    return new_p, AdamState(count=count, mu=new_m, nu=new_v)
