"""Minimal-but-production Adam/AdamW on pytrees (no external deps).

Features needed at scale: fp32 moments regardless of param dtype (or bf16
moments for memory-tight configs), decoupled weight decay, global-norm
clipping, bias correction, masked updates (the paper's Algorithm 3), and a
post-update projection hook (projected gradient descent).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp

__all__ = ["AdamConfig", "AdamState", "adam_init", "adam_update",
           "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = 1.0
    moment_dtype: Any = jnp.float32    # bf16 for memory-tight giant configs


class AdamState(NamedTuple):
    count: jnp.ndarray
    mu: Any
    nu: Any


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.zeros(())
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree: Any, max_norm: float) -> Any:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype), tree)


def adam_init(params: Any, cfg: AdamConfig = AdamConfig()) -> AdamState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return AdamState(
        count=jnp.zeros((), jnp.int32),
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
    )


def adam_update(grads: Any, state: AdamState, params: Any,
                cfg: AdamConfig = AdamConfig(),
                lr: Optional[jnp.ndarray] = None,
                mask: Any = None):
    """Returns (new_params, new_state). `lr` overrides cfg.lr (schedules).
    `mask` (same treedef, {0,1}) freezes masked-out entries (Algorithm 3)."""
    if cfg.clip_norm is not None:
        grads = clip_by_global_norm(grads, cfg.clip_norm)
    if mask is not None:
        grads = jax.tree_util.tree_map(lambda g, m: g * m.astype(g.dtype),
                                       grads, mask)
    count = state.count + 1
    lr_t = cfg.lr if lr is None else lr
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        mhat = m_new / b1c
        vhat = v_new / b2c
        step = lr_t * mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            step = step + lr_t * cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - step).astype(p.dtype),
                m_new.astype(cfg.moment_dtype), v_new.astype(cfg.moment_dtype))

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, AdamState(count=count, mu=new_m, nu=new_v)
