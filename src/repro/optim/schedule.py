"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_with_warmup(peak_lr: float, warmup_steps: int, total_steps: int,
                       final_frac: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
        t = jnp.clip((step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)
    return fn


def step_decay(lr: float, decay: float, every: int):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        return jnp.asarray(lr, jnp.float32) * decay ** jnp.floor(step / every)
    return fn
