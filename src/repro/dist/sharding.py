"""Logical-axis sharding rules — the GSPMD substrate (DESIGN.md §4).

Every layer annotates params and activations with *logical* axis names
("batch", "heads", "mlp", ...); this module owns the single mapping from
logical names to physical mesh axes:

  * ``default_rules(multi_pod=...)`` — the canonical DP(+pod) x TP(model)
    layout with FSDP-over-data weights (launch/steps.py specializes it per
    cell: decode moves the model axis onto the KV-cache sequence).
  * ``axis_rules(mesh, rules)``      — context manager activating a
    (mesh, rules) pair during tracing; thread-local, nestable.
  * ``current_rules()``              — the innermost active (mesh, rules)
    pair, or None (moe_shardmap uses this to pick its dispatch impl).
  * ``shard(x, *names)``             — with_sharding_constraint through the
    active rules; a no-op outside a context, on a None mesh, and on any dim
    the mesh axes do not divide (25 heads on a 16-way axis replicate rather
    than error — ``fit_spec`` below is the single divisibility policy,
    shared with launch/steps.py's cache shardings).
  * ``logical_spec(names, rules)``   — PartitionSpec from logical names
    without constraining anything (out_shardings construction).

Rules values may be a mesh axis name, a tuple of names (e.g. batch over
("pod", "data")), or None (replicated). Unknown logical names replicate.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["default_rules", "axis_rules", "current_rules", "logical_spec",
           "fit_spec", "shard"]

Axes = Union[None, str, Tuple[str, ...]]


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------

def default_rules(multi_pod: bool = False) -> dict:
    """Logical-name -> mesh-axes mapping for the production train/prefill
    layout: data parallel over ("pod",) "data", tensor parallel over "model",
    FSDP weight sharding over "data". Decode/long-context cells override
    cache_seq / kv_heads in launch/steps.rules_for_cell."""
    batch = ("pod", "data") if multi_pod else "data"
    return {
        # parameters
        "fsdp": "data",            # FSDP: weights sharded over the data axis
        "mlp": "model",
        "heads": "model",
        "kv_heads": "model",
        "vocab": "model",          # vocab is padded to /128 so this divides
        "embed": None,
        "experts": "model",        # EP: stacked expert dim
        "layers": None,            # scan-stacked layer dim is never sharded
        # activations
        "batch": batch,
        "seq": None,
        "attn_seq": None,
        "expert_cap": None,
        # decode cache
        "cache_batch": batch,
        "cache_seq": None,
    }


# ---------------------------------------------------------------------------
# (mesh, rules) context
# ---------------------------------------------------------------------------

_STATE = threading.local()


def _stack():
    stack = getattr(_STATE, "stack", None)
    if stack is None:
        stack = _STATE.stack = []
    return stack


@contextlib.contextmanager
def axis_rules(mesh: Optional[Mesh], rules: Optional[dict]):
    """Activate (mesh, rules) for shard() calls traced inside the block.
    Passing mesh=None (single-device paths) makes shard() a no-op."""
    _stack().append((mesh, rules))
    try:
        yield
    finally:
        _stack().pop()


def current_rules() -> Optional[Tuple[Optional[Mesh], Optional[dict]]]:
    """Innermost active (mesh, rules) pair, or None outside any context."""
    stack = _stack()
    return stack[-1] if stack else None


# ---------------------------------------------------------------------------
# specs + constraints
# ---------------------------------------------------------------------------

def logical_spec(names: Sequence[Optional[str]],
                 rules: Optional[dict]) -> P:
    """PartitionSpec from logical axis names via `rules` (no divisibility
    check — use for out_shardings where shapes are not at hand)."""
    rules = rules or {}
    return P(*[rules.get(n) if n is not None else None for n in names])


def _axes_size(mesh: Mesh, axes: Axes) -> int:
    if axes is None:
        return 1
    tup = (axes,) if isinstance(axes, str) else tuple(axes)
    size = 1
    for a in tup:
        if a not in mesh.shape:
            return 0               # axis absent from this mesh -> replicate
        size *= mesh.shape[a]
    return size


def fit_spec(mesh: Mesh, spec_axes: Sequence[Axes],
             shape: Tuple[int, ...]) -> P:
    """PartitionSpec from already-resolved mesh axes, dropping any that are
    missing from the mesh or do not divide the corresponding dim (e.g.
    batch=1 long-context decode, 25 heads on a 16-way axis). The single
    divisibility policy — launch/steps.py uses it for cache shardings too."""
    out = []
    for dim, axes in zip(shape, spec_axes):
        size = _axes_size(mesh, axes)
        out.append(axes if size and dim % size == 0 else None)
    return P(*out)


def _fit_spec(mesh: Mesh, names: Sequence[Optional[str]], rules: dict,
              shape: Tuple[int, ...]) -> P:
    """Map logical names through rules, then fit to the mesh."""
    axes = [rules.get(n) if n is not None else None for n in names]
    return fit_spec(mesh, axes, shape)


def shard(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Constrain activation `x` to the sharding its logical `names` imply
    under the innermost axis_rules context. One name (or None) per dim."""
    state = current_rules()
    if state is None:
        return x
    mesh, rules = state
    if mesh is None or rules is None:
        return x
    if len(names) != x.ndim:
        raise ValueError(
            f"shard: {len(names)} names for rank-{x.ndim} array {x.shape}")
    spec = _fit_spec(mesh, names, rules, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
