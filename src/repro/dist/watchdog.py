"""Straggler detection for the training loop (DESIGN.md §4).

``StepWatchdog`` wraps each step in start()/stop() and keeps an EWMA of the
step time. A step slower than ``threshold`` x EWMA (once ``grace_steps``
warm-up steps have completed — the first steps include compilation) fires
``on_straggler`` and is recorded in ``.events``; straggler samples are NOT
folded into the EWMA so one slow host cannot drag the baseline up and mask
the next one, and warm-up samples fold clamped to threshold x EWMA for the
same reason.

``metrics()`` exposes the detector state as a flat per-step metrics dict
(step time, EWMA, straggler flag/total) — the train loop
(``train/loop.py``) records it every step and the launcher
(``launch/train.py``) prints the straggler summary, so a slow host shows
up in the run's metric stream, not just on stderr.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Tuple

__all__ = ["StepWatchdog"]


class StepWatchdog:
    """Per-step wall-clock straggler detector.

    threshold:    multiple of the EWMA above which a step is a straggler.
    grace_steps:  completed steps before detection arms (compile warm-up).
    alpha:        EWMA smoothing factor (weight of the newest sample).
    on_straggler: callback (step, dt_seconds, ewma_seconds).
    clock:        injectable time source (tests); defaults to time.monotonic.
    """

    def __init__(self, threshold: float = 3.0, grace_steps: int = 5,
                 alpha: float = 0.25,
                 on_straggler: Optional[Callable[[int, float, float],
                                                 None]] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.threshold = float(threshold)
        self.grace_steps = int(grace_steps)
        self.alpha = float(alpha)
        self.on_straggler = on_straggler
        self.clock = clock
        self.events: List[Tuple[int, float, float]] = []
        self.ewma: Optional[float] = None
        self._n = 0
        self._t0: Optional[float] = None
        self._last_step: Optional[int] = None
        self._last_dt: Optional[float] = None
        self._last_straggler = False

    def start(self) -> None:
        self._t0 = self.clock()

    def stop(self, step: int) -> float:
        """End timing for `step`; returns the step duration in seconds."""
        if self._t0 is None:
            raise RuntimeError("StepWatchdog.stop() without start()")
        dt = self.clock() - self._t0
        self._t0 = None
        self._last_step = int(step)
        self._last_dt = float(dt)
        self._last_straggler = False
        armed = self.ewma is not None and self._n >= self.grace_steps
        if armed and dt > self.threshold * self.ewma:
            self._last_straggler = True
            self.events.append((int(step), float(dt), float(self.ewma)))
            if self.on_straggler is not None:
                self.on_straggler(step, dt, self.ewma)
        elif self.ewma is None:
            self.ewma = dt
        else:
            # unarmed spikes fold clamped so warm-up stragglers cannot
            # inflate the baseline past the detection threshold
            dt_c = min(dt, self.threshold * self.ewma)
            self.ewma = (1.0 - self.alpha) * self.ewma + self.alpha * dt_c
        self._n += 1
        return dt

    def metrics(self) -> Dict[str, float]:
        """Detector state as a flat per-step metrics dict.

        Call after :meth:`stop`; the snapshot describes the step just
        stopped. Keys: ``step`` (int), ``step_time_s``,
        ``step_time_ewma_s`` (0.0 until the first sample folds),
        ``straggler`` (1.0 iff the step just stopped fired the detector
        — straggler steps do NOT fold into the EWMA, so the baseline the
        flag was judged against is the one reported), and
        ``straggler_events_total`` (cumulative count, == len(events)).
        """
        return {
            "step": float(-1 if self._last_step is None
                          else self._last_step),
            "step_time_s": float(self._last_dt or 0.0),
            "step_time_ewma_s": float(self.ewma or 0.0),
            "straggler": 1.0 if self._last_straggler else 0.0,
            "straggler_events_total": float(len(self.events)),
        }
