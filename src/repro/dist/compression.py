"""Gradient compression for cross-pod data parallelism (DESIGN.md §4).

Within-pod reductions stay exact (ICI bandwidth is cheap); only the cross-pod
(DCI) combine is compressed:

  * ``int8_quantize`` / ``int8_dequantize`` — shared-scale symmetric int8
    (4x traffic cut, error <= scale/2 per element).
  * ``topk_compress`` / ``topk_decompress`` — magnitude top-k sparsification
    to (values, flat indices) and back.
  * ``ef_step`` — error-feedback wrapper (Karimireddy et al.): the residual
    of each compression round is fed back into the next, so the *cumulative*
    transmitted gradient is unbiased and SGD converges at the dense rate.
  * ``compressed_psum`` — the collective: a psum usable inside shard_map
    whose payload is int8-quantized (shared scale via pmax) or top-k sparse.

Everything is jit/shard_map-safe: k is derived from static shapes, scales
are traced scalars.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["int8_quantize", "int8_dequantize", "topk_compress",
           "topk_decompress", "ef_step", "compressed_psum"]


# ---------------------------------------------------------------------------
# int8 shared-scale quantization
# ---------------------------------------------------------------------------

def int8_quantize(x: jnp.ndarray,
                  scale: jnp.ndarray = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric int8 quantization. Returns (q int8, scale f32 scalar) with
    x ~= q * scale and |x - q*scale| <= scale/2. An explicit `scale` lets
    participants of a collective share one scale (see compressed_psum)."""
    xf = x.astype(jnp.float32)
    if scale is None:
        scale = jnp.max(jnp.abs(xf)) / 127.0
    scale = jnp.maximum(scale, jnp.finfo(jnp.float32).tiny)
    q = jnp.clip(jnp.round(xf / scale), -127.0, 127.0).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def int8_dequantize(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# magnitude top-k
# ---------------------------------------------------------------------------

def _k_for(size: int, k_frac: float) -> int:
    return max(1, min(size, int(round(size * k_frac))))


def topk_compress(g: jnp.ndarray,
                  k_frac: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Keep the k = round(k_frac * size) largest-|.| entries. Returns
    (values (k,), flat int32 indices (k,)); k is static under jit."""
    k = _k_for(g.size, k_frac)
    flat = g.reshape(-1)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx.astype(jnp.int32)


def topk_decompress(vals: jnp.ndarray, idx: jnp.ndarray, shape,
                    dtype) -> jnp.ndarray:
    """Scatter (values, indices) back to a dense zero-filled array."""
    size = 1
    for d in shape:
        size *= int(d)
    dense = jnp.zeros((size,), dtype).at[idx].set(vals.astype(dtype))
    return dense.reshape(shape)


def ef_step(g: jnp.ndarray, err: jnp.ndarray,
            k_frac: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One error-feedback compression round: sparsify (g + err), return
    (sparse update to transmit, new residual). sparse + new_err == g + err
    exactly, so no gradient mass is ever dropped — only delayed."""
    corrected = g + err
    vals, idx = topk_compress(corrected, k_frac)
    sparse = topk_decompress(vals, idx, corrected.shape, corrected.dtype)
    return sparse, corrected - sparse


# ---------------------------------------------------------------------------
# the collective
# ---------------------------------------------------------------------------

def compressed_psum(tree, axis_name: str, mode: str = "int8",
                    k_frac: float = 0.05):
    """psum of a gradient pytree over `axis_name` (inside shard_map) with a
    compressed payload.

    The compressed modes move the *compressed* representation across the
    link — an all_gather of the narrow payload plus a local reduce — rather
    than psum-ing a dequantized/densified array (which would put full-width
    elements back on the wire and void the compression).

    mode:
      "none" — exact psum (baseline / within-pod).
      "int8" — shared-scale int8: pmax of the local absmax fixes one scale,
               the int8 payload is all_gathered and summed locally. For P
               participants the error is <= P * scale/2 and the per-hop
               payload is 1 byte/element vs 4 for an fp32 reduce.
      "topk" — EF-free magnitude top-k: each participant transmits only its
               k (values, indices) pairs, scatter-added locally (biased;
               pair with ef_step residuals for convergence guarantees).
    """
    if mode == "none":
        return jax.tree_util.tree_map(
            lambda g: jax.lax.psum(g, axis_name), tree)

    if mode == "int8":
        def one(g):
            absmax = jax.lax.pmax(jnp.max(jnp.abs(g.astype(jnp.float32))),
                                  axis_name)
            q, scale = int8_quantize(g, absmax / 127.0)
            q_all = jax.lax.all_gather(q, axis_name)        # int8 on the wire
            total = jnp.sum(q_all.astype(jnp.int32), axis=0)
            return int8_dequantize(total, scale, g.dtype)
        return jax.tree_util.tree_map(one, tree)

    if mode == "topk":
        def one(g):
            vals, idx = topk_compress(g, k_frac)
            vals_all = jax.lax.all_gather(vals, axis_name)  # (P, k)
            idx_all = jax.lax.all_gather(idx, axis_name)
            flat = jnp.zeros((g.size,), g.dtype).at[idx_all.reshape(-1)].add(
                vals_all.reshape(-1).astype(g.dtype))
            return flat.reshape(g.shape)
        return jax.tree_util.tree_map(one, tree)

    raise ValueError(f"unknown compression mode {mode!r}")
