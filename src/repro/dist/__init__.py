"""Distributed substrate: logical-axis sharding rules, gradient compression,
pipeline parallelism, and straggler detection (DESIGN.md §4).

Submodules:
  sharding    — default_rules / axis_rules / current_rules / logical_spec /
                shard (GSPMD logical-axis layer under every model)
  compression — error-feedback top-k + shared-scale int8, compressed_psum
  pipeline    — build_pipeline_fn microbatch ring pipeline (shard_map)
  projection  — mesh-resident packed l1,inf projection (shard_map segmented
                Newton; mesh-divisible shards never gather — DESIGN.md §7)
  watchdog    — StepWatchdog EWMA straggler detector
"""
from . import compression, pipeline, projection, sharding, watchdog
from .compression import (compressed_psum, ef_step, int8_dequantize,
                          int8_quantize, topk_compress, topk_decompress)
from .pipeline import build_pipeline_fn
from .projection import project_plan_sharded, shard_packed_plan
from .sharding import (axis_rules, current_rules, default_rules, logical_spec,
                       shard)
from .watchdog import StepWatchdog

__all__ = [
    "sharding", "compression", "pipeline", "projection", "watchdog",
    "default_rules", "axis_rules", "current_rules", "logical_spec", "shard",
    "ef_step", "int8_quantize", "int8_dequantize", "topk_compress",
    "topk_decompress", "compressed_psum", "build_pipeline_fn",
    "project_plan_sharded", "shard_packed_plan", "StepWatchdog",
]
