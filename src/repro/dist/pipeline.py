"""Microbatch pipeline parallelism via shard_map + ppermute (DESIGN.md §4).

GPipe-style schedule on a ring: each mesh rank along `axis_name` owns one
stage's parameters; activations flow rank -> rank+1 one hop per tick. With
S stages and M microbatches the loop runs S + M - 1 ticks; rank r is busy on
ticks [r, r + M), so bubble overhead is (S-1)/(S+M-1).

Only the stage handoff (one microbatch of activations) crosses the link per
tick — weights never move. The returned function is jit-safe and closes over
the mesh, so it is called as ``jax.jit(pipe)(stage_params, x)`` with
full (unsharded) inputs; shard_map splits the stage dim internally.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

__all__ = ["build_pipeline_fn"]


def build_pipeline_fn(stage_fn: Callable[[Any, jnp.ndarray], jnp.ndarray],
                      n_stages: int, n_micro: int, mesh,
                      axis_name: str) -> Callable:
    """Build ``pipe(stage_params, x) -> y``.

    stage_fn:     (per-stage params, microbatch activations) -> activations
                  (shape-preserving on the activations).
    stage_params: pytree whose leaves have a leading n_stages dim (sharded
                  one stage per rank).
    x:            (n_micro, *microbatch_shape) — replicated input; y has the
                  same shape and equals sequentially applying every stage.
    """
    if mesh.shape.get(axis_name) != n_stages:
        raise ValueError(
            f"pipeline needs mesh axis {axis_name!r} == n_stages "
            f"({mesh.shape.get(axis_name)} != {n_stages})")
    fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]
    ticks = n_stages + n_micro - 1

    def body(stage_loc, x_full):
        # stage_loc leaves: (1, ...) — this rank's stage
        W = jax.tree_util.tree_map(lambda w: w[0], stage_loc)
        r = jax.lax.axis_index(axis_name)
        h0 = jnp.zeros(x_full.shape[1:], x_full.dtype)
        out0 = jnp.zeros_like(x_full)

        def tick(t, carry):
            h, out = carry
            # stage 0 feeds from the input stream; later stages from the ring
            mb = jnp.clip(t, 0, n_micro - 1)
            x_in = jax.lax.dynamic_index_in_dim(x_full, mb, 0, keepdims=False)
            y = stage_fn(W, jnp.where(r == 0, x_in, h))
            # the last stage emits microbatch t - (S-1) once the fill ends
            oi = t - (n_stages - 1)
            emit = jnp.logical_and(r == n_stages - 1, oi >= 0)
            written = jax.lax.dynamic_update_index_in_dim(
                out, y, jnp.clip(oi, 0, n_micro - 1), 0)
            out = jnp.where(emit, written, out)
            h = jax.lax.ppermute(y, axis_name, perm=fwd)
            return h, out

        _, out = jax.lax.fori_loop(0, ticks, tick, (h0, out0))
        # only the last rank wrote anything; psum broadcasts the result
        return jax.lax.psum(out, axis_name)

    return shard_map(body, mesh=mesh, in_specs=(P(axis_name), P()),
                     out_specs=P(), check_rep=False)
