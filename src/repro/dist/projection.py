"""Sharding-aware packed l1,inf projection (DESIGN.md §7).

The packed engine in ``core.constraints``/``core.engine`` concatenates every
l1,inf leaf into one (n_max, sum m) buffer. Single-device that is ideal; under
GSPMD it is a disaster: the concatenate forces every FSDP/TP-sharded weight to
be all-gathered into a replicated buffer each step. This module keeps the
math identical while keeping shards resident:

  * the packed buffer is laid out COLUMN-SHARDED over the whole mesh — each
    rank owns ``m / D`` columns of every entry (columns are independent
    sub-problems: sort, prefix sums, and the final clip never cross columns);
  * entering ``shard_map``, GSPMD moves each leaf from its parameter layout
    (e.g. FSDP rows over "data", TP columns over "model") to the canonical
    column shard — a balanced all-to-all of ``|leaf| / D`` bytes per rank,
    never a full-weight all-gather;
  * the segmented Newton runs on local blocks; the only cross-rank traffic
    per Eq.-(19) evaluation is one psum of a (num_segments,) f32 vector
    (``core.l1inf.project_l1inf_segmented_sharded``);
  * leaves whose column count the mesh does not divide FALL BACK to
    replication inside the body (their reduction contributions are masked
    to rank 0 so every column is counted exactly once) — that fallback IS
    a per-step gather of the leaf, so ``shard_packed_plan`` warns loudly;
    pad the projected dim to a device-count multiple to stay resident.

Theta (and hence the projected weights) match the gathered solve up to fp
reduction order.

Family dispatch (PR 4): the plan names its constraint family
(``core.families``) and the shard_map body runs that family's per-column
statistics — every hook is per-column given the shared theta, so plain,
weighted, masked, and bilevel sub-buffers all keep the one-psum-per-eval
contract; weight-aware families slice their per-column ``w_col`` vector
rank-locally (never communicated).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..core.constraints import (PackedPlan, _PackedEntry, _pack_entry,
                                _unpack_entry, _LANE)
from ..core.families import get_family, project_segmented_family_sharded

__all__ = ["ShardedPlan", "shard_packed_plan", "project_plan_sharded",
           "fused_plan_sharded"]


@dataclasses.dataclass(frozen=True)
class ShardedPlan:
    """Per-rank layout of one PackedPlan on a mesh (all fields static).

    ``local`` is a PackedPlan describing each rank's column block: entries
    keep their global rows/lead/segment ids but ``m``/``m_pad``/``col_start``
    are per-rank. ``col_sharded[i]`` says entry i's columns are split over
    the mesh (vs replicated on every rank and owned by rank 0).

    >>> sp = shard_packed_plan(plan, n_devices=8)   # sp: ShardedPlan
    """
    global_plan: PackedPlan
    local: PackedPlan
    col_sharded: Tuple[bool, ...]
    n_devices: int

    def owned_cols(self) -> np.ndarray:
        """Static part of the contribution mask: True for columns of
        column-sharded entries (every rank owns its slice); False for
        replicated entries' columns (ownership resolves to rank 0 at
        trace time) and for lane padding (invalid anyway)."""
        owned = np.zeros((self.local.total_cols,), bool)
        for e, sh in zip(self.local.entries, self.col_sharded):
            if sh:
                lo = e.col_start
                owned[lo: lo + e.lead * e.m_pad] = True
        return owned

    def virtual_owned_cols(self) -> np.ndarray:
        """Dense-layout twin of :meth:`owned_cols` for the fused step's
        VIRTUAL packing (no lane padding, entry order — see
        ``PackedPlan.virtual_seg_ids``): True on every column of a
        column-sharded entry, False on replicated entries' columns
        (resolved to rank 0 at trace time)."""
        parts = [np.full((e.lead * e.m,), sh, bool)
                 for e, sh in zip(self.local.entries, self.col_sharded)]
        return (np.concatenate(parts) if parts
                else np.zeros((0,), bool))


def shard_packed_plan(plan: PackedPlan, n_devices: int) -> ShardedPlan:
    """Split a packed plan column-wise over ``n_devices`` ranks.

    Entries whose column count is divisible by the device count get
    ``m / D`` columns per rank (lane-padded locally); the rest stay
    replicated. Pure shape bookkeeping — safe during tracing.

    >>> sp = shard_packed_plan(plan, n_devices=len(jax.devices()))
    """
    entries, flags, col = [], [], 0
    for e in plan.entries:
        sharded = n_devices > 1 and e.m % n_devices == 0
        if not sharded and n_devices > 1:
            # replication means GSPMD gathers this leaf at the shard_map
            # boundary every step — the cost the sharded engine exists to
            # avoid. Loud, because the caller can usually fix it by padding
            # the projected dim to a device-count multiple.
            warnings.warn(
                f"sharded projection: leaf {e.shape} has {e.m} columns, "
                f"not divisible by the {n_devices}-device mesh — this "
                f"entry is replicated (a per-step all-gather)",
                stacklevel=2)
        m_loc = e.m // n_devices if sharded else e.m
        m_pad = -(-m_loc // _LANE) * _LANE
        entries.append(dataclasses.replace(e, m=m_loc, m_pad=m_pad,
                                           col_start=col))
        flags.append(sharded)
        col += e.lead * m_pad
    local = PackedPlan(key=plan.key, every_k=plan.every_k, n_max=plan.n_max,
                       total_cols=col, num_segments=plan.num_segments,
                       entries=tuple(entries), family=plan.family)
    return ShardedPlan(global_plan=plan, local=local,
                       col_sharded=tuple(flags), n_devices=n_devices)


def _col_dim(e: _PackedEntry) -> int:
    """Index of the canonical COLUMN dim in the entry's original leaf shape
    (the trailing matrix dim, or the one before it when the spec's max axis
    selected the trailing dim)."""
    return len(e.shape) - 2 if e.transpose else len(e.shape) - 1


def _leaf_spec(e: _PackedEntry, sharded: bool,
               axis_names: Tuple[str, ...]) -> P:
    if not sharded:
        return P(*([None] * len(e.shape)))
    axes = [None] * len(e.shape)
    axes[_col_dim(e)] = axis_names if len(axis_names) > 1 else axis_names[0]
    return P(*axes)


def project_plan_sharded(leaves: Sequence[jnp.ndarray], plan: PackedPlan,
                         mesh: Mesh,
                         theta0: Optional[jnp.ndarray] = None,
                         max_iter: int = 32):
    """Project one packed plan's leaves, shards resident (shard_map).

    ``leaves`` are the plan entries' leaf arrays in entry order (any
    sharding — GSPMD reshards to the canonical column layout at the
    shard_map boundary, an all-to-all, not a gather); ``theta0``:
    optional (num_segments,) f32 warm start. Returns
    (projected_leaves list, theta (num_segments,) f32, iters int32) with
    theta/iters replicated; projected leaves keep their input shardings.

    >>> outs, theta, iters = project_plan_sharded(vals, plan, mesh)
    """
    axis_names = tuple(mesh.axis_names)
    D = int(np.prod([mesh.shape[a] for a in axis_names], dtype=np.int64))
    sp = shard_packed_plan(plan, D)
    sids = sp.local.seg_ids()
    C_seg = plan.radii()
    owned = sp.owned_cols()
    n_max = plan.n_max
    G = plan.num_segments
    fam = get_family(plan.family)
    if theta0 is None:
        theta0 = jnp.zeros((G,), jnp.float32)

    def _local_wcol(rank):
        """This rank's slice of the packed per-column weight vector: a
        column-sharded entry owns the contiguous GSPMD block
        [rank*m_loc, (rank+1)*m_loc) of its global weights; replicated
        entries carry them whole. Lane padding weights 1.0."""
        parts = []
        for e, sh in zip(sp.local.entries, sp.col_sharded):
            if e.weights is None:
                parts.append(jnp.ones((e.lead * e.m_pad,), jnp.float32))
                continue
            wg = jnp.asarray(e.weights, jnp.float32)
            w_loc = (jax.lax.dynamic_slice(wg, (rank * e.m,), (e.m,))
                     if sh else wg)
            w_loc = jnp.pad(w_loc, (0, e.m_pad - e.m), constant_values=1.0)
            parts.append(jnp.tile(w_loc, e.lead))
        return jnp.concatenate(parts) if len(parts) > 1 else parts[0]

    def body(th0, *lv):
        rank = jnp.zeros((), jnp.int32)
        for a in axis_names:
            rank = rank * mesh.shape[a] + jax.lax.axis_index(a)
        contrib = jnp.logical_or(jnp.asarray(owned), rank == 0)
        pieces = [_pack_entry(x, e, n_max)
                  for x, e in zip(lv, sp.local.entries)]
        Ypk = jnp.concatenate(pieces, axis=1) if len(pieces) > 1 else pieces[0]
        w_col = _local_wcol(rank) if fam.uses_weights else None
        Xpk, theta, iters = project_segmented_family_sharded(
            Ypk, jnp.asarray(sids), jnp.asarray(C_seg), num_segments=G,
            axis_names=axis_names, family=plan.family, w_col=w_col,
            theta0=th0, contrib=contrib, max_iter=max_iter)
        outs = []
        for x, e in zip(lv, sp.local.entries):
            block = jax.lax.slice_in_dim(
                Xpk, e.col_start, e.col_start + e.lead * e.m_pad, axis=1)
            outs.append(_unpack_entry(block, e, x))
        return tuple(outs), theta, iters

    leaf_specs = tuple(_leaf_spec(e, sh, axis_names)
                       for e, sh in zip(plan.entries, sp.col_sharded))
    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(None),) + leaf_specs,
                   out_specs=(leaf_specs, P(None), P()),
                   check_rep=False)
    outs, theta, iters = fn(jnp.asarray(theta0, jnp.float32), *leaves)
    return list(outs), theta, iters


def _local_virtual_wcol(sp: ShardedPlan, rank):
    """This rank's slice of the DENSE per-column weight vector (the
    virtual-packing twin of ``_local_wcol``): a column-sharded entry owns
    the contiguous GSPMD block [rank*m_loc, (rank+1)*m_loc) of its global
    weights; replicated entries carry them whole. No lane padding exists
    in the dense layout, so no 1.0 filler is inserted."""
    parts = []
    for e, sh in zip(sp.local.entries, sp.col_sharded):
        if e.weights is None:
            parts.append(jnp.ones((e.lead * e.m,), jnp.float32))
            continue
        wg = jnp.asarray(e.weights, jnp.float32)
        w_loc = (jax.lax.dynamic_slice(wg, (rank * e.m,), (e.m,))
                 if sh else wg)
        parts.append(jnp.tile(w_loc, e.lead))
    return jnp.concatenate(parts) if len(parts) > 1 else parts[0]


def fused_plan_sharded(plan: PackedPlan, mesh: Mesh,
                       g_leaves: Sequence[jnp.ndarray],
                       m_leaves: Sequence[jnp.ndarray],
                       v_leaves: Sequence[jnp.ndarray],
                       p_leaves: Sequence[jnp.ndarray],
                       mask_leaves: Sequence[Optional[jnp.ndarray]],
                       *, acfg, lr_t, b1c, b2c, scale=None,
                       theta0: Optional[jnp.ndarray] = None,
                       max_iter: int = 32):
    """The PR-7 two-HBM-pass megakernel inside shard_map, shards resident.

    One fused optimizer+projection step for one packed plan whose family
    streams its Newton aux from per-column statistics (``from_colstats``):

      * pass 1 (``fused_adam_colstats``) runs RANK-LOCAL on each rank's
        column shard — rows are resident, so every per-column (sum, max)
        statistic is bitwise the gathered value;
      * the per-segment reductions cross the mesh inside the warm-started
        segmented Newton with ONE stacked (2, num_segments) f32 psum per
        Eq.-(19) evaluation (never an all-gather; ``shard_packed_plan``'s
        owned-columns/contrib machinery counts replicated leaves once);
      * pass 2 (``fused_adam_clip_apply``) recomputes u from the moments
        pass 1 just wrote and clips rank-local — PR 7's moment-consistent
        recompute invariant holds bit-for-bit per rank.

    ``g/m/v/p/mask_leaves`` are the plan entries' leaf arrays in entry
    order (any sharding — GSPMD reshards to the canonical column layout
    at the shard_map boundary, an all-to-all of |leaf|/D bytes per rank);
    ``mask_leaves`` entries may be None. ``lr_t``/``b1c``/``b2c``/``scale``
    are the traced step scalars (``optim.adam.adam_scalars`` /
    ``clip_scale``). Returns ``(p_new, m_new, v_new, theta, iters)`` with
    the leaf lists in entry order (input shardings preserved), theta
    (num_segments,) f32 replicated. Params match the gathered fused solve
    up to the fp reduction order of the theta psums.

    >>> ps, ms, vs, th, it = fused_plan_sharded(plan, mesh, gs, ms0, vs0,
    ...     ps0, [None]*len(gs), acfg=acfg, lr_t=lr_t, b1c=b1c, b2c=b2c)
    """
    from ..core.engine import _MU_INF
    from ..core.l1inf import _segmented_newton
    from ..kernels.fused_step import (fused_adam_clip_apply,
                                      fused_adam_colstats)

    axis_names = tuple(mesh.axis_names)
    D = int(np.prod([mesh.shape[a] for a in axis_names], dtype=np.int64))
    sp = shard_packed_plan(plan, D)
    sids = sp.local.virtual_seg_ids()
    C_seg = plan.radii()
    owned = sp.virtual_owned_cols()
    G = plan.num_segments
    fam = get_family(plan.family)
    stat = getattr(fam.seg_ops, "colstats_stat", "abs")
    mode = getattr(fam.seg_ops, "fused_mode", "clip")
    if theta0 is None:
        theta0 = jnp.zeros((G,), jnp.float32)
    sc = {"lr_t": jnp.asarray(lr_t, jnp.float32),
          "b1c": jnp.asarray(b1c, jnp.float32),
          "b2c": jnp.asarray(b2c, jnp.float32)}
    if scale is not None:
        sc["scale"] = jnp.asarray(scale, jnp.float32)

    def body(th0, sc, gs, ms, vs, ps, mks):
        rank = jnp.zeros((), jnp.int32)
        for a in axis_names:
            rank = rank * mesh.shape[a] + jax.lax.axis_index(a)
        contrib = jnp.logical_or(jnp.asarray(owned), rank == 0)
        sids_a = jnp.asarray(sids)
        # pass 1, rank-local: moments written, O(m_loc) statistics out —
        # the updated values never reach HBM, the shard never moves
        new_m, new_v, sums, maxes = [], [], [], []
        for g, m, v, p, mk, e in zip(gs, ms, vs, ps, mks, sp.local.entries):
            mn, vn, cs, cm = fused_adam_colstats(
                g, m, v, p, cfg=acfg, lr_t=sc["lr_t"], b1c=sc["b1c"],
                b2c=sc["b2c"], scale=sc.get("scale"), mask=mk,
                transpose=e.transpose, stat=stat)
            new_m.append(mn)
            new_v.append(vn)
            sums.append(cs.reshape(-1))
            maxes.append(cm.reshape(-1))
        colsum = jnp.concatenate(sums) if len(sums) > 1 else sums[0]
        colmax = jnp.concatenate(maxes) if len(maxes) > 1 else maxes[0]
        w_col = _local_virtual_wcol(sp, rank) if fam.uses_weights else None
        aux = fam.seg_ops.from_colstats(colsum, colmax, w_col)
        mu, theta, iters, inside_seg, zero_seg = _segmented_newton(
            aux, sids_a, jnp.asarray(C_seg), G, th0, max_iter,
            ops=fam.seg_ops, axis_names=axis_names, contrib=contrib)
        # fold the identity/zero segment gating into the clip level, as in
        # the single-device fused step — no padding exists in the dense
        # layout, so the lookups need no sentinel extension
        if mode == "scale":
            lvl = fam.seg_ops.fused_scale(aux, mu)
            mu_eff = jnp.where(zero_seg[sids_a], 0.0,
                               jnp.where(inside_seg[sids_a], 1.0, lvl))
        else:
            mu_eff = jnp.where(zero_seg[sids_a], 0.0,
                               jnp.where(inside_seg[sids_a], _MU_INF, mu))
        # pass 2, rank-local: recompute u from the just-written moments,
        # clip at mu — the step's only param write, shard still resident
        new_p, off = [], 0
        for p, mn, vn, mk, e in zip(ps, new_m, new_v, mks,
                                    sp.local.entries):
            span = e.lead * e.m
            mu_leaf = mu_eff[off:off + span].reshape(e.lead, e.m)
            off += span
            new_p.append(fused_adam_clip_apply(
                mn, vn, p, mu_leaf, cfg=acfg, lr_t=sc["lr_t"],
                b1c=sc["b1c"], b2c=sc["b2c"], mask=mk,
                transpose=e.transpose, mode=mode))
        return tuple(new_p), tuple(new_m), tuple(new_v), theta, iters

    leaf_specs = tuple(_leaf_spec(e, sh, axis_names)
                       for e, sh in zip(plan.entries, sp.col_sharded))
    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(None), P(), leaf_specs, leaf_specs,
                             leaf_specs, leaf_specs, leaf_specs),
                   out_specs=(leaf_specs, leaf_specs, leaf_specs,
                              P(None), P()),
                   check_rep=False)
    new_p, new_m, new_v, theta, iters = fn(
        jnp.asarray(theta0, jnp.float32), sc, tuple(g_leaves),
        tuple(m_leaves), tuple(v_leaves), tuple(p_leaves),
        tuple(mask_leaves))
    return list(new_p), list(new_m), list(new_v), theta, iters
