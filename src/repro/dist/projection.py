"""Sharding-aware packed l1,inf projection (DESIGN.md §7).

The packed engine in ``core.constraints``/``core.engine`` concatenates every
l1,inf leaf into one (n_max, sum m) buffer. Single-device that is ideal; under
GSPMD it is a disaster: the concatenate forces every FSDP/TP-sharded weight to
be all-gathered into a replicated buffer each step. This module keeps the
math identical while keeping shards resident:

  * the packed buffer is laid out COLUMN-SHARDED over the whole mesh — each
    rank owns ``m / D`` columns of every entry (columns are independent
    sub-problems: sort, prefix sums, and the final clip never cross columns);
  * entering ``shard_map``, GSPMD moves each leaf from its parameter layout
    (e.g. FSDP rows over "data", TP columns over "model") to the canonical
    column shard — a balanced all-to-all of ``|leaf| / D`` bytes per rank,
    never a full-weight all-gather;
  * the segmented Newton runs on local blocks; the only cross-rank traffic
    per Eq.-(19) evaluation is one psum of a (num_segments,) f32 vector
    (``core.l1inf.project_l1inf_segmented_sharded``);
  * leaves whose column count the mesh does not divide FALL BACK to
    replication inside the body (their reduction contributions are masked
    to rank 0 so every column is counted exactly once) — that fallback IS
    a per-step gather of the leaf, so ``shard_packed_plan`` warns loudly;
    pad the projected dim to a device-count multiple to stay resident.

Theta (and hence the projected weights) match the gathered solve up to fp
reduction order.

Family dispatch (PR 4): the plan names its constraint family
(``core.families``) and the shard_map body runs that family's per-column
statistics — every hook is per-column given the shared theta, so plain,
weighted, masked, and bilevel sub-buffers all keep the one-psum-per-eval
contract; weight-aware families slice their per-column ``w_col`` vector
rank-locally (never communicated).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..core.constraints import (PackedPlan, _PackedEntry, _pack_entry,
                                _unpack_entry, _LANE)
from ..core.families import get_family, project_segmented_family_sharded

__all__ = ["ShardedPlan", "shard_packed_plan", "project_plan_sharded"]


@dataclasses.dataclass(frozen=True)
class ShardedPlan:
    """Per-rank layout of one PackedPlan on a mesh (all fields static).

    ``local`` is a PackedPlan describing each rank's column block: entries
    keep their global rows/lead/segment ids but ``m``/``m_pad``/``col_start``
    are per-rank. ``col_sharded[i]`` says entry i's columns are split over
    the mesh (vs replicated on every rank and owned by rank 0).

    >>> sp = shard_packed_plan(plan, n_devices=8)   # sp: ShardedPlan
    """
    global_plan: PackedPlan
    local: PackedPlan
    col_sharded: Tuple[bool, ...]
    n_devices: int

    def owned_cols(self) -> np.ndarray:
        """Static part of the contribution mask: True for columns of
        column-sharded entries (every rank owns its slice); False for
        replicated entries' columns (ownership resolves to rank 0 at
        trace time) and for lane padding (invalid anyway)."""
        owned = np.zeros((self.local.total_cols,), bool)
        for e, sh in zip(self.local.entries, self.col_sharded):
            if sh:
                lo = e.col_start
                owned[lo: lo + e.lead * e.m_pad] = True
        return owned


def shard_packed_plan(plan: PackedPlan, n_devices: int) -> ShardedPlan:
    """Split a packed plan column-wise over ``n_devices`` ranks.

    Entries whose column count is divisible by the device count get
    ``m / D`` columns per rank (lane-padded locally); the rest stay
    replicated. Pure shape bookkeeping — safe during tracing.

    >>> sp = shard_packed_plan(plan, n_devices=len(jax.devices()))
    """
    entries, flags, col = [], [], 0
    for e in plan.entries:
        sharded = n_devices > 1 and e.m % n_devices == 0
        if not sharded and n_devices > 1:
            # replication means GSPMD gathers this leaf at the shard_map
            # boundary every step — the cost the sharded engine exists to
            # avoid. Loud, because the caller can usually fix it by padding
            # the projected dim to a device-count multiple.
            warnings.warn(
                f"sharded projection: leaf {e.shape} has {e.m} columns, "
                f"not divisible by the {n_devices}-device mesh — this "
                f"entry is replicated (a per-step all-gather)",
                stacklevel=2)
        m_loc = e.m // n_devices if sharded else e.m
        m_pad = -(-m_loc // _LANE) * _LANE
        entries.append(dataclasses.replace(e, m=m_loc, m_pad=m_pad,
                                           col_start=col))
        flags.append(sharded)
        col += e.lead * m_pad
    local = PackedPlan(key=plan.key, every_k=plan.every_k, n_max=plan.n_max,
                       total_cols=col, num_segments=plan.num_segments,
                       entries=tuple(entries), family=plan.family)
    return ShardedPlan(global_plan=plan, local=local,
                       col_sharded=tuple(flags), n_devices=n_devices)


def _col_dim(e: _PackedEntry) -> int:
    """Index of the canonical COLUMN dim in the entry's original leaf shape
    (the trailing matrix dim, or the one before it when the spec's max axis
    selected the trailing dim)."""
    return len(e.shape) - 2 if e.transpose else len(e.shape) - 1


def _leaf_spec(e: _PackedEntry, sharded: bool,
               axis_names: Tuple[str, ...]) -> P:
    if not sharded:
        return P(*([None] * len(e.shape)))
    axes = [None] * len(e.shape)
    axes[_col_dim(e)] = axis_names if len(axis_names) > 1 else axis_names[0]
    return P(*axes)


def project_plan_sharded(leaves: Sequence[jnp.ndarray], plan: PackedPlan,
                         mesh: Mesh,
                         theta0: Optional[jnp.ndarray] = None,
                         max_iter: int = 32):
    """Project one packed plan's leaves, shards resident (shard_map).

    ``leaves`` are the plan entries' leaf arrays in entry order (any
    sharding — GSPMD reshards to the canonical column layout at the
    shard_map boundary, an all-to-all, not a gather); ``theta0``:
    optional (num_segments,) f32 warm start. Returns
    (projected_leaves list, theta (num_segments,) f32, iters int32) with
    theta/iters replicated; projected leaves keep their input shardings.

    >>> outs, theta, iters = project_plan_sharded(vals, plan, mesh)
    """
    axis_names = tuple(mesh.axis_names)
    D = int(np.prod([mesh.shape[a] for a in axis_names], dtype=np.int64))
    sp = shard_packed_plan(plan, D)
    sids = sp.local.seg_ids()
    C_seg = plan.radii()
    owned = sp.owned_cols()
    n_max = plan.n_max
    G = plan.num_segments
    fam = get_family(plan.family)
    if theta0 is None:
        theta0 = jnp.zeros((G,), jnp.float32)

    def _local_wcol(rank):
        """This rank's slice of the packed per-column weight vector: a
        column-sharded entry owns the contiguous GSPMD block
        [rank*m_loc, (rank+1)*m_loc) of its global weights; replicated
        entries carry them whole. Lane padding weights 1.0."""
        parts = []
        for e, sh in zip(sp.local.entries, sp.col_sharded):
            if e.weights is None:
                parts.append(jnp.ones((e.lead * e.m_pad,), jnp.float32))
                continue
            wg = jnp.asarray(e.weights, jnp.float32)
            w_loc = (jax.lax.dynamic_slice(wg, (rank * e.m,), (e.m,))
                     if sh else wg)
            w_loc = jnp.pad(w_loc, (0, e.m_pad - e.m), constant_values=1.0)
            parts.append(jnp.tile(w_loc, e.lead))
        return jnp.concatenate(parts) if len(parts) > 1 else parts[0]

    def body(th0, *lv):
        rank = jnp.zeros((), jnp.int32)
        for a in axis_names:
            rank = rank * mesh.shape[a] + jax.lax.axis_index(a)
        contrib = jnp.logical_or(jnp.asarray(owned), rank == 0)
        pieces = [_pack_entry(x, e, n_max)
                  for x, e in zip(lv, sp.local.entries)]
        Ypk = jnp.concatenate(pieces, axis=1) if len(pieces) > 1 else pieces[0]
        w_col = _local_wcol(rank) if fam.uses_weights else None
        Xpk, theta, iters = project_segmented_family_sharded(
            Ypk, jnp.asarray(sids), jnp.asarray(C_seg), num_segments=G,
            axis_names=axis_names, family=plan.family, w_col=w_col,
            theta0=th0, contrib=contrib, max_iter=max_iter)
        outs = []
        for x, e in zip(lv, sp.local.entries):
            block = jax.lax.slice_in_dim(
                Xpk, e.col_start, e.col_start + e.lead * e.m_pad, axis=1)
            outs.append(_unpack_entry(block, e, x))
        return tuple(outs), theta, iters

    leaf_specs = tuple(_leaf_spec(e, sh, axis_names)
                       for e, sh in zip(plan.entries, sp.col_sharded))
    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(None),) + leaf_specs,
                   out_specs=(leaf_specs, P(None), P()),
                   check_rep=False)
    outs, theta, iters = fn(jnp.asarray(theta0, jnp.float32), *leaves)
    return list(outs), theta, iters
