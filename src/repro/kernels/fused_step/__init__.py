from .ops import fused_adam_colstats, fused_adam_clip_apply
