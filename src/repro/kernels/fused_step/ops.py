"""Dispatch wrappers for the fused Adam+projection passes (DESIGN.md §11).

One projected train step over a constrained leaf = ``fused_adam_colstats``
(pass 1: moments out, per-column |u| statistics out, u never written) +
the O(num_segments) segmented Newton on those statistics (the engine's
job, ``core.engine``) + ``fused_adam_clip_apply`` (pass 2: recompute u
from the stored moments, clip, write). Two HBM passes per leaf, against
the >= 4 of the unfused adam-write/pack/solve/clip pipeline.

Both wrappers take the leaf in its OWN layout (any rank >= 2; leading dims
are stacked matrices) — virtual packing: no packed buffer, no concatenate
copy, the caller only threads per-leaf slices of the flat statistics
vector. ``impl`` picks the backend: ``"pallas"`` (the TPU kernels of
``kernel.py``; interpret mode off-TPU), ``"ref"`` (the jnp twins of
``ref.py`` — what XLA fuses best on CPU/GPU), or ``"auto"`` (pallas on
TPU, ref elsewhere). The two implementations are tile-for-tile identical;
tests diff them in interpret mode.

The step scalars (lr_t, b1c, b2c) come from ``optim.adam.adam_scalars``
and ``scale`` from ``optim.adam.clip_scale`` so the fused and unfused
paths share one definition of the update math.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from . import kernel as _k

__all__ = ["fused_adam_colstats", "fused_adam_clip_apply"]

_SUB = 16     # sublane padding multiple (bf16-safe; f32 needs only 8)
_LANE = 128   # lane padding multiple


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl not in ("pallas", "ref"):
        raise ValueError(f"unknown impl {impl!r} (auto | pallas | ref)")
    return impl


def _view3(x):
    return x.reshape((-1,) + x.shape[-2:]) if x.ndim > 2 else x[None]


def _pad3(x, Rp, Cp):
    L, R, C = x.shape
    if R != Rp or C != Cp:
        x = jnp.pad(x, ((0, 0), (0, Rp - R), (0, Cp - C)))
    return x


def _padded_dims(shape):
    R, C = shape[-2:]
    return -(-R // _SUB) * _SUB, -(-C // _LANE) * _LANE


def _scalars(scale, lr_t, b1c, b2c):
    one = jnp.ones((), jnp.float32)
    return jnp.stack([
        one if scale is None else jnp.asarray(scale, jnp.float32),
        jnp.asarray(lr_t, jnp.float32) * one,
        jnp.asarray(b1c, jnp.float32),
        jnp.asarray(b2c, jnp.float32)])


def fused_adam_colstats(g, m, v, p, *, cfg, lr_t, b1c, b2c,
                        scale=None, mask=None, transpose: bool = False,
                        stat: str = "abs",
                        impl: str = "auto", interpret=None):
    """Pass 1 of the fused step: Adam moments + Newton column statistics.

    ``g``/``m``/``v``/``p``: gradient, first/second moment, and param leaf
    (rank >= 2, leading dims stacked; moments in ``cfg.moment_dtype``).
    ``cfg``: AdamConfig; ``lr_t``/``b1c``/``b2c``: the traced step scalars
    (``optim.adam.adam_scalars``); ``scale``: optional global-norm clip
    multiplier (``optim.adam.clip_scale``); ``mask``: optional {0,1} leaf
    (Algorithm-3 freeze — zeroes grads AND the whole step); ``transpose``:
    True when the spec's max axis is the trailing dim (canonical columns
    are then the second-to-last dim); ``stat``: what the colsum slot
    accumulates — ``"abs"`` (sum |u|) or ``"sq"`` (sum u^2, the l1,2
    family's column energies; the family's ``colstats_stat`` attribute
    picks this). Returns ``(m_new, v_new, colsum, colmax)`` — moments with
    the leaf's shape/``moment_dtype``, statistics f32 (lead, m) of the
    updated-but-never-written values |u|.

    >>> mn, vn, cs, cm = fused_adam_colstats(g, m, v, p, cfg=acfg,
    ...     lr_t=1e-3, b1c=b1c, b2c=b2c, transpose=True)
    """
    if stat not in ("abs", "sq"):
        raise ValueError(f"unknown stat {stat!r} (abs | sq)")
    if _resolve(impl) == "ref":
        return ref.adam_colstats_ref(g, m, v, p, cfg=cfg, lr_t=lr_t,
                                     b1c=b1c, b2c=b2c, scale=scale,
                                     mask=mask, transpose=transpose,
                                     stat=stat)
    shape = p.shape
    R, C = shape[-2:]
    Rp, Cp = _padded_dims(shape)
    pad = lambda x: _pad3(_view3(x), Rp, Cp)
    mk = None if mask is None else pad(mask)
    m_new, v_new, colsum, colmax = _k.adam_colstats(
        _scalars(scale, lr_t, b1c, b2c), pad(g), pad(m), pad(v), pad(p), mk,
        moment_dtype=cfg.moment_dtype, b1=cfg.b1, b2=cfg.b2, eps=cfg.eps,
        wd=cfg.weight_decay, transpose=transpose, stat=stat,
        interpret=(jax.default_backend() != "tpu"
                   if interpret is None else interpret))
    mcols = R if transpose else C
    return (m_new[:, :R, :C].reshape(shape),
            v_new[:, :R, :C].reshape(shape),
            colsum[:, :mcols], colmax[:, :mcols])


def fused_adam_clip_apply(m, v, p, mu, *, cfg, lr_t, b1c, b2c,
                          mask=None, transpose: bool = False,
                          mode: str = "clip",
                          impl: str = "auto", interpret=None):
    """Pass 2 of the fused step: recompute the update, clip, write params.

    ``m``/``v``: the moments pass 1 just wrote (recomputing u from them is
    what keeps the two passes bit-consistent — see ``ref.py``); ``p``: the
    ORIGINAL (pre-step) params; ``mu``: (lead, m) f32 per-column clip level
    with the engine's gating folded in (1e30-class sentinel = segment
    inside the ball -> identity; 0 = dead column). ``mode``: ``"clip"``
    writes sign(u) * min(|u|, mu); ``"scale"`` writes u * mu with mu a
    per-column multiplier (the l1,2 family's ``fused_mode``; identity
    sentinel 1.0, dead column 0.0). Other args as in
    ``fused_adam_colstats``. Returns the projected params (leaf shape and
    dtype) — the only param write of the whole step.

    >>> p_new = fused_adam_clip_apply(mn, vn, p, mu, cfg=acfg,
    ...     lr_t=1e-3, b1c=b1c, b2c=b2c)
    """
    if mode not in ("clip", "scale"):
        raise ValueError(f"unknown mode {mode!r} (clip | scale)")
    if _resolve(impl) == "ref":
        return ref.adam_clip_apply_ref(m, v, p, mu, cfg=cfg, lr_t=lr_t,
                                       b1c=b1c, b2c=b2c, mask=mask,
                                       transpose=transpose, mode=mode)
    shape = p.shape
    R, C = shape[-2:]
    Rp, Cp = _padded_dims(shape)
    pad = lambda x: _pad3(_view3(x), Rp, Cp)
    mk = None if mask is None else pad(mask)
    mcols_p = Rp if transpose else Cp
    mu3 = jnp.asarray(mu, jnp.float32)
    if mu3.shape[1] != mcols_p:
        mu3 = jnp.pad(mu3, ((0, 0), (0, mcols_p - mu3.shape[1])))
    x = _k.adam_clip_apply(
        _scalars(None, lr_t, b1c, b2c), pad(m), pad(v), pad(p), mu3, mk,
        b1=cfg.b1, b2=cfg.b2, eps=cfg.eps, wd=cfg.weight_decay,
        transpose=transpose, mode=mode,
        interpret=(jax.default_backend() != "tpu"
                   if interpret is None else interpret))
    return x[:, :R, :C].reshape(shape)
