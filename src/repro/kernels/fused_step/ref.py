"""jnp reference twins of the fused optimizer+projection passes.

These are the EXACT math of ``kernel.py`` expressed as plain XLA ops — the
dispatch layer (``ops.py``) runs them on non-TPU backends (where Pallas
interpret mode would serialize the grid) and the tests diff the Pallas
kernels against them tile-for-tile. Two invariants both implementations
must keep (DESIGN.md §11):

1. **Moment-consistent recompute.** Pass 1 stores the new moments in
   ``cfg.moment_dtype`` and derives the updated value u from the STORED
   (cast) moments; pass 2 recomputes u from those same stored moments.
   The two passes therefore agree bit-for-bit on u — pass 1's statistics
   describe exactly the matrix pass 2 clips. With fp32 moments the cast is
   the identity and u also matches the unfused ``adam_update`` bit-for-bit;
   with bf16 moments the fused step quantizes the moments BEFORE the step
   (the unfused path steps on the pre-cast fp32 moments), a one-ulp-class
   deviation documented in DESIGN.md §11.

2. **Param-dtype rounding before statistics.** u is rounded through the
   param dtype before |.| statistics and before the clip, matching the
   unfused path where the packer reads the already-written (rounded)
   params. Without this, bf16 params would see stats of values that never
   exist in memory.

The update formula itself mirrors ``optim.adam.adam_leaf_update`` — any
change there must land here and in ``kernel.py`` in the same commit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _view3(x):
    """Leaf -> (lead, R, C) canonical 3-D view (lead = stacked matrices)."""
    return x.reshape((-1,) + x.shape[-2:]) if x.ndim > 2 else x[None]


def _u_from_moments(m_st, v_st, p, cfg, lr_t, b1c, b2c, mask):
    """Updated value u in the PARAM dtype from the stored moments."""
    mhat = m_st.astype(jnp.float32) / b1c
    vhat = v_st.astype(jnp.float32) / b2c
    step = lr_t * mhat / (jnp.sqrt(vhat) + cfg.eps)
    if cfg.weight_decay:
        step = step + lr_t * cfg.weight_decay * p.astype(jnp.float32)
    if mask is not None:
        step = step * mask.astype(jnp.float32)
    return (p.astype(jnp.float32) - step).astype(p.dtype)


def adam_colstats_ref(g, m, v, p, *, cfg, lr_t, b1c, b2c,
                      scale=None, mask=None, transpose=False, stat="abs"):
    """Pass 1: Adam moments + per-column (sum, max) of |u| — u never stored.

    Returns (m_new, v_new, colsum, colmax): moments in ``cfg.moment_dtype``
    with the leaf's shape, stats f32 (lead, m) over the canonical columns
    (the trailing dim, or the second-to-last when ``transpose``).
    ``stat``: what the colsum slot accumulates — ``"abs"`` (sum |u|, the
    l1,inf families) or ``"sq"`` (sum u^2, the l1,2 family's column
    energies; colmax stays max |u| either way).
    """
    shape = p.shape
    g3, m3, v3, p3 = _view3(g), _view3(m), _view3(v), _view3(p)
    mk3 = None if mask is None else _view3(mask)
    if scale is not None:
        g3 = (g3 * scale).astype(g3.dtype)
    if mk3 is not None:
        g3 = g3 * mk3.astype(g3.dtype)
    g32 = g3.astype(jnp.float32)
    m_new = cfg.b1 * m3.astype(jnp.float32) + (1 - cfg.b1) * g32
    v_new = cfg.b2 * v3.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
    m_st = m_new.astype(cfg.moment_dtype)
    v_st = v_new.astype(cfg.moment_dtype)
    u = _u_from_moments(m_st, v_st, p3, cfg, lr_t, b1c, b2c, mk3)
    a = jnp.abs(u.astype(jnp.float32))
    red = 2 if transpose else 1
    colsum = jnp.sum(a * a if stat == "sq" else a, axis=red)
    colmax = jnp.max(a, axis=red)
    return m_st.reshape(shape), v_st.reshape(shape), colsum, colmax


def adam_clip_apply_ref(m_st, v_st, p, mu, *, cfg, lr_t, b1c, b2c,
                        mask=None, transpose=False, mode="clip"):
    """Pass 2: recompute u from the stored moments, clip at mu, write params.

    ``mu``: (lead, m) f32 per-column clip level over the canonical columns
    (1e30-class sentinel = identity, 0 = column zeroed — the engine folds
    the inside/zero segment gating into mu). ``mode``: ``"clip"`` writes
    sign(u) * min(|u|, mu) (the l1,inf families); ``"scale"`` writes
    u * mu, mu being a per-column multiplier (the l1,2 family; identity
    sentinel is 1.0, dead column 0.0). Returns the clipped params in the
    leaf's shape/dtype.
    """
    shape = p.shape
    m3, v3, p3 = _view3(m_st), _view3(v_st), _view3(p)
    mk3 = None if mask is None else _view3(mask)
    u = _u_from_moments(m3, v3, p3, cfg, lr_t, b1c, b2c, mk3)
    uf = u.astype(jnp.float32)
    mu_b = mu[:, :, None] if transpose else mu[:, None, :]
    if mode == "scale":
        x = uf * mu_b
    else:
        x = jnp.sign(uf) * jnp.minimum(jnp.abs(uf), mu_b)
    if mk3 is not None:
        x = x * mk3.astype(jnp.float32)
    return x.astype(p.dtype).reshape(shape)
