"""Pallas TPU kernels: the fused Adam+projection train step (DESIGN.md §11).

Two kernels, two HBM passes over each constrained leaf — the whole
projected train step's weight traffic:

  * ``adam_colstats``  (pass 1): reads one (grad, mu, nu, param) tile set,
    computes the Adam update IN-REGISTER, writes the new moments, and
    accumulates the per-column (sum |u|, max |u|) statistics of the updated
    values u — which are never written to HBM. The O(num_segments) Newton
    solve runs on those statistics between the passes (host of the launch:
    ``core.engine``).
  * ``adam_clip_apply`` (pass 2): recomputes u from the just-written
    moments (register recompute is free — HBM is the bottleneck, and
    stashing u would BE a third pass) and writes sign(u) * min(|u|, mu_j)
    directly: the clipped parameter.

Both kernels keep the two ``ref.py`` invariants (moment-consistent
recompute, param-dtype rounding before statistics); the update formula
mirrors ``optim.adam.adam_leaf_update``. Leaves keep their own layout —
the grid runs over the (lead, rows, cols) view of each leaf ("virtual
packing"); there is no packed buffer and no concatenate copy.

Grid: (lead, col_tiles, reduce_tiles) with the reduce dim innermost
(sequential on TPU) so the stats accumulate across row tiles exactly like
``kernels/l1inf/kernel.py::colstats``. The ``transpose`` static flips the
tile orientation for specs whose max axis is the trailing dim. Traced
step scalars [clip_scale, lr_t, b1c, b2c] ride in one prefetched (4,)
vector; compile-time constants (betas, eps, weight decay) close over the
kernel body.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _adam_u(sc_ref, g, m, v, p, mk, mo_ref, vo_ref, *, b1, b2, eps, wd,
            with_moment_update):
    """Shared in-register update: returns u (param dtype); optionally
    updates + stores the moments (pass 1) or steps on them as-is (pass 2).
    """
    lr_t, b1c, b2c = sc_ref[1], sc_ref[2], sc_ref[3]
    if with_moment_update:
        g = (g * sc_ref[0]).astype(g.dtype)
        if mk is not None:
            g = g * mk.astype(g.dtype)
        g32 = g.astype(jnp.float32)
        m_st = (b1 * m.astype(jnp.float32)
                + (1 - b1) * g32).astype(mo_ref.dtype)
        v_st = (b2 * v.astype(jnp.float32)
                + (1 - b2) * g32 * g32).astype(vo_ref.dtype)
        mo_ref[0] = m_st
        vo_ref[0] = v_st
    else:
        m_st, v_st = m, v
    mhat = m_st.astype(jnp.float32) / b1c
    vhat = v_st.astype(jnp.float32) / b2c
    step = lr_t * mhat / (jnp.sqrt(vhat) + eps)
    if wd:
        step = step + lr_t * wd * p.astype(jnp.float32)
    if mk is not None:
        step = step * mk.astype(jnp.float32)
    return (p.astype(jnp.float32) - step).astype(p.dtype)


def _adam_colstats_kernel(sc_ref, g_ref, m_ref, v_ref, p_ref, *rest,
                          b1, b2, eps, wd, has_mask, transpose, stat):
    if has_mask:
        mk_ref, mo_ref, vo_ref, sum_ref, max_ref = rest
        mk = mk_ref[0]
    else:
        mo_ref, vo_ref, sum_ref, max_ref = rest
        mk = None
    i = pl.program_id(2)   # reduce-tile index (innermost, sequential)
    u = _adam_u(sc_ref, g_ref[0], m_ref[0], v_ref[0], p_ref[0], mk,
                mo_ref, vo_ref, b1=b1, b2=b2, eps=eps, wd=wd,
                with_moment_update=True)
    a = jnp.abs(u.astype(jnp.float32))
    red = 1 if transpose else 0
    psum = jnp.sum(a * a if stat == "sq" else a, axis=red)[None, :]
    pmax = jnp.max(a, axis=red)[None, :]

    @pl.when(i == 0)
    def _init():
        sum_ref[...] = psum
        max_ref[...] = pmax

    @pl.when(i > 0)
    def _acc():
        sum_ref[...] = sum_ref[...] + psum
        max_ref[...] = jnp.maximum(max_ref[...], pmax)


def _adam_clip_apply_kernel(sc_ref, m_ref, v_ref, p_ref, mu_ref, *rest,
                            b1, b2, eps, wd, has_mask, transpose, mode):
    if has_mask:
        mk_ref, x_ref = rest
        mk = mk_ref[0]
    else:
        (x_ref,) = rest
        mk = None
    u = _adam_u(sc_ref, None, m_ref[0], v_ref[0], p_ref[0], mk,
                None, None, b1=b1, b2=b2, eps=eps, wd=wd,
                with_moment_update=False)
    uf = u.astype(jnp.float32)
    mu = mu_ref[0]                                    # (bm,)
    mu_b = mu[:, None] if transpose else mu[None, :]
    if mode == "scale":
        x = uf * mu_b
    else:
        x = jnp.sign(uf) * jnp.minimum(jnp.abs(uf), mu_b)
    if mk is not None:
        x = x * mk.astype(jnp.float32)
    x_ref[0] = x.astype(x_ref.dtype)


def _tiles(Rp: int, Cp: int, transpose: bool):
    """(bm, bn, grid tail): col tile, reduce tile, (col_tiles, red_tiles).

    Lane dim (the trailing Cp) tiles in 128s, sublane (Rp) in 16s (safe for
    f32 and bf16); the reduce tile is capped so a 4-buffer f32 tile set
    stays within ~2 MiB of VMEM.
    """
    def pick(dim, lo, cap):
        b = min(dim, cap)
        while b > lo and dim % b:
            b -= lo
        return b

    if transpose:                    # cols = rows dim, reduce = lane dim
        bm = pick(Rp, 16, 128)
        bn = pick(Cp, 128, 512)
    else:                            # cols = lane dim, reduce = rows dim
        bm = pick(Cp, 128, 128)
        bn = pick(Rp, 16, 512)
    cols = Rp if transpose else Cp
    red = Cp if transpose else Rp
    return bm, bn, (cols // bm, red // bn)


def _data_spec(bm, bn, transpose):
    if transpose:
        return pl.BlockSpec((1, bm, bn), lambda l, j, i, sc: (l, j, i))
    return pl.BlockSpec((1, bn, bm), lambda l, j, i, sc: (l, i, j))


_STAT_SPEC = lambda bm: pl.BlockSpec((1, bm), lambda l, j, i, sc: (l, j))


def adam_colstats(sc, g, m, v, p, mask=None, *, moment_dtype,
                  b1, b2, eps, wd, transpose: bool, stat: str = "abs",
                  interpret: bool = False):
    """Pass-1 launch on padded (L, Rp, Cp) views (see module docstring).

    ``sc``: (4,) f32 traced scalars [clip_scale, lr_t, b1c, b2c]. Returns
    (m_new, v_new (L, Rp, Cp) in ``moment_dtype``, colsum, colmax (L, mcols)
    f32). ``stat``: "abs" accumulates sum |u| into colsum, "sq" sum u^2
    (l1,2 column energies). Rp must be a multiple of 16 and Cp of 128
    (``ops.py`` pads).
    """
    L, Rp, Cp = p.shape
    bm, bn, tail = _tiles(Rp, Cp, transpose)
    grid = (L,) + tail
    mcols = Rp if transpose else Cp
    kern = functools.partial(_adam_colstats_kernel, b1=b1, b2=b2, eps=eps,
                             wd=wd, has_mask=mask is not None,
                             transpose=transpose, stat=stat)
    data = lambda: _data_spec(bm, bn, transpose)
    in_specs = [data(), data(), data(), data()]
    args = [g, m, v, p]
    if mask is not None:
        in_specs.append(data())
        args.append(mask)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=[data(), data(), _STAT_SPEC(bm), _STAT_SPEC(bm)],
    )
    m_new, v_new, colsum, colmax = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((L, Rp, Cp), moment_dtype),
                   jax.ShapeDtypeStruct((L, Rp, Cp), moment_dtype),
                   jax.ShapeDtypeStruct((L, mcols), jnp.float32),
                   jax.ShapeDtypeStruct((L, mcols), jnp.float32)],
        interpret=interpret,
    )(sc, *args)
    return m_new, v_new, colsum, colmax


def adam_clip_apply(sc, m, v, p, mu, mask=None, *,
                    b1, b2, eps, wd, transpose: bool, mode: str = "clip",
                    interpret: bool = False):
    """Pass-2 launch: clipped params (L, Rp, Cp) in p's dtype.

    ``mu``: (L, mcols) f32 per-column clip level (sentinel-folded by the
    engine: 1e30 = identity, 0 = dead column). ``mode``: "clip" writes
    sign(u) * min(|u|, mu), "scale" writes u * mu (per-column multiplier,
    identity sentinel 1.0). Same padding contract as ``adam_colstats``.
    """
    L, Rp, Cp = p.shape
    bm, bn, tail = _tiles(Rp, Cp, transpose)
    grid = (L,) + tail
    kern = functools.partial(_adam_clip_apply_kernel, b1=b1, b2=b2, eps=eps,
                             wd=wd, has_mask=mask is not None,
                             transpose=transpose, mode=mode)
    data = lambda: _data_spec(bm, bn, transpose)
    in_specs = [data(), data(), data(), _STAT_SPEC(bm)]
    args = [m, v, p, mu]
    if mask is not None:
        in_specs.append(data())
        args.append(mask)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=in_specs,
        out_specs=data(),
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((L, Rp, Cp), p.dtype),
        interpret=interpret,
    )(sc, *args)
