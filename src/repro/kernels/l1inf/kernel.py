"""Pallas TPU kernels for the l1,inf projection hot path.

TPU-native adaptation of the paper's near-linear projection (DESIGN.md §2):
instead of heaps (sequential) or per-column sorts (log n HBM passes under
XLA), the water-level solve is FUSED in VMEM — each grid program loads an
(n x bm) tile of |Y| once and runs the entire per-column bisection +
Michelot-polish iteration on-chip. One HBM pass per outer Newton step on
theta, and with the sparsity-adaptive engine in ``ops.py`` the pass only
covers the compacted active-column prefix (J-proportional work; DESIGN.md
§3).

Kernels:
  * colstats:   per-column (sum, max) of |Y|, row-tiled accumulation
  * mu_solve:   per-column water level mu_j(theta) + exact (k_j, S_kj)
                payloads for the outer Eq.-(19) Newton update. theta may be
                a scalar (one ball) or a per-column vector (packed
                multi-ball buffers, one theta per segment). An SMEM-style
                active-block count lets grid programs beyond the compacted
                active prefix skip the solve entirely.
  * clip_apply: X = sign(Y) * min(|Y|, mu_j), fully tiled, memory-bound

All kernels use explicit BlockSpec VMEM tiling and are validated against
``ref.py`` in interpret mode (this container is CPU-only; TPU is the target).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_BIG = -1e30


# -----------------------------------------------------------------------------
# colstats
# -----------------------------------------------------------------------------

def _colstats_kernel(y_ref, sum_ref, max_ref):
    i = pl.program_id(1)  # row-tile index (innermost, sequential on TPU)
    y = jnp.abs(y_ref[...].astype(jnp.float32))
    psum = jnp.sum(y, axis=0, keepdims=True)
    pmax = jnp.max(y, axis=0, keepdims=True)

    @pl.when(i == 0)
    def _init():
        sum_ref[...] = psum
        max_ref[...] = pmax

    @pl.when(i > 0)
    def _acc():
        sum_ref[...] = sum_ref[...] + psum
        max_ref[...] = jnp.maximum(max_ref[...], pmax)


def colstats(Y: jnp.ndarray, *, block_m: int = 128, block_n: int = 512,
             interpret: bool = False):
    """Per-column (sum, max) of |Y|. Y is (n, m) with n % block_n == 0 and
    m % block_m == 0 (callers pad)."""
    n, m = Y.shape
    grid = (m // block_m, n // block_n)
    out = pl.pallas_call(
        _colstats_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_n, block_m), lambda j, i: (i, j))],
        out_specs=[pl.BlockSpec((1, block_m), lambda j, i: (0, j)),
                   pl.BlockSpec((1, block_m), lambda j, i: (0, j))],
        out_shape=[jax.ShapeDtypeStruct((1, m), jnp.float32),
                   jax.ShapeDtypeStruct((1, m), jnp.float32)],
        interpret=interpret,
    )(Y)
    return out[0][0], out[1][0]


# -----------------------------------------------------------------------------
# mu_solve: fused per-column water-level solve at a given theta
# -----------------------------------------------------------------------------

def _mu_solve_kernel(nact_ref, theta_ref, y_ref, mu_ref, k_ref, s_ref,
                     act_ref, *, n_bisect: int, n_polish: int):
    j = pl.program_id(0)

    @pl.when(j < nact_ref[0])
    def _solve():
        y = jnp.abs(y_ref[...].astype(jnp.float32))      # (n, bm) in VMEM
        theta = theta_ref[0, :]                          # (1,) or (bm,)
        colsum = jnp.sum(y, axis=0)
        colmax = jnp.max(y, axis=0)
        active = colsum > theta

        # --- bisection: shrink [lo, hi] around mu*; removed(mu) decreasing --
        def bis(_, lohi):
            lo, hi = lohi
            mid = 0.5 * (lo + hi)
            removed = jnp.sum(jnp.maximum(y - mid[None, :], 0.0), axis=0)
            ge = removed >= theta
            return jnp.where(ge, mid, lo), jnp.where(ge, hi, mid)

        lo, hi = jax.lax.fori_loop(
            0, n_bisect, bis, (jnp.zeros_like(colsum), colmax))

        # --- Michelot polish from below (monotone, finitely convergent) -----
        def mich(_, mu):
            gt = y > mu[None, :]
            k = jnp.maximum(jnp.sum(gt.astype(jnp.float32), axis=0), 1.0)
            S = jnp.sum(jnp.where(gt, y, 0.0), axis=0)
            return jnp.maximum((S - theta) / k, mu)

        mu = jax.lax.fori_loop(0, n_polish, mich, lo)
        mu = jnp.maximum(mu, 0.0)

        # exact payloads at the solved level
        gt = y > mu[None, :]
        k = jnp.maximum(jnp.sum(gt.astype(jnp.float32), axis=0), 1.0)
        S = jnp.sum(jnp.where(gt, y, 0.0), axis=0)

        mu_ref[...] = jnp.where(active, mu, 0.0)[None, :]
        k_ref[...] = jnp.where(active, k, 1.0)[None, :]
        s_ref[...] = jnp.where(active, S, 0.0)[None, :]
        act_ref[...] = active.astype(jnp.float32)[None, :]

    @pl.when(j >= nact_ref[0])
    def _skip():
        # Block lies past the compacted active prefix: every column is
        # dominated, payloads are the inactive defaults. No solve runs, and
        # the input index_maps alias these grid steps to block 0, so no
        # fresh HBM traffic is pipelined in for them either.
        mu_ref[...] = jnp.zeros(mu_ref.shape, mu_ref.dtype)
        k_ref[...] = jnp.ones(k_ref.shape, k_ref.dtype)
        s_ref[...] = jnp.zeros(s_ref.shape, s_ref.dtype)
        act_ref[...] = jnp.zeros(act_ref.shape, act_ref.dtype)


def mu_solve(Yabs: jnp.ndarray, theta: jnp.ndarray, *, block_m: int = 128,
             n_bisect: int = 26, n_polish: int = 8, interpret: bool = False,
             nact_blocks=None):
    """Water level per column at removed mass theta. Yabs is (n, m) with
    m % block_m == 0; the full column must fit one VMEM block.

    theta: scalar (one ball) or (m,) vector (per-column, for packed
    multi-segment buffers). nact_blocks: optional traced count of leading
    column blocks that still contain active columns — grid programs at or
    beyond it skip the solve, emit inactive payloads, AND have their input
    DMA aliased to block 0 via scalar-prefetch index_maps, so both compute
    and HBM traffic stay J-proportional (the shrinking engine's inner
    pass). None means all blocks solve.
    """
    n, m = Yabs.shape
    nblocks = m // block_m
    theta = jnp.asarray(theta, jnp.float32)

    def gated(j, nact):
        return jnp.where(j < nact[0], j, 0)

    if theta.ndim == 0:
        theta = jnp.reshape(theta, (1, 1))
        theta_spec = pl.BlockSpec((1, 1), lambda j, nact: (0, 0))
    else:
        theta = jnp.reshape(theta, (1, m))
        theta_spec = pl.BlockSpec((1, block_m),
                                  lambda j, nact: (0, gated(j, nact)))
    if nact_blocks is None:
        nact_blocks = nblocks
    nact = jnp.reshape(jnp.asarray(nact_blocks, jnp.int32), (1,))
    kern = functools.partial(_mu_solve_kernel, n_bisect=n_bisect,
                             n_polish=n_polish)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nblocks,),
        in_specs=[theta_spec,
                  pl.BlockSpec((n, block_m),
                               lambda j, nact: (0, gated(j, nact)))],
        out_specs=[pl.BlockSpec((1, block_m), lambda j, nact: (0, j))] * 4,
    )
    outs = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((1, m), jnp.float32)] * 4,
        interpret=interpret,
    )(nact, theta, Yabs)
    mu, k, S, act = (o[0] for o in outs)
    return mu, k, S, act > 0.5


# -----------------------------------------------------------------------------
# clip_apply
# -----------------------------------------------------------------------------

def _clip_apply_kernel(y_ref, mu_ref, x_ref):
    y = y_ref[...]
    mu = mu_ref[...].astype(y.dtype)         # (1, bm)
    a = jnp.abs(y)
    x_ref[...] = jnp.sign(y) * jnp.minimum(a, mu)


def clip_apply(Y: jnp.ndarray, mu: jnp.ndarray, *, block_m: int = 128,
               block_n: int = 512, interpret: bool = False) -> jnp.ndarray:
    """X = sign(Y) * min(|Y|, mu_j). Fused elementwise, memory-bound."""
    n, m = Y.shape
    grid = (m // block_m, n // block_n)
    return pl.pallas_call(
        _clip_apply_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_n, block_m), lambda j, i: (i, j)),
                  pl.BlockSpec((1, block_m), lambda j, i: (0, j))],
        out_specs=pl.BlockSpec((block_n, block_m), lambda j, i: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), Y.dtype),
        interpret=interpret,
    )(Y, mu.reshape(1, m))
