"""Pallas TPU kernels for the l1,inf projection hot path.

TPU-native adaptation of the paper's near-linear projection (DESIGN.md §2):
instead of heaps (sequential) or per-column sorts (log n HBM passes under
XLA), the water-level solve is FUSED in VMEM — each grid program loads an
(n x bm) tile of |Y| once and runs the entire per-column bisection +
Michelot-polish iteration on-chip. One HBM pass per outer Newton step on
theta (<= ~8 steps), versus sort-based lowering that materializes sorted
copies and prefix sums in HBM.

Kernels:
  * colstats:   per-column (sum, max) of |Y|, row-tiled accumulation
  * mu_solve:   per-column water level mu_j(theta) + exact (k_j, S_kj)
                payloads for the outer Eq.-(19) Newton update
  * clip_apply: X = sign(Y) * min(|Y|, mu_j), fully tiled, memory-bound

All kernels use explicit BlockSpec VMEM tiling and are validated against
``ref.py`` in interpret mode (this container is CPU-only; TPU is the target).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG_BIG = -1e30


# -----------------------------------------------------------------------------
# colstats
# -----------------------------------------------------------------------------

def _colstats_kernel(y_ref, sum_ref, max_ref):
    i = pl.program_id(1)  # row-tile index (innermost, sequential on TPU)
    y = jnp.abs(y_ref[...].astype(jnp.float32))
    psum = jnp.sum(y, axis=0, keepdims=True)
    pmax = jnp.max(y, axis=0, keepdims=True)

    @pl.when(i == 0)
    def _init():
        sum_ref[...] = psum
        max_ref[...] = pmax

    @pl.when(i > 0)
    def _acc():
        sum_ref[...] = sum_ref[...] + psum
        max_ref[...] = jnp.maximum(max_ref[...], pmax)


def colstats(Y: jnp.ndarray, *, block_m: int = 128, block_n: int = 512,
             interpret: bool = False):
    """Per-column (sum, max) of |Y|. Y is (n, m) with n % block_n == 0 and
    m % block_m == 0 (callers pad)."""
    n, m = Y.shape
    grid = (m // block_m, n // block_n)
    out = pl.pallas_call(
        _colstats_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_n, block_m), lambda j, i: (i, j))],
        out_specs=[pl.BlockSpec((1, block_m), lambda j, i: (0, j)),
                   pl.BlockSpec((1, block_m), lambda j, i: (0, j))],
        out_shape=[jax.ShapeDtypeStruct((1, m), jnp.float32),
                   jax.ShapeDtypeStruct((1, m), jnp.float32)],
        interpret=interpret,
    )(Y)
    return out[0][0], out[1][0]


# -----------------------------------------------------------------------------
# mu_solve: fused per-column water-level solve at a given theta
# -----------------------------------------------------------------------------

def _mu_solve_kernel(theta_ref, y_ref, mu_ref, k_ref, s_ref, act_ref,
                     *, n_bisect: int, n_polish: int):
    y = jnp.abs(y_ref[...].astype(jnp.float32))          # (n, bm) in VMEM
    theta = theta_ref[0, 0]
    colsum = jnp.sum(y, axis=0)
    colmax = jnp.max(y, axis=0)
    active = colsum > theta

    # --- bisection: shrink [lo, hi] around mu*; removed(mu) decreasing ------
    def bis(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        removed = jnp.sum(jnp.maximum(y - mid[None, :], 0.0), axis=0)
        ge = removed >= theta
        return jnp.where(ge, mid, lo), jnp.where(ge, hi, mid)

    lo, hi = jax.lax.fori_loop(
        0, n_bisect, bis, (jnp.zeros_like(colsum), colmax))

    # --- Michelot polish from below (monotone, finitely convergent) ---------
    def mich(_, mu):
        gt = y > mu[None, :]
        k = jnp.maximum(jnp.sum(gt.astype(jnp.float32), axis=0), 1.0)
        S = jnp.sum(jnp.where(gt, y, 0.0), axis=0)
        return jnp.maximum((S - theta) / k, mu)

    mu = jax.lax.fori_loop(0, n_polish, mich, lo)
    mu = jnp.maximum(mu, 0.0)

    # exact payloads at the solved level
    gt = y > mu[None, :]
    k = jnp.maximum(jnp.sum(gt.astype(jnp.float32), axis=0), 1.0)
    S = jnp.sum(jnp.where(gt, y, 0.0), axis=0)

    mu_ref[...] = jnp.where(active, mu, 0.0)[None, :]
    k_ref[...] = jnp.where(active, k, 1.0)[None, :]
    s_ref[...] = jnp.where(active, S, 0.0)[None, :]
    act_ref[...] = active.astype(jnp.float32)[None, :]


def mu_solve(Yabs: jnp.ndarray, theta: jnp.ndarray, *, block_m: int = 128,
             n_bisect: int = 26, n_polish: int = 8, interpret: bool = False):
    """Water level per column at removed mass theta. Yabs is (n, m) with
    m % block_m == 0; the full column must fit one VMEM block."""
    n, m = Yabs.shape
    grid = (m // block_m,)
    theta = jnp.reshape(theta.astype(jnp.float32), (1, 1))
    kern = functools.partial(_mu_solve_kernel, n_bisect=n_bisect,
                             n_polish=n_polish)
    outs = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((1, 1), lambda j: (0, 0)),
                  pl.BlockSpec((n, block_m), lambda j: (0, j))],
        out_specs=[pl.BlockSpec((1, block_m), lambda j: (0, j))] * 4,
        out_shape=[jax.ShapeDtypeStruct((1, m), jnp.float32)] * 4,
        interpret=interpret,
    )(theta, Yabs)
    mu, k, S, act = (o[0] for o in outs)
    return mu, k, S, act > 0.5


# -----------------------------------------------------------------------------
# clip_apply
# -----------------------------------------------------------------------------

def _clip_apply_kernel(y_ref, mu_ref, x_ref):
    y = y_ref[...]
    mu = mu_ref[...].astype(y.dtype)         # (1, bm)
    a = jnp.abs(y)
    x_ref[...] = jnp.sign(y) * jnp.minimum(a, mu)


def clip_apply(Y: jnp.ndarray, mu: jnp.ndarray, *, block_m: int = 128,
               block_n: int = 512, interpret: bool = False) -> jnp.ndarray:
    """X = sign(Y) * min(|Y|, mu_j). Fused elementwise, memory-bound."""
    n, m = Y.shape
    grid = (m // block_m, n // block_n)
    return pl.pallas_call(
        _clip_apply_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_n, block_m), lambda j, i: (i, j)),
                  pl.BlockSpec((1, block_m), lambda j, i: (0, j))],
        out_specs=pl.BlockSpec((block_n, block_m), lambda j, i: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), Y.dtype),
        interpret=interpret,
    )(Y, mu.reshape(1, m))
