"""Jitted wrappers: the sparsity-adaptive l1,inf projection engine built on
the Pallas kernels.

Engine shape (DESIGN.md §3):

  * outer monotone Newton on theta, warm-startable via ``theta0=`` (any
    value >= 0; an overshooting stale guess is repaired by the first
    unclamped Eq.-(19) step);
  * **active-column shrinking** — after the first full ``mu_solve`` pass the
    surviving columns are compacted into the leading slots of a packed
    buffer, ordered by descending death margin (a column dies exactly when
    its segment's theta passes its l1 norm, so deaths peel off the END of
    the prefix), and every subsequent Newton step solves only the exact
    still-alive prefix of ``ceil(J / block_m)`` column blocks — the bound
    re-tightens each iteration as theta rises (J-proportional work; blocks
    past the prefix skip via an in-kernel predicate). ``mu`` is carried
    through the loop, so the old post-loop extra ``mu_solve`` pass is gone,
    and the water levels are scattered back through the inverse permutation
    right before ``clip_apply`` (a permutation scatter is exact — see
    DESIGN.md §3);
  * **packed multi-ball** (``project_l1inf_pallas_segmented``) — one packed
    (n, M) buffer with a per-column segment id projects a whole group of
    matrices, each onto its own radius, with ONE kernel launch per Newton
    step (theta becomes a per-segment vector, Eq. (19) a segment-sum).

A ``work_cols`` counter (columns swept per ``mu_solve`` launch, accumulated
through the loop carry) makes the J-proportional claim measurable in
interpret mode; ``return_stats=True`` exposes it together with the Newton
evaluation count.

On non-TPU backends the kernels run in interpret mode (correctness
validation); the lowering target is TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.l1inf import _PAD_THETA, active_compaction
from .kernel import colstats, mu_solve, clip_apply


def _pad_to(x: jnp.ndarray, mult0: int, mult1: int) -> jnp.ndarray:
    n, m = x.shape
    pn = (-n) % mult0
    pm = (-m) % mult1
    if pn or pm:
        x = jnp.pad(x, ((0, pn), (0, pm)))
    return x


def _pick_block_m(n_pad: int, vmem_budget: int = 4 * 1024 * 1024) -> int:
    """Largest power-of-two block_m <= 128 such that an (n_pad, bm) f32 tile
    fits the VMEM budget (TPU lane dim prefers 128)."""
    bm = 128
    while bm > 8 and n_pad * bm * 4 > vmem_budget:
        bm //= 2
    return bm


def _pick_block_n(n_pad: int, cap: int = 512) -> int:
    """Largest divisor of n_pad that is <= cap and a multiple of 8.

    Shared by the colstats and clip_apply launch sites. n_pad is always a
    multiple of 8 (callers pad), so 8 is a guaranteed fallback — but unlike
    the old ``512-or-8`` rule this never collapses e.g. n_pad=520 to an
    8-row grid (a ~64x grid blowup); 520 -> 104.
    """
    if n_pad % 8:
        raise ValueError(f"n_pad must be a multiple of 8, got {n_pad}")
    best = 8
    for bn in range(8, min(cap, n_pad) + 1, 8):
        if n_pad % bn == 0:
            best = bn
    return best


def _engine(Ypad, seg_ids, C_seg, num_segments, theta0, *, bm, n_bisect,
            n_polish, max_newton, interpret, shrink):
    """Shared sparsity-adaptive Newton engine over a padded (n_pad, m_pad)
    buffer whose columns map to `num_segments` independent balls (plus the
    dummy padding segment `num_segments`).

    Returns (mu_full, theta_seg, norm_seg, colsum, stats) where mu_full and
    colsum are in the ORIGINAL column order (mu already scattered back
    through the compaction permutation) and stats carries the Newton/work
    counters.

    NOTE: the outer-Newton structure (bootstrap, monotone ascent, carried
    mu, cap-exit re-eval) is the Pallas twin of core/l1inf.py's
    _newton_solve / project_l1inf_segmented — keep structural fixes in
    sync.
    """
    n_pad, m_pad = Ypad.shape
    G = int(num_segments)
    nblocks = m_pad // bm
    Aabs = jnp.abs(Ypad.astype(jnp.float32))
    seg_ids = jnp.asarray(seg_ids, jnp.int32)
    C_seg = jnp.asarray(C_seg, jnp.float32)
    tiny = jnp.float32(1e-30)

    bn = _pick_block_n(n_pad)
    colsum, colmax = colstats(Aabs, block_m=bm, block_n=bn,
                              interpret=interpret)
    valid = seg_ids < G
    sum_all = functools.partial(jax.ops.segment_sum, segment_ids=seg_ids,
                                num_segments=G + 1)
    norm_seg = sum_all(jnp.where(valid, colmax, 0.0))[:G]
    m_seg = sum_all(valid.astype(jnp.float32))[:G]

    Csafe = jnp.where(C_seg > 0, C_seg, jnp.ones_like(C_seg))
    cold = jnp.maximum((norm_seg - Csafe) / jnp.maximum(m_seg, 1.0), 0.0)
    if theta0 is None:
        start = cold
    else:
        start = jnp.maximum(
            jnp.maximum(jnp.asarray(theta0, jnp.float32), 0.0), cold)

    def theta_cols(th_seg, sids):
        ext = jnp.concatenate(
            [th_seg, jnp.full((1,), _PAD_THETA, jnp.float32)])
        return ext[jnp.minimum(sids, G)]

    def eval_step(th_seg, A, sids, nact_blocks):
        """One mu_solve launch + segmented Eq.-(19) update at th_seg."""
        mu, k, S, act = mu_solve(A, theta_cols(th_seg, sids), block_m=bm,
                                 n_bisect=n_bisect, n_polish=n_polish,
                                 interpret=interpret,
                                 nact_blocks=nact_blocks)
        act = jnp.logical_and(act, sids < G)
        seg_sum = functools.partial(jax.ops.segment_sum, segment_ids=sids,
                                    num_segments=G + 1)
        Aa = seg_sum(jnp.where(act, S / k, 0.0))[:G]
        Ba = seg_sum(jnp.where(act, 1.0 / k, 0.0))[:G]
        new = (Aa - Csafe) / jnp.maximum(Ba, tiny)
        return new, mu

    # --- pass 1: full sweep (establishes a point <= theta* per segment).
    # Clamp the repair to the COLD bound, not 0: cold <= theta* always, and
    # cold > 0 for any segment outside its ball, which keeps theta away
    # from the degenerate theta=0 water level (mu = colmax, empty active
    # set) where the kernel's Eq.-(19) payloads carry no slope information.
    t1 = jnp.maximum(eval_step(start, Aabs, seg_ids, nblocks)[0], cold)

    # --- active-column shrinking: theta is monotone non-decreasing from t1,
    # so any column with colsum <= theta_cols(t1) is dead forever. Compact
    # the survivors into the leading blocks, ordered by DESCENDING death
    # margin (colsum - theta at t1): column j dies exactly when its
    # segment's theta passes colsum_j, so deaths peel off the END of the
    # packed prefix and the still-alive set stays (near-)contiguous. The
    # loop re-tightens the prefix bound every iteration from the exact
    # last-alive index — J-proportional work that keeps shrinking as
    # columns die, not just once.
    if shrink:
        act1 = jnp.logical_and(colsum > theta_cols(t1, seg_ids), valid)
        perm, J = active_compaction(act1, key=theta_cols(t1, seg_ids) - colsum)
        Ap = jnp.take(Aabs, perm, axis=1)
        sids_p = jnp.take(seg_ids, perm)
        colsum_p = jnp.take(colsum, perm)
        iota = jnp.arange(m_pad, dtype=jnp.int32)

        def nact_of(th_seg):
            alive = jnp.logical_and(colsum_p > theta_cols(th_seg, sids_p),
                                    sids_p < G)
            last = jnp.max(jnp.where(alive, iota, -1))
            return ((last + 1) + bm - 1) // bm
    else:
        act1 = valid
        perm = jnp.arange(m_pad, dtype=jnp.int32)
        J = jnp.asarray(m_pad, jnp.int32)
        Ap, sids_p = Aabs, seg_ids

        def nact_of(th_seg):
            return jnp.asarray(nblocks, jnp.int32)

    # --- pass 2 + monotone loop on the packed prefix, mu carried ----------
    nact1 = nact_of(t1)
    t2, mu1 = eval_step(t1, Ap, sids_p, nact1)
    t2 = jnp.maximum(t2, t1)
    work0 = jnp.asarray(nblocks * bm, jnp.int32) + nact1 * bm

    def cond(carry):
        i, th, prev, _, _ = carry
        return jnp.logical_and(i < max_newton, jnp.any(th > prev))

    def body(carry):
        i, th, _, _, work = carry
        nact = nact_of(th)
        new, mu = eval_step(th, Ap, sids_p, nact)
        return (i + 1, jnp.maximum(new, th), th, mu, work + nact * bm)

    iters, theta, prev, mu_p, work = jax.lax.while_loop(
        cond, body, (jnp.asarray(2, jnp.int32), t2, t1, mu1, work0))
    # max_iter-cap exit: the carried mu lags the final theta by one iterate
    # for the still-moving segments; re-evaluate to keep (theta, mu)
    # consistent (free when converged).
    mu_p = jax.lax.cond(
        jnp.any(theta > prev),
        lambda: eval_step(theta, Ap, sids_p, nact_of(theta))[1],
        lambda: mu_p)

    # scatter back: perm is a bijection, so this is exact (DESIGN.md §3)
    mu_full = jnp.zeros((m_pad,), jnp.float32).at[perm].set(mu_p)
    stats = {
        "newton_iters": iters,
        "num_active": J,
        "active_cols_per_step": nact_of(theta) * bm,
        "work_cols": work,
        "full_cols": jnp.asarray(m_pad, jnp.int32),
    }
    return mu_full, theta, norm_seg, colsum, stats


@functools.partial(jax.jit, static_argnames=("block_m", "n_bisect",
                                             "n_polish", "max_newton",
                                             "interpret", "shrink",
                                             "return_stats"))
def project_l1inf_pallas(Y: jnp.ndarray, C, *, theta0=None, block_m: int = 0,
                         n_bisect: int = 26, n_polish: int = 8,
                         max_newton: int = 32, interpret: bool = True,
                         shrink: bool = True, return_stats: bool = False):
    """Exact projection of Y (n, m; max over axis 0) onto the l1,inf ball.

    Sort-free sparsity-adaptive engine: outer monotone Newton on theta
    (Eq. 19, warm-startable via ``theta0``), inner fused VMEM bisection +
    polish per column, active-column shrinking after the first pass.
    ``interpret=True`` for CPU validation. With ``return_stats=True``
    returns (X, stats) where stats carries the Newton-evaluation count and
    the ``work_cols`` counter (columns swept across all mu_solve launches).
    """
    if Y.ndim != 2:
        raise ValueError("expected 2-D input")
    n, m = Y.shape
    C = jnp.asarray(C, jnp.float32)

    Ypad = _pad_to(Y, 8, 128)
    n_pad, m_pad = Ypad.shape
    bm = block_m or _pick_block_m(n_pad)
    if m_pad % bm:
        Ypad = _pad_to(Ypad, 8, bm)
        n_pad, m_pad = Ypad.shape
    seg_ids = jnp.where(jnp.arange(m_pad) < m, 0, 1).astype(jnp.int32)
    th0 = None if theta0 is None else jnp.reshape(
        jnp.asarray(theta0, jnp.float32), (1,))

    mu_full, theta, norm_seg, colsum, stats = _engine(
        Ypad, seg_ids, jnp.reshape(C, (1,)), 1, th0, bm=bm,
        n_bisect=n_bisect, n_polish=n_polish, max_newton=max_newton,
        interpret=interpret, shrink=shrink)

    bn = _pick_block_n(n_pad)
    Xpad = clip_apply(Ypad, mu_full.astype(Ypad.dtype), block_m=bm,
                      block_n=bn, interpret=interpret)
    X = Xpad[:n, :m]
    inside = norm_seg[0] <= C
    X = jnp.where(inside, Y, X)
    X = jnp.where(C > 0, X, jnp.zeros_like(X)).astype(Y.dtype)
    if not return_stats:
        return X
    stats = dict(stats)
    stats["theta"] = jnp.where(C > 0,
                               jnp.where(inside, 0.0, theta[0]),
                               jnp.max(colsum, initial=0.0))
    return X, stats


@functools.partial(jax.jit, static_argnames=("num_segments", "block_m",
                                             "n_bisect", "n_polish",
                                             "max_newton", "interpret",
                                             "shrink", "return_stats"))
def project_l1inf_pallas_segmented(Y: jnp.ndarray, seg_ids: jnp.ndarray,
                                   C_seg, *, num_segments: int, theta0=None,
                                   block_m: int = 0, n_bisect: int = 26,
                                   n_polish: int = 8, max_newton: int = 32,
                                   interpret: bool = True,
                                   shrink: bool = True,
                                   return_stats: bool = False):
    """Packed multi-ball projection: one engine run, one kernel launch per
    Newton step, for EVERY segment of a packed (n, M) buffer.

    seg_ids (M,) int32 maps column -> ball in [0, num_segments); the value
    ``num_segments`` marks lane-padding columns (dummy segment, returned
    unchanged). C_seg (num_segments,) is the per-ball radius; theta0
    (num_segments,) warm-starts all balls. Returns (X, theta_seg) or
    (X, theta_seg, stats) with ``return_stats=True``.
    """
    if Y.ndim != 2:
        raise ValueError("expected a packed 2-D buffer")
    n, m = Y.shape
    G = int(num_segments)
    C_seg = jnp.asarray(C_seg, jnp.float32)

    Ypad = _pad_to(Y, 8, 128)
    n_pad, m_pad = Ypad.shape
    bm = block_m or _pick_block_m(n_pad)
    if m_pad % bm:
        Ypad = _pad_to(Ypad, 8, bm)
        n_pad, m_pad = Ypad.shape
    sids = jnp.full((m_pad,), G, jnp.int32).at[:m].set(
        jnp.asarray(seg_ids, jnp.int32))
    th0 = None if theta0 is None else jnp.asarray(theta0, jnp.float32)

    mu_full, theta, norm_seg, colsum, stats = _engine(
        Ypad, sids, C_seg, G, th0, bm=bm, n_bisect=n_bisect,
        n_polish=n_polish, max_newton=max_newton, interpret=interpret,
        shrink=shrink)

    bn = _pick_block_n(n_pad)
    Xpad = clip_apply(Ypad, mu_full.astype(Ypad.dtype), block_m=bm,
                      block_n=bn, interpret=interpret)

    inside_seg = norm_seg <= C_seg
    zero_seg = C_seg <= 0
    ext_in = jnp.concatenate([inside_seg, jnp.array([True])])
    ext_zero = jnp.concatenate([zero_seg, jnp.array([False])])
    inside_col = ext_in[jnp.minimum(sids, G)]
    zero_col = ext_zero[jnp.minimum(sids, G)]
    Xpad = jnp.where(inside_col[None, :], Ypad, Xpad)
    Xpad = jnp.where(zero_col[None, :], 0.0, Xpad).astype(Y.dtype)
    X = Xpad[:n, :m]

    seg_max = jax.ops.segment_max(
        jnp.where(sids < G, colsum, 0.0), sids, num_segments=G + 1)[:G]
    theta_out = jnp.where(zero_seg, seg_max,
                          jnp.where(inside_seg, 0.0, theta))
    if not return_stats:
        return X, theta_out
    return X, theta_out, stats


@functools.partial(jax.jit, static_argnames=("num_segments", "block_m",
                                             "max_newton", "interpret",
                                             "return_stats"))
def project_bilevel_pallas_segmented(Y: jnp.ndarray, seg_ids: jnp.ndarray,
                                     C_seg, *, num_segments: int, theta0=None,
                                     block_m: int = 0, max_newton: int = 32,
                                     interpret: bool = True,
                                     return_stats: bool = False):
    """Packed multi-ball BI-LEVEL projection (arXiv:2407.16293) on the fused
    kernels: same contract as ``project_l1inf_pallas_segmented``.

    The bi-level operator's Eq.-(19) statistics are pinned at k = 1 (only
    the column maximum carries removal mass — see ``core.bilevel``), so the
    whole Newton iteration state is the (M,) column-max vector produced by
    ONE ``colstats`` sweep. The plain engine's per-iteration ``mu_solve``
    launches and the active-column compaction machinery are structurally
    unnecessary here: after the single stats sweep no per-row work remains,
    each Newton step is an O(M) segment-sum on data already resident, and
    the only other kernel launch is the final ``clip_apply`` — exactly two
    full-buffer HBM passes however many segments or iterations, the
    linear-time claim of the bi-level paper made concrete.

    Returns (X, theta_seg) or (X, theta_seg, stats) with
    ``return_stats=True`` (stats: ``newton_iters`` and the two-sweep
    ``work_cols`` accounting comparable to the plain engine's counter).
    """
    if Y.ndim != 2:
        raise ValueError("expected a packed 2-D buffer")
    n, m = Y.shape
    G = int(num_segments)
    C_seg = jnp.asarray(C_seg, jnp.float32)

    Ypad = _pad_to(Y, 8, 128)
    n_pad, m_pad = Ypad.shape
    bm = block_m or _pick_block_m(n_pad)
    if m_pad % bm:
        Ypad = _pad_to(Ypad, 8, bm)
        n_pad, m_pad = Ypad.shape
    sids = jnp.full((m_pad,), G, jnp.int32).at[:m].set(
        jnp.asarray(seg_ids, jnp.int32))
    valid = sids < G
    bn = _pick_block_n(n_pad)
    _, u = colstats(jnp.abs(Ypad.astype(jnp.float32)), block_m=bm,
                    block_n=bn, interpret=interpret)

    sum_seg = functools.partial(jax.ops.segment_sum, segment_ids=sids,
                                num_segments=G + 1)
    norm_seg = sum_seg(jnp.where(valid, u, 0.0))[:G]
    m_seg = sum_seg(valid.astype(jnp.float32))[:G]
    Csafe = jnp.where(C_seg > 0, C_seg, jnp.ones_like(C_seg))
    cold = jnp.maximum((norm_seg - Csafe) / jnp.maximum(m_seg, 1.0), 0.0)
    if theta0 is None:
        start = cold
    else:
        start = jnp.maximum(
            jnp.maximum(jnp.asarray(theta0, jnp.float32), 0.0), cold)

    def theta_cols(th_seg):
        ext = jnp.concatenate(
            [th_seg, jnp.full((1,), _PAD_THETA, jnp.float32)])
        return ext[jnp.minimum(sids, G)]

    # the outer-Newton twin of core/bilevel.py::_bilevel_impl (k = 1 stats;
    # active convention: a column exactly at the threshold stays in the
    # tangent) — keep structural fixes in sync with it and with _engine
    def eval_step(th_seg):
        th_col = theta_cols(th_seg)
        active = jnp.logical_and(jnp.logical_not(u < th_col), valid)
        Aa = sum_seg(jnp.where(active, u, 0.0))[:G]
        Ba = sum_seg(active.astype(jnp.float32))[:G]
        new = (Aa - Csafe) / jnp.maximum(Ba, jnp.float32(1e-30))
        mu = jnp.where(active, jnp.maximum(u - th_col, 0.0), 0.0)
        return new, mu

    t1 = jnp.maximum(eval_step(start)[0], cold)
    t2, mu1 = eval_step(t1)
    t2 = jnp.maximum(t2, t1)

    def cond(carry):
        i, th, prev, _ = carry
        return jnp.logical_and(i < max_newton, jnp.any(th > prev))

    def body(carry):
        i, th, _, _ = carry
        new, mu = eval_step(th)
        return (i + 1, jnp.maximum(new, th), th, mu)

    iters, theta, prev, mu = jax.lax.while_loop(
        cond, body, (jnp.asarray(2, jnp.int32), t2, t1, mu1))
    mu = jax.lax.cond(jnp.any(theta > prev),
                      lambda: eval_step(theta)[1],
                      lambda: mu)

    Xpad = clip_apply(Ypad, mu.astype(Ypad.dtype), block_m=bm, block_n=bn,
                      interpret=interpret)
    inside_seg = norm_seg <= C_seg
    zero_seg = C_seg <= 0
    ext_in = jnp.concatenate([inside_seg, jnp.array([True])])
    ext_zero = jnp.concatenate([zero_seg, jnp.array([False])])
    inside_col = ext_in[jnp.minimum(sids, G)]
    zero_col = ext_zero[jnp.minimum(sids, G)]
    Xpad = jnp.where(inside_col[None, :], Ypad, Xpad)
    Xpad = jnp.where(zero_col[None, :], 0.0, Xpad).astype(Y.dtype)
    X = Xpad[:n, :m]

    # a bilevel column dies as soon as theta passes its MAXIMUM (not its l1
    # norm): the C <= 0 threshold is the per-segment max of u
    seg_max = jax.ops.segment_max(
        jnp.where(valid, u, 0.0), sids, num_segments=G + 1)[:G]
    theta_out = jnp.where(zero_seg, seg_max,
                          jnp.where(inside_seg, 0.0, theta))
    if not return_stats:
        return X, theta_out
    stats = {
        "newton_iters": iters,
        "work_cols": jnp.asarray(2 * m_pad, jnp.int32),   # colstats + clip
        "full_cols": jnp.asarray(m_pad, jnp.int32),
    }
    return X, theta_out, stats
