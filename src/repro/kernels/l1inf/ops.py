"""Jitted wrapper: full sort-free l1,inf projection built on the Pallas
kernels (outer monotone Newton on theta; each iteration is ONE fused HBM pass
over |Y| via the mu_solve kernel).

On non-TPU backends the kernels run in interpret mode (correctness
validation); the lowering target is TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import colstats, mu_solve, clip_apply


def _pad_to(x: jnp.ndarray, mult0: int, mult1: int) -> jnp.ndarray:
    n, m = x.shape
    pn = (-n) % mult0
    pm = (-m) % mult1
    if pn or pm:
        x = jnp.pad(x, ((0, pn), (0, pm)))
    return x


def _pick_block_m(n_pad: int, vmem_budget: int = 4 * 1024 * 1024) -> int:
    """Largest power-of-two block_m <= 128 such that an (n_pad, bm) f32 tile
    fits the VMEM budget (TPU lane dim prefers 128)."""
    bm = 128
    while bm > 8 and n_pad * bm * 4 > vmem_budget:
        bm //= 2
    return bm


@functools.partial(jax.jit, static_argnames=("block_m", "n_bisect",
                                             "n_polish", "max_newton",
                                             "interpret"))
def project_l1inf_pallas(Y: jnp.ndarray, C, *, block_m: int = 0,
                         n_bisect: int = 26, n_polish: int = 8,
                         max_newton: int = 32,
                         interpret: bool = True) -> jnp.ndarray:
    """Exact projection of Y (n, m; max over axis 0) onto the l1,inf ball.

    Sort-free: outer monotone Newton on theta (Eq. 19), inner fused
    VMEM bisection+polish per column. `interpret=True` for CPU validation.
    """
    if Y.ndim != 2:
        raise ValueError("expected 2-D input")
    n, m = Y.shape
    C = jnp.asarray(C, jnp.float32)

    Ypad = _pad_to(Y, 8, 128)
    n_pad, m_pad = Ypad.shape
    bm = block_m or _pick_block_m(n_pad)
    if m_pad % bm:
        Ypad = _pad_to(Ypad, 8, bm)
        n_pad, m_pad = Ypad.shape
    Aabs = jnp.abs(Ypad.astype(jnp.float32))

    colsum, colmax = colstats(Aabs, block_m=bm,
                              block_n=min(n_pad, 512) if n_pad % 512 == 0 or n_pad < 512 else 8,
                              interpret=interpret)
    norm = jnp.sum(colmax)
    inside = norm <= C

    theta0 = jnp.maximum((norm - C) / m, 0.0)

    def newton_cond(carry):
        i, theta, prev = carry
        return jnp.logical_and(i < max_newton, theta > prev)

    def newton_body(carry):
        i, theta, _ = carry
        mu, k, S, act = mu_solve(Aabs, theta, block_m=bm, n_bisect=n_bisect,
                                 n_polish=n_polish, interpret=interpret)
        Aa = jnp.sum(jnp.where(act, S / k, 0.0))
        Ba = jnp.sum(jnp.where(act, 1.0 / k, 0.0))
        new = (Aa - C) / jnp.maximum(Ba, 1e-30)
        return (i + 1, jnp.maximum(new, theta), theta)

    _, theta, _ = jax.lax.while_loop(
        newton_cond, newton_body, (jnp.asarray(0), theta0, jnp.float32(-1.0)))

    mu, _, _, _ = mu_solve(Aabs, theta, block_m=bm, n_bisect=n_bisect,
                           n_polish=n_polish, interpret=interpret)
    bn = min(n_pad, 512)
    if n_pad % bn:
        bn = 8
    Xpad = clip_apply(Ypad, mu.astype(Ypad.dtype), block_m=bm, block_n=bn,
                      interpret=interpret)
    X = Xpad[:n, :m]
    X = jnp.where(inside, Y, X)
    return jnp.where(C > 0, X, jnp.zeros_like(X)).astype(Y.dtype)
