from .ops import project_l1inf_pallas
from .kernel import colstats, mu_solve, clip_apply
from . import ref
