from .ops import (project_l1inf_pallas, project_l1inf_pallas_segmented,
                  project_bilevel_pallas_segmented)
from .kernel import colstats, mu_solve, clip_apply
from . import ref
