"""Pure-jnp oracle for the l1,inf Pallas kernel suite.

Self-contained reference semantics for each kernel:
  * column stats:   per-column (sum, max) of |Y|
  * mu-solve:       per-column water level mu_j(theta) with exact active-set
                    payloads (k_j, S_kj)
  * clip-apply:     X = sign(Y) * min(|Y|, mu_j)
  * full projection oracle (sort-based, exact)
"""
from __future__ import annotations

import jax.numpy as jnp


def colstats_ref(Y: jnp.ndarray):
    A = jnp.abs(Y.astype(jnp.float32))
    return jnp.sum(A, axis=0), jnp.max(A, axis=0)


def mu_solve_ref(Yabs: jnp.ndarray, theta: jnp.ndarray):
    """Exact per-column water level for removed mass theta (sort-based).

    Returns (mu, k, S_k, active): for active columns (colsum > theta),
    sum_i (y - mu)_+ = theta with k = |{y > mu}|, S_k = sum of the top k.
    Inactive columns report mu = 0, k = 1, S_k = 0.
    """
    A = jnp.abs(Yabs.astype(jnp.float32))
    n, m = A.shape
    theta = jnp.asarray(theta, jnp.float32)
    Z = -jnp.sort(-A, axis=0)
    S = jnp.cumsum(Z, axis=0)
    k = jnp.arange(1, n + 1, dtype=jnp.float32)[:, None]
    # largest k with z_k * k > S_k - theta  (simplex active set)
    valid = Z * k > (S - theta)
    kj = jnp.clip(jnp.sum(valid.astype(jnp.int32), axis=0), 1, n)
    S_k = jnp.take_along_axis(S, (kj - 1)[None, :], axis=0)[0]
    kf = kj.astype(jnp.float32)
    mu = (S_k - theta) / kf
    active = S[n - 1] > theta
    mu = jnp.where(active, jnp.maximum(mu, 0.0), 0.0)
    kf = jnp.where(active, kf, 1.0)
    S_k = jnp.where(active, S_k, 0.0)
    return mu, kf, S_k, active


def clip_apply_ref(Y: jnp.ndarray, mu: jnp.ndarray):
    A = jnp.abs(Y)
    return (jnp.sign(Y) * jnp.minimum(A, mu[None, :].astype(Y.dtype))).astype(Y.dtype)


def project_l1inf_segmented_ref(Y, seg_ids, C_seg, num_segments: int):
    """Packed multi-ball oracle: per-segment loop over the plain projection.

    Semantics contract for the packed engines: each segment's columns are
    projected onto that segment's ball independently; padding columns
    (seg_ids == num_segments) pass through unchanged. Python loop — test
    oracle only.
    """
    import numpy as np
    Y = np.asarray(Y, np.float32)
    seg_ids = np.asarray(seg_ids)
    C_seg = np.asarray(C_seg, np.float32)
    X = Y.copy()
    for g in range(num_segments):
        cols = np.nonzero(seg_ids == g)[0]
        if cols.size == 0:
            continue
        Xg = project_l1inf_ref(jnp.asarray(Y[:, cols]), float(C_seg[g]))
        X[:, cols] = np.asarray(Xg)
    return X


def project_l1inf_ref(Y: jnp.ndarray, C) -> jnp.ndarray:
    """Full exact projection oracle (per-column sort + scalar Newton)."""
    A = jnp.abs(Y.astype(jnp.float32))
    n, m = A.shape
    C = jnp.asarray(C, jnp.float32)
    colsum, colmax = colstats_ref(Y)
    inside = jnp.sum(colmax) <= C

    theta = jnp.maximum((jnp.sum(colmax) - C) / m, 0.0)
    # monotone Newton (finite convergence; 64 is a safe cap)
    def body(i, th):
        mu, kf, S_k, active = mu_solve_ref(A, th)
        Aa = jnp.sum(jnp.where(active, S_k / kf, 0.0))
        Ba = jnp.sum(jnp.where(active, 1.0 / kf, 0.0))
        return jnp.maximum((Aa - C) / jnp.maximum(Ba, 1e-30), th)
    import jax
    theta = jax.lax.fori_loop(0, 64, body, theta)
    mu, _, _, _ = mu_solve_ref(A, theta)
    X = clip_apply_ref(Y, mu)
    X = jnp.where(inside, Y, X)
    return jnp.where(C > 0, X, jnp.zeros_like(X))
