"""Jitted wrapper: model-shaped GQA flash attention.

Accepts the model-layer layout (B, S, H, hd) / (B, S, KV, hd) and folds
batch x heads into the kernel grid. Target is TPU; on CPU backends pass
interpret=True (tests) — the models' jnp chunked attention remains the
CPU/dry-run execution path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_fwd


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_kv", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_kv: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q: (B, Sq, H, hd); k/v: (B, Skv, KV, hd) -> (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    groups = H // KV
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, Skv, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, Skv, hd)
    out = flash_attention_fwd(qf, kf, vf, groups=groups, causal=causal,
                              window=window, block_q=block_q,
                              block_kv=block_kv, interpret=interpret)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
