from .ops import flash_attention
from .kernel import flash_attention_fwd
from . import ref
