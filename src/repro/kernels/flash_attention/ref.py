"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  groups: int = 1, causal: bool = True,
                  window: int = 0) -> jnp.ndarray:
    """q: (BH, Sq, hd); k/v: (BKV, Skv, hd), BH = BKV * groups."""
    BH, Sq, hd = q.shape
    BKV, Skv, _ = k.shape
    k = jnp.repeat(k, groups, axis=0)
    v = jnp.repeat(v, groups, axis=0)
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    q_pos = jnp.arange(Sq)[:, None]
    kv_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= q_pos >= kv_pos
    if window:
        mask &= (q_pos - kv_pos) < window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[None], p, 0.0)
    out = jnp.einsum("bqk,bkh->bqh", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
