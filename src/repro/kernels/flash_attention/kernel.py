"""Flash attention (forward) Pallas TPU kernel.

Online-softmax with explicit BlockSpec VMEM tiling: grid = (batch*heads,
q_tiles, kv_tiles); the kv dimension is the innermost (sequential on TPU)
grid axis, accumulating into output-resident (acc, m, l) tiles — one HBM
pass over K/V per q tile, no S x S materialization. GQA is handled in the
index map (kv head = q head // group).

Causal/sliding-window masking is applied per tile; fully-masked tiles skip
the matmul via pl.when. Backward uses the pure-jnp chunked attention
(models/attention.py) — on-TPU training would pair this with the standard
flash backward; serving (prefill) is forward-only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, out_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bkv: int, n_kv: int, causal: bool, window: int,
                  scale: float):
    j = pl.program_id(2)
    i = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)
    kv_pos = j * bkv + jax.lax.broadcasted_iota(jnp.int32, (1, bkv), 1)
    relevant = True
    if causal:
        relevant = (j * bkv) <= (i * bq + bq - 1)
    if window:
        relevant = jnp.logical_and(
            relevant, (i * bq - (j * bkv + bkv - 1)) < window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                 # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                 # (bkv, hd)
        v = v_ref[0].astype(jnp.float32)                 # (bkv, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((bq, bkv), jnp.bool_)
        if causal:
            mask &= q_pos >= kv_pos
        if window:
            mask &= (q_pos - kv_pos) < window
        s = jnp.where(mask, s, _NEG)
        m_prev = m_ref[0]                                # (bq,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[0] = l_ref[0] * corr + jnp.sum(p, axis=1)
        acc_ref[0] = (acc_ref[0] * corr[:, None]
                      + jax.lax.dot_general(
                          p, v, (((1,), (0,)), ((), ())),
                          preferred_element_type=jnp.float32))
        m_ref[0] = m_new

    @pl.when(j == n_kv - 1)
    def _finalize():
        out_ref[0] = (acc_ref[0]
                      / jnp.maximum(l_ref[0], 1e-30)[:, None]
                      ).astype(out_ref.dtype)


def flash_attention_fwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        groups: int = 1, causal: bool = True,
                        window: int = 0, block_q: int = 128,
                        block_kv: int = 128,
                        interpret: bool = False) -> jnp.ndarray:
    """q: (BH, Sq, hd); k/v: (BKV, Skv, hd) with BH = BKV * groups.

    Returns (BH, Sq, hd). Sq % block_q == 0, Skv % block_kv == 0.
    """
    BH, Sq, hd = q.shape
    BKV, Skv, _ = k.shape
    assert BH == BKV * groups
    bq = min(block_q, Sq)
    bkv = min(block_kv, Skv)
    assert Sq % bq == 0 and Skv % bkv == 0
    nq, nkv = Sq // bq, Skv // bkv
    grid = (BH, nq, nkv)
    kern = functools.partial(
        _flash_kernel, bq=bq, bkv=bkv, n_kv=nkv, causal=causal,
        window=window, scale=hd ** -0.5)
    out, acc, m, l = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, hd),
                         lambda b, i, j, g=groups: (b // g, j, 0)),
            pl.BlockSpec((1, bkv, hd),
                         lambda b, i, j, g=groups: (b // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Sq, hd), q.dtype),
            jax.ShapeDtypeStruct((BH, Sq, hd), jnp.float32),
            jax.ShapeDtypeStruct((BH, Sq), jnp.float32),
            jax.ShapeDtypeStruct((BH, Sq), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out
