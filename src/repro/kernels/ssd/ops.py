"""Jitted wrapper: model-shaped SSD via the Pallas kernel.

Accepts the models/ssm.py tensor layout: x (B, S, H, P), dt (B, S, H),
A_log/D (H,), B/C (B, S, N) (single group). Target TPU; interpret=True for
CPU validation — the jnp chunked scan stays the dry-run execution path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssd_fwd


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_attention(x: jnp.ndarray, dt: jnp.ndarray, A_log: jnp.ndarray,
                  D: jnp.ndarray, Bm: jnp.ndarray, Cm: jnp.ndarray, *,
                  chunk: int = 64, interpret: bool = False) -> jnp.ndarray:
    """x: (B, S, H, P); dt: (B, S, H); A_log/D: (H,); Bm/Cm: (B, S, N)."""
    Bb, S, H, P = x.shape
    N = Bm.shape[-1]
    xf = x.transpose(0, 2, 1, 3).reshape(Bb * H, S, P)
    dtf = dt.transpose(0, 2, 1).reshape(Bb * H, S)
    a = jnp.tile(-jnp.exp(A_log.astype(jnp.float32)), Bb)
    d = jnp.tile(D.astype(jnp.float32), Bb)
    y, _ = ssd_fwd(xf, dtf, a, d, Bm, Cm, chunk=chunk, groups=H,
                   interpret=interpret)
    return y.reshape(Bb, H, S, P).transpose(0, 2, 1, 3)
