"""Pure-jnp oracle for the SSD kernel: the naive sequential recurrence.

    h_t = exp(a dt_t) h_{t-1} + dt_t B_t x_tᵀ      (h in R^{P x N})
    y_t = h_t C_t + D x_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray, d: jnp.ndarray,
            B: jnp.ndarray, C: jnp.ndarray, groups: int = 1):
    """x: (BH, S, P); dt: (BH, S); a/d: (BH,); B/C: (BG, S, N)."""
    BH, S, P = x.shape
    N = B.shape[-1]
    Bf = jnp.repeat(B, groups, axis=0).astype(jnp.float32)
    Cf = jnp.repeat(C, groups, axis=0).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def per_bh(x1, dt1, a1, d1, B1, C1):
        def step(h, inp):
            xt, dtt, bt, ct = inp
            h = jnp.exp(a1 * dtt) * h + dtt * jnp.outer(xt, bt)
            y = h @ ct + d1 * xt
            return h, y

        h0 = jnp.zeros((P, N), jnp.float32)
        hT, ys = jax.lax.scan(step, h0, (x1, dt1, B1, C1))
        return ys, hT

    ys, hT = jax.vmap(per_bh)(xf, dtf, a.astype(jnp.float32),
                              d.astype(jnp.float32), Bf, Cf)
    return ys.astype(x.dtype), hT
