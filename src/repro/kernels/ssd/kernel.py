"""Mamba2 SSD (state-space duality) chunked-scan Pallas TPU kernel.

The jnp chunked SSD (models/ssm.py) materializes the (Q x Q) decay tile L
and the (Q x Q) Gram tile C·Bᵀ in HBM for every chunk — the dominant memory
term of the mamba2/hymba cells (EXPERIMENTS.md §Roofline). This kernel keeps
both tiles in VMEM: grid = (batch*heads, chunks) with the chunk axis
innermost (sequential on TPU); the inter-chunk SSM state (P x N) lives in an
output-resident accumulator carried across grid steps.

Per program (one chunk of one head):
    da   = dt * a;  cum = cumsum(da)
    L    = tril(exp(cum_i - cum_j))              (Q x Q, VMEM only)
    G    = C Bᵀ                                  (Q x Q, VMEM only)
    y    = (G ⊙ L ⊙ dt_j) x + (C ⊙ exp(cum)) hᵀ + D x
    h'   = exp(Σda) h + Bᵀ (dt ⊙ exp(Σda - cum) ⊙ x)

B/C are shared across the heads of a group via the index map (like GQA in
the flash kernel). Forward only (training pairs it with recompute, like
flash); validated in interpret mode against ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(a_ref, d_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, state_ref,
                *, chunk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    a = a_ref[0, 0]                                   # scalar decay rate < 0
    dcoef = d_ref[0, 0]                               # skip coefficient
    x = x_ref[0].astype(jnp.float32)                  # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)                # (Q,)
    B = b_ref[0].astype(jnp.float32)                  # (Q, N)
    C = c_ref[0].astype(jnp.float32)                  # (Q, N)

    da = dt * a                                       # (Q,)
    cum = jnp.cumsum(da)
    seg = cum[-1]

    # intra-chunk: everything below stays in VMEM
    diff = cum[:, None] - cum[None, :]
    q_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    k_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(q_idx >= k_idx, jnp.exp(diff), 0.0)
    G = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    M = G * L * dt[None, :]
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk from the carried state h (P, N)
    h = state_ref[0]                                  # (P, N)
    Ce = C * jnp.exp(cum)[:, None]
    y = y + jax.lax.dot_general(Ce, h, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y = y + dcoef * x
    y_ref[0] = y.astype(y_ref.dtype)

    # state update: h' = exp(seg) h + xᵀ (dt * exp(seg - cum) * B)
    w = (dt * jnp.exp(seg - cum))[:, None] * B        # (Q, N)
    upd = jax.lax.dot_general(x, w, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, N)
    state_ref[0] = jnp.exp(seg) * h + upd


def ssd_fwd(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray, d: jnp.ndarray,
            B: jnp.ndarray, C: jnp.ndarray, *, chunk: int = 64,
            groups: int = 1, interpret: bool = False):
    """x: (BH, S, P); dt: (BH, S); a/d: (BH,); B/C: (BG, S, N) with
    BH = BG * groups. Returns (y (BH, S, P), final_state (BH, P, N))."""
    BH, S, P = x.shape
    BG, _, N = B.shape
    assert BH == BG * groups and S % chunk == 0
    nc = S // chunk
    grid = (BH, nc)
    kern = functools.partial(_ssd_kernel, chunk=chunk)
    y, state = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, j: (b, 0)),            # a
            pl.BlockSpec((1, 1), lambda b, j: (b, 0)),            # d
            pl.BlockSpec((1, chunk, P), lambda b, j: (b, j, 0)),  # x
            pl.BlockSpec((1, chunk), lambda b, j: (b, j)),        # dt
            pl.BlockSpec((1, chunk, N),
                         lambda b, j, g=groups: (b // g, j, 0)),  # B
            pl.BlockSpec((1, chunk, N),
                         lambda b, j, g=groups: (b // g, j, 0)),  # C
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, P, N), lambda b, j: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, P), x.dtype),
            jax.ShapeDtypeStruct((BH, P, N), jnp.float32),
        ],
        interpret=interpret,
    )(a.reshape(BH, 1), d.reshape(BH, 1), x, dt, B, C)
    return y, state
