from .ops import ssd_attention
from .kernel import ssd_fwd
from . import ref
