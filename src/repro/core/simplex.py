"""Projections onto the simplex and the l1 ball.

These are the building blocks of the paper's l1,inf machinery (every column
sub-problem is a simplex projection) and the l1 comparison method of the SAE
experiments.

All jnp functions are jit/vmap/pjit-safe (static shapes, lax control flow).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

__all__ = [
    "project_simplex_sort",
    "project_l1_ball",
    "project_weighted_l1_ball",
    "simplex_threshold",
    "project_simplex_michelot_np",
    "project_simplex_condat_np",
]


def simplex_threshold(y: jnp.ndarray, radius, axis: int = -1) -> jnp.ndarray:
    """Water-level tau such that sum(max(y - tau, 0)) == radius along `axis`.

    Assumes ``sum(max(y,0)) >= radius`` (caller handles the interior case) and
    y >= 0 is NOT required — standard sort formulation works for any y.

    Sort-based O(n log n): tau = (cumsum_k - radius)/k for the largest valid k.
    """
    y = jnp.asarray(y)
    u = jnp.sort(y, axis=axis)
    u = jnp.flip(u, axis=axis)  # descending
    css = jnp.cumsum(u, axis=axis)
    n = y.shape[axis]
    k = jnp.arange(1, n + 1, dtype=y.dtype)
    shape = [1] * y.ndim
    shape[axis] = n
    k = k.reshape(shape)
    # valid(k): u_k > (css_k - radius)/k
    valid = u * k > (css - radius)
    # rho = last valid k (>= 1 always when sum(y) > radius and radius > 0)
    rho_idx = jnp.sum(valid.astype(jnp.int32), axis=axis, keepdims=True) - 1
    rho_idx = jnp.clip(rho_idx, 0, n - 1)
    css_rho = jnp.take_along_axis(css, rho_idx, axis=axis)
    tau = (css_rho - radius) / (rho_idx.astype(y.dtype) + 1.0)
    return jnp.squeeze(tau, axis=axis)


def project_simplex_sort(y: jnp.ndarray, radius=1.0, axis: int = -1) -> jnp.ndarray:
    """Euclidean projection of y onto the solid simplex
    {x >= 0 : sum(x) <= radius} along `axis`.

    If y is already inside (y >= 0 elementwise and sum <= radius) returns y.
    """
    y = jnp.asarray(y)
    radius = jnp.asarray(radius, dtype=y.dtype)
    tau = simplex_threshold(y, radius, axis=axis)
    proj = jnp.maximum(y - jnp.expand_dims(tau, axis), 0.0)
    inside = jnp.logical_and(
        jnp.all(y >= 0, axis=axis), jnp.sum(y, axis=axis) <= radius
    )
    return jnp.where(jnp.expand_dims(inside, axis), y, proj)


def project_l1_ball(y: jnp.ndarray, radius=1.0) -> jnp.ndarray:
    """Euclidean projection of (flattened) y onto the l1 ball of `radius`."""
    y = jnp.asarray(y)
    radius = jnp.asarray(radius, dtype=y.dtype)
    flat = jnp.abs(y).reshape(-1)
    inside = jnp.sum(flat) <= radius
    tau = simplex_threshold(flat, radius, axis=0)
    proj = jnp.sign(y) * jnp.maximum(jnp.abs(y) - tau, 0.0)
    return jnp.where(inside, y, proj)


def project_weighted_l1_ball(y: jnp.ndarray, w: jnp.ndarray, radius=1.0) -> jnp.ndarray:
    """Projection onto {x : sum_i w_i |x_i| <= radius}, w > 0 (Perez et al. 2022).

    KKT: x_i = sign(y_i) max(|y_i| - tau w_i, 0) with
    tau = (sum_{i in A} w_i|y_i| - radius)/ sum_{i in A} w_i^2 over the active set.
    Solved by sorting |y_i|/w_i descending.
    """
    y = jnp.asarray(y)
    w = jnp.asarray(w, dtype=y.dtype)
    a = jnp.abs(y).reshape(-1)
    ww = jnp.broadcast_to(w, y.shape).reshape(-1)
    inside = jnp.sum(ww * a) <= radius
    r = a / ww
    order = jnp.argsort(-r)
    wa = (ww * a)[order]
    w2 = (ww * ww)[order]
    cwa = jnp.cumsum(wa)
    cw2 = jnp.cumsum(w2)
    taus = (cwa - radius) / cw2
    # active set: r_sorted_k > taus_k
    valid = r[order] > taus
    rho = jnp.clip(jnp.sum(valid.astype(jnp.int32)) - 1, 0, a.shape[0] - 1)
    tau = jnp.maximum(taus[rho], 0.0)
    proj = jnp.sign(y) * jnp.maximum(jnp.abs(y) - tau * jnp.broadcast_to(w, y.shape), 0.0)
    return jnp.where(inside, y, proj)


# ----------------------------------------------------------------------------
# Numpy reference algorithms (for benchmarks and cross-checks)
# ----------------------------------------------------------------------------

def project_simplex_michelot_np(y: np.ndarray, radius: float = 1.0) -> np.ndarray:
    """Michelot's iterative active-set algorithm (numpy, exact)."""
    y = np.asarray(y, dtype=np.float64)
    if y.min() >= 0 and y.sum() <= radius:
        return y.copy()
    v = y.copy()
    rho = (v.sum() - radius) / v.size
    while True:
        v2 = v[v > rho]
        if v2.size == v.size:
            break
        v = v2
        if v.size == 0:
            rho = 0.0
            break
        rho = (v.sum() - radius) / v.size
    return np.maximum(y - rho, 0.0)


def project_simplex_condat_np(y: np.ndarray, radius: float = 1.0) -> np.ndarray:
    """Condat (2016) fast projection (numpy port, exact, O(n) expected)."""
    y = np.asarray(y, dtype=np.float64)
    if y.min() >= 0 and y.sum() <= radius:
        return y.copy()
    # Fall back to the sorted method; Condat's scan is pointer-heavy in python,
    # the sorted method is both exact and fast enough in numpy for our benches.
    u = np.sort(y)[::-1]
    css = np.cumsum(u)
    k = np.arange(1, y.size + 1)
    valid = u * k > (css - radius)
    rho = np.nonzero(valid)[0][-1]
    tau = (css[rho] - radius) / (rho + 1.0)
    return np.maximum(y - tau, 0.0)
