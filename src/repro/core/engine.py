"""ProjectionEngine: ONE projected-update path for every train loop.

PR 2 left three hand-rolled copies of "adam_update -> packed projection ->
every_k gate" (train/loop.py, sae/train.py, launch/steps.py), each wiring the
packing, theta warm-start state, and gating by hand — and the production
launch path cold-started Newton every step because nothing threaded the
state. This module centralizes the runtime side of the constraint system:

  * ``ProjectionEngine`` owns plan building (``core.constraints``), packing,
    per-plan theta state, and solver dispatch:
      - ``newton``  — single-buffer segmented Newton (default, 1 device);
      - ``pallas``  — fused-kernel engine (interpret mode off-TPU);
      - ``sharded`` — mesh-resident shard_map solve (``dist.projection``):
        weight shards never gather; per-segment statistics cross the link
        as one (num_segments,) psum per Newton evaluation.
  * ``engine.apply(params, step=, state=)`` projects a param pytree —
    the packed fast path plus the per-leaf fallback for unpackable norms.
  * ``engine.projected_update(grads, opt_state, params, acfg, ...)`` is the
    shared step core all three train loops build on: optimizer update,
    projection, optional support-mask freeze, warm-start state threading.

The theta warm-start contract (DESIGN.md §1/§7): each plan's state entry is
the previous solve's per-segment theta vector; passing it back makes
steady-state solves converge in the 2 bootstrap Eq.-(19) evaluations.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .constraints import (ProjectionSpec, build_packed_plans, engine_count,
                          _apply_2d, _gated, _pack_entry, _project_fn,
                          _unpack_entry)
from .families import get_family, project_segmented_family
from .l1inf import _segmented_newton

__all__ = ["ProjectionEngine", "apply_constraints_packed",
           "init_projection_state"]

_SOLVERS = ("newton", "pallas", "sharded", "fused", "fused_sharded")

# Identity sentinel for the fused clip pass: a per-column clip level far
# above any parameter magnitude, so sign(u) * min(|u|, _MU_INF) == u exactly
# (segments already inside the ball must pass through untouched).
_MU_INF = 1e30


class ProjectionEngine:
    """Plan building + theta state + solver dispatch for projection specs.

    Construct once per step-build (the specs and solver are static); call
    ``apply``/``projected_update`` inside the traced step. ``solver`` is the
    default for every packed plan ("newton" | "pallas" | "sharded" |
    "fused" | "fused_sharded"); ``mesh`` is required for "sharded" and
    "fused_sharded". "fused" runs the two-HBM-pass optimizer+projection
    megakernel inside ``projected_update`` for every plan whose family
    provides the ``from_colstats`` streaming hook at ``every_k == 1``
    (DESIGN.md §11) and is bit-identical to "newton" everywhere else
    (``apply`` and all fallback plans solve exactly as "newton" would).
    "fused_sharded" is the mesh twin (DESIGN.md §12): the same two passes
    run rank-local inside shard_map on each rank's column shard
    (``dist.projection.fused_plan_sharded``) with one stacked
    (2, num_segments) psum per Newton evaluation, and every plan the
    megakernel cannot take falls back to the "sharded" shard_map Newton —
    bit-identical to what ``solver="sharded"`` would produce. The engine
    itself is stateless — the theta warm-start dict returned by
    ``init_state`` threads through the caller's train state.

    >>> engine = ProjectionEngine((spec,)); state = engine.init_state(params)
    """

    def __init__(self, specs: Sequence[ProjectionSpec],
                 *, solver: str = "newton", mesh=None):
        if solver not in _SOLVERS:
            raise ValueError(f"unknown solver {solver!r} (one of {_SOLVERS})")
        if solver in ("sharded", "fused_sharded") and mesh is None:
            raise ValueError(f"solver={solver!r} needs a mesh")
        self.specs = tuple(specs or ())
        self.solver = solver
        self.mesh = mesh

    # -- static plan/state helpers (shape-only, safe while tracing) ---------

    def plans(self, params: Any):
        """(packed plans, per-leaf remainder) for this param pytree."""
        return build_packed_plans(params, self.specs)

    def init_state(self, params: Any) -> Dict[str, Any]:
        """Zero theta warm-start vectors, one per packed plan (pytree-safe,
        works on ShapeDtypeStructs for dry-run lowering)."""
        plans, _ = self.plans(params)
        return {p.key: jnp.zeros((p.num_segments,), jnp.float32)
                for p in plans}

    # -- the projection ------------------------------------------------------

    def _solve_plan(self, plan, leaves, theta0):
        """One packed solve of one family sub-buffer. Returns
        (projected-by-leaf-index dict, theta, iters). The constraint family
        named by the plan supplies the per-column Newton statistics
        (``core.families``); a family without a fused-kernel implementation
        falls back to the packed Newton path under solver='pallas', and
        plans the fused step cannot take (``projected_update`` dispatches
        those here) solve exactly as solver='newton' — or, under
        solver='fused_sharded', exactly as solver='sharded' (the shard_map
        Newton, shards resident)."""
        eff = {"fused": "newton",
               "fused_sharded": "sharded"}.get(self.solver, self.solver)
        engine_count(f"{plan.key}/{eff}")
        fam = get_family(plan.family)
        if eff == "sharded":
            from ..dist.projection import project_plan_sharded
            vals = [leaves[e.index] for e in plan.entries]
            outs, theta, iters = project_plan_sharded(
                vals, plan, self.mesh, theta0=theta0)
            return dict(zip((e.index for e in plan.entries), outs)), \
                theta, iters
        pieces = [_pack_entry(leaves[e.index], e, plan.n_max)
                  for e in plan.entries]
        Ypk = jnp.concatenate(pieces, axis=1) if len(pieces) > 1 else pieces[0]
        sids = jnp.asarray(plan.seg_ids())
        C_seg = jnp.asarray(plan.radii())
        w_col = jnp.asarray(plan.col_weights()) if fam.uses_weights else None
        if self.solver == "pallas" and fam.pallas_loader is not None:
            pallas_fn = fam.pallas_loader()
            Xpk, theta = pallas_fn(
                Ypk, sids, C_seg, num_segments=plan.num_segments,
                theta0=theta0,
                interpret=jax.default_backend() != "tpu")
            iters = jnp.asarray(-1, jnp.int32)   # kernel keeps its own count
        else:
            Xpk, theta, iters = project_segmented_family(
                Ypk, sids, C_seg, num_segments=plan.num_segments,
                family=plan.family, w_col=w_col, theta0=theta0)
        outs = {}
        for e in plan.entries:
            block = jax.lax.slice_in_dim(
                Xpk, e.col_start, e.col_start + e.lead * e.m_pad, axis=1)
            outs[e.index] = _unpack_entry(block, e, leaves[e.index])
        return outs, theta, iters

    def apply(self, params: Any, *, step: Optional[jnp.ndarray] = None,
              state: Optional[Dict[str, Any]] = None,
              with_stats: bool = False):
        """Project matching leaves of ``params``.

        Leaves are packed into ONE buffer per (constraint family, every_k)
        pair and each sub-buffer is projected by a single solve of the
        configured solver — a mixed-family spec list (plain + weighted +
        bilevel, same every_k) costs one engine invocation per family;
        unpackable norms (the l1 ball and per-leaf-only families like
        hoyer) fall back to the per-leaf path. ``state`` threads the
        per-plan theta vectors (Newton warm start) between train steps —
        pass the dict from ``init_state`` (or a previous call) and reuse
        the returned dict. ``step`` gates ``every_k > 1`` specs.

        Returns (params, new_state), plus a {plan.key: Eq.-(19) eval count}
        stats dict when ``with_stats``. Results are bit-equal (up to fp
        accumulation order) to per-matrix projection on every leaf,
        whichever solver runs.
        """
        if not self.specs:
            out = (params, dict(state or {}))
            return out + ({},) if with_stats else out
        flat, treedef = jax.tree_util.tree_flatten_with_path(params)
        leaves = [leaf for _, leaf in flat]
        plans, per_leaf = self.plans(params)
        new_state: Dict[str, Any] = {}
        stats: Dict[str, Any] = {}

        for plan in plans:
            theta0 = None if state is None else state.get(plan.key)
            projected, theta, iters = self._solve_plan(plan, leaves, theta0)
            for e in plan.entries:
                leaves[e.index] = _gated(projected[e.index], leaves[e.index],
                                         step, plan.every_k)
            if step is not None and plan.every_k > 1:
                do = (step % plan.every_k) == 0
                prev = theta0 if theta0 is not None else jnp.zeros_like(theta)
                theta = jnp.where(do, theta, prev)
            new_state[plan.key] = theta
            stats[plan.key] = iters

        for i, spec in per_leaf:
            engine_count("per_leaf")
            fn = _project_fn(spec)
            projected = _apply_2d(fn, leaves[i], spec.radius, spec.axis)
            leaves[i] = _gated(projected, leaves[i], step, spec.every_k)

        params = jax.tree_util.tree_unflatten(treedef, leaves)
        if with_stats:
            return params, new_state, stats
        return params, new_state

    # -- the shared projected-update step core -------------------------------

    def projected_update(self, grads: Any, opt_state, params: Any, acfg,
                         *, lr=None, mask: Any = None,
                         state: Optional[Dict[str, Any]] = None,
                         with_stats: bool = False,
                         grad_reduce: Optional[Any] = None):
        """Optimizer update + projection + gating: the step core shared by
        train/loop.py, sae/train.py, and launch/steps.py.

        Runs ``adam_update`` (with optional ``lr`` schedule override and
        ``mask`` gradient freeze), projects through ``apply`` gated on the
        NEW optimizer count, re-applies ``mask`` to the params afterwards
        (the double-descent support freeze — projection may revive a clipped
        column, the mask keeps it dead), and threads the theta state.

        Under ``solver="fused"``, plans whose family streams its Newton
        statistics (``from_colstats``) at ``every_k == 1`` take the
        two-HBM-pass fused step instead (``kernels/fused_step``,
        DESIGN.md §11): pass 1 is the Adam update and the per-column
        statistics in one read of (grad, mu, nu, param), the segmented
        Newton runs on O(num_segments) state, pass 2 recomputes the update
        from the just-written moments and writes the clipped params — the
        unclipped parameters never reach HBM and no packed buffer exists.
        Everything else (per-leaf specs, ``every_k``-gated plans, families
        without the hook) falls back to this unfused path, leaf-exact.
        ``solver="fused_sharded"`` runs the same two passes rank-local
        inside shard_map (``dist.projection.fused_plan_sharded``); its
        fallback plans take the shard_map Newton instead, so no path
        gathers a weight shard.

        ``grad_reduce``: optional callable applied to ``grads`` FIRST —
        the hook for explicit-collective data-parallel callers whose grads
        are still per-rank partials (e.g. ``dist.compression
        .compressed_psum`` inside a shard_map'd DP step; see
        examples/compressed_dp.py). The reduction composes with the
        projection in one jitted step and leaves the projection's
        one-psum-per-eval contract untouched. Under GSPMD ``jax.grad``
        grads arrive already reduced — leave it None there.

        Returns (params, opt_state, proj_state) (+ stats when requested).
        """
        if grad_reduce is not None:
            grads = grad_reduce(grads)
        if self.solver in ("fused", "fused_sharded") and self.specs:
            plans, per_leaf = self.plans(params)
            fused_plans = [
                p for p in plans
                if p.every_k == 1
                and hasattr(get_family(p.family).seg_ops, "from_colstats")]
            if fused_plans:
                return self._projected_update_fused(
                    grads, opt_state, params, acfg, lr=lr, mask=mask,
                    state=state, plans=plans, per_leaf=per_leaf,
                    fused_plans=fused_plans, with_stats=with_stats)
        from ..optim.adam import adam_update
        new_params, new_opt = adam_update(grads, opt_state, params, acfg,
                                          lr=lr, mask=mask)
        stats: Dict[str, Any] = {}
        if self.specs:
            new_params, state, stats = self.apply(
                new_params, step=new_opt.count, state=state, with_stats=True)
            if mask is not None:
                new_params = jax.tree_util.tree_map(
                    lambda p, m: p * m, new_params, mask)
        else:
            state = dict(state or {})
        if with_stats:
            return new_params, new_opt, state, stats
        return new_params, new_opt, state

    def _projected_update_fused(self, grads, opt_state, params: Any, acfg,
                                *, lr, mask, state, plans, per_leaf,
                                fused_plans, with_stats):
        """The two-HBM-pass step (DESIGN.md §11). ``fused_plans`` take the
        megakernel; every other plan/leaf replays the unfused path on the
        already-updated leaves, so mixed spec lists stay exact."""
        from ..optim.adam import (AdamState, adam_leaf_update, adam_scalars,
                                  clip_scale)
        from ..kernels.fused_step import (fused_adam_clip_apply,
                                          fused_adam_colstats)

        p_leaves, treedef = jax.tree_util.tree_flatten(params)
        g_leaves = jax.tree_util.tree_leaves(grads)
        m_leaves = jax.tree_util.tree_leaves(opt_state.mu)
        v_leaves = jax.tree_util.tree_leaves(opt_state.nu)
        mk_leaves = (jax.tree_util.tree_leaves(mask) if mask is not None
                     else [None] * len(p_leaves))

        count = opt_state.count + 1
        lr_t, b1c, b2c = adam_scalars(acfg, count, lr)
        scale = (clip_scale(grads, acfg.clip_norm)
                 if acfg.clip_norm is not None else None)

        fused_idx = {e.index for plan in fused_plans for e in plan.entries}
        new_p, new_m, new_v = (list(p_leaves), list(m_leaves), list(v_leaves))
        for i in range(len(p_leaves)):
            if i in fused_idx:
                continue
            new_p[i], new_m[i], new_v[i] = adam_leaf_update(
                g_leaves[i], m_leaves[i], v_leaves[i], p_leaves[i], acfg,
                lr_t, b1c, b2c, mask=mk_leaves[i], scale=scale)

        new_state: Dict[str, Any] = {}
        stats: Dict[str, Any] = {}
        for plan in fused_plans:
            engine_count(f"{plan.key}/{self.solver}")
            fam = get_family(plan.family)
            theta0 = None if state is None else state.get(plan.key)
            if self.solver == "fused_sharded":
                # mesh path: both passes + the one-psum-per-eval Newton run
                # inside shard_map with the column shards resident
                from ..dist.projection import fused_plan_sharded
                idx = [e.index for e in plan.entries]
                ps, ms, vs, theta, iters = fused_plan_sharded(
                    plan, self.mesh,
                    [g_leaves[i] for i in idx], [m_leaves[i] for i in idx],
                    [v_leaves[i] for i in idx], [p_leaves[i] for i in idx],
                    [mk_leaves[i] for i in idx],
                    acfg=acfg, lr_t=lr_t, b1c=b1c, b2c=b2c, scale=scale,
                    theta0=theta0)
                for i, p_i, m_i, v_i in zip(idx, ps, ms, vs):
                    new_p[i], new_m[i], new_v[i] = p_i, m_i, v_i
                new_state[plan.key] = theta
                stats[plan.key] = iters
                continue
            stat = getattr(fam.seg_ops, "colstats_stat", "abs")
            mode = getattr(fam.seg_ops, "fused_mode", "clip")
            sums, maxes = [], []
            # pass 1: one read of (grad, mu, nu, param) per leaf -> moments
            # written, O(m) statistics out, the updated values never stored
            for e in plan.entries:
                i = e.index
                new_m[i], new_v[i], cs, cm = fused_adam_colstats(
                    g_leaves[i], m_leaves[i], v_leaves[i], p_leaves[i],
                    cfg=acfg, lr_t=lr_t, b1c=b1c, b2c=b2c,
                    scale=scale, mask=mk_leaves[i], transpose=e.transpose,
                    stat=stat)
                sums.append(cs.reshape(-1))
                maxes.append(cm.reshape(-1))
            colsum = jnp.concatenate(sums) if len(sums) > 1 else sums[0]
            colmax = jnp.concatenate(maxes) if len(maxes) > 1 else maxes[0]
            sids = jnp.asarray(plan.virtual_seg_ids())
            C_seg = jnp.asarray(plan.radii())
            w_col = (jnp.asarray(plan.virtual_col_weights())
                     if fam.uses_weights else None)
            aux = fam.seg_ops.from_colstats(colsum, colmax, w_col)
            mu, theta, iters, inside_seg, zero_seg = _segmented_newton(
                aux, sids, C_seg, plan.num_segments, theta0, 32,
                ops=fam.seg_ops)
            # fold the identity/zero segment gating into the per-column
            # level so pass 2 is a single min()/multiply — no virtual
            # columns are padding, so the lookups need no sentinel
            # extension. Clip families gate with the 1e30 clip sentinel;
            # scale families (l1,2) turn mu into the column multiplier via
            # fused_scale and gate with the 1.0 identity multiplier.
            if mode == "scale":
                lvl = fam.seg_ops.fused_scale(aux, mu)
                mu_eff = jnp.where(zero_seg[sids], 0.0,
                                   jnp.where(inside_seg[sids], 1.0, lvl))
            else:
                mu_eff = jnp.where(zero_seg[sids], 0.0,
                                   jnp.where(inside_seg[sids], _MU_INF, mu))
            off = 0
            # pass 2: recompute the update from the just-written moments,
            # clip/scale at mu, write the params — the step's only param
            # write
            for e in plan.entries:
                span = e.lead * e.m
                mu_leaf = mu_eff[off:off + span].reshape(e.lead, e.m)
                off += span
                i = e.index
                new_p[i] = fused_adam_clip_apply(
                    new_m[i], new_v[i], p_leaves[i], mu_leaf,
                    cfg=acfg, lr_t=lr_t, b1c=b1c, b2c=b2c,
                    mask=mk_leaves[i], transpose=e.transpose, mode=mode)
            new_state[plan.key] = theta
            stats[plan.key] = iters

        # unfused remainder: every_k-gated plans and families without the
        # streaming hook (packed Newton), then unpackable per-leaf norms
        fused_keys = {plan.key for plan in fused_plans}
        for plan in plans:
            if plan.key in fused_keys:
                continue
            theta0 = None if state is None else state.get(plan.key)
            projected, theta, iters = self._solve_plan(plan, new_p, theta0)
            for e in plan.entries:
                new_p[e.index] = _gated(projected[e.index], new_p[e.index],
                                        count, plan.every_k)
            if plan.every_k > 1:
                do = (count % plan.every_k) == 0
                prev = (theta0 if theta0 is not None
                        else jnp.zeros_like(theta))
                theta = jnp.where(do, theta, prev)
            new_state[plan.key] = theta
            stats[plan.key] = iters

        for i, spec in per_leaf:
            engine_count("per_leaf")
            fn = _project_fn(spec)
            projected = _apply_2d(fn, new_p[i], spec.radius, spec.axis)
            new_p[i] = _gated(projected, new_p[i], count, spec.every_k)

        if mask is not None:
            # support freeze on the unfused leaves; the fused clip pass
            # already multiplies its output by the mask in-kernel
            for i in range(len(new_p)):
                if i not in fused_idx:
                    new_p[i] = new_p[i] * mk_leaves[i]

        new_params = jax.tree_util.tree_unflatten(treedef, new_p)
        new_opt = AdamState(count=count,
                            mu=jax.tree_util.tree_unflatten(treedef, new_m),
                            nu=jax.tree_util.tree_unflatten(treedef, new_v))
        if with_stats:
            return new_params, new_opt, new_state, stats
        return new_params, new_opt, new_state


# ---------------------------------------------------------------------------
# functional wrappers (the PR-2 API, now thin shims over the engine)
# ---------------------------------------------------------------------------

def init_projection_state(params: Any,
                          specs: Sequence[ProjectionSpec]) -> Dict[str, Any]:
    """Zero theta warm-start vectors, one per packed plan (pytree-safe).

    ``params``: pytree of arrays or ShapeDtypeStructs (only shapes are
    read). Returns ``{plan key: (num_segments,) f32 zeros}`` — the state
    threaded through ``apply_constraints_packed`` between steps.

    >>> state = init_projection_state(params, specs)
    """
    return ProjectionEngine(specs).init_state(params)


def apply_constraints_packed(params: Any, specs: Sequence[ProjectionSpec],
                             step: Optional[jnp.ndarray] = None,
                             state: Optional[Dict[str, Any]] = None,
                             engine: str = "newton", mesh=None):
    """Project matching leaves with packed multi-tensor batching.

    Functional form of ``ProjectionEngine.apply`` — ``engine`` picks the
    solver ("newton" | "pallas" | "sharded"; the latter needs ``mesh``).
    ``params``: any pytree; ``step``: optional scalar int (every_k gating);
    ``state``: the dict from ``init_projection_state`` or a previous call.
    Returns (projected params, new_state).

    >>> params, state = apply_constraints_packed(params, specs, state=state)
    """
    return ProjectionEngine(specs, solver=engine, mesh=mesh).apply(
        params, step=step, state=state)
