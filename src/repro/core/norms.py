"""The comparison norms of the SAE experiments and the Moreau-dual prox.

  * l1 ball on the flattened matrix            (paper's `l1` column)
  * l1,2 / group-lasso ball (sum of column l2) (paper's `l2,1` column)
  * prox of the l_inf,1 norm via Moreau + the l1,inf projection (Eq. 16)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .simplex import project_l1_ball, simplex_threshold
from .l1inf import project_l1inf_newton

__all__ = [
    "project_l1_ball",
    "project_l12_ball",
    "prox_linf1",
    "linf1_norm",
    "l12_norm",
]


def l12_norm(Y: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    """sum_j ||y_j||_2 (column l2 norms summed; group-lasso norm)."""
    return jnp.sum(jnp.sqrt(jnp.sum(Y * Y, axis=axis)))


def linf1_norm(Y: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    """max_j sum_i |Y_ij| — the dual of the l1,inf norm (Eq. 14)."""
    return jnp.max(jnp.sum(jnp.abs(Y), axis=axis))


@functools.partial(jax.jit, static_argnames=("axis",))
def project_l12_ball(Y: jnp.ndarray, C, axis: int = 0) -> jnp.ndarray:
    """Projection onto {X : sum_j ||x_j||_2 <= C} (group-lasso ball).

    Column norms are projected onto the l1 ball; columns are rescaled.
    """
    dt = jnp.promote_types(Y.dtype, jnp.float32)
    Yf = Y.astype(dt)
    C = jnp.asarray(C, dtype=dt)
    nu = jnp.sqrt(jnp.sum(Yf * Yf, axis=axis))
    inside = jnp.sum(nu) <= C
    tau = simplex_threshold(nu, C, axis=0)
    nu_new = jnp.maximum(nu - tau, 0.0)
    scale = jnp.where(nu > 0, nu_new / jnp.maximum(nu, jnp.finfo(dt).tiny), 0.0)
    X = Yf * jnp.expand_dims(scale, axis)
    X = jnp.where(inside, Yf, X)
    X = jnp.where(C > 0, X, jnp.zeros_like(X))
    return X.astype(Y.dtype)


@functools.partial(jax.jit, static_argnames=("axis",))
def prox_linf1(Y: jnp.ndarray, C, axis: int = 0) -> jnp.ndarray:
    """prox_{C ||.||_inf,1}(Y) = Y - P_{B_{1,inf}^C}(Y)  (Moreau, Eq. 16)."""
    return Y - project_l1inf_newton(Y, C, axis=axis)
