"""Bi-level l1,inf projection (Barlaud, Perez, Marmorat, arXiv:2407.16293).

The bi-level operator targets the SAME constraint set as the paper's exact
projection — the ball B = {X : ||X||_{1,inf} <= C} — but replaces the
Euclidean projection with a two-level composition that is strictly cheaper
and empirically sparser for autoencoder training:

  level 1 (columns -> maxima):  u_j = max_i |Y_ij|
  level 2 (outer l1 ball):      v   = P_{B_1(C)}(u)        (simplex thresh)
  inner  (per-column l_inf):    X_ij = sign(Y_ij) min(|Y_ij|, v_j)

Because u >= 0, level 2 is a soft threshold v_j = (u_j - theta)_+ with
theta solving g(theta) = sum_j (u_j - theta)_+ = C. That g is exactly the
paper's Eq.-(19) objective RESTRICTED to k = 1 (only the column maximum
carries removal mass), so the whole monotone-Newton machinery of
``core.l1inf`` applies verbatim with per-column statistics

    a_j = u_j,  b_j = 1,  active_j <=> u_j >= theta,  mu_j = (u_j - theta)_+

— no per-column sort, no prefix sums: the iteration state is O(m), making
the solve linear-time O(nm) (one max + one clip sweep) versus the exact
projection's O(nm log n) sort. Columns with u_j <= theta* are zeroed whole,
so the operator is a structured-sparsity projection with the same support
semantics as the exact one. Feasibility is exact: sum_j v_j <= C implies
||X||_{1,inf} <= C. See DESIGN.md §8 for the KKT derivation and the
deviation notes vs Eq. (19).

Warm-start contract: identical to ``project_l1inf_newton`` — any
``theta0 >= 0`` is repaired by the unclamped bootstrap step, and the packed
segmented form threads one theta per segment (see ``core.families``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .l1inf import l1inf_norm, _prep, _post
from .simplex import simplex_threshold

__all__ = [
    "bilevel_norm",
    "project_bilevel",
    "project_bilevel_stats",
    "project_bilevel_ref",
]

# the bi-level operator's feasible set is the plain l1,inf ball
bilevel_norm = l1inf_norm


class _BilevelSegOps:
    """Segmented-Newton hooks of the bi-level family (the ``_PlainSegOps``
    contract of ``core.l1inf``): Eq.-(19) statistics pinned at k = 1.

    The active convention mirrors the plain family's ``_theta_state`` on a
    one-row matrix (b_1 = S_1 = u): active <=> NOT (u < theta), i.e. a
    column exactly at the threshold stays in the tangent with mu = 0 —
    keeping tie behavior identical to the exact solver's n = 1 case.

    This is the family with the OPTIONAL ``from_colstats`` hook: its whole
    Newton state is the column-max vector, a streaming per-column statistic,
    so the fused optimizer+projection step (``kernels/fused_step``,
    DESIGN.md §11) can emit the aux from its first HBM pass without ever
    materializing the updated matrix. Families whose aux needs per-column
    sorts/prefix sums (plain/weighted/masked) cannot provide the hook and
    keep the unfused path.
    """
    uses_weights = False

    @staticmethod
    def prepare(A, w=None):
        return {"u": jnp.max(A, axis=0)}

    @staticmethod
    def from_colstats(colsum, colmax, w=None):
        # streaming twin of prepare: same aux, built from the per-column
        # (sum |.|, max |.|) pair a single tiled sweep can accumulate
        return {"u": colmax}

    @staticmethod
    def stats(aux, th_col):
        u = aux["u"]
        active = jnp.logical_not(u < th_col)
        mu = jnp.maximum(u - th_col, 0.0)
        return u, jnp.ones_like(u), active, mu

    @staticmethod
    def stats0(aux):
        return aux["u"], jnp.ones_like(aux["u"])

    @staticmethod
    def colnorm(aux):
        return aux["u"]

    @staticmethod
    def death(aux):
        # a column dies as soon as theta passes its maximum
        return aux["u"]

    @staticmethod
    def finalize(Ydt, A, mu):
        return jnp.sign(Ydt) * jnp.minimum(A, mu[None, :])


def _bilevel_impl(Yt, C, dt, theta0, max_iter):
    """Shared Newton body on the column-max vector. Returns (X, theta, iters).

    Mirrors ``core.l1inf._project_newton_impl`` structurally (cold bound,
    bootstrap repair, monotone ascent, carried mu) so theta threads
    interchangeably between the per-matrix and the packed segmented forms.
    """
    A = jnp.abs(Yt)
    n, m = A.shape
    u = jnp.max(A, axis=0)
    norm = jnp.sum(u)
    tiny = jnp.finfo(dt).tiny

    Csafe = jnp.where(C > 0, C, jnp.asarray(1.0, dt))
    cold = jnp.maximum((norm - Csafe) / m, 0.0)
    if theta0 is None:
        start = cold
    else:
        start = jnp.maximum(jnp.maximum(jnp.asarray(theta0, dt), 0.0), cold)

    def eval_step(th):
        active = jnp.logical_not(u < th)
        Aa = jnp.sum(jnp.where(active, u, 0.0))
        Ba = jnp.sum(active.astype(dt))
        new = (Aa - Csafe) / jnp.maximum(Ba, tiny)
        mu = jnp.where(active, jnp.maximum(u - th, 0.0), 0.0)
        return new, mu

    t1 = jnp.maximum(eval_step(start)[0], cold)
    t2, mu1 = eval_step(t1)
    t2 = jnp.maximum(t2, t1)

    def cond(carry):
        i, th, prev, _ = carry
        return jnp.logical_and(i < max_iter, th > prev)

    def body(carry):
        i, th, _, _ = carry
        new, mu = eval_step(th)
        return (i + 1, jnp.maximum(new, th), th, mu)

    iters, theta, prev, mu = jax.lax.while_loop(
        cond, body, (jnp.asarray(2, jnp.int32), t2, t1, mu1))
    mu = jax.lax.cond(theta > prev,
                      lambda: eval_step(theta)[1],
                      lambda: mu)

    X = jnp.sign(Yt) * jnp.minimum(A, mu[None, :])
    inside = norm <= C
    X = jnp.where(inside, Yt, X)
    X = jnp.where(C > 0, X, jnp.zeros_like(X))
    theta_out = jnp.where(C > 0,
                          jnp.where(inside, jnp.zeros_like(theta), theta),
                          jnp.max(u, initial=0.0))
    return X, theta_out, iters


@functools.partial(jax.jit, static_argnames=("axis", "max_iter"))
def project_bilevel(Y: jnp.ndarray, C, axis: int = 0, max_iter: int = 32, *,
                    theta0: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Bi-level l1,inf projection of Y (max over `axis`) at radius C.

    Linear-time: one |.|-max sweep, a monotone Newton on the (m,) maxima
    vector (<= ~10 O(m) iterations, 1-2 with a ``theta0`` warm start), and
    one clip sweep. Inside the ball the operator is the identity; C <= 0
    maps to zero — the same gating as ``project_l1inf_newton``.
    """
    Yt, transpose, dt = _prep(Y, axis)
    C = jnp.asarray(C, dtype=dt)
    X, _, _ = _bilevel_impl(Yt, C, dt, theta0, max_iter)
    return _post(X, Y, transpose)


@functools.partial(jax.jit, static_argnames=("axis", "max_iter"))
def project_bilevel_stats(Y: jnp.ndarray, C, axis: int = 0,
                          max_iter: int = 32, *,
                          theta0: Optional[jnp.ndarray] = None):
    """Like ``project_bilevel`` but returns (X, {"theta", "iters"})."""
    Yt, transpose, dt = _prep(Y, axis)
    C = jnp.asarray(C, dtype=dt)
    X, theta, iters = _bilevel_impl(Yt, C, dt, theta0, max_iter)
    return _post(X, Y, transpose), {"theta": theta, "iters": iters}


@functools.partial(jax.jit, static_argnames=("axis",))
def project_bilevel_ref(Y: jnp.ndarray, C, axis: int = 0) -> jnp.ndarray:
    """Exact sort-based reference of the bi-level operator (tests/benches).

    Implements the definition literally: simplex-threshold the column-max
    vector (one O(m log m) sort), then clip. The Newton solve must match
    this to fp tolerance on any input, ties included.
    """
    Yt, transpose, dt = _prep(Y, axis)
    C = jnp.asarray(C, dtype=dt)
    A = jnp.abs(Yt)
    u = jnp.max(A, axis=0)
    inside = jnp.sum(u) <= C
    Csafe = jnp.where(C > 0, C, jnp.asarray(1.0, dt))
    tau = jnp.maximum(simplex_threshold(u, Csafe, axis=0), 0.0)
    v = jnp.maximum(u - tau, 0.0)
    X = jnp.sign(Yt) * jnp.minimum(A, v[None, :])
    X = jnp.where(inside, Yt, X)
    X = jnp.where(C > 0, X, jnp.zeros_like(X))
    return _post(X, Y, transpose)
