"""Faithful CPU implementation of the paper's Algorithm 2 (inverse total
order with lazy heaps), plus Algorithm 1 (naive iterated l1).

This is the paper's actual contribution, kept in its native sequential form
(numpy + heapq). Complexity O(nm + T log(nm)) where T is the number of
breakpoints *above* theta* — at high sparsity theta* is large, T ~ 0, and the
cost collapses to the O(nm) column-sum pass. Columns that end up zeroed are
never heapified (the paper's "columns elimination by design").

The TPU-native adaptations live in ``repro.core.l1inf`` (see DESIGN.md §2).
"""
from __future__ import annotations

import heapq
from typing import Tuple

import numpy as np

__all__ = ["project_l1inf_heap", "project_l1inf_naive", "theta_l1inf_heap"]


def _check_and_absorb(Y: np.ndarray, C: float):
    """Common preamble: |Y|, inside-ball check, degenerate radii."""
    A = np.abs(np.asarray(Y, dtype=np.float64))
    if A.ndim != 2:
        raise ValueError("expected a 2-D matrix")
    norm = A.max(axis=0).sum() if A.size else 0.0
    return A, norm


def theta_l1inf_heap(Y: np.ndarray, C: float) -> float:
    """theta* via the reverse total-order walk (Algorithm 2). 0 if inside."""
    A, norm = _check_and_absorb(Y, C)
    if norm <= C:
        return 0.0
    return _walk_theta(A, float(C))


def project_l1inf_heap(Y: np.ndarray, C: float) -> np.ndarray:
    """Faithful Algorithm 2: exact projection onto the l1,inf ball.

    Walks the global breakpoint total order in *decreasing* theta using one
    lazy global heap (keyed on each column's next breakpoint) and one lazy
    min-heap per activated column, maintaining the Eq.-(19) sums (A, B)
    incrementally. Fires as soon as the candidate theta falls inside the
    current segment.
    """
    Y = np.asarray(Y)
    A, norm = _check_and_absorb(Y, C)
    if C <= 0:
        return np.zeros_like(Y)
    if norm <= C:
        return Y.copy()
    n, m = A.shape

    theta, k_arr, S_arr, entered = _walk_state(A, float(C))
    # water levels: entered columns use their segment (k, S_k); others are dead
    mu = np.zeros(m)
    act = entered & (S_arr - theta > 0)
    mu[act] = (S_arr[act] - theta) / k_arr[act]
    X = np.sign(Y) * np.minimum(A, mu[None, :])
    return X.astype(Y.dtype, copy=False)


def _walk_theta(A: np.ndarray, C: float) -> float:
    return _walk_state(A, C)[0]


def _walk_state(A: np.ndarray, C: float):
    """Core reverse walk. Returns (theta, k, S_k, entered) per column."""
    n, m = A.shape
    colsums = A.sum(axis=0)

    # global max-heap over columns keyed by the next (largest unseen)
    # breakpoint; entry breakpoint of column j is its death b_n = ||y_j||_1.
    H = [(-colsums[j], j) for j in range(m)]
    heapq.heapify(H)

    k_arr = np.zeros(m, dtype=np.int64)     # current active count (0: not entered)
    S_arr = colsums.copy()                   # S_k for the current k
    col_heaps: dict[int, list] = {}
    A_sum = 0.0                              # sum_j S_kj / k_j  over entered
    B_sum = 0.0                              # sum_j 1 / k_j     over entered

    theta = None
    while H:
        negb, j = H[0]
        b = -negb
        if B_sum > 0.0:
            cand = (A_sum - C) / B_sum
            if cand >= b:                    # theta* in [b, prev_b)
                theta = cand
                break
        heapq.heappop(H)
        if k_arr[j] == 0:
            # entry: column activates with k = n; lazy heapify (min-heap so
            # pops yield z_n, z_{n-1}, ... exactly in breakpoint order)
            k_arr[j] = n
            h = A[:, j].tolist()
            heapq.heapify(h)
            col_heaps[j] = h
            A_sum += S_arr[j] / n
            B_sum += 1.0 / n
        else:
            # transition k -> k-1: drop z_k (the smallest of the top-k)
            k = k_arr[j]
            z = heapq.heappop(col_heaps[j])
            A_sum -= S_arr[j] / k
            B_sum -= 1.0 / k
            S_arr[j] -= z
            k_arr[j] = k - 1
            if k - 1 >= 1:
                A_sum += S_arr[j] / (k - 1)
                B_sum += 1.0 / (k - 1)
        k = k_arr[j]
        if k >= 1:
            z_top = col_heaps[j][0]
            b_next = S_arr[j] - k * z_top    # b_{k-1} = S_k - k z_k
            heapq.heappush(H, (-b_next, j))
    if theta is None:
        theta = (A_sum - C) / B_sum if B_sum > 0 else 0.0
    entered = k_arr >= 1
    return theta, k_arr, S_arr, entered


# -----------------------------------------------------------------------------
# Algorithm 1 (naive iterated l1 projection, as in Bejar et al. / the paper)
# -----------------------------------------------------------------------------

def project_l1inf_naive(Y: np.ndarray, C: float, max_iter: int = 10_000
                        ) -> np.ndarray:
    """Algorithm 1: iterate theta updates from full per-column simplex
    projections until theta stabilizes. Exact but O(n^2 m P) worst case."""
    Y = np.asarray(Y)
    A, norm = _check_and_absorb(Y, C)
    if C <= 0:
        return np.zeros_like(Y)
    if norm <= C:
        return Y.copy()
    n, m = A.shape

    Z = -np.sort(-A, axis=0)
    S = np.cumsum(Z, axis=0)
    active = np.ones(m, dtype=bool)
    theta = (Z[0].sum() - C) / m
    for _ in range(max_iter):
        # drop dominated columns (Prop. 3)
        active &= S[-1] > theta
        if not active.any():
            break
        # per-column active counts at the current theta (Prop. 2 gathering)
        k = np.zeros(m, dtype=np.int64)
        Ssel = np.zeros(m)
        for j in np.nonzero(active)[0]:
            # largest k with z_k > (S_k - theta)/k  (simplex active set)
            kk = np.arange(1, n + 1)
            valid = Z[:, j] * kk > (S[:, j] - theta)
            kj = int(np.nonzero(valid)[0][-1]) + 1
            k[j] = kj
            Ssel[j] = S[kj - 1, j]
        num = (Ssel[active] / k[active]).sum() - C
        den = (1.0 / k[active]).sum()
        new_theta = num / den
        if new_theta <= theta * (1 + 1e-15):
            theta = new_theta
            break
        theta = new_theta
    mu = np.zeros(m)
    for j in np.nonzero(active)[0]:
        kk = np.arange(1, n + 1)
        valid = Z[:, j] * kk > (S[:, j] - theta)
        idx = np.nonzero(valid)[0]
        if idx.size == 0:
            continue
        kj = idx[-1] + 1
        mu[j] = max(0.0, (S[kj - 1, j] - theta) / kj)
    X = np.sign(Y) * np.minimum(A, mu[None, :])
    return X.astype(Y.dtype, copy=False)
