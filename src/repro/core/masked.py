"""Masked l1,inf projection (paper Eq. 20).

Keeps the original magnitudes but zeroes exactly the support removed by the
real projection: X = Y if inside the ball, else Y * sign(P(|Y|)). Only whole
dominated columns (mu_j = 0) are zeroed; surviving entries are NOT clipped.

Both public entry points share ONE Newton solve (``_masked_solve``): the
column mask is derived from the water level mu of the same
``project_l1inf_newton_stats`` call that defines the projection — callers
needing the projection AND its mask no longer pay two solves, and the two
functions can never disagree on ties.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .l1inf import (l1inf_norm, project_l1inf_newton_stats, _PlainSegOps,
                    _prep, _post)

__all__ = ["project_l1inf_masked", "l1inf_column_mask"]


class _MaskedSegOps(_PlainSegOps):
    """Segmented-Newton hooks of the masked family: identical Eq.-(19)
    statistics to the plain family (same theta, same support), but the
    output map keeps surviving columns UNCLIPPED — finalize multiplies by
    the column-survival indicator instead of clamping at mu."""

    @staticmethod
    def finalize(Ydt, A, mu):
        return Ydt * (mu > 0.0)[None, :]


def _masked_solve(Y: jnp.ndarray, C, axis: int):
    """One Newton solve -> (X_masked, alive) on the canonical layout.

    ``alive`` is the per-column support of the TRUE projection P(|Y|)
    (inside the ball that projection is |Y| itself, so the mask degrades
    to the plain column support); ``X_masked`` is Y on surviving columns,
    0 on dead ones, with the inside-ball identity gate applied.
    """
    Yt, transpose, dt = _prep(Y, axis)
    C = jnp.asarray(C, dtype=dt)
    P, _ = project_l1inf_newton_stats(jnp.abs(Yt), C, axis=0)
    alive = jnp.any(P > 0, axis=0)
    inside = l1inf_norm(Yt, axis=0) <= C
    X = jnp.where(inside, Yt, Yt * alive[None, :])
    return _post(X, Y, transpose), alive, transpose


@functools.partial(jax.jit, static_argnames=("axis",))
def l1inf_column_mask(Y: jnp.ndarray, C, axis: int = 0) -> jnp.ndarray:
    """Boolean per-column mask: True for columns surviving P_{B_{1,inf}^C}."""
    _, alive, _ = _masked_solve(Y, C, axis)
    return alive


@functools.partial(jax.jit, static_argnames=("axis",))
def project_l1inf_masked(Y: jnp.ndarray, C, axis: int = 0) -> jnp.ndarray:
    """Masked projection P^M (Eq. 20)."""
    X, _, _ = _masked_solve(Y, C, axis)
    return X
