"""Masked l1,inf projection (paper Eq. 20).

Keeps the original magnitudes but zeroes exactly the support removed by the
real projection: X = Y if inside the ball, else Y * sign(P(|Y|)). Only whole
dominated columns (mu_j = 0) are zeroed; surviving entries are NOT clipped.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .l1inf import project_l1inf_newton, l1inf_norm

__all__ = ["project_l1inf_masked", "l1inf_column_mask"]


@functools.partial(jax.jit, static_argnames=("axis",))
def l1inf_column_mask(Y: jnp.ndarray, C, axis: int = 0) -> jnp.ndarray:
    """Boolean per-column mask: True for columns surviving P_{B_{1,inf}^C}."""
    P = project_l1inf_newton(jnp.abs(Y), C, axis=axis)
    return jnp.any(P > 0, axis=axis)


@functools.partial(jax.jit, static_argnames=("axis",))
def project_l1inf_masked(Y: jnp.ndarray, C, axis: int = 0) -> jnp.ndarray:
    """Masked projection P^M (Eq. 20)."""
    inside = l1inf_norm(Y, axis=axis) <= C
    P = project_l1inf_newton(jnp.abs(Y), C, axis=axis)
    masked = Y * jnp.sign(P)
    return jnp.where(inside, Y, masked)
