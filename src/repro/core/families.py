"""Constraint-family registry: pluggable balls for the ProjectionEngine.

PR 3's engine hard-coded the plain l1,inf ball in every layer (packing,
Newton, Pallas, sharded). This module turns that single code path into a
registry of ``ConstraintFamily`` records so every ball that factors through
a per-column threshold rides the SAME machinery — packing with per-family
sub-buffers (``core.constraints``), warm-started segmented Newton
(``core.l1inf._segmented_solve``), the Pallas engine (``kernels/l1inf``),
and the shard_map solve (``dist.projection``) — for free.

A family declares (DESIGN.md §8):

  * ``norms``        — the ``ProjectionSpec.norm`` strings it serves;
  * ``seg_ops``      — the per-column segmented-Newton statistics hooks
                       (the ``core.l1inf._PlainSegOps`` contract: prepare /
                       stats / stats0 / colnorm / death / finalize, plus the
                       OPTIONAL ``from_colstats(colsum, colmax, w)`` — aux
                       from streaming per-column sum/max statistics, which
                       is what qualifies a family for the fused two-pass
                       train step of ``kernels/fused_step``, DESIGN.md §11;
                       seg_ops with ``colstats_stat``/``fused_mode`` attrs
                       steer what pass 1 accumulates and how pass 2 writes
                       — the l1,2 family streams sum-of-squares and scales
                       instead of clipping, DESIGN.md §14). ``seg_ops=None``
                       marks a family as NOT packable (no shared per-segment
                       threshold exists — e.g. ``hoyer``): its specs stay on
                       the per-leaf path under every solver. Because every
                       hook is per-column given the shared theta, the SAME
                       ops power the local, packed, and sharded solves;
  * ``norm_fn``      — the constraint norm (feasibility test);
  * ``project_leaf`` — the per-matrix projection (per-leaf fallback path);
  * ``reference``    — an independent exact reference (tests/benches);
  * ``pallas_loader``— optional: lazily imports the fused-kernel packed
                       solver (None -> the packed Newton path is used even
                       when the engine is configured for Pallas);
  * ``uses_weights`` — whether ``ProjectionSpec.weights`` feeds a packed
                       per-column weight vector into the solve;
  * ``feasible``     — optional ``(Y, C, axis, w) -> bool`` feasibility
                       test for families whose constraint is NOT of the
                       form norm(Y) <= C (``hoyer``: every column's
                       sparseness RATIO must sit above the radius). When
                       None the conformance harness derives feasibility
                       from ``norm_fn``.

Registered families: ``l1inf`` (plain, also serving ``l1inf_sorted``
specs), ``l1inf_weighted`` (Perez et al. 2022-style column weights),
``l1inf_masked`` (paper Eq. 20 — plain support, unclipped magnitudes),
``bilevel`` (arXiv:2407.16293 — Eq. (19) restricted to k = 1, linear
time), ``l12`` (group lasso on column energies, DESIGN.md §14 — the
retired ``norms.py::project_l12_ball`` is its reference), and ``hoyer``
(Thom & Palm arXiv:1303.5259 sparseness ratio — per-leaf only).

Warm-start semantics are family-uniform: each packed plan threads one
theta per segment; any theta0 >= 0 is repaired by the bootstrap step, so
states may be exchanged across solvers (newton | pallas | sharded) of the
same family but MUST NOT cross families (their thetas live on different
scales — e.g. the weighted theta multiplies w_j). The per-(family,
every_k) plan keys enforce that separation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .l1inf import (_PlainSegOps, _segmented_solve, l1inf_norm,
                    project_l1inf_newton, project_l1inf_sorted)
from .weighted import (_WeightedSegOps, l1inf_weighted_norm,
                       project_l1inf_weighted)
from .masked import _MaskedSegOps, project_l1inf_masked
from .bilevel import _BilevelSegOps, project_bilevel, project_bilevel_ref
from .l12 import _L12SegOps, project_l12_newton
from .norms import l12_norm, project_l12_ball
from .hoyer import hoyer_sparseness, project_hoyer, project_hoyer_ref

__all__ = [
    "ConstraintFamily",
    "register_family",
    "get_family",
    "family_for_norm",
    "family_names",
    "packable_norms",
    "registered_norms",
    "project_segmented_family",
    "project_segmented_family_sharded",
]


@dataclasses.dataclass(frozen=True)
class ConstraintFamily:
    """One registered constraint ball (see module docstring).

    Frozen record: ``norms`` (the ProjectionSpec.norm strings served),
    ``seg_ops`` (the per-column segmented-Newton hooks — the
    ``core.l1inf._PlainSegOps`` contract, DESIGN.md §8 — or None for
    per-leaf-only families), ``norm_fn`` ``(Y, axis, w) -> scalar``,
    ``project_leaf``/``reference`` ``(Y, C, axis, w) -> X`` on (n, m)
    f32/bf16 matrices, an optional ``pallas_loader`` for the fused packed
    kernel, ``uses_weights``, and an optional ``feasible``
    ``(Y, C, axis, w) -> bool`` for non-norm-ball constraints.

    >>> fam = ConstraintFamily(name="l1inf", norms=("l1inf",), seg_ops=ops,
    ...                        norm_fn=nf, project_leaf=pl, reference=ref)
    """
    name: str
    norms: Tuple[str, ...]
    seg_ops: object
    norm_fn: Callable
    project_leaf: Callable           # (Y, C, axis, w) -> X
    reference: Callable              # (Y, C, axis, w) -> X (independent)
    pallas_loader: Optional[Callable] = None
    uses_weights: bool = False
    feasible: Optional[Callable] = None   # (Y, C, axis, w) -> bool


_REGISTRY: Dict[str, ConstraintFamily] = {}
_NORM_TO_FAMILY: Dict[str, str] = {}


def register_family(fam: ConstraintFamily) -> ConstraintFamily:
    """Register ``fam`` under its name and each of its spec norms.

    Re-registering a name replaces it (norm bindings follow, and norms the
    replacement no longer declares are unbound); a norm string already
    claimed by a DIFFERENT family is an error. Returns ``fam`` so the call
    can double as a decorator-style definition.

    >>> register_family(my_family)   # my_family.norms now accepted in specs
    """
    for norm in fam.norms:
        owner = _NORM_TO_FAMILY.get(norm)
        if owner is not None and owner != fam.name:
            raise ValueError(
                f"norm {norm!r} is already served by family {owner!r}")
    for norm, owner in list(_NORM_TO_FAMILY.items()):
        if owner == fam.name and norm not in fam.norms:
            del _NORM_TO_FAMILY[norm]
    _REGISTRY[fam.name] = fam
    for norm in fam.norms:
        _NORM_TO_FAMILY[norm] = fam.name
    return fam


def get_family(name: str) -> ConstraintFamily:
    """Look up a registered family by its name (NOT by spec norm — that is
    ``family_for_norm``). Raises ValueError for unknown names, listing the
    registered ones.

    >>> fam = get_family("bilevel")
    """
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown constraint family {name!r} "
            f"(registered: {family_names()})") from None


def family_for_norm(norm: str) -> Optional[ConstraintFamily]:
    """The family serving a spec norm, or None (the hand-wired ``l1`` ball
    is the only norm without a family).

    ``norm``: a ``ProjectionSpec.norm`` string. One family may serve
    several norms (``l1inf`` also serves ``l1inf_sorted``). A returned
    family with ``seg_ops is None`` (``hoyer``) is registered but NOT
    packable — its specs route per-leaf.

    >>> family_for_norm("l1inf_masked").name   # 'l1inf_masked'
    """
    name = _NORM_TO_FAMILY.get(norm)
    return _REGISTRY[name] if name is not None else None


def family_names() -> Tuple[str, ...]:
    """Sorted tuple of every registered family name.

    >>> family_names()   # ('bilevel', 'l1inf', 'l1inf_masked', ...)
    """
    return tuple(sorted(_REGISTRY))


def packable_norms() -> frozenset:
    """Every spec norm that packs into a family sub-buffer: the norms of
    families WITH seg_ops. The complement (``l1``, and registered
    per-leaf-only families like ``hoyer``) stays on the per-leaf path —
    see ``core.constraints``.

    >>> "bilevel" in packable_norms()   # True

    """
    return frozenset(n for n, f in _NORM_TO_FAMILY.items()
                     if _REGISTRY[f].seg_ops is not None)


def registered_norms() -> frozenset:
    """Every spec norm any registered family serves, packable or not
    (superset of ``packable_norms`` — includes ``hoyer``).

    >>> "hoyer" in registered_norms()   # True
    """
    return frozenset(_NORM_TO_FAMILY)


# ---------------------------------------------------------------------------
# packed segmented solves, family-dispatched
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("num_segments", "family",
                                             "max_iter"))
def project_segmented_family(Y: jnp.ndarray, seg_ids: jnp.ndarray, C_seg, *,
                             num_segments: int, family: str = "l1inf",
                             w_col: Optional[jnp.ndarray] = None,
                             theta0: Optional[jnp.ndarray] = None,
                             max_iter: int = 32):
    """Family-dispatching twin of ``project_l1inf_segmented``: project each
    column group of a packed (n, M) buffer onto its own ball of the named
    family.

    ``Y``: (n, M) f32 packed buffer; ``seg_ids``: (M,) int32 per-column
    ball ids in [0, num_segments] (num_segments = padding sentinel);
    ``C_seg``: (num_segments,) f32 radii; ``w_col``: optional (M,) f32
    per-column weights for weight-aware families (ignored otherwise);
    ``theta0``: optional (num_segments,) f32 warm start. Returns
    (X (n, M) f32, theta_seg (num_segments,) f32, iters scalar int32).

    >>> X, theta, iters = project_segmented_family(Y, sids, C, num_segments=3)
    """
    fam = get_family(family)
    if fam.seg_ops is None:
        raise ValueError(f"family {family!r} is per-leaf only (seg_ops=None)")
    return _segmented_solve(Y, seg_ids, C_seg, num_segments, theta0,
                            max_iter, ops=fam.seg_ops,
                            w_col=w_col if fam.uses_weights else None)


def project_segmented_family_sharded(Y: jnp.ndarray, seg_ids: jnp.ndarray,
                                     C_seg, *, num_segments: int,
                                     axis_names: Tuple[str, ...],
                                     family: str = "l1inf",
                                     w_col: Optional[jnp.ndarray] = None,
                                     theta0: Optional[jnp.ndarray] = None,
                                     contrib: Optional[jnp.ndarray] = None,
                                     max_iter: int = 32):
    """Sharded twin of ``project_segmented_family`` — call inside shard_map
    (the ``project_l1inf_segmented_sharded`` contract: one (num_segments,)
    psum per Eq.-(19) evaluation, shards never leave their rank).

    Same shapes/returns as ``project_segmented_family`` but ``Y``/``seg_ids``/
    ``w_col`` are the RANK-LOCAL column block; ``axis_names`` are the mesh
    axes to psum over and ``contrib`` an optional (M_local,) bool mask
    (False = this rank's copy of a replicated column does not count).

    >>> X, th, it = project_segmented_family_sharded(Yl, sidl, C,
    ...     num_segments=3, axis_names=("data",))
    """
    fam = get_family(family)
    if fam.seg_ops is None:
        raise ValueError(f"family {family!r} is per-leaf only (seg_ops=None)")
    return _segmented_solve(Y, seg_ids, C_seg, num_segments, theta0,
                            max_iter, axis_names=tuple(axis_names),
                            contrib=contrib, ops=fam.seg_ops,
                            w_col=w_col if fam.uses_weights else None)


# ---------------------------------------------------------------------------
# the built-in families
# ---------------------------------------------------------------------------

def _load_plain_pallas():
    from ..kernels.l1inf.ops import project_l1inf_pallas_segmented
    return project_l1inf_pallas_segmented


def _load_bilevel_pallas():
    from ..kernels.l1inf.ops import project_bilevel_pallas_segmented
    return project_bilevel_pallas_segmented


register_family(ConstraintFamily(
    name="l1inf",
    norms=("l1inf", "l1inf_sorted"),
    seg_ops=_PlainSegOps,
    norm_fn=lambda Y, axis=0, w=None: l1inf_norm(Y, axis=axis),
    project_leaf=lambda Y, C, axis=0, w=None:
        project_l1inf_newton(Y, C, axis=axis),
    reference=lambda Y, C, axis=0, w=None:
        project_l1inf_sorted(Y, C, axis=axis),
    pallas_loader=_load_plain_pallas,
))

register_family(ConstraintFamily(
    name="l1inf_weighted",
    norms=("l1inf_weighted",),
    seg_ops=_WeightedSegOps,
    norm_fn=lambda Y, axis=0, w=None: l1inf_weighted_norm(
        Y, jnp.ones((Y.shape[1 if axis in (0, -2) else 0],), jnp.float32)
        if w is None else jnp.asarray(w, jnp.float32), axis=axis),
    project_leaf=lambda Y, C, axis=0, w=None: project_l1inf_weighted(
        Y, jnp.ones((Y.shape[1 if axis in (0, -2) else 0],), jnp.float32)
        if w is None else jnp.asarray(w, jnp.float32), C, axis=axis),
    reference=lambda Y, C, axis=0, w=None: project_l1inf_weighted(
        Y, jnp.ones((Y.shape[1 if axis in (0, -2) else 0],), jnp.float32)
        if w is None else jnp.asarray(w, jnp.float32), C, axis=axis),
    uses_weights=True,
))

register_family(ConstraintFamily(
    name="l1inf_masked",
    norms=("l1inf_masked",),
    seg_ops=_MaskedSegOps,
    norm_fn=lambda Y, axis=0, w=None: l1inf_norm(Y, axis=axis),
    project_leaf=lambda Y, C, axis=0, w=None:
        project_l1inf_masked(Y, C, axis=axis),
    reference=lambda Y, C, axis=0, w=None:
        project_l1inf_masked(Y, C, axis=axis),
))

register_family(ConstraintFamily(
    name="bilevel",
    norms=("bilevel",),
    seg_ops=_BilevelSegOps,
    norm_fn=lambda Y, axis=0, w=None: l1inf_norm(Y, axis=axis),
    project_leaf=lambda Y, C, axis=0, w=None:
        project_bilevel(Y, C, axis=axis),
    reference=lambda Y, C, axis=0, w=None:
        project_bilevel_ref(Y, C, axis=axis),
    pallas_loader=_load_bilevel_pallas,
))

# l1,2 / group lasso (DESIGN.md §14): column energies replace column
# maxima, finalize scales instead of clips. Both per-leaf slots are the
# retired ``norms.py::project_l12_ball`` sort-based closed form, so
# pre-registry ``norm="l12"`` specs are bit-unchanged on the per-leaf
# path; the packed/fused solves run the Newton on energies and are
# checked against this reference. No pallas_loader: solver="pallas"
# falls back to the packed Newton (documented engine behavior).
register_family(ConstraintFamily(
    name="l12",
    norms=("l12",),
    seg_ops=_L12SegOps,
    norm_fn=lambda Y, axis=0, w=None: l12_norm(Y, axis=axis),
    project_leaf=lambda Y, C, axis=0, w=None:
        project_l12_ball(Y, C, axis=axis),
    reference=lambda Y, C, axis=0, w=None:
        project_l12_ball(Y, C, axis=axis),
))

# Hoyer sparseness ratio (arXiv:1303.5259, DESIGN.md §14): seg_ops=None —
# the constraint has no shared per-segment threshold and the row count
# enters it through k(n, s), so zero-row packing would CHANGE the
# constraint; specs route per-leaf under every solver. The radius is the
# target sparseness s in (0, 1]; the constraint direction is inverted
# (sparser = MORE feasible), so ``feasible`` — min column sparseness >= s
# — is the authoritative test, and ``norm_fn`` reports that min ratio
# (NOT a norm; kept for reporting only).
register_family(ConstraintFamily(
    name="hoyer",
    norms=("hoyer",),
    seg_ops=None,
    norm_fn=lambda Y, axis=0, w=None:
        jnp.min(hoyer_sparseness(Y, axis=axis)),
    project_leaf=lambda Y, C, axis=0, w=None:
        project_hoyer(Y, C, axis=axis),
    reference=lambda Y, C, axis=0, w=None:
        project_hoyer_ref(Y, C, axis=axis),
    feasible=lambda Y, C, axis=0, w=None:
        jnp.min(hoyer_sparseness(Y, axis=axis)) >= C - 1e-5,
))
