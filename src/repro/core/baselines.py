"""CPU baselines the paper compares against (numpy, exact).

  * Quattoni et al. 2009  — materialized total order: build all nm
    breakpoints, one global sort, linear walk. O(nm log nm) always.
  * Bejar et al. 2021     — "fastest l1,inf prox in the West": column
    pre-elimination preprocess + naive iterated projection.
  * Chu et al. 2020-class — semismooth Newton on theta (per-column presort +
    finitely-convergent monotone Newton; same iteration class).

All return the exact projection; they differ in complexity profile, which is
what benchmarks/proj_* measure (paper Figs. 1-3).
"""
from __future__ import annotations

import numpy as np

from .heap import project_l1inf_naive

__all__ = [
    "project_l1inf_quattoni",
    "project_l1inf_bejar",
    "project_l1inf_newton_np",
]


def _prep(Y, C):
    A = np.abs(np.asarray(Y, dtype=np.float64))
    norm = A.max(axis=0).sum() if A.size else 0.0
    return A, norm


def _sorted_stats(A):
    n, m = A.shape
    Z = -np.sort(-A, axis=0)
    S = np.cumsum(Z, axis=0)
    k = np.arange(1, n, dtype=np.float64)[:, None]
    b = np.concatenate([S[: n - 1] - k * Z[1:], S[n - 1 : n]], axis=0)
    return Z, S, b


def _finalize(Y, A, S, b, theta):
    n, m = A.shape
    idx = (b < theta).sum(axis=0)
    active = idx < n
    k = np.clip(idx + 1, 1, n).astype(np.float64)
    S_k = S[np.clip(idx, 0, n - 1), np.arange(m)]
    mu = np.where(active, np.maximum((S_k - theta) / k, 0.0), 0.0)
    X = np.sign(Y) * np.minimum(A, mu[None, :])
    return X.astype(np.asarray(Y).dtype, copy=False)


def project_l1inf_quattoni(Y: np.ndarray, C: float) -> np.ndarray:
    """Materialized total order (Quattoni-class): full global sort of all nm
    breakpoints + prefix scan + segment selection."""
    Y = np.asarray(Y)
    A, norm = _prep(Y, C)
    if C <= 0:
        return np.zeros_like(Y)
    if norm <= C:
        return Y.copy()
    n, m = A.shape
    Z, S, b = _sorted_stats(A)

    k = np.arange(1, n, dtype=np.float64)[:, None]
    dA = np.concatenate([S[1:] / (k + 1) - S[: n - 1] / k,
                         -(S[n - 1 : n] / n)], axis=0).ravel()
    dB = np.concatenate([np.broadcast_to(1.0 / (k + 1) - 1.0 / k, (n - 1, m)),
                         np.full((1, m), -1.0 / n)], axis=0).ravel()
    bf = b.ravel()
    order = np.argsort(bf, kind="stable")
    b_sorted = bf[order]
    A_state = np.concatenate([[S[0].sum()], S[0].sum() + np.cumsum(dA[order])])
    B_state = np.concatenate([[float(m)], float(m) + np.cumsum(dB[order])])
    lo = np.concatenate([[0.0], b_sorted])
    hi = np.concatenate([b_sorted, [np.inf]])
    with np.errstate(divide="ignore", invalid="ignore"):
        theta_t = (A_state - C) / B_state
    valid = (B_state > 0) & (theta_t > lo - 1e-12) & (theta_t <= hi + 1e-12)
    t = int(np.argmax(valid))
    theta = max(theta_t[t], 0.0)
    return _finalize(Y, A, S, b, theta)


def project_l1inf_bejar(Y: np.ndarray, C: float) -> np.ndarray:
    """Bejar et al.: O(nm + m log m) column pre-elimination, then the naive
    iterated projection on the surviving columns."""
    Y = np.asarray(Y)
    A, norm = _prep(Y, C)
    if C <= 0:
        return np.zeros_like(Y)
    if norm <= C:
        return Y.copy()
    n, m = A.shape
    colsums = A.sum(axis=0)
    colmax = A.max(axis=0)

    # Pre-elimination: a column j is provably zeroed if ||y_j||_1 <= theta_lb.
    # Lower-bound theta by Eq. (19) with every column at k = n over columns
    # sorted by decreasing colsum (Bejar's preprocess, vectorized):
    order = np.argsort(-colsums, kind="stable")
    cs = colsums[order]
    css = np.cumsum(cs)
    r = np.arange(1, m + 1, dtype=np.float64)
    # candidate theta using the top-r columns fully active at k=n:
    cand = (css / n - C) / (r / n)
    # keep columns whose colsum exceeds the best (largest) valid lower bound
    theta_lb = 0.0
    for i in range(m):
        if cand[i] <= cs[i]:
            theta_lb = cand[i]
    keep = colsums > max(theta_lb, 0.0)
    if not keep.any():
        keep = colsums >= colsums.max()
    sub = project_l1inf_naive(Y[:, keep], C)
    X = np.zeros_like(np.asarray(Y))
    X[:, keep] = sub
    return X


def project_l1inf_newton_np(Y: np.ndarray, C: float, max_iter: int = 128
                            ) -> np.ndarray:
    """Semismooth Newton on theta (Chu et al. 2020 class), numpy."""
    Y = np.asarray(Y)
    A, norm = _prep(Y, C)
    if C <= 0:
        return np.zeros_like(Y)
    if norm <= C:
        return Y.copy()
    n, m = A.shape
    Z, S, b = _sorted_stats(A)
    cols = np.arange(m)
    theta = max((S[0].sum() - C) / m, 0.0)
    for _ in range(max_iter):
        idx = (b < theta).sum(axis=0)
        active = idx < n
        k = np.clip(idx + 1, 1, n).astype(np.float64)
        S_k = S[np.clip(idx, 0, n - 1), cols]
        Aa = (S_k[active] / k[active]).sum()
        Ba = (1.0 / k[active]).sum()
        new_theta = (Aa - C) / Ba
        if new_theta <= theta:
            break
        theta = new_theta
    return _finalize(Y, A, S, b, theta)
