"""Weighted l1,inf ball projection (beyond-paper extension).

    B_w = { X : sum_j w_j * max_i |X_ij| <= C },   w_j > 0.

Generalizes the paper's operator the way Perez et al. 2022 generalized the
l1 ball (the paper's own citation [16]). Note this is NOT a rescaling of
the unweighted projection: the norm weights columns but the Euclidean
metric stays Frobenius.

KKT structure (same derivation as DESIGN.md §1): column j is zeroed iff
||y_j||_1 <= theta * w_j; otherwise clipped at mu_j with removal mass
sum_i (|y_ij| - mu_j)_+ = theta * w_j; theta solves

    g(theta) = sum_j w_j * mu_j(theta * w_j) = C,

which is again convex decreasing piecewise-linear (slopes -w_j^2/k_j), so
the monotone semismooth Newton applies verbatim with

    theta' = ( sum_A w_j S_{k_j}/k_j - C ) / ( sum_A w_j^2/k_j ).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .l1inf import _sorted_stats, _theta_state, _prep, _post

__all__ = ["project_l1inf_weighted", "l1inf_weighted_norm"]


def l1inf_weighted_norm(Y: jnp.ndarray, w: jnp.ndarray,
                        axis: int = 0) -> jnp.ndarray:
    return jnp.sum(w * jnp.max(jnp.abs(Y), axis=axis))


def _state(S, b, w, theta):
    """Per-column (k, S_k, active) at column thresholds theta * w_j."""
    return _theta_state(S, b, theta * w)


class _WeightedSegOps:
    """Segmented-Newton hooks of the weighted family (the ``_PlainSegOps``
    contract of ``core.l1inf``): each column sees its own threshold
    theta * w_j, and the Eq.-(19) tangent carries w_j (numerator) and
    w_j^2 (denominator) factors — the slopes of the module docstring.
    ``w_col`` is the packed per-column weight vector (1.0 on padding lanes);
    all statistics stay per-column, so the same ops run inside shard_map.
    """
    uses_weights = True

    @staticmethod
    def prepare(A, w=None):
        if w is None:
            w = jnp.ones((A.shape[1],), A.dtype)
        Z, S, b = _sorted_stats(A)
        return {"S": S, "b": b, "w": w, "colmax": Z[0], "colsum": S[-1]}

    @staticmethod
    def stats(aux, th_col):
        w = aux["w"]
        tw = th_col * w
        k, S_k, active = _theta_state(aux["S"], aux["b"], tw)
        mu = jnp.maximum((S_k - tw) / k, 0.0)
        return w * S_k / k, w * w / k, active, mu

    @staticmethod
    def stats0(aux):
        return aux["w"] * aux["colmax"], aux["w"] * aux["w"]

    @staticmethod
    def colnorm(aux):
        return aux["w"] * aux["colmax"]

    @staticmethod
    def death(aux):
        # column j dies once theta * w_j >= ||y_j||_1
        return aux["colsum"] / aux["w"]

    @staticmethod
    def finalize(Ydt, A, mu):
        return jnp.sign(Ydt) * jnp.minimum(A, mu[None, :])


@functools.partial(jax.jit, static_argnames=("axis", "max_iter"))
def project_l1inf_weighted(Y: jnp.ndarray, w: jnp.ndarray, C,
                           axis: int = 0, max_iter: int = 48) -> jnp.ndarray:
    """Exact projection onto B_w (w > 0 per column; axis = max axis)."""
    Yt, transpose, dt = _prep(Y, axis)
    A = jnp.abs(Yt)
    n, m = A.shape
    w = jnp.asarray(w, dt).reshape(m)
    C = jnp.asarray(C, dt)

    Z, S, b = _sorted_stats(A)
    inside = jnp.sum(w * Z[0]) <= C

    # Newton from below: theta_0 from the all-active k=1 segment
    theta0 = jnp.maximum(
        (jnp.sum(w * S[0]) - C) / jnp.maximum(jnp.sum(w * w), 1e-30), 0.0)

    def step(theta):
        k, S_k, active = _state(S, b, w, theta)
        Aa = jnp.sum(jnp.where(active, w * S_k / k, 0.0))
        Ba = jnp.sum(jnp.where(active, w * w / k, 0.0))
        return (Aa - C) / jnp.maximum(Ba, jnp.finfo(dt).tiny)

    def cond(c):
        i, th, prev = c
        return jnp.logical_and(i < max_iter, th > prev)

    def body(c):
        i, th, _ = c
        return (i + 1, step(th), th)

    _, theta, _ = jax.lax.while_loop(cond, body,
                                     (jnp.asarray(1), step(theta0), theta0))

    k, S_k, active = _state(S, b, w, theta)
    mu = jnp.where(active, jnp.maximum((S_k - theta * w) / k, 0.0), 0.0)
    X = jnp.sign(Yt) * jnp.minimum(A, mu[None, :])
    X = jnp.where(inside, Yt, X)
    X = jnp.where(C > 0, X, jnp.zeros_like(X))
    return _post(X, Y, transpose)
