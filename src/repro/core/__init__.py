"""Core library: the paper's l1,inf projection family and its integration.

Public API:
    project_l1inf            — dispatcher (newton | sorted), jit/pjit-safe
    project_l1inf_newton     — semismooth Newton production path
    project_l1inf_sorted     — exact vectorized total order
    project_l1inf_heap       — faithful paper Algorithm 2 (CPU, numpy+heapq)
    project_l1inf_naive      — paper Algorithm 1
    project_l1inf_masked     — masked projection (Eq. 20)
    prox_linf1               — prox of the dual norm via Moreau (Eq. 16)
    project_l1_ball / project_l12_ball / project_simplex_sort
    project_l1inf_segmented  — packed multi-ball solve (one sweep per group)
    support_indices / compact_columns — host-side support gather: the
        serving-time column-compaction primitives (``repro.sae.serve``)
    project_l1inf_segmented_sharded — shard_map twin (psum per iteration)
    project_bilevel          — bi-level l1,inf operator (arXiv:2407.16293),
        linear-time; project_bilevel_ref is its sort-based exact reference
    project_l12_newton       — l1,2 (group-lasso) ball via the segmented
        Newton on column energies (fuses: DESIGN.md §14)
    project_hoyer            — Hoyer sparseness-ratio projection
        (arXiv:1303.5259); project_hoyer_ref is its sorted closed form,
        hoyer_sparseness the per-column sigma diagnostic
    ConstraintFamily / register_family / get_family / family_for_norm —
        the pluggable constraint-family registry (core.families): every
        family rides the packed / Pallas / sharded engine machinery
    project_segmented_family / project_segmented_family_sharded —
        family-dispatching packed solves
    ProjectionSpec / apply_constraints / column_masks — training integration
    ProjectionEngine         — plan building + theta state + solver dispatch
        (newton | pallas | sharded) + the projected_update step core every
        train loop builds on; one packed solve per (family, every_k)
    apply_constraints_packed / init_projection_state  — functional shims
        over the engine (packed batching with warm-started Newton)
    engine_counters / engine_counters_reset — solver-invocation accounting
"""
from .simplex import (project_simplex_sort, project_l1_ball,
                      project_weighted_l1_ball, simplex_threshold)
from .l1inf import (l1inf_norm, project_l1inf, project_l1inf_sorted,
                    project_l1inf_newton, project_l1inf_newton_stats,
                    project_l1inf_segmented, project_l1inf_segmented_sharded,
                    theta_l1inf, column_support, active_compaction,
                    support_indices, compact_columns)
from .heap import project_l1inf_heap, project_l1inf_naive, theta_l1inf_heap
from .baselines import (project_l1inf_quattoni, project_l1inf_bejar,
                        project_l1inf_newton_np)
from .norms import project_l12_ball, prox_linf1, linf1_norm, l12_norm
from .masked import project_l1inf_masked, l1inf_column_mask
from .weighted import project_l1inf_weighted, l1inf_weighted_norm
from .bilevel import (project_bilevel, project_bilevel_stats,
                      project_bilevel_ref, bilevel_norm)
from .l12 import project_l12_newton, project_l12_stats
from .hoyer import hoyer_sparseness, project_hoyer, project_hoyer_ref
from .families import (ConstraintFamily, register_family, get_family,
                       family_for_norm, family_names, packable_norms,
                       registered_norms, project_segmented_family,
                       project_segmented_family_sharded)
from .constraints import (ProjectionSpec, apply_constraints,
                          build_packed_plans, column_masks, apply_masks,
                          sparsity_report, engine_counters,
                          engine_counters_reset)
from .engine import (ProjectionEngine, apply_constraints_packed,
                     init_projection_state)
