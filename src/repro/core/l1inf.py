"""Projection onto the l1,inf ball — TPU-native JAX implementations.

Paper: Perez, Condat, Barlaud, "Near-Linear Time Projection onto the l1,inf
Ball; Application to Sparse Autoencoders" (2023).

Math recap (see DESIGN.md §1). For Y in R^{n x m} (columns indexed by j, the
max is taken over the n rows within each column):

    ||Y||_{1,inf} = sum_j max_i |Y_ij|.

The projection factorizes through a scalar threshold theta >= 0:
  * column j is zeroed iff ||y_j||_1 <= theta,
  * otherwise it is clipped at mu_j where sum_i (|y_ij| - mu_j)_+ = theta,
  * theta solves g(theta) := sum_j mu_j(theta) = C.

With per-column descending sort z_1 >= ... >= z_n, prefix sums S_k, the
*breakpoints* of the piecewise-linear convex decreasing g are

    b_k = S_k - k z_{k+1} (k < n),   b_n = S_n  (column death).

On the segment theta in (b_{k_j-1}, b_{k_j}] of each column, Eq. (19) of the
paper gives theta = (sum_A S_{k_j}/k_j - C) / (sum_A 1/k_j) over the active
set A.

Exact implementations, all jit/pjit/vmap-safe:

  * ``project_l1inf_sorted``  — vectorized total order (Quattoni, TPU-native):
    one global sort of all nm breakpoints + prefix scan of slope payloads,
    then select the unique segment. O(nm log nm) work, ~15 parallel ops.
  * ``project_l1inf_newton``  — semismooth Newton on theta (Chu-class, the
    production path): per-column sort once, then finitely-convergent monotone
    Newton iterations, each a vectorized compare-and-sum. The per-column
    water level mu is carried through the loop, so the final clip needs no
    extra active-set pass.
  * ``project_l1inf_segmented`` — many independent balls in ONE packed
    (n, M) buffer: a per-column segment id maps each column to its ball and
    Eq. (19) becomes a segment-sum, so a whole group of weight matrices is
    projected with a single fused sweep (see ``core.constraints`` packing).

Warm-start contract (``theta0=``): ``project_l1inf_newton`` /
``project_l1inf_segmented`` (and the Pallas engine in ``kernels/l1inf``)
accept the previous solve's theta* as ``theta0``. Any value >= 0 is safe —
an overshooting guess (theta0 > theta*) is repaired by the first unclamped
Eq.-(19) step, which lands at or below theta* (the supporting line of the
convex g crosses C left of theta*), after which the usual monotone ascent
resumes. Under SGD the optimum moves O(lr) per step, so steady-state solves
converge in 1-2 Newton iterations instead of ~8-15. Exactness is unaffected:
the final theta is still the exact root for its active set.

The paper's own heap algorithm (inherently sequential) lives in
``repro.core.heap`` as the faithful CPU reference; see DESIGN.md §2 for the
hardware-adaptation rationale.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

__all__ = [
    "l1inf_norm",
    "project_l1inf",
    "project_l1inf_sorted",
    "project_l1inf_newton",
    "project_l1inf_newton_stats",
    "project_l1inf_segmented",
    "project_l1inf_segmented_sharded",
    "theta_l1inf",
    "column_support",
    "active_compaction",
    "support_indices",
    "compact_columns",
]

# Sentinel theta assigned to padding columns (dummy segment) in packed
# buffers: far above any real breakpoint, so they are never active.
_PAD_THETA = 1e30


def l1inf_norm(Y: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    """||Y||_{1,inf}: sum over columns of the max |.| within each column.

    `axis` is the *max* axis (paper convention: axis=0, columns are axis 1).
    """
    return jnp.sum(jnp.max(jnp.abs(Y), axis=axis))


def column_support(X: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    """Boolean per-column support (True where the column is not all-zero)."""
    return jnp.any(X != 0, axis=axis)


def active_compaction(active: jnp.ndarray,
                      key: Optional[jnp.ndarray] = None
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Stable permutation packing the True columns of `active` first.

    Returns (perm, num_active): ``x[:, perm]`` is the packed layout with the
    surviving columns occupying the leading ``num_active`` slots, and
    ``out.at[perm].set(packed)`` is the exact scatter-back (a permutation is
    bijective and values are untouched, so pack -> solve -> scatter is
    exact). With ``key`` given, the active prefix is additionally ordered by
    ascending key — the Pallas engine in ``kernels/l1inf/ops.py`` passes the
    negated death margin (theta - colsum) so that column deaths peel off the
    END of the prefix as theta rises (see DESIGN.md §3).
    """
    if key is None:
        key = jnp.zeros(active.shape, jnp.float32)
    sort_key = jnp.where(active, key.astype(jnp.float32), jnp.inf)
    perm = jnp.argsort(sort_key)
    return perm, jnp.sum(active.astype(jnp.int32))


def support_indices(support) -> np.ndarray:
    """Host-side static column-gather indices from a boolean support vector.

    ``support``: bool (m,) (array-like; jax or numpy). Returns int32 (J,)
    — the indices of the True entries, ascending. This is exactly the
    active prefix of ``active_compaction(support)`` with the default key
    (both orderings are stable in the original column index), but resolved
    on the host so the count J is a static Python int and downstream
    gathers get static shapes — the serving-time twin of the traced
    ``active_compaction`` (which keeps shapes and lives inside jit).

    >>> support_indices(np.array([True, False, True]))   # -> [0, 2]
    """
    return np.nonzero(np.asarray(support))[0].astype(np.int32)


def compact_columns(x: jnp.ndarray, idx, axis: int = -1) -> jnp.ndarray:
    """Gather the surviving columns ``idx`` of ``x`` along ``axis``.

    ``x``: any-rank array (any dtype — values pass through untouched);
    ``idx``: int (J,) from ``support_indices``. Returns ``x`` with ``axis``
    reduced to length J. A gather of untouched values is exact (no
    arithmetic), which is the first half of the serving exactness argument
    (DESIGN.md §9); decoder-row co-compaction is the same gather applied
    to the output axis of the decoder with the SAME ``idx``.

    >>> compact_columns(jnp.ones((4, 8)), np.array([1, 5]), axis=1).shape
    (4, 2)
    """
    return jnp.take(x, jnp.asarray(idx, jnp.int32), axis=axis)


# -----------------------------------------------------------------------------
# shared pieces
# -----------------------------------------------------------------------------

def _sorted_stats(A: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-column descending sort Z, prefix sums S (1-based: S[k-1]=S_k), and
    the (n, m) breakpoint matrix b (rows k=1..n-1 transitions, last row death).

    A: (n, m) nonnegative. Returned b is non-decreasing along axis 0.
    """
    n, m = A.shape
    Z = -jnp.sort(-A, axis=0)               # descending
    S = jnp.cumsum(Z, axis=0)               # S[k-1, j] = S_k
    k = jnp.arange(1, n, dtype=A.dtype)[:, None]
    b_trans = S[: n - 1] - k * Z[1:]        # b_k = S_k - k z_{k+1}, k=1..n-1
    b_death = S[n - 1 : n]                  # b_n = S_n
    b = jnp.concatenate([b_trans, b_death], axis=0)
    return Z, S, b


def _theta_state(S: jnp.ndarray, b: jnp.ndarray, theta: jnp.ndarray):
    """Per-column segment state at threshold `theta` (scalar or (m,) vector).

    Returns (k, S_k, active): k in [1, n] the active count, S_k the prefix sum
    at k, active=False where the column is dominated (theta >= b_n = S_n).

    Vectorized compare-and-sum (no searchsorted): O(nm) but a single fused
    compare+reduce, GSPMD-friendly.
    """
    n = S.shape[0]
    dt = S.dtype
    idx = jnp.sum((b < theta).astype(jnp.int32), axis=0)       # in [0, n]
    active = idx < n
    k = jnp.clip(idx + 1, 1, n)
    S_k = jnp.take_along_axis(S, (k - 1)[None, :], axis=0)[0]
    return k.astype(dt), S_k, active


def _eq19_step(S, b, Csafe, theta):
    """One Eq.-(19) evaluation at `theta`: the tangent-line root of g and the
    per-column water level mu(theta). Scalar-ball version (theta scalar);
    the segmented twin lives inside ``project_l1inf_segmented``."""
    k, S_k, active = _theta_state(S, b, theta)
    Aa = jnp.sum(jnp.where(active, S_k / k, 0.0))
    Ba = jnp.sum(jnp.where(active, 1.0 / k, 0.0))
    new = (Aa - Csafe) / jnp.maximum(Ba, jnp.finfo(S.dtype).tiny)
    mu = jnp.where(active, jnp.maximum((S_k - theta) / k, 0.0), 0.0)
    return new, mu


def _newton_solve(S, b, Csafe, theta_start, max_iter):
    """Warm-start-safe semismooth Newton for g(theta) = Csafe.

    `theta_start` may be ANY value >= 0 (cold lower bound or a stale warm
    start above theta*). Two unclamped Eq.-(19) steps re-establish a point
    <= theta* (tangents of the convex g cross C left of theta*), then the
    classic monotone ascent runs to finite convergence. The water level mu
    is carried through the loop, so callers need no extra active-set pass
    after convergence. Returns (theta, mu, n_eq19_evals).

    NOTE: the segmented twin of this loop lives in project_l1inf_segmented
    and the Pallas engine's in kernels/l1inf/ops.py::_engine — structural
    fixes here (bootstrap, cap-exit re-eval) must be mirrored there.
    """
    t1, _ = _eq19_step(S, b, Csafe, theta_start)
    t1 = jnp.maximum(t1, 0.0)
    t2, mu1 = _eq19_step(S, b, Csafe, t1)
    t2 = jnp.maximum(t2, t1)

    def cond(carry):
        i, th, prev, _ = carry
        return jnp.logical_and(i < max_iter, th > prev)

    def body(carry):
        i, th, _, _ = carry
        new, mu = _eq19_step(S, b, Csafe, th)
        return (i + 1, jnp.maximum(new, th), th, mu)

    i, th, prev, mu = jax.lax.while_loop(
        cond, body, (jnp.asarray(2, jnp.int32), t2, t1, mu1))
    # On convergence th == prev and the carried mu was evaluated at th. If
    # the max_iter cap cut the ascent mid-stride (th > prev), the carried mu
    # lags one iterate — re-evaluate at th so (theta, mu) stay consistent.
    # lax.cond keeps the common converged case free of the extra pass.
    mu = jax.lax.cond(th > prev,
                      lambda: _eq19_step(S, b, Csafe, th)[1],
                      lambda: mu)
    return th, mu, i


def _prep(Y: jnp.ndarray, axis: int):
    if Y.ndim != 2:
        raise ValueError(f"project_l1inf expects a 2-D matrix, got {Y.shape}")
    if axis not in (0, 1, -1, -2):
        raise ValueError("axis must index one of the two matrix dims")
    transpose = axis in (1, -1)
    Yt = Y.T if transpose else Y
    dt = jnp.promote_types(Y.dtype, jnp.float32)
    return Yt.astype(dt), transpose, dt


def _post(X, Y, transpose):
    X = X.T if transpose else X
    return X.astype(Y.dtype)


# -----------------------------------------------------------------------------
# exact vectorized total order (Quattoni-class, TPU-native)
# -----------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("axis",))
def project_l1inf_sorted(Y: jnp.ndarray, C, axis: int = 0) -> jnp.ndarray:
    """Exact projection of Y onto {X : ||X||_{1,inf} <= C}.

    Vectorized total-order algorithm: global sort of all breakpoints + prefix
    scan of the (dA, dB) slope payloads, then select the unique segment t with
    theta_t in (b_t, b_{t+1}]. A final Newton polish removes any fp boundary
    wobble. `axis` is the max axis.
    """
    Yt, transpose, dt = _prep(Y, axis)
    C = jnp.asarray(C, dtype=dt)
    A = jnp.abs(Yt)
    n, m = A.shape

    Z, S, b = _sorted_stats(A)

    # slope payloads for crossing each breakpoint left->right
    k = jnp.arange(1, n, dtype=dt)[:, None]
    dA_trans = S[1:] / (k + 1) - S[: n - 1] / k       # k -> k+1
    dB_trans = jnp.broadcast_to(1.0 / (k + 1) - 1.0 / k, (n - 1, m))
    dA_death = -(S[n - 1 : n] / n)                    # column removed
    dB_death = jnp.full((1, m), -1.0 / n, dtype=dt)
    dA = jnp.concatenate([dA_trans, dA_death], axis=0).reshape(-1)
    dB = jnp.concatenate([dB_trans, dB_death], axis=0).reshape(-1)
    bf = b.reshape(-1)

    order = jnp.argsort(bf)
    b_sorted = bf[order]
    A0 = jnp.sum(S[0])                                # all columns at k=1
    B0 = jnp.asarray(m, dtype=dt)
    A_state = jnp.concatenate([A0[None], A0 + jnp.cumsum(dA[order])])
    B_state = jnp.concatenate([B0[None], B0 + jnp.cumsum(dB[order])])

    # segment t covers (lo_t, hi_t], t = 0..nm
    lo = jnp.concatenate([jnp.zeros((1,), dt), b_sorted])
    hi = jnp.concatenate([b_sorted, jnp.full((1,), jnp.inf, dt)])
    safeB = jnp.maximum(B_state, jnp.finfo(dt).tiny)
    theta_t = (A_state - C) / safeB
    eps = jnp.finfo(dt).eps * jnp.maximum(jnp.abs(hi[:-1]).max(initial=1.0), 1.0)
    valid = (B_state > 0) & (theta_t > lo - eps) & (theta_t <= hi + eps)
    t = jnp.argmax(valid)                             # first valid segment
    theta = jnp.maximum(theta_t[t], 0.0)

    # Newton polish (exact active set => Eq. 19 exact; fixes boundary wobble)
    # and carried mu — the clip reuses the last evaluation's water level.
    Csafe = jnp.where(C > 0, C, jnp.asarray(1.0, dt))
    _, mu, _ = _newton_solve(S, b, Csafe, theta, max_iter=4)

    X = jnp.sign(Yt) * jnp.minimum(A, mu[None, :])
    inside = jnp.sum(Z[0]) <= C
    X = jnp.where(inside, Yt, X)
    X = jnp.where(C > 0, X, jnp.zeros_like(X))
    return _post(X, Y, transpose)


# -----------------------------------------------------------------------------
# semismooth Newton (production path)
# -----------------------------------------------------------------------------

def _project_newton_impl(Yt, C, dt, theta0, max_iter):
    """Shared Newton engine body. Returns (X, theta_out, iters)."""
    A = jnp.abs(Yt)
    n, m = A.shape
    Z, S, b = _sorted_stats(A)
    colmax = Z[0]
    colsum = S[n - 1]
    norm = jnp.sum(colmax)

    Csafe = jnp.where(C > 0, C, jnp.asarray(1.0, dt))
    # theta_cold: Eq. (19) with every column active at k=1 (the paper's line 2)
    cold = jnp.maximum((norm - Csafe) / m, 0.0)
    if theta0 is None:
        start = cold
    else:
        start = jnp.maximum(jnp.maximum(jnp.asarray(theta0, dt), 0.0), cold)

    theta, mu, iters = _newton_solve(S, b, Csafe, start, max_iter)

    X = jnp.sign(Yt) * jnp.minimum(A, mu[None, :])
    inside = norm <= C
    X = jnp.where(inside, Yt, X)
    X = jnp.where(C > 0, X, jnp.zeros_like(X))
    # theta consistent with the C > 0 gating: C <= 0 removes every column,
    # i.e. the norm-removal threshold max_j ||y_j||_1.
    theta_out = jnp.where(C > 0,
                          jnp.where(inside, jnp.zeros_like(theta), theta),
                          jnp.max(colsum, initial=0.0))
    return X, theta_out, iters


@functools.partial(jax.jit, static_argnames=("axis", "max_iter"))
def project_l1inf_newton(Y: jnp.ndarray, C, axis: int = 0,
                         max_iter: int = 32, *,
                         theta0: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Exact projection via monotone semismooth Newton on theta.

    One per-column sort + cumsum, then <= ~15 Newton steps (1-2 with a good
    ``theta0`` warm start — see the module docstring for the contract), each
    a fused compare-and-sum over the breakpoint matrix. The water level mu is
    carried through the loop, so no extra active-set pass runs after
    convergence. This is the default inside jitted/pjitted train steps.
    """
    Yt, transpose, dt = _prep(Y, axis)
    C = jnp.asarray(C, dtype=dt)
    X, _, _ = _project_newton_impl(Yt, C, dt, theta0, max_iter)
    return _post(X, Y, transpose)


@functools.partial(jax.jit, static_argnames=("axis", "max_iter"))
def project_l1inf_newton_stats(Y: jnp.ndarray, C, axis: int = 0,
                               max_iter: int = 32, *,
                               theta0: Optional[jnp.ndarray] = None):
    """Like ``project_l1inf_newton`` but returns (X, stats).

    stats = {"theta": theta*, "iters": #Eq.-(19) evaluations}. ``theta`` is
    what train loops thread back in as next step's ``theta0`` warm start.
    """
    Yt, transpose, dt = _prep(Y, axis)
    C = jnp.asarray(C, dtype=dt)
    X, theta, iters = _project_newton_impl(Yt, C, dt, theta0, max_iter)
    return _post(X, Y, transpose), {"theta": theta, "iters": iters}


# -----------------------------------------------------------------------------
# segmented Newton: many independent balls in one packed buffer
# -----------------------------------------------------------------------------

class _PlainSegOps:
    """Per-column statistics of the PLAIN l1,inf family for the segmented
    Newton solver — the reference implementation of the ``seg_ops`` contract
    every constraint family provides (see ``core.families`` / DESIGN.md §8):

      prepare(A, w)       -> aux pytree (per-column sort/prefix state)
      stats(aux, th_col)  -> (a, b, active, mu): per-column Eq.-(19)
                             numerator/denominator contributions, the
                             active flag, and the water level at th_col
      stats0(aux)         -> (a, b) at theta = 0 in closed form (cold start)
      colnorm(aux)        -> per-column contribution to the constraint norm
      death(aux)          -> per-column theta at which the column dies
                             (the C <= 0 norm-removal threshold)
      finalize(Ydt, A, mu)-> projected output before inside/zero gating

    Optional hook (absent here — the plain family cannot provide it):

      from_colstats(colsum, colmax, w) -> aux built from STREAMING
                             per-column (sum |.|, max |.|) statistics
                             alone. Families with this hook can run the
                             fused two-HBM-pass train step
                             (``kernels/fused_step``, DESIGN.md §11);
                             the plain family's aux needs per-column
                             sorted prefix sums, which no single
                             streaming sweep can emit.

    All hooks are per-column given the shared theta, so the same ops run
    unchanged inside ``shard_map`` (rows resident, columns sharded).
    """
    uses_weights = False

    @staticmethod
    def prepare(A, w=None):
        Z, S, b = _sorted_stats(A)
        return {"S": S, "b": b, "colmax": Z[0], "colsum": S[-1]}

    @staticmethod
    def stats(aux, th_col):
        k, S_k, active = _theta_state(aux["S"], aux["b"], th_col)
        mu = jnp.maximum((S_k - th_col) / k, 0.0)
        return S_k / k, 1.0 / k, active, mu

    @staticmethod
    def stats0(aux):
        return aux["colmax"], jnp.ones_like(aux["colmax"])

    @staticmethod
    def colnorm(aux):
        return aux["colmax"]

    @staticmethod
    def death(aux):
        return aux["colsum"]

    @staticmethod
    def finalize(Ydt, A, mu):
        return jnp.sign(Ydt) * jnp.minimum(A, mu[None, :])


def _segmented_newton(aux, seg_ids: jnp.ndarray, C_seg,
                      num_segments: int,
                      theta0: Optional[jnp.ndarray],
                      max_iter: int,
                      *, ops,
                      axis_names: Tuple[str, ...] = (),
                      contrib: Optional[jnp.ndarray] = None,
                      dt=jnp.float32):
    """Segmented Newton on PREPARED per-column statistics (no buffer).

    The iteration half of ``_segmented_solve``, factored out so callers
    that build ``aux`` without materializing a packed buffer — the fused
    optimizer+projection step (``kernels/fused_step``, DESIGN.md §11)
    assembles it from streamed per-column (sum, max) statistics via the
    family's ``from_colstats`` hook — run the exact same solve on the
    O(num_segments) state. ``aux`` is the family's prepare/from_colstats
    output for the M (virtual) columns mapped by ``seg_ids``; everything
    else follows the ``_segmented_solve`` contract.

    Returns (mu (M,), theta_out (G,), iters, inside_seg (G,), zero_seg (G,))
    — mu is the per-column water level at theta* BEFORE inside/zero gating
    (callers apply the identity/zero overrides; ``_segmented_solve`` does it
    via column lookups, the fused clip pass folds it into mu).
    """
    G = int(num_segments)
    seg_ids = jnp.asarray(seg_ids, jnp.int32)
    C_seg = jnp.asarray(C_seg, dt)
    tiny = jnp.finfo(dt).tiny

    def allsum(v):
        return jax.lax.psum(v, axis_names) if axis_names else v

    def allmax(v):
        return jax.lax.pmax(v, axis_names) if axis_names else v

    valid = seg_ids < G
    own = valid if contrib is None else jnp.logical_and(valid, contrib)
    sum_seg = functools.partial(jax.ops.segment_sum, segment_ids=seg_ids,
                                num_segments=G + 1)
    # one stacked psum for the pre-loop per-segment state: the family's
    # constraint norm plus the closed-form theta=0 Eq.-(19) stats (for the
    # plain family: norm, norm, column count)
    a0, b0 = ops.stats0(aux)
    pre = allsum(jnp.stack([
        sum_seg(jnp.where(own, ops.colnorm(aux), 0.0))[:G],
        sum_seg(jnp.where(own, a0, 0.0))[:G],
        sum_seg(jnp.where(own, b0, 0.0))[:G],
    ]))
    norm_seg, num0, den0 = pre[0], pre[1], pre[2]

    Csafe = jnp.where(C_seg > 0, C_seg, jnp.ones_like(C_seg))
    cold = jnp.maximum((num0 - Csafe) / jnp.maximum(den0, 1.0), 0.0)
    if theta0 is None:
        start = cold
    else:
        start = jnp.maximum(jnp.maximum(jnp.asarray(theta0, dt), 0.0), cold)

    def theta_cols(th_seg):
        ext = jnp.concatenate([th_seg, jnp.full((1,), _PAD_THETA, dt)])
        return ext[jnp.minimum(seg_ids, G)]

    def eval_step(th_seg):
        th_col = theta_cols(th_seg)
        a, b_, active, mu = ops.stats(aux, th_col)
        active = jnp.logical_and(active, valid)
        counted = jnp.logical_and(active, own)
        # ONE stacked psum per Eq.-(19) evaluation: the numerator and
        # denominator segment sums cross the link together as a single
        # (2, num_segments) all-reduce — the contract the sharded and
        # fused-sharded engines assert on in HLO (one all-reduce in the
        # Newton while-loop body, 2 * num_segments f32 on the wire).
        AB = allsum(jnp.stack([
            sum_seg(jnp.where(counted, a, 0.0))[:G],
            sum_seg(jnp.where(counted, b_, 0.0))[:G],
        ]))
        new = (AB[0] - Csafe) / jnp.maximum(AB[1], tiny)
        mu = jnp.where(active, mu, 0.0)
        return new, mu

    # NOTE: this outer loop is the jnp twin of the Pallas engine's in
    # kernels/l1inf/ops.py::_engine — bootstrap, monotone ascent, carried
    # mu, and the cap-exit re-eval must stay in sync between the two.
    # Clamp the repair to the cold bound (> 0 for outside-ball segments),
    # matching the Pallas engine, which additionally NEEDS it to avoid the
    # degenerate theta=0 water level of its bisection payloads.
    t1 = jnp.maximum(eval_step(start)[0], cold)
    t2, mu1 = eval_step(t1)
    t2 = jnp.maximum(t2, t1)

    def cond(carry):
        i, th, prev, _ = carry
        return jnp.logical_and(i < max_iter, jnp.any(th > prev))

    def body(carry):
        i, th, _, _ = carry
        new, mu = eval_step(th)
        return (i + 1, jnp.maximum(new, th), th, mu)

    iters, theta, prev, mu = jax.lax.while_loop(
        cond, body, (jnp.asarray(2, jnp.int32), t2, t1, mu1))
    # max_iter-cap exit: the carried mu lags the final theta by one iterate
    # for the still-moving segments; re-evaluate to keep (theta, mu)
    # consistent (free when converged).
    mu = jax.lax.cond(jnp.any(theta > prev),
                      lambda: eval_step(theta)[1],
                      lambda: mu)

    inside_seg = norm_seg <= C_seg
    zero_seg = C_seg <= 0
    # max is idempotent, so replicated columns need no ownership mask here
    seg_max = allmax(jax.ops.segment_max(
        jnp.where(valid, ops.death(aux), 0.0), seg_ids,
        num_segments=G + 1)[:G])
    theta_out = jnp.where(zero_seg, seg_max,
                          jnp.where(inside_seg, 0.0, theta))
    return mu, theta_out, iters, inside_seg, zero_seg


def _segmented_solve(Y: jnp.ndarray, seg_ids: jnp.ndarray, C_seg,
                     num_segments: int,
                     theta0: Optional[jnp.ndarray],
                     max_iter: int,
                     axis_names: Tuple[str, ...] = (),
                     contrib: Optional[jnp.ndarray] = None,
                     ops=None,
                     w_col: Optional[jnp.ndarray] = None):
    """Shared body of the segmented Newton solve (local and sharded forms).

    With ``axis_names`` empty this is the single-buffer solve. With
    ``axis_names`` given, the function must run inside ``shard_map`` over
    those mesh axes: ``Y``/``seg_ids``/``contrib`` are the rank's LOCAL
    column block and every per-segment reduction is followed by a
    ``psum``/``pmax`` over ``axis_names``, so the (num_segments,)-vector
    Newton state is bit-identical on every rank and identical (up to fp
    reduction order) to the gathered solve. Only O(num_segments) floats
    cross the link per Eq.-(19) evaluation — never a column.

    ``contrib`` (M,) bool marks the columns this rank OWNS for reduction
    purposes: a column replicated across ranks (a leaf whose width the mesh
    does not divide) must be summed exactly once, so only rank 0 sets its
    contrib bit; the clip/identity output math still runs on every rank
    (it is pure per-column given the shared theta).

    ``ops`` selects the constraint family's per-column statistics (the
    ``_PlainSegOps`` contract; default: plain l1,inf) and ``w_col`` (M,)
    carries the per-column weights for weight-aware families.
    """
    if Y.ndim != 2:
        raise ValueError("packed buffer must be 2-D")
    if ops is None:
        ops = _PlainSegOps
    dt = jnp.promote_types(Y.dtype, jnp.float32)
    A = jnp.abs(Y.astype(dt))
    G = int(num_segments)
    seg_ids = jnp.asarray(seg_ids, jnp.int32)
    C_seg = jnp.asarray(C_seg, dt)
    if w_col is not None:
        w_col = jnp.asarray(w_col, dt)

    aux = ops.prepare(A, w_col)
    mu, theta_out, iters, inside_seg, zero_seg = _segmented_newton(
        aux, seg_ids, C_seg, G, theta0, max_iter, ops=ops,
        axis_names=axis_names, contrib=contrib, dt=dt)

    X = ops.finalize(Y.astype(dt), A, mu)
    ext_b = jnp.concatenate([inside_seg, jnp.array([True])])
    inside_col = ext_b[jnp.minimum(seg_ids, G)]       # padding: identity
    ext_z = jnp.concatenate([zero_seg, jnp.array([False])])
    zero_col = ext_z[jnp.minimum(seg_ids, G)]
    X = jnp.where(inside_col[None, :], Y.astype(dt), X)
    X = jnp.where(zero_col[None, :], 0.0, X)
    return X.astype(Y.dtype), theta_out, iters


@functools.partial(jax.jit, static_argnames=("num_segments", "max_iter"))
def project_l1inf_segmented(Y: jnp.ndarray, seg_ids: jnp.ndarray, C_seg,
                            *, num_segments: int,
                            theta0: Optional[jnp.ndarray] = None,
                            max_iter: int = 32):
    """Project each column group of a packed (n, M) buffer onto its own ball.

    ``seg_ids`` (M,) int32 maps column -> segment in [0, num_segments);
    columns with ``seg_ids == num_segments`` are lane padding (dummy segment:
    never active, projected to themselves). ``C_seg`` (num_segments,) holds
    one radius per segment. The max axis is 0 (callers canonicalize).

    The Newton iteration runs on a theta VECTOR (one per segment): the
    Eq.-(19) sums become segment-sums and every step is still one fused
    compare-and-sum over the whole packed buffer — one sweep per step for
    ALL matrices of a group instead of one solve per matrix. ``theta0``
    (num_segments,) warm-starts all segments (see module docstring).

    Returns (X, theta_seg, iters) with iters the max Eq.-(19) evaluation
    count across segments.
    """
    return _segmented_solve(Y, seg_ids, C_seg, num_segments, theta0,
                            max_iter)


def project_l1inf_segmented_sharded(Y: jnp.ndarray, seg_ids: jnp.ndarray,
                                    C_seg, *, num_segments: int,
                                    axis_names: Tuple[str, ...],
                                    theta0: Optional[jnp.ndarray] = None,
                                    contrib: Optional[jnp.ndarray] = None,
                                    max_iter: int = 32):
    """Sharded twin of ``project_l1inf_segmented`` — call inside shard_map.

    ``Y``/``seg_ids``/``contrib`` are this rank's LOCAL column block of the
    packed buffer (columns sharded over ``axis_names``, rows resident).
    Per-segment statistics are reduced locally then combined with ONE
    ``psum`` of the stacked (2, num_segments) Eq.-(19) numerator/denominator
    per evaluation (plus one ``pmax`` for the C<=0 threshold), so theta is
    identical on every rank
    and equal to the gathered solve up to fp reduction order; weight shards
    never leave their device. See ``repro.dist.projection`` for the packing
    orchestration and DESIGN.md §7 for the math and byte counts.
    """
    return _segmented_solve(Y, seg_ids, C_seg, num_segments, theta0,
                            max_iter, axis_names=tuple(axis_names),
                            contrib=contrib)


@functools.partial(jax.jit, static_argnames=("axis",))
def theta_l1inf(Y: jnp.ndarray, C, axis: int = 0) -> jnp.ndarray:
    """The optimal threshold theta* (0 if Y is already inside the ball).

    For C <= 0 the projection is the zero matrix (see ``project_l1inf_*``'s
    C > 0 gating); the consistent threshold is the norm-removal level
    max_j ||y_j||_1 — the smallest theta at which every column dies.

    Used for the paper's Figs. 6/8 (theta as a function of the radius)."""
    Yt, _, dt = _prep(Y, axis)
    C = jnp.asarray(C, dtype=dt)
    _, theta, _ = _project_newton_impl(Yt, C, dt, None, 32)
    return theta


def project_l1inf(Y: jnp.ndarray, C, axis: int = 0,
                  method: str = "newton") -> jnp.ndarray:
    """Dispatcher. method in {"newton", "sorted"}."""
    if method == "newton":
        return project_l1inf_newton(Y, C, axis=axis)
    if method == "sorted":
        return project_l1inf_sorted(Y, C, axis=axis)
    raise ValueError(f"unknown method {method!r}")
