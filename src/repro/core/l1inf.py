"""Projection onto the l1,inf ball — TPU-native JAX implementations.

Paper: Perez, Condat, Barlaud, "Near-Linear Time Projection onto the l1,inf
Ball; Application to Sparse Autoencoders" (2023).

Math recap (see DESIGN.md §1). For Y in R^{n x m} (columns indexed by j, the
max is taken over the n rows within each column):

    ||Y||_{1,inf} = sum_j max_i |Y_ij|.

The projection factorizes through a scalar threshold theta >= 0:
  * column j is zeroed iff ||y_j||_1 <= theta,
  * otherwise it is clipped at mu_j where sum_i (|y_ij| - mu_j)_+ = theta,
  * theta solves g(theta) := sum_j mu_j(theta) = C.

With per-column descending sort z_1 >= ... >= z_n, prefix sums S_k, the
*breakpoints* of the piecewise-linear convex decreasing g are

    b_k = S_k - k z_{k+1} (k < n),   b_n = S_n  (column death).

On the segment theta in (b_{k_j-1}, b_{k_j}] of each column, Eq. (19) of the
paper gives theta = (sum_A S_{k_j}/k_j - C) / (sum_A 1/k_j) over the active
set A.

Two exact implementations, both jit/pjit/vmap-safe:

  * ``project_l1inf_sorted``  — vectorized total order (Quattoni, TPU-native):
    one global sort of all nm breakpoints + prefix scan of slope payloads,
    then select the unique segment. O(nm log nm) work, ~15 parallel ops.
  * ``project_l1inf_newton``  — semismooth Newton on theta (Chu-class, the
    production path): per-column sort once, then finitely-convergent monotone
    Newton iterations, each a vectorized compare-and-sum.

The paper's own heap algorithm (inherently sequential) lives in
``repro.core.heap`` as the faithful CPU reference; see DESIGN.md §2 for the
hardware-adaptation rationale.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "l1inf_norm",
    "project_l1inf",
    "project_l1inf_sorted",
    "project_l1inf_newton",
    "theta_l1inf",
    "column_support",
]


def l1inf_norm(Y: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    """||Y||_{1,inf}: sum over columns of the max |.| within each column.

    `axis` is the *max* axis (paper convention: axis=0, columns are axis 1).
    """
    return jnp.sum(jnp.max(jnp.abs(Y), axis=axis))


def column_support(X: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    """Boolean per-column support (True where the column is not all-zero)."""
    return jnp.any(X != 0, axis=axis)


# -----------------------------------------------------------------------------
# shared pieces
# -----------------------------------------------------------------------------

def _sorted_stats(A: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-column descending sort Z, prefix sums S (1-based: S[k-1]=S_k), and
    the (n, m) breakpoint matrix b (rows k=1..n-1 transitions, last row death).

    A: (n, m) nonnegative. Returned b is non-decreasing along axis 0.
    """
    n, m = A.shape
    Z = -jnp.sort(-A, axis=0)               # descending
    S = jnp.cumsum(Z, axis=0)               # S[k-1, j] = S_k
    k = jnp.arange(1, n, dtype=A.dtype)[:, None]
    b_trans = S[: n - 1] - k * Z[1:]        # b_k = S_k - k z_{k+1}, k=1..n-1
    b_death = S[n - 1 : n]                  # b_n = S_n
    b = jnp.concatenate([b_trans, b_death], axis=0)
    return Z, S, b


def _theta_state(S: jnp.ndarray, b: jnp.ndarray, theta: jnp.ndarray):
    """Per-column segment state at threshold `theta`.

    Returns (k, S_k, active): k in [1, n] the active count, S_k the prefix sum
    at k, active=False where the column is dominated (theta >= b_n = S_n).

    Vectorized compare-and-sum (no searchsorted): O(nm) but a single fused
    compare+reduce, GSPMD-friendly.
    """
    n = S.shape[0]
    dt = S.dtype
    idx = jnp.sum((b < theta).astype(jnp.int32), axis=0)       # in [0, n]
    active = idx < n
    k = jnp.clip(idx + 1, 1, n)
    S_k = jnp.take_along_axis(S, (k - 1)[None, :], axis=0)[0]
    return k.astype(dt), S_k, active


def _finalize(Y: jnp.ndarray, A: jnp.ndarray, S: jnp.ndarray, b: jnp.ndarray,
              theta: jnp.ndarray) -> jnp.ndarray:
    """Clip |Y| at the per-column water level implied by theta, restore signs."""
    k, S_k, active = _theta_state(S, b, theta)
    mu = jnp.where(active, (S_k - theta) / k, 0.0)
    mu = jnp.maximum(mu, 0.0)
    return jnp.sign(Y) * jnp.minimum(A, mu[None, :])


def _newton_theta(S: jnp.ndarray, b: jnp.ndarray, C: jnp.ndarray,
                  theta0: jnp.ndarray, max_iter: int = 32) -> jnp.ndarray:
    """Monotone semismooth Newton for g(theta) = C. Finite convergence since g
    is convex decreasing piecewise-linear and theta0 <= theta*."""
    def step(theta):
        k, S_k, active = _theta_state(S, b, theta)
        Aa = jnp.sum(jnp.where(active, S_k / k, 0.0))
        Ba = jnp.sum(jnp.where(active, 1.0 / k, 0.0))
        # Ba > 0 guaranteed while theta <= theta* and C > 0
        return (Aa - C) / jnp.maximum(Ba, jnp.finfo(S.dtype).tiny)

    def cond(carry):
        i, theta, prev = carry
        return jnp.logical_and(i < max_iter, theta > prev)

    def body(carry):
        i, theta, _ = carry
        return (i + 1, step(theta), theta)

    theta1 = step(theta0)
    _, theta, _ = jax.lax.while_loop(
        cond, body, (jnp.asarray(1), theta1, theta0))
    return theta


def _prep(Y: jnp.ndarray, axis: int):
    if Y.ndim != 2:
        raise ValueError(f"project_l1inf expects a 2-D matrix, got {Y.shape}")
    if axis not in (0, 1, -1, -2):
        raise ValueError("axis must index one of the two matrix dims")
    transpose = axis in (1, -1)
    Yt = Y.T if transpose else Y
    dt = jnp.promote_types(Y.dtype, jnp.float32)
    return Yt.astype(dt), transpose, dt


def _post(X, Y, transpose):
    X = X.T if transpose else X
    return X.astype(Y.dtype)


# -----------------------------------------------------------------------------
# exact vectorized total order (Quattoni-class, TPU-native)
# -----------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("axis",))
def project_l1inf_sorted(Y: jnp.ndarray, C, axis: int = 0) -> jnp.ndarray:
    """Exact projection of Y onto {X : ||X||_{1,inf} <= C}.

    Vectorized total-order algorithm: global sort of all breakpoints + prefix
    scan of the (dA, dB) slope payloads, then select the unique segment t with
    theta_t in (b_t, b_{t+1}]. A final Newton polish removes any fp boundary
    wobble. `axis` is the max axis.
    """
    Yt, transpose, dt = _prep(Y, axis)
    C = jnp.asarray(C, dtype=dt)
    A = jnp.abs(Yt)
    n, m = A.shape

    Z, S, b = _sorted_stats(A)

    # slope payloads for crossing each breakpoint left->right
    k = jnp.arange(1, n, dtype=dt)[:, None]
    dA_trans = S[1:] / (k + 1) - S[: n - 1] / k       # k -> k+1
    dB_trans = jnp.broadcast_to(1.0 / (k + 1) - 1.0 / k, (n - 1, m))
    dA_death = -(S[n - 1 : n] / n)                    # column removed
    dB_death = jnp.full((1, m), -1.0 / n, dtype=dt)
    dA = jnp.concatenate([dA_trans, dA_death], axis=0).reshape(-1)
    dB = jnp.concatenate([dB_trans, dB_death], axis=0).reshape(-1)
    bf = b.reshape(-1)

    order = jnp.argsort(bf)
    b_sorted = bf[order]
    A0 = jnp.sum(S[0])                                # all columns at k=1
    B0 = jnp.asarray(m, dtype=dt)
    A_state = jnp.concatenate([A0[None], A0 + jnp.cumsum(dA[order])])
    B_state = jnp.concatenate([B0[None], B0 + jnp.cumsum(dB[order])])

    # segment t covers (lo_t, hi_t], t = 0..nm
    lo = jnp.concatenate([jnp.zeros((1,), dt), b_sorted])
    hi = jnp.concatenate([b_sorted, jnp.full((1,), jnp.inf, dt)])
    safeB = jnp.maximum(B_state, jnp.finfo(dt).tiny)
    theta_t = (A_state - C) / safeB
    eps = jnp.finfo(dt).eps * jnp.maximum(jnp.abs(hi[:-1]).max(initial=1.0), 1.0)
    valid = (B_state > 0) & (theta_t > lo - eps) & (theta_t <= hi + eps)
    t = jnp.argmax(valid)                             # first valid segment
    theta = jnp.maximum(theta_t[t], 0.0)

    # Newton polish (exact active set => Eq. 19 exact; fixes boundary wobble)
    theta = _newton_theta(S, b, C, theta, max_iter=4)

    X = _finalize(Yt, A, S, b, theta)
    inside = jnp.sum(Z[0]) <= C
    X = jnp.where(inside, Yt, X)
    X = jnp.where(C > 0, X, jnp.zeros_like(X))
    return _post(X, Y, transpose)


# -----------------------------------------------------------------------------
# semismooth Newton (production path)
# -----------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("axis", "max_iter"))
def project_l1inf_newton(Y: jnp.ndarray, C, axis: int = 0,
                         max_iter: int = 32) -> jnp.ndarray:
    """Exact projection via monotone semismooth Newton on theta.

    One per-column sort + cumsum, then <= ~15 Newton steps, each a fused
    compare-and-sum over the breakpoint matrix. This is the default inside
    jitted/pjitted train steps (no global sort, no long prefix scans).
    """
    Yt, transpose, dt = _prep(Y, axis)
    C = jnp.asarray(C, dtype=dt)
    A = jnp.abs(Yt)
    n, m = A.shape

    Z, S, b = _sorted_stats(A)
    # theta_0: Eq. (19) with every column active at k=1 (the paper's line 2)
    theta0 = (jnp.sum(S[0]) - C) / m
    theta0 = jnp.maximum(theta0, 0.0)
    theta = _newton_theta(S, b, C, theta0, max_iter=max_iter)

    X = _finalize(Yt, A, S, b, theta)
    inside = jnp.sum(Z[0]) <= C
    X = jnp.where(inside, Yt, X)
    X = jnp.where(C > 0, X, jnp.zeros_like(X))
    return _post(X, Y, transpose)


@functools.partial(jax.jit, static_argnames=("axis",))
def theta_l1inf(Y: jnp.ndarray, C, axis: int = 0) -> jnp.ndarray:
    """The optimal threshold theta* (0 if Y is already inside the ball).

    Used for the paper's Figs. 6/8 (theta as a function of the radius)."""
    Yt, _, dt = _prep(Y, axis)
    C = jnp.asarray(C, dtype=dt)
    A = jnp.abs(Yt)
    Z, S, b = _sorted_stats(A)
    m = A.shape[1]
    theta0 = jnp.maximum((jnp.sum(S[0]) - C) / m, 0.0)
    theta = _newton_theta(S, b, C, theta0)
    inside = jnp.sum(Z[0]) <= C
    return jnp.where(inside, jnp.zeros_like(theta), theta)


def project_l1inf(Y: jnp.ndarray, C, axis: int = 0,
                  method: str = "newton") -> jnp.ndarray:
    """Dispatcher. method in {"newton", "sorted"}."""
    if method == "newton":
        return project_l1inf_newton(Y, C, axis=axis)
    if method == "sorted":
        return project_l1inf_sorted(Y, C, axis=axis)
    raise ValueError(f"unknown method {method!r}")
