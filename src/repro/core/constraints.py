"""Training-time integration: structured-sparsity constraints on param pytrees.

A ``ProjectionSpec`` selects parameter leaves by path regex and applies one of
the ball projections after each optimizer update (projected gradient descent,
the paper's Algorithm 3). Leaves with more than 2 dims (scan-stacked layers,
stacked experts) are vmapped over their leading dims so the constraint applies
per layer / per expert.

Packed multi-tensor batching: instead of one projection launch per matching
weight matrix, every leaf of a registered constraint family
(``core.families``) is canonicalized (max axis -> 0), lane-padded, and
concatenated into ONE (n_max, sum m) buffer per (family, every_k) pair with
a per-column segment id; a stacked (L, n, m) leaf contributes L segments,
so the packing subsumes the per-layer vmap. Each family sub-buffer is
projected by ``families.project_segmented_family`` in a single fused sweep
— one compile, one launch, one HBM pass per family per train step — and
unpacked exactly (slicing off padding). Per-segment radii ride in a C
vector and weight-aware families a per-column w vector, so specs with
different radii/weights still share one launch. A per-plan theta vector
threads through the train state as next step's Newton warm start (plan
keys isolate warm starts per family — thetas never cross families).

This module owns the STATIC side of that story — specs, leaf matching, plan
building, pack/unpack, masks/reports, and the invocation counters. The
runtime side (solver dispatch newton|pallas|sharded, theta state, the
shared projected-update step core) lives in ``core.engine``; the
mesh-resident distributed solve lives in ``dist.projection``.

This module is what makes the paper's technique a first-class framework
feature: every arch config carries a tuple of specs (see configs/*.py).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .families import family_for_norm, get_family, registered_norms
from .norms import project_l1_ball

__all__ = ["ProjectionSpec", "apply_constraints", "build_packed_plans",
           "column_masks", "apply_masks", "sparsity_report", "leaf_path_str",
           "engine_count", "engine_counters", "engine_counters_reset"]

# spec norms: every registered constraint family's norms (packable families
# pack into per-family sub-buffers; seg_ops=None families like hoyer route
# per-leaf) plus the hand-wired per-leaf-only l1 ball
_EXTRA_NORMS = {"l1"}


def _known_norms():
    return registered_norms() | _EXTRA_NORMS
_LANE = 128   # TPU lane width: per-matrix column padding unit
_SUBLANE = 8  # TPU sublane: packed-buffer row padding unit

# Python-level projection-engine invocation counters, keyed by
# "<plan key>/<solver>" for packed launches and "per_leaf" for the per-matrix
# fallback. Incremented once per solver call issued while tracing/executing
# eagerly — benchmarks and tests use them to demonstrate the
# one-launch-per-step property of the packed path. Unlike the old
# ENGINE_INVOCATIONS module dict, the registry is snapshot/reset-able so
# concurrent benchmarks and tests cannot bleed counts into each other.
_COUNTERS: Dict[str, int] = {}


def engine_count(key: str) -> None:
    """Increment one invocation counter (engine-internal).

    ``key``: str — ``"<plan key>/<solver>"`` for packed launches (e.g.
    ``"l1inf_packed/k1/newton"``) or ``"per_leaf"`` for the fallback path.
    Counts Python-level solver calls (once per trace/eager call), so jit'd
    steady state adds nothing — tests use that to prove one-launch-per-step.

    >>> engine_count("l1inf_packed/k1/newton")
    """
    _COUNTERS[key] = _COUNTERS.get(key, 0) + 1


def engine_counters() -> Dict[str, int]:
    """Snapshot of all per-plan/per-path invocation counters.

    Returns a plain ``{key: int}`` dict copy (mutating it does not touch
    the live registry). Pair with ``engine_counters_reset`` around a
    measured region to count solver launches attributable to that region.

    >>> before = engine_counters()
    """
    return dict(_COUNTERS)


def engine_counters_reset() -> None:
    """Zero every counter (call before a measured region).

    Global across all plans/solvers — benchmarks and tests reset, run one
    region, then diff against ``engine_counters()``.

    >>> engine_counters_reset()
    """
    _COUNTERS.clear()


@dataclasses.dataclass(frozen=True)
class ProjectionSpec:
    """One structured-sparsity constraint.

    pattern:  regex matched against the '/'-joined param path.
    norm:     a registered constraint-family norm (l1inf | l1inf_sorted |
              l1inf_weighted | l1inf_masked | bilevel | l12 | hoyer — see
              ``core.families``; hoyer's radius is the target sparseness
              ratio s in (0, 1]) or the per-leaf-only l1 ball.
    radius:   ball radius C (> 0).
    axis:     the *max* axis of the trailing 2-D slice (paper: 0 — columns
              are prunable structures along the other axis).
    every_k:  apply every k optimizer steps (1 = every step).
    weights:  per-column weights for the l1inf_weighted family (a tuple of
              floats, one per canonical column of every matching leaf;
              None = uniform 1.0). Stored as a static tuple so specs stay
              hashable/trace-safe.

    Hashable/frozen — carry tuples of specs in static config (configs/*.py).

    >>> spec = ProjectionSpec(pattern=r"enc1/w", norm="l1inf", radius=0.1, axis=1)
    """
    pattern: str
    norm: str = "l1inf"
    radius: float = 1.0
    axis: int = 0
    every_k: int = 1
    weights: Optional[Tuple[float, ...]] = None

    def __post_init__(self):
        if self.norm not in _known_norms():
            raise ValueError(f"unknown norm {self.norm!r}")
        if self.radius <= 0:
            raise ValueError("radius must be > 0")
        if self.weights is not None:
            fam = family_for_norm(self.norm)
            if fam is None or not fam.uses_weights:
                raise ValueError(
                    f"norm {self.norm!r} does not take per-column weights")
            w = tuple(float(x) for x in self.weights)
            if any(x <= 0 for x in w):
                raise ValueError("weights must be > 0")
            object.__setattr__(self, "weights", w)


def leaf_path_str(path) -> str:
    """'/'-joined name of one pytree leaf path — the string spec patterns
    match against.

    ``path``: the key-path tuple from ``jax.tree_util``'s ``_with_path``
    APIs (dict keys, sequence indices, and attribute names all stringify).
    Returns e.g. ``"enc1/w"`` for ``params["enc1"]["w"]``.

    >>> name = leaf_path_str(path)   # from tree_flatten_with_path
    """
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _project_fn(spec: "ProjectionSpec") -> Callable:
    """Per-leaf projection (x_2d, C, axis) -> x_2d for one spec.

    Family norms — l12 and hoyer included — dispatch through the registry
    (``l1inf_sorted`` keeps the total-order solver on this path); only the
    flat l1 ball stays hand-wired.
    """
    if spec.norm == "l1inf_sorted":
        from .l1inf import project_l1inf_sorted
        return lambda x, C, axis: project_l1inf_sorted(x, C, axis=axis)
    if spec.norm == "l1":
        return lambda x, C, axis: project_l1_ball(x, C)
    fam = family_for_norm(spec.norm)
    w = spec.weights

    def fn(x, C, axis):
        wj = None if w is None else jnp.asarray(w, jnp.float32)
        return fam.project_leaf(x, C, axis=axis, w=wj)

    return fn


def _apply_2d(fn: Callable, x: jnp.ndarray, C: float, axis: int) -> jnp.ndarray:
    """Apply a 2-D projection to the trailing 2 dims, vmapping leading dims."""
    if x.ndim < 2:
        raise ValueError(f"projection target must have >=2 dims, got {x.shape}")
    if x.ndim == 2:
        return fn(x, C, axis)
    lead = x.shape[: x.ndim - 2]
    flat = x.reshape((-1,) + x.shape[-2:])
    out = jax.vmap(lambda m: fn(m, C, axis))(flat)
    return out.reshape(lead + x.shape[-2:])


def _first_match(specs: Sequence[ProjectionSpec], name: str, leaf):
    for spec in specs:
        if re.search(spec.pattern, name) and hasattr(leaf, "ndim") \
                and leaf.ndim >= 2:
            if spec.weights is not None:
                # canonical columns = the non-max axis of the trailing slice
                m = leaf.shape[-2 if spec.axis in (1, -1) else -1]
                if len(spec.weights) != m:
                    raise ValueError(
                        f"spec {spec.pattern!r}: {len(spec.weights)} weights "
                        f"for a leaf with {m} canonical columns "
                        f"(shape {tuple(leaf.shape)})")
            return spec
    return None


def _gated(projected, original, step, every_k):
    if step is not None and every_k > 1:
        do = (step % every_k) == 0
        return jax.tree_util.tree_map(
            lambda p, o: jnp.where(do, p, o), projected, original)
    return projected


def apply_constraints(params: Any, specs: Sequence[ProjectionSpec],
                      step: Optional[jnp.ndarray] = None) -> Any:
    """Project matching leaves of `params`, one launch per matrix.

    ``params``: any pytree (constrained leaves must be >= 2-D, any float
    dtype — the solve runs in f32 and casts back); ``specs``: ordered —
    first matching spec wins per leaf; ``step``: optional scalar int for
    ``every_k`` gating. Returns the projected pytree, same structure/
    dtypes. jit-safe (cond on step % every_k). The packed fast path is
    ``apply_constraints_packed``; this per-leaf form stays as the simple
    reference used by tests and the per-leaf-only norms (l1, hoyer).

    >>> params = apply_constraints(params, (spec,))
    """
    if not specs:
        return params
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    leaves = []
    for path, leaf in flat:
        spec = _first_match(specs, leaf_path_str(path), leaf)
        out = leaf
        if spec is not None:
            engine_count("per_leaf")
            fn = _project_fn(spec)
            projected = _apply_2d(fn, out, spec.radius, spec.axis)
            out = _gated(projected, out, step, spec.every_k)
        leaves.append(out)
    return jax.tree_util.tree_unflatten(treedef, leaves)


# -----------------------------------------------------------------------------
# packed multi-tensor batching
# -----------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _PackedEntry:
    """One leaf's slot inside a packed plan (all fields static)."""
    index: int                 # position in the flattened leaf list
    shape: Tuple[int, ...]     # original leaf shape
    lead: int                  # number of stacked (leading-dim) matrices
    n: int                     # canonical max-axis length
    m: int                     # canonical column count per matrix
    transpose: bool            # spec.axis selected the trailing dim
    radius: float
    m_pad: int                 # m padded up to the lane multiple
    col_start: int             # first column in the packed buffer
    seg_start: int             # first segment id
    weights: Optional[Tuple[float, ...]] = None   # per canonical column


@dataclasses.dataclass(frozen=True)
class PackedPlan:
    """Static packing layout for one (family, every_k) sub-buffer.

    Mixed-family spec lists split into one plan — one packed solve — per
    constraint family (``core.families``): families differ in their
    per-column Newton statistics and their thetas live on different scales,
    so segments never mix across families, but everything of ONE family
    with one ``every_k`` still solves in a single fused sweep.
    """
    key: str
    every_k: int
    n_max: int                 # padded row count of the packed buffer
    total_cols: int
    num_segments: int
    entries: Tuple[_PackedEntry, ...]
    family: str = "l1inf"

    def seg_ids(self) -> np.ndarray:
        """Per-column segment id; ``num_segments`` marks lane padding."""
        sids = np.full((self.total_cols,), self.num_segments, np.int32)
        for e in self.entries:
            for l in range(e.lead):
                lo = e.col_start + l * e.m_pad
                sids[lo : lo + e.m] = e.seg_start + l
        return sids

    def radii(self) -> np.ndarray:
        C = np.zeros((self.num_segments,), np.float32)
        for e in self.entries:
            C[e.seg_start : e.seg_start + e.lead] = e.radius
        return C

    def col_weights(self) -> np.ndarray:
        """Per-column weight vector of the packed buffer (1.0 on lane
        padding and on entries without spec weights) — the ``w_col`` input
        of weight-aware families; stacked matrices repeat their weights."""
        w = np.ones((self.total_cols,), np.float32)
        for e in self.entries:
            if e.weights is None:
                continue
            for l in range(e.lead):
                lo = e.col_start + l * e.m_pad
                w[lo : lo + e.m] = np.asarray(e.weights, np.float32)
        return w

    # -- virtual packing (the fused step, DESIGN.md §11) ---------------------
    # The fused train step never materializes the packed buffer: leaves keep
    # their own layout and only their O(m) per-column statistics are
    # concatenated, in entry order, with NO lane padding. These twins of
    # seg_ids()/col_weights() describe that dense layout.

    def virtual_num_cols(self) -> int:
        """Column count of the dense (un-lane-padded) statistics vector."""
        return sum(e.lead * e.m for e in self.entries)

    def virtual_seg_ids(self) -> np.ndarray:
        """Segment id per dense statistics column (entry order, stacked
        matrices contiguous, no padding sentinel — every column is real)."""
        parts = [np.repeat(np.arange(e.lead, dtype=np.int32) + e.seg_start,
                           e.m)
                 for e in self.entries]
        return (np.concatenate(parts) if parts
                else np.zeros((0,), np.int32))

    def virtual_col_weights(self) -> np.ndarray:
        """Per-column weights for the dense statistics layout (the
        ``w_col`` twin of :meth:`col_weights`)."""
        parts = []
        for e in self.entries:
            if e.weights is None:
                parts.append(np.ones((e.lead * e.m,), np.float32))
            else:
                parts.append(np.tile(np.asarray(e.weights, np.float32),
                                     e.lead))
        return (np.concatenate(parts) if parts
                else np.zeros((0,), np.float32))


def build_packed_plans(params: Any, specs: Sequence[ProjectionSpec]):
    """Split the leaves into packed plans — one per (constraint family,
    every_k) pair — and a per-leaf remainder [(leaf_index, spec)] for the
    unpackable balls (the l1 ball and seg_ops-less families like hoyer).

    ``params``: pytree of arrays or ShapeDtypeStructs (shapes are all that
    is read); ``specs``: ProjectionSpec sequence. Returns
    ``(plans, per_leaf)`` with ``plans`` a list of ``PackedPlan`` (static
    layout: lane-padded column blocks, per-column segment ids, per-segment
    radii). Pure shape bookkeeping — safe to call during tracing.

    >>> plans, per_leaf = build_packed_plans(params, specs)
    """
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    groups: Dict[Tuple[str, int], list] = {}
    per_leaf = []
    for i, (path, leaf) in enumerate(flat):
        spec = _first_match(specs, leaf_path_str(path), leaf)
        if spec is None:
            continue
        fam = family_for_norm(spec.norm)
        if fam is not None and fam.seg_ops is not None:
            groups.setdefault((fam.name, spec.every_k), []).append(
                (i, leaf, spec))
        else:
            per_leaf.append((i, spec))

    plans = []
    for family, every_k in sorted(groups):
        col, seg, entries, n_max = 0, 0, [], 0
        for i, leaf, spec in groups[(family, every_k)]:
            shape = tuple(leaf.shape)
            lead = int(np.prod(shape[:-2])) if len(shape) > 2 else 1
            n, m = shape[-2:]
            transpose = spec.axis in (1, -1)
            if transpose:
                n, m = m, n
            m_pad = -(-m // _LANE) * _LANE
            entries.append(_PackedEntry(
                index=i, shape=shape, lead=lead, n=n, m=m,
                transpose=transpose, radius=float(spec.radius),
                m_pad=m_pad, col_start=col, seg_start=seg,
                weights=spec.weights))
            col += lead * m_pad
            seg += lead
            n_max = max(n_max, n)
        n_max = -(-n_max // _SUBLANE) * _SUBLANE
        plans.append(PackedPlan(
            key=f"{family}_packed/k{every_k}", every_k=every_k, n_max=n_max,
            total_cols=col, num_segments=seg, entries=tuple(entries),
            family=family))
    return plans, per_leaf


def _pack_entry(x: jnp.ndarray, e: _PackedEntry, n_max: int) -> jnp.ndarray:
    """Leaf -> (n_max, lead * m_pad) canonical column block (f32)."""
    x2 = x.reshape((-1,) + x.shape[-2:]) if x.ndim > 2 else x[None]
    if e.transpose:
        x2 = jnp.swapaxes(x2, 1, 2)
    x2 = x2.astype(jnp.float32)
    x2 = jnp.pad(x2, ((0, 0), (0, n_max - e.n), (0, e.m_pad - e.m)))
    return jnp.moveaxis(x2, 0, 1).reshape(n_max, e.lead * e.m_pad)


def _unpack_entry(block: jnp.ndarray, e: _PackedEntry,
                  like: jnp.ndarray) -> jnp.ndarray:
    """(n_max, lead * m_pad) column block -> leaf with `like`'s shape/dtype."""
    x2 = jnp.moveaxis(block.reshape(block.shape[0], e.lead, e.m_pad), 1, 0)
    x2 = x2[:, : e.n, : e.m]
    if e.transpose:
        x2 = jnp.swapaxes(x2, 1, 2)
    return x2.reshape(like.shape).astype(like.dtype)


def _stacked_axis(axis: int, ndim: int) -> int:
    """Map a spec's max axis (defined on the trailing 2-D slice) to the
    corresponding axis of an ndim-rank stacked leaf. Negative axes already
    index from the trailing end, so they pass through unchanged; positive
    axes shift past the leading stack dims."""
    return axis if axis < 0 else axis + ndim - 2


def column_masks(params: Any, specs: Sequence[ProjectionSpec]) -> Any:
    """Per-leaf {0,1} masks from the current column support of matching leaves
    (the paper's double-descent mask M0). Non-matching leaves get ones.

    ``params``: pytree (constrained leaves >= 2-D); returns a pytree of the
    SAME structure/shapes/dtypes where each matching leaf holds 1.0 on
    columns with any nonzero entry (along the spec's max axis, per stacked
    slice for ndim > 2 leaves) and 0.0 on dead columns. The serving path
    (``sae/serve.support_selection``) derives its gather from this same
    mask, so training freeze and serving compaction cannot disagree.

    >>> masks = column_masks(params, (spec,))
    """
    def one(path, leaf):
        name = leaf_path_str(path)
        for spec in specs:
            if re.search(spec.pattern, name) and hasattr(leaf, "ndim") and leaf.ndim >= 2:
                nz = jnp.any(leaf != 0,
                             axis=_stacked_axis(spec.axis, leaf.ndim),
                             keepdims=True)
                return jnp.broadcast_to(nz, leaf.shape).astype(leaf.dtype)
        return jnp.ones_like(leaf)

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in flat])


def apply_masks(tree: Any, masks: Any) -> Any:
    """Elementwise tree * mask (grad masking of Algorithm 3).

    ``tree`` and ``masks``: pytrees of identical structure (broadcastable
    leaves — typically grads and the ``column_masks`` output). Returns the
    masked tree, dtypes following numpy promotion of ``t * m``.

    >>> grads = apply_masks(grads, masks)
    """
    return jax.tree_util.tree_map(lambda t, m: t * m, tree, masks)


def sparsity_report(params: Any, specs: Sequence[ProjectionSpec]) -> dict:
    """Column sparsity (%) per matching leaf — the paper's `Colsp` metric.

    Returns ``{leaf path: float percent}`` of fully-zero columns along the
    spec's max axis (stacked ndim > 2 leaves pool all slices). Host-side
    convenience (floats, not traced values) for logging and benches.

    >>> sparsity_report(params, (spec,))   # {'enc1/w': 99.0}
    """
    out = {}
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in flat:
        name = leaf_path_str(path)
        for spec in specs:
            if re.search(spec.pattern, name) and hasattr(leaf, "ndim") and leaf.ndim >= 2:
                mat = leaf.reshape((-1,) + leaf.shape[-2:]) if leaf.ndim > 2 else leaf[None]
                dead = jnp.all(mat == 0, axis=_stacked_axis(spec.axis, 3))
                out[name] = float(100.0 * jnp.mean(dead.astype(jnp.float32)))
                break
    return out
