"""Training-time integration: structured-sparsity constraints on param pytrees.

A ``ProjectionSpec`` selects parameter leaves by path regex and applies one of
the ball projections after each optimizer update (projected gradient descent,
the paper's Algorithm 3). Leaves with more than 2 dims (scan-stacked layers,
stacked experts) are vmapped over their leading dims so the constraint applies
per layer / per expert.

This module is what makes the paper's technique a first-class framework
feature: every arch config carries a tuple of specs (see configs/*.py).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .l1inf import project_l1inf_newton, project_l1inf_sorted
from .masked import project_l1inf_masked
from .norms import project_l1_ball, project_l12_ball

__all__ = ["ProjectionSpec", "apply_constraints", "column_masks",
           "apply_masks", "sparsity_report", "leaf_path_str"]

_NORMS = {"l1inf", "l1inf_sorted", "l1inf_masked", "l1", "l12"}


@dataclasses.dataclass(frozen=True)
class ProjectionSpec:
    """One structured-sparsity constraint.

    pattern:  regex matched against the '/'-joined param path.
    norm:     l1inf | l1inf_sorted | l1inf_masked | l1 | l12
    radius:   ball radius C (> 0).
    axis:     the *max* axis of the trailing 2-D slice (paper: 0 — columns
              are prunable structures along the other axis).
    every_k:  apply every k optimizer steps (1 = every step).
    """
    pattern: str
    norm: str = "l1inf"
    radius: float = 1.0
    axis: int = 0
    every_k: int = 1

    def __post_init__(self):
        if self.norm not in _NORMS:
            raise ValueError(f"unknown norm {self.norm!r}")
        if self.radius <= 0:
            raise ValueError("radius must be > 0")


def leaf_path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _project_fn(norm: str) -> Callable:
    return {
        "l1inf": lambda x, C, axis: project_l1inf_newton(x, C, axis=axis),
        "l1inf_sorted": lambda x, C, axis: project_l1inf_sorted(x, C, axis=axis),
        "l1inf_masked": lambda x, C, axis: project_l1inf_masked(x, C, axis=axis),
        "l1": lambda x, C, axis: project_l1_ball(x, C),
        "l12": lambda x, C, axis: project_l12_ball(x, C, axis=axis),
    }[norm]


def _apply_2d(fn: Callable, x: jnp.ndarray, C: float, axis: int) -> jnp.ndarray:
    """Apply a 2-D projection to the trailing 2 dims, vmapping leading dims."""
    if x.ndim < 2:
        raise ValueError(f"projection target must have >=2 dims, got {x.shape}")
    if x.ndim == 2:
        return fn(x, C, axis)
    lead = x.shape[: x.ndim - 2]
    flat = x.reshape((-1,) + x.shape[-2:])
    out = jax.vmap(lambda m: fn(m, C, axis))(flat)
    return out.reshape(lead + x.shape[-2:])


def apply_constraints(params: Any, specs: Sequence[ProjectionSpec],
                      step: Optional[jnp.ndarray] = None) -> Any:
    """Project matching leaves of `params`. jit-safe (cond on step % every_k)."""
    if not specs:
        return params
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    leaves = []
    for path, leaf in flat:
        name = leaf_path_str(path)
        out = leaf
        for spec in specs:
            if re.search(spec.pattern, name) and hasattr(leaf, "ndim") and leaf.ndim >= 2:
                fn = _project_fn(spec.norm)
                projected = _apply_2d(fn, out, spec.radius, spec.axis)
                if step is not None and spec.every_k > 1:
                    do = (step % spec.every_k) == 0
                    out = jax.tree_util.tree_map(
                        lambda p, o: jnp.where(do, p, o), projected, out)
                else:
                    out = projected
                break  # first matching spec wins
        leaves.append(out)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def column_masks(params: Any, specs: Sequence[ProjectionSpec]) -> Any:
    """Per-leaf {0,1} masks from the current column support of matching leaves
    (the paper's double-descent mask M0). Non-matching leaves get ones."""
    def one(path, leaf):
        name = leaf_path_str(path)
        for spec in specs:
            if re.search(spec.pattern, name) and hasattr(leaf, "ndim") and leaf.ndim >= 2:
                nz = jnp.any(leaf != 0, axis=spec.axis if leaf.ndim == 2 else
                             (spec.axis - 2 if spec.axis < 0 else spec.axis + leaf.ndim - 2),
                             keepdims=True)
                return jnp.broadcast_to(nz, leaf.shape).astype(leaf.dtype)
        return jnp.ones_like(leaf)

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in flat])


def apply_masks(tree: Any, masks: Any) -> Any:
    """Elementwise tree * mask (grad masking of Algorithm 3)."""
    return jax.tree_util.tree_map(lambda t, m: t * m, tree, masks)


def sparsity_report(params: Any, specs: Sequence[ProjectionSpec]) -> dict:
    """Column sparsity (%) per matching leaf — the paper's `Colsp` metric."""
    out = {}
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    for path, leaf in flat:
        name = leaf_path_str(path)
        for spec in specs:
            if re.search(spec.pattern, name) and hasattr(leaf, "ndim") and leaf.ndim >= 2:
                mat = leaf.reshape((-1,) + leaf.shape[-2:]) if leaf.ndim > 2 else leaf[None]
                ax = spec.axis + 1 if spec.axis >= 0 else spec.axis
                dead = jnp.all(mat == 0, axis=ax)
                out[name] = float(100.0 * jnp.mean(dead.astype(jnp.float32)))
                break
    return out
