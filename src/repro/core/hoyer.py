"""Hoyer l1/l2 sparseness-ratio projection (Thom & Palm, arXiv:1303.5259).

The Hoyer sparseness of a nonzero vector y in R^n is

    sigma(y) = (sqrt(n) - ||y||_1 / ||y||_2) / (sqrt(n) - 1)   in [0, 1]

— 1 for a 1-sparse vector, 0 for a flat one, and invariant to scale. The
constraint set {sigma(y_j) >= s for every column j} is the normalized
sparsity target the radius-based families cannot express (halving C halves
the ball, but sigma is unchanged by scaling): popular in the GSP line of
work (``/root/related/riohib__GSP``; SNIPPETS.md's ``sparse_opt``
exemplar is its sorted closed form).

sigma(y) >= s is equivalent to ||y||_1 <= k ||y||_2 with

    k = sqrt(n) - s (sqrt(n) - 1)   in [1, sqrt(n)],

so the projection preserves each column's energy L2 = ||y||_2, targets
L1 = k L2, and projects b = |y| onto the (nonconvex) sphere-simplex
intersection {z >= 0 : sum z = L1, ||z||_2 = L2}, restoring signs after.
Infeasible columns shrink their small entries to zero; feasible and zero
columns pass through untouched.

Two solvers, per the family contract (``core.families``):

  * ``project_hoyer``     — Hoyer's 2004 alternating projection
    (hyperplane -> sphere-through-midpoint -> zero negatives, repeat;
    each round fixes at least one entry at zero, so <= n rounds),
    vectorized over columns under one ``lax.while_loop``;
  * ``project_hoyer_ref`` — the exact closed form: on the descending-
    sorted column the optimum is z = c1 b + c2 on a top-p active set with
    c1 = sqrt((L2^2 - L1^2/p) / (Q_p - S_p^2/p)), c2 = (L1 - c1 S_p)/p;
    scan every p via cumulative sums, keep the feasible candidates
    (p >= k^2, positive smallest active entry), pick the one of minimal
    distance to b.

Why this family is NOT packable/fusable (DESIGN.md §14): there is no
shared per-segment threshold — each column solves its own 1-D problem in
which the row count n enters the constraint itself through k(n, s), so
zero-row padding CHANGES the constraint (padding rows raise sqrt(n) and
could even receive mass), and the per-column solve needs the sorted
column, not a streaming statistic. The family registers with
``seg_ops=None``: every solver setting routes its specs through the
per-leaf path (``core.constraints``), which is the explicit fallback.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .l1inf import _prep, _post

__all__ = [
    "hoyer_sparseness",
    "project_hoyer",
    "project_hoyer_ref",
]

_FEAS_RTOL = 1e-6   # relative slack on the l1 <= k l2 feasibility test


def hoyer_sparseness(Y: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    """Per-column Hoyer sparseness sigma in [0, 1] along ``axis``.

    ``Y``: any float array (the reduction runs in f32+). Zero columns and
    n = 1 columns are defined as maximally sparse (sigma = 1) — both are
    feasible for every target s, matching the projection's identity
    behavior there.

    >>> sig = hoyer_sparseness(Y)        # (m,) f32, 1 = one-hot columns
    """
    dt = jnp.promote_types(Y.dtype, jnp.float32)
    Yf = jnp.asarray(Y, dt)
    n = Yf.shape[axis]
    l1 = jnp.sum(jnp.abs(Yf), axis=axis)
    l2 = jnp.sqrt(jnp.sum(Yf * Yf, axis=axis))
    if n == 1:
        return jnp.ones_like(l1)
    rn = jnp.sqrt(jnp.asarray(n, dt))
    sig = (rn - l1 / jnp.maximum(l2, jnp.finfo(dt).tiny)) / (rn - 1.0)
    return jnp.where(l2 > 0, sig, jnp.ones_like(sig))


def _hoyer_targets(b, s, n, dt):
    """(feasible mask, L1 target, L2 target, k) for the |.| columns ``b``."""
    l1 = jnp.sum(b, axis=0)
    l2 = jnp.sqrt(jnp.sum(b * b, axis=0))
    rn = jnp.sqrt(jnp.asarray(n, dt))
    k = jnp.clip(rn - jnp.asarray(s, dt) * (rn - 1.0), 1.0, rn)
    feas = jnp.logical_or(l1 <= k * l2 * (1.0 + _FEAS_RTOL), l2 == 0)
    return feas, k * l2, l2, k


def _alternating_cols(b, L1, L2, n):
    """Hoyer's alternating projection, all columns at once. ``b`` (n, m)
    nonneg; ``L1``/``L2`` (m,) targets. Returns z (n, m) >= 0 with
    sum z = L1 and ||z||_2 = L2 per column (up to fp; exact ties of every
    active entry settle on the hyperplane midpoint)."""
    dt = b.dtype
    tiny = jnp.finfo(dt).tiny
    m = b.shape[1]
    z0 = b + (L1 - jnp.sum(b, axis=0))[None, :] / n
    active0 = jnp.ones(b.shape, bool)
    done0 = jnp.zeros((m,), bool)

    def cond(carry):
        i, _, _, done = carry
        return jnp.logical_and(i < n + 2, jnp.logical_not(jnp.all(done)))

    def body(carry):
        i, z, active, done = carry
        p = jnp.sum(active.astype(dt), axis=0)
        mid = jnp.where(active, (L1 / jnp.maximum(p, 1.0))[None, :], 0.0)
        d = z - mid
        A = jnp.sum(d * d, axis=0)
        B = jnp.sum(mid * d, axis=0)
        Cq = jnp.sum(mid * mid, axis=0) - L2 * L2
        disc = jnp.maximum(B * B - A * Cq, 0.0)
        alpha = (-B + jnp.sqrt(disc)) / jnp.maximum(A, tiny)
        zs = mid + alpha[None, :] * d        # on the sphere AND the plane
        colneg = jnp.any(jnp.logical_and(zs < 0, active), axis=0)
        # zero the negatives, fix them, re-project onto the hyperplane
        act2 = jnp.logical_and(active, zs >= 0)
        zc = jnp.maximum(zs, 0.0)
        p2 = jnp.sum(act2.astype(dt), axis=0)
        corr = (L1 - jnp.sum(zc, axis=0)) / jnp.maximum(p2, 1.0)
        zn = jnp.where(act2, zc + corr[None, :], 0.0)
        upd = jnp.logical_not(done)
        z_next = jnp.where(upd[None, :],
                           jnp.where(colneg[None, :], zn, zs), z)
        active_next = jnp.where(upd[None, :],
                                jnp.where(colneg[None, :], act2, active),
                                active)
        done_next = jnp.logical_or(
            done, jnp.logical_and(upd, jnp.logical_not(colneg)))
        return i + 1, z_next, active_next, done_next

    _, z, _, _ = jax.lax.while_loop(
        cond, body, (jnp.asarray(0, jnp.int32), z0, active0, done0))
    return jnp.maximum(z, 0.0)


@functools.partial(jax.jit, static_argnames=("axis",))
def project_hoyer(Y: jnp.ndarray, s, axis: int = 0) -> jnp.ndarray:
    """Project each column of Y to Hoyer sparseness >= s (energy kept).

    ``Y``: (n, m) float matrix (``axis`` selects the within-column dim,
    like the other families' max axis); ``s``: target sparseness in
    (0, 1]. Each column keeps its l2 energy and sign pattern; columns
    already at sigma >= s (and zero columns) are untouched — the operator
    is idempotent. Alternating-projection solve (<= n rounds, jit-safe,
    vmappable for stacked leaves).

    >>> X = project_hoyer(Y, 0.9)        # every column now >= 0.9 sparse
    """
    Yt, transpose, dt = _prep(Y, axis)
    n, m = Yt.shape
    b = jnp.abs(Yt)
    feas, L1, L2, _ = _hoyer_targets(b, s, n, dt)
    z = _alternating_cols(b, L1, L2, n)
    X = jnp.sign(Yt) * z
    X = jnp.where(feas[None, :], Yt, X)
    return _post(X, Y, transpose)


@functools.partial(jax.jit, static_argnames=("axis",))
def project_hoyer_ref(Y: jnp.ndarray, s, axis: int = 0) -> jnp.ndarray:
    """Exact closed-form reference of ``project_hoyer`` (tests/benches).

    Sorts each column, scans every active-set size p via cumulative sums
    (the ``sparse_opt`` construction: z = c1 b + c2 on the top p entries
    with the two Lagrange multipliers in closed form), keeps the feasible
    candidates and picks the one of minimal distance to |y|. O(nm log n);
    the alternating solve must match it to fp tolerance on inputs without
    exact ties.

    >>> X = project_hoyer_ref(Y, 0.9)
    """
    Yt, transpose, dt = _prep(Y, axis)
    n, m = Yt.shape
    tiny = jnp.finfo(dt).tiny
    b = jnp.abs(Yt)
    feas, L1, L2, k = _hoyer_targets(b, s, n, dt)

    bs = jnp.sort(b, axis=0)[::-1]                 # descending per column
    order = jnp.argsort(-b, axis=0)
    inv = jnp.argsort(order, axis=0)
    S = jnp.cumsum(bs, axis=0)                     # S_p at row p-1
    Q = jnp.cumsum(bs * bs, axis=0)
    p = jnp.arange(1, n + 1, dtype=dt)[:, None]

    num = (L2 * L2)[None, :] - (L1 * L1)[None, :] / p
    var = Q - S * S / p
    c1 = jnp.sqrt(jnp.maximum(num, 0.0) / jnp.maximum(var, tiny))
    c2 = (L1[None, :] - c1 * S) / p
    z_small = c1 * bs + c2                         # candidate's smallest entry
    ok = (num >= 0.0) & (var > tiny) & (z_small > 0.0)

    dist = ((c1 - 1.0) ** 2 * Q + 2.0 * (c1 - 1.0) * c2 * S
            + p * c2 * c2 + (Q[-1][None, :] - Q))
    cost = jnp.where(ok, dist, jnp.inf)
    pbest = jnp.argmin(cost, axis=0)               # (m,) row index = p - 1
    c1b = jnp.take_along_axis(c1, pbest[None, :], axis=0)
    c2b = jnp.take_along_axis(c2, pbest[None, :], axis=0)
    rows = jnp.arange(n)[:, None]
    zs = jnp.where(rows <= pbest[None, :],
                   jnp.maximum(c1b * bs + c2b, 0.0), 0.0)

    # degenerate fallback (every active entry exactly tied: var == 0 for
    # all p): spread L1 equally over ceil(k^2) entries
    has = jnp.any(ok, axis=0)
    p0 = jnp.clip(jnp.ceil(k * k), 1.0, float(n))
    zs_fb = jnp.where(rows < p0, (L1 / p0)[None, :], 0.0)
    zs = jnp.where(has[None, :], zs, zs_fb)

    z = jnp.take_along_axis(zs, inv, axis=0)
    X = jnp.sign(Yt) * z
    X = jnp.where(feas[None, :], Yt, X)
    return _post(X, Y, transpose)
