"""l1,2 (group-lasso) ball as a registered constraint family.

The l1,2 ball B = {X : sum_j ||x_j||_2 <= C} is the paper's group-lasso
comparison norm (`norms.py::project_l12_ball` is the sort-based closed
form). Its Euclidean projection factors through per-column *energies*
exactly the way the l1,inf families factor through per-column maxima:

  level 1 (columns -> energies):  nu_j = ||y_j||_2
  level 2 (outer l1 ball):        v    = P_{B_1(C)}(nu)     (simplex thresh)
  inner  (per-column rescale):    x_j  = y_j * v_j / nu_j

Because nu >= 0, level 2 is a soft threshold v_j = (nu_j - theta)_+ with
theta solving g(theta) = sum_j (nu_j - theta)_+ = C — the SAME piecewise-
linear scalar equation the bi-level family solves on column maxima, so the
whole monotone-Newton machinery applies verbatim with statistics

    a_j = nu_j,  b_j = 1,  active_j <=> nu_j >= theta,  mu_j = (nu_j - theta)_+

and a ``finalize`` that SCALES columns by mu_j / nu_j instead of clipping
entries at mu_j. The iteration state is O(m); the solve is one energy
sweep + O(m) Newton + one scale sweep.

Fusability (DESIGN.md §14): the Newton aux is the column-energy vector,
i.e. the square root of a streaming per-column sum — sum_i u_ij^2
accumulates across row tiles exactly like the column maxima the bi-level
family streams. ``_L12SegOps`` therefore provides ``from_colstats`` (with
``colstats_stat = "sq"``: pass 1 of the fused step accumulates sum u^2
instead of sum |u|) and ``fused_mode = "scale"`` (pass 2 multiplies the
recomputed update by a per-column factor instead of clipping), so
``norm="l12"`` plans ride the two-HBM-pass fused and fused_sharded steps
of ``kernels/fused_step`` / ``dist.projection``.

Warm-start contract: identical to ``project_bilevel`` — any theta0 >= 0 is
repaired by the unclamped bootstrap step; packed plans thread one theta
per segment.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .l1inf import _prep, _post
from .norms import l12_norm, project_l12_ball

__all__ = [
    "project_l12_newton",
    "project_l12_stats",
]


class _L12SegOps:
    """Segmented-Newton hooks of the l1,2 family (the ``_PlainSegOps``
    contract of ``core.l1inf``) on per-column energies.

    Structurally ``_BilevelSegOps`` with nu = ||y_j||_2 in place of
    u = max_i |Y_ij| and a scaling ``finalize``: the active convention
    (NOT (nu < theta), ties stay in the tangent with mu = 0) and the
    ``from_colstats`` streaming hook carry over unchanged. Two class
    attributes steer the fused step: ``colstats_stat = "sq"`` makes pass 1
    accumulate sum u^2 into the colsum slot (colmax is unused), and
    ``fused_mode = "scale"`` makes pass 2 multiply the recomputed update by
    the per-column factor ``fused_scale`` derives from (aux, mu) — with
    1.0 as the inside-ball identity sentinel where the clip families use
    ``_MU_INF``.
    """
    uses_weights = False
    colstats_stat = "sq"      # pass-1 colsum accumulates sum u^2 (not sum|u|)
    fused_mode = "scale"      # pass-2 multiplies by a factor (not a clip)

    @staticmethod
    def prepare(A, w=None):
        # A = |Y|, so sum A^2 = sum Y^2: the column energies
        return {"nu": jnp.sqrt(jnp.sum(A * A, axis=0))}

    @staticmethod
    def from_colstats(colsum, colmax, w=None):
        # streaming twin of prepare: under colstats_stat="sq" the colsum
        # slot arrives as sum_i u_ij^2, so aux is just its square root
        return {"nu": jnp.sqrt(colsum)}

    @staticmethod
    def stats(aux, th_col):
        nu = aux["nu"]
        active = jnp.logical_not(nu < th_col)
        mu = jnp.maximum(nu - th_col, 0.0)
        return nu, jnp.ones_like(nu), active, mu

    @staticmethod
    def stats0(aux):
        return aux["nu"], jnp.ones_like(aux["nu"])

    @staticmethod
    def colnorm(aux):
        return aux["nu"]

    @staticmethod
    def death(aux):
        # a column dies as soon as theta passes its energy
        return aux["nu"]

    @staticmethod
    def finalize(Ydt, A, mu):
        nu = jnp.sqrt(jnp.sum(A * A, axis=0))
        tiny = jnp.finfo(Ydt.dtype).tiny
        scale = jnp.where(nu > 0, mu / jnp.maximum(nu, tiny), 0.0)
        return Ydt * scale[None, :]

    @staticmethod
    def fused_scale(aux, mu):
        # per-column multiplier for the fused pass 2 (mode="scale"):
        # x_j = u_j * mu_j / nu_j, zero-energy columns stay zero
        nu = aux["nu"]
        tiny = jnp.finfo(nu.dtype).tiny
        return jnp.where(nu > 0, mu / jnp.maximum(nu, tiny), 0.0)


def _l12_impl(Yt, C, dt, theta0, max_iter):
    """Shared Newton body on the column-energy vector: (X, theta, iters).

    Mirrors ``core.bilevel._bilevel_impl`` structurally (cold bound,
    bootstrap repair, monotone ascent, carried mu) so theta threads
    interchangeably between the per-matrix and packed segmented forms.
    """
    A = jnp.abs(Yt)
    n, m = A.shape
    nu = jnp.sqrt(jnp.sum(A * A, axis=0))
    norm = jnp.sum(nu)
    tiny = jnp.finfo(dt).tiny

    Csafe = jnp.where(C > 0, C, jnp.asarray(1.0, dt))
    cold = jnp.maximum((norm - Csafe) / m, 0.0)
    if theta0 is None:
        start = cold
    else:
        start = jnp.maximum(jnp.maximum(jnp.asarray(theta0, dt), 0.0), cold)

    def eval_step(th):
        active = jnp.logical_not(nu < th)
        Aa = jnp.sum(jnp.where(active, nu, 0.0))
        Ba = jnp.sum(active.astype(dt))
        new = (Aa - Csafe) / jnp.maximum(Ba, tiny)
        mu = jnp.where(active, jnp.maximum(nu - th, 0.0), 0.0)
        return new, mu

    t1 = jnp.maximum(eval_step(start)[0], cold)
    t2, mu1 = eval_step(t1)
    t2 = jnp.maximum(t2, t1)

    def cond(carry):
        i, th, prev, _ = carry
        return jnp.logical_and(i < max_iter, th > prev)

    def body(carry):
        i, th, _, _ = carry
        new, mu = eval_step(th)
        return (i + 1, jnp.maximum(new, th), th, mu)

    iters, theta, prev, mu = jax.lax.while_loop(
        cond, body, (jnp.asarray(2, jnp.int32), t2, t1, mu1))
    mu = jax.lax.cond(theta > prev,
                      lambda: eval_step(theta)[1],
                      lambda: mu)

    scale = jnp.where(nu > 0, mu / jnp.maximum(nu, tiny), 0.0)
    X = Yt * scale[None, :]
    inside = norm <= C
    X = jnp.where(inside, Yt, X)
    X = jnp.where(C > 0, X, jnp.zeros_like(X))
    theta_out = jnp.where(C > 0,
                          jnp.where(inside, jnp.zeros_like(theta), theta),
                          jnp.max(nu, initial=0.0))
    return X, theta_out, iters


@functools.partial(jax.jit, static_argnames=("axis", "max_iter"))
def project_l12_newton(Y: jnp.ndarray, C, axis: int = 0, max_iter: int = 32,
                       *, theta0: Optional[jnp.ndarray] = None
                       ) -> jnp.ndarray:
    """Newton-form l1,2 projection of Y (column l2 over `axis`) at radius C.

    Sort-free: one energy sweep, a monotone Newton on the (m,) energy
    vector (<= ~10 O(m) iterations, 1-2 with a ``theta0`` warm start), and
    one scale sweep. Matches ``project_l12_ball`` to fp tolerance on any
    input. Inside the ball the operator is the identity; C <= 0 maps to
    zero — the same gating as ``project_l1inf_newton``.

    >>> X = project_l12_newton(Y, 1.0)      # sum_j ||x_j||_2 <= 1
    """
    Yt, transpose, dt = _prep(Y, axis)
    C = jnp.asarray(C, dtype=dt)
    X, _, _ = _l12_impl(Yt, C, dt, theta0, max_iter)
    return _post(X, Y, transpose)


@functools.partial(jax.jit, static_argnames=("axis", "max_iter"))
def project_l12_stats(Y: jnp.ndarray, C, axis: int = 0, max_iter: int = 32,
                      *, theta0: Optional[jnp.ndarray] = None):
    """Like ``project_l12_newton`` but returns (X, {"theta", "iters"}).

    >>> X, st = project_l12_stats(Y, 1.0)   # st["theta"] warm-starts a re-solve
    """
    Yt, transpose, dt = _prep(Y, axis)
    C = jnp.asarray(C, dtype=dt)
    X, theta, iters = _l12_impl(Yt, C, dt, theta0, max_iter)
    return _post(X, Y, transpose), {"theta": theta, "iters": iters}
