"""repro — near-linear l1,inf projection (arXiv 2307.09836) grown into a
sharded JAX training/serving stack. See DESIGN.md for the layer map."""
from . import compat as _compat

_compat.install()
