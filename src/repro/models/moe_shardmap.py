"""Manual expert-parallel MoE via shard_map — the §Perf replacement for the
pure-GSPMD sort-based dispatch.

Why: under pjit, the capacity-buffer scatter/gather with cross-shard indices
lowers to replicated-buffer masked all-reduces — measured at ~100 TB/device
per step for deepseek-v2 train_4k (EXPERIMENTS.md §Perf). The shard_map
formulation exploits the 2-D mesh structure instead:

  * activations are data-sharded and *replicated over the model axis* within
    each data row (they already are, post-attention);
  * every model rank owns E/model_size experts (w1/w2/w3 P("model",...));
  * each rank locally selects + buckets the tokens routed to ITS experts
    (x is replicated -> pure local gather, NO dispatch communication);
  * expert FFN on the local (E_loc, cap, d) buffer;
  * one psum over `model` combines the per-rank partial outputs.

Per-layer communication drops from O(E*cap*d) replicated-buffer reductions
to exactly one (T_loc, d) psum + the usual FSDP weight all-gathers (done
explicitly here with lax.all_gather so the traffic is identical to GSPMD's
FSDP handling).
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..dist.sharding import current_rules


def _capacity(T: int, top_k: int, n_experts: int, factor: float) -> int:
    cap = int(math.ceil(T * top_k * factor / n_experts))
    return max(8, -(-cap // 8) * 8)


def moe_apply_shardmap(params, x: jnp.ndarray, *, n_experts: int, top_k: int,
                       capacity_factor: float = 1.25,
                       mlp_kind: str = "swiglu", router_norm: bool = True
                       ) -> Tuple[jnp.ndarray, dict]:
    """Drop-in for moe_apply when a mesh context is active (EP mode only:
    n_experts % model_size == 0). Falls back to local math on 1 device."""
    state = current_rules()
    mesh = state[0] if state else None
    if mesh is None or "model" not in mesh.shape:
        from .moe import moe_apply
        return moe_apply(params, x, n_experts=n_experts, top_k=top_k,
                         capacity_factor=capacity_factor, mlp_kind=mlp_kind)

    B, S, d = x.shape
    data_ax = "data"
    model_size = mesh.shape["model"]
    data_size = mesh.shape[data_ax]
    pod = "pod" in mesh.shape
    batch_axes = ("pod", "data") if pod else ("data",)
    assert n_experts % model_size == 0, (n_experts, model_size)
    E_loc = n_experts // model_size
    eff_data = data_size * (mesh.shape["pod"] if pod else 1)
    T_loc = (B // eff_data) * S
    cap_e = _capacity(T_loc, top_k, n_experts, capacity_factor)

    def body(router_w, w1, w3, w2, x_loc):
        # x_loc: (B_loc, S, d) — replicated over `model`
        # w*: FSDP-sharded over data on the d/ff dim -> gather explicitly
        w1f = jax.lax.all_gather(w1, data_ax, axis=1, tiled=True)
        w3f = jax.lax.all_gather(w3, data_ax, axis=1, tiled=True)
        w2f = jax.lax.all_gather(w2, data_ax, axis=2, tiled=True)
        my_rank = jax.lax.axis_index("model")

        xf = x_loc.reshape(-1, d)
        T = xf.shape[0]
        logits = xf.astype(jnp.float32) @ router_w            # (T, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, idx = jax.lax.top_k(probs, top_k)               # (T, k)
        if router_norm:
            gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

        # local selection: assignments routed to MY experts
        flat_e = idx.reshape(-1)                              # (T*k,)
        flat_g = gate.reshape(-1)
        tok = jnp.arange(T * top_k) // top_k
        mine = (flat_e // E_loc) == my_rank
        e_loc = jnp.where(mine, flat_e % E_loc, E_loc)        # E_loc = drop
        order = jnp.argsort(e_loc)                            # mine first,
        sorted_e = e_loc[order]                               # grouped by e
        grp_start = jnp.searchsorted(sorted_e, jnp.arange(E_loc), "left")
        pos = jnp.arange(T * top_k) - grp_start[jnp.minimum(sorted_e,
                                                            E_loc - 1)]
        keep = (sorted_e < E_loc) & (pos < cap_e)
        dest = jnp.where(keep, sorted_e * cap_e + pos, E_loc * cap_e)

        buf = jnp.zeros((E_loc * cap_e + 1, d), x.dtype)
        buf = buf.at[dest].set(xf[tok[order]])                # local gather
        buf = buf[:-1].reshape(E_loc, cap_e, d)

        h1 = jnp.einsum("ecd,edf->ecf", buf, w1f)
        h3 = jnp.einsum("ecd,edf->ecf", buf, w3f)
        act = jax.nn.silu(h1) if mlp_kind == "swiglu" else jax.nn.gelu(h1)
        out_buf = jnp.einsum("ecf,efd->ecd", act * h3, w2f)

        flat_out = out_buf.reshape(E_loc * cap_e, d)
        gathered = jnp.where(
            keep[:, None],
            flat_out[jnp.minimum(dest, E_loc * cap_e - 1)], 0.0)
        weights = flat_g[order][:, None].astype(x.dtype)
        y = jnp.zeros((T, d), x.dtype).at[tok[order]].add(gathered * weights)
        # each token's k experts may live on other ranks: combine
        y = jax.lax.psum(y, "model")
        y = y.reshape(x_loc.shape)

        me = jnp.mean(probs, axis=0)
        one_hot = jax.nn.one_hot(idx, n_experts, dtype=jnp.float32)
        ce = jnp.mean(jnp.sum(one_hot, axis=1), axis=0)
        lb = n_experts * jnp.sum(me * ce) / top_k
        z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
        dropped = 1.0 - jnp.mean(keep.astype(jnp.float32)) * (
            T * top_k) / jnp.maximum(jnp.sum(mine.astype(jnp.float32)), 1.0)
        aux = {"lb_loss": lb, "z_loss": z,
               "dropped_frac": jnp.clip(dropped, 0.0, 1.0)}
        return y, aux

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(), P("model", "data", None), P("model", "data", None),
                  P("model", None, "data"),
                  P(batch_axes, None, None)),
        out_specs=(P(batch_axes, None, None),
                   {"lb_loss": P(), "z_loss": P(), "dropped_frac": P()}),
        check_rep=False)
    y, aux = fn(params["router"], params["w1"], params["w3"], params["w2"],
                x)
    if "shared" in params:
        from .layers import mlp_apply
        y = y + mlp_apply(params["shared"], x, mlp_kind)
    return y, aux
