"""Attention family: GQA/MQA, sliding-window, cross-attention, MLA.

Training/prefill uses a pure-JAX *chunked* (flash-style) attention — running
max/denominator over KV chunks — so S x S logits are never materialized (a
hard requirement at 32k prefill; the Pallas flash kernel in
repro/kernels/flash_attention is the TPU drop-in for the same math).
Irrelevant (fully masked) KV chunks are skipped with lax.cond.

Decode attends one new token against a KV cache; with sequence-parallel
rules the cache seq dim is sharded over `data` and GSPMD lowers the softmax
reductions to the flash-decoding all-reduce pattern.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .param import PM
from .layers import apply_rope
from ..dist.sharding import shard

_NEG = -1e30


# ------------------------------ layouts -------------------------------------

def attn_layout(d: int, n_heads: int, n_kv: int, head_dim: int,
                qkv_bias: bool = False):
    lay = {
        "wq": PM((d, n_heads, head_dim), ("fsdp", "heads", None), init="scaled"),
        "wk": PM((d, n_kv, head_dim), ("fsdp", "kv_heads", None), init="scaled"),
        "wv": PM((d, n_kv, head_dim), ("fsdp", "kv_heads", None), init="scaled"),
        "wo": PM((n_heads, head_dim, d), ("heads", None, "fsdp"), init="scaled"),
    }
    if qkv_bias:
        lay["bq"] = PM((n_heads, head_dim), ("heads", None), init="zeros")
        lay["bk"] = PM((n_kv, head_dim), ("kv_heads", None), init="zeros")
        lay["bv"] = PM((n_kv, head_dim), ("kv_heads", None), init="zeros")
    return lay


def mla_layout(d: int, n_heads: int, q_lora: int, kv_lora: int,
               nope: int, rope: int, v_dim: int):
    return {
        "wq_a": PM((d, q_lora), ("fsdp", None), init="scaled"),
        "q_norm": PM((q_lora,), (None,), init="ones"),
        "wq_b": PM((q_lora, n_heads, nope + rope), (None, "heads", None),
                   init="scaled"),
        "wkv_a": PM((d, kv_lora + rope), ("fsdp", None), init="scaled"),
        "kv_norm": PM((kv_lora,), (None,), init="ones"),
        "wk_b": PM((kv_lora, n_heads, nope), (None, "heads", None),
                   init="scaled"),
        "wv_b": PM((kv_lora, n_heads, v_dim), (None, "heads", None),
                   init="scaled"),
        "wo": PM((n_heads, v_dim, d), ("heads", None, "fsdp"), init="scaled"),
    }


# --------------------------- chunked attention ------------------------------

def _chunk_body(qc, kc, vc, q_pos, kv_pos, carry, causal, window, scale):
    """One (q_chunk x kv_chunk) tile of online-softmax attention.

    qc: (B, cq, KV, R, hd); kc/vc: (B, ck, KV, hd);
    carry = (acc (B,cq,KV,R,hd) f32, m (B,cq,KV,R) f32, l like m).

    Explicit sharding pins: remat recompute + scan bodies can drop the
    batch/kv-head sharding of captured chunk tensors (measured as ~16x
    replicated tile traffic, EXPERIMENTS.md §Perf gemma iteration 2).
    """
    acc, m, l = carry
    qc = shard(qc, "batch", "attn_seq", "kv_heads", None, None)
    kc = shard(kc, "batch", None, "kv_heads", None)
    vc = shard(vc, "batch", None, "kv_heads", None)
    logits = jnp.einsum("bqkrh,bskh->bqkrs", qc.astype(jnp.float32),
                        kc.astype(jnp.float32)) * scale  # (B,cq,KV,R,ck)
    mask = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    if causal:
        mask &= q_pos[:, None] >= kv_pos[None, :]
    if window:
        mask &= (q_pos[:, None] - kv_pos[None, :]) < window
    mask_b = mask[None, :, None, None, :]
    logits = jnp.where(mask_b, logits, _NEG)
    m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
    p = jnp.exp(logits - m_new[..., None])
    p = jnp.where(mask_b, p, 0.0)
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1)
    acc_new = acc * corr[..., None] + jnp.einsum(
        "bqkrs,bskh->bqkrh", p, vc.astype(jnp.float32))
    return acc_new, m_new, l_new


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool = True, window: int = 0,
                      q_chunk: int = 512, kv_chunk: int = 512,
                      q_offset: int = 0,
                      sliced_window: bool = False) -> jnp.ndarray:
    """q: (B, Sq, KV, R, hd); k/v: (B, Skv, KV, hd) -> (B, Sq, KV, R, hd).

    Online-softmax over KV chunks; fully-masked tiles are skipped via
    lax.cond (halves causal FLOPs at runtime). Each q-chunk row is wrapped
    in jax.checkpoint so the backward pass RECOMPUTES tile probabilities
    (flash-attention semantics) instead of storing every
    (q_chunk x kv_chunk) tile — without this, training at 4k+ context
    stores O(S^2) probabilities and blows HBM.
    """
    B, Sq, KV, R, hd = q.shape
    Skv = k.shape[1]
    v_hd = v.shape[-1]          # may differ from hd (MLA)
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    if Sq % q_chunk:
        q_chunk = Sq            # non-divisible (rare): single chunk
    if Skv % kv_chunk:
        kv_chunk = Skv          # e.g. 1600 image tokens vs 512 chunks
    assert Sq % q_chunk == 0 and Skv % kv_chunk == 0, (Sq, q_chunk, Skv, kv_chunk)
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    scale = hd ** -0.5

    q_r = q.reshape(B, nq, q_chunk, KV, R, hd)

    # sliced-window fast path: each q chunk attends at most the trailing
    # (window + q_chunk) keys — slice just that range so the lowered HLO is
    # O(S*window), not O(S^2)-masked (gemma3/mixtral/hymba local layers).
    use_slice = (sliced_window and window and causal
                 and 0 < window + q_chunk < Skv)
    if use_slice:
        W2 = min(Skv, -(-(window + q_chunk) // kv_chunk) * kv_chunk)
        nk_eff = W2 // kv_chunk
    else:
        k_r = k.reshape(B, nk, kv_chunk, KV, hd)
        v_r = v.reshape(B, nk, kv_chunk, KV, v_hd)
        nk_eff = nk

    def per_q_chunk(iq, qc):
        q_pos = q_offset + iq * q_chunk + jnp.arange(q_chunk)
        if use_slice:
            q_end = iq * q_chunk + q_chunk
            start = jnp.clip(q_end - W2, 0, Skv - W2)
            ks = jax.lax.dynamic_slice(k, (0, start, 0, 0),
                                       (B, W2, KV, hd))
            vs = jax.lax.dynamic_slice(v, (0, start, 0, 0),
                                       (B, W2, KV, v_hd))
            ks_r = ks.reshape(B, nk_eff, kv_chunk, KV, hd)
            vs_r = vs.reshape(B, nk_eff, kv_chunk, KV, v_hd)
        else:
            start = 0
            ks_r, vs_r = k_r, v_r

        def kv_step(carry, ik):
            kc = ks_r[:, ik]
            vc = vs_r[:, ik]
            kv_pos = start + ik * kv_chunk + jnp.arange(kv_chunk)
            relevant = jnp.asarray(True)
            if causal:
                relevant &= kv_pos[0] <= q_pos[-1]
            if window:
                relevant &= (q_pos[0] - kv_pos[-1]) < window

            def compute(c):
                return _chunk_body(qc, kc, vc, q_pos, kv_pos, c,
                                   causal, window, scale)

            carry = jax.lax.cond(relevant, compute, lambda c: c, carry)
            return carry, None

        acc0 = shard(jnp.zeros((B, q_chunk, KV, R, v_hd), jnp.float32),
                     "batch", "attn_seq", "kv_heads", None, None)
        m0 = jnp.full((B, q_chunk, KV, R), _NEG, jnp.float32)
        l0 = jnp.zeros((B, q_chunk, KV, R), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0),
                                      jnp.arange(nk_eff))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    per_q_chunk = jax.checkpoint(per_q_chunk,
                                 static_argnums=())  # flash-style recompute
    outs = jax.lax.map(lambda i: per_q_chunk(i, q_r[:, i]), jnp.arange(nq))
    # outs: (nq, B, cq, KV, R, v_hd) -> (B, Sq, KV, R, v_hd)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, KV, R, v_hd)
    return out.astype(q.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, pos: jnp.ndarray,
                     window: int = 0) -> jnp.ndarray:
    """One-token attention against a cache.

    q: (B, 1, KV, R, hd); caches: (B, Smax, KV, hd); pos: current position
    (tokens at indices <= pos are valid) — a scalar shared by the batch
    (cohort decode) or a (B,) vector of per-row positions (continuous
    batching: every slot sits at its own depth, DESIGN.md §13).
    """
    B, _, KVh, R, hd = q.shape
    Smax = k_cache.shape[1]
    scale = hd ** -0.5
    logits = jnp.einsum("bqkrh,bskh->bqkrs", q.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    kv_pos = jnp.arange(Smax)
    pos = jnp.asarray(pos)
    pos_b = pos[:, None] if pos.ndim else pos
    valid = kv_pos <= pos_b                       # () or (B,) -> bcast
    if window:
        valid &= kv_pos > pos_b - window
    valid = jnp.broadcast_to(valid, (B, Smax))
    logits = jnp.where(valid[:, None, None, None, :], logits, _NEG)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bqkrs,bskh->bqkrh", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


def _decode_positions(pos, B: int) -> jnp.ndarray:
    """Normalize a decode position argument to (B, 1) int32 for RoPE:
    scalar pos broadcasts over the batch, a (B,) vector is per-row."""
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        return jnp.full((B, 1), pos, jnp.int32)
    return pos.astype(jnp.int32)[:, None]


def _cache_write(cache: jnp.ndarray, new: jnp.ndarray, pos) -> jnp.ndarray:
    """Write one new timestep into a (B, Smax, ...) cache at `pos` — a
    dynamic_update_slice for scalar pos (cohort decode), a per-row scatter
    for (B,) pos (continuous batching). Values written are identical; the
    scatter drops out-of-range rows (inactive slots clamp their pos)."""
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(
            cache, new.astype(cache.dtype), pos, axis=1)
    B = cache.shape[0]
    return cache.at[jnp.arange(B), pos].set(new[:, 0].astype(cache.dtype),
                                            mode="drop")


# ------------------------------ GQA module ----------------------------------

def _project_qkv(params, x, n_heads, n_kv, head_dim, positions, rope_theta,
                 rope_frac):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if rope_theta:
        q = apply_rope(q, positions, rope_theta, rope_frac)
        k = apply_rope(k, positions, rope_theta, rope_frac)
    return q, k, v


def attn_apply(params, x, *, n_heads: int, n_kv: int, head_dim: int,
               positions, causal: bool = True, window: int = 0,
               rope_theta: float = 10000.0, rope_frac: float = 1.0,
               q_chunk: int = 512, kv_chunk: int = 512,
               sliced_window: bool = False) -> jnp.ndarray:
    """Full-sequence (train / prefill) GQA. x: (B, S, d).

    Sequence parallelism: when the mesh rules define "attn_seq" (archs whose
    head counts don't divide the model axis), the attention interior is
    sharded over the query-sequence dim — otherwise every model-axis rank
    would redundantly compute the full attention."""
    B, S, d = x.shape
    R = n_heads // n_kv
    x = shard(x, "batch", "attn_seq", "embed")
    q, k, v = _project_qkv(params, x, n_heads, n_kv, head_dim, positions,
                           rope_theta, rope_frac)
    q = shard(q, "batch", "attn_seq", "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    qg = q.reshape(B, S, n_kv, R, head_dim)
    out = chunked_attention(qg, k, v, causal=causal, window=window,
                            q_chunk=q_chunk, kv_chunk=kv_chunk,
                            sliced_window=sliced_window)
    out = out.reshape(B, S, n_heads, head_dim)
    out = shard(out, "batch", "attn_seq", "heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return shard(y, "batch", "seq", "embed")


def attn_prefill_cache(params, x, *, n_heads, n_kv, head_dim, positions,
                       rope_theta=10000.0, rope_frac=1.0):
    """K/V for cache initialization from a prefilled sequence."""
    _, k, v = _project_qkv(params, x, n_heads, n_kv, head_dim, positions,
                           rope_theta, rope_frac)
    return k, v


def attn_decode(params, x, cache: Tuple[jnp.ndarray, jnp.ndarray],
                pos, *, n_heads: int, n_kv: int, head_dim: int,
                window: int = 0, rope_theta: float = 10000.0,
                rope_frac: float = 1.0):
    """One-token decode. x: (B, 1, d); cache: (k, v) each (B, Smax, KV, hd);
    pos: int32 index of the new token — scalar (whole batch at one depth)
    or (B,) per-row (continuous batching). Returns (y, new_cache)."""
    B = x.shape[0]
    positions = _decode_positions(pos, B)
    q, k_new, v_new = _project_qkv(params, x, n_heads, n_kv, head_dim,
                                   positions, rope_theta, rope_frac)
    k_cache, v_cache = cache
    k_cache = _cache_write(k_cache, k_new, pos)
    v_cache = _cache_write(v_cache, v_new, pos)
    k_cache = shard(k_cache, "cache_batch", "cache_seq", "kv_heads", None)
    v_cache = shard(v_cache, "cache_batch", "cache_seq", "kv_heads", None)
    R = n_heads // n_kv
    qg = q.reshape(B, 1, n_kv, R, head_dim)
    out = decode_attention(qg, k_cache, v_cache, pos, window=window)
    out = out.reshape(B, 1, n_heads, head_dim)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, (k_cache, v_cache)


# ---------------------------- cross attention -------------------------------

def cross_attn_layout(d: int, n_heads: int, head_dim: int, d_mem: int):
    return {
        "wq": PM((d, n_heads, head_dim), ("fsdp", "heads", None), init="scaled"),
        "wk": PM((d_mem, n_heads, head_dim), ("fsdp", "heads", None), init="scaled"),
        "wv": PM((d_mem, n_heads, head_dim), ("fsdp", "heads", None), init="scaled"),
        "wo": PM((n_heads, head_dim, d), ("heads", None, "fsdp"), init="scaled"),
    }


def cross_attn_apply(params, x, memory, *, n_heads: int, head_dim: int,
                     q_chunk: int = 512, kv_chunk: int = 512):
    """x: (B, S, d) queries; memory: (B, Sm, d_mem) keys/values (no RoPE)."""
    B, S, _ = x.shape
    x = shard(x, "batch", "attn_seq", "embed")
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", memory, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", memory, params["wv"])
    q = shard(q, "batch", "attn_seq", "heads", None)
    qg = q.reshape(B, S, n_heads, 1, head_dim)
    out = chunked_attention(qg, k, v, causal=False, q_chunk=q_chunk,
                            kv_chunk=kv_chunk)
    out = out.reshape(B, S, n_heads, head_dim)
    out = shard(out, "batch", "attn_seq", "heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return shard(y, "batch", "seq", "embed")


# -------------------------------- MLA ---------------------------------------

def _mla_qkv(params, x, n_heads, nope, rope_dim, positions, rope_theta):
    from .layers import rmsnorm_apply
    cq = rmsnorm_apply({"scale": params["q_norm"]}, x @ params["wq_a"])
    q = jnp.einsum("bsl,lhk->bshk", cq, params["wq_b"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, rope_theta)

    ckr = x @ params["wkv_a"]
    kv_lora = params["wkv_a"].shape[1] - rope_dim
    c, k_rope_raw = ckr[..., :kv_lora], ckr[..., kv_lora:]
    c = rmsnorm_apply({"scale": params["kv_norm"]}, c)
    k_rope = apply_rope(k_rope_raw, positions, rope_theta)  # (B,S,rope)
    return q_nope, q_rope, c, k_rope


def mla_apply(params, x, *, n_heads: int, nope: int, rope_dim: int,
              v_dim: int, positions, rope_theta: float = 10000.0,
              q_chunk: int = 512, kv_chunk: int = 512) -> jnp.ndarray:
    """Multi-head Latent Attention, full-sequence form (train / prefill)."""
    B, S, _ = x.shape
    q_nope, q_rope, c, k_rope = _mla_qkv(params, x, n_heads, nope, rope_dim,
                                         positions, rope_theta)
    k_nope = jnp.einsum("bsl,lhk->bshk", c, params["wk_b"])
    v = jnp.einsum("bsl,lhk->bshk", c, params["wv_b"])
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                (B, S, n_heads, rope_dim))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    qg = q_full.reshape(B, S, n_heads, 1, nope + rope_dim)
    # note: v_dim may differ from qk dim; chunked_attention only needs
    # matching k/q dims — pad v path via separate einsum shape
    out = chunked_attention(qg, k_full, v, causal=True, q_chunk=q_chunk,
                            kv_chunk=kv_chunk)
    out = out.reshape(B, S, n_heads, v_dim)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"])


def mla_decode(params, x, cache, pos, *, n_heads: int, nope: int,
               rope_dim: int, v_dim: int, rope_theta: float = 10000.0,
               absorb: bool = False):
    """MLA decode with the *compressed* cache (c, k_rope) — (B, Smax,
    kv_lora) + (B, Smax, rope). `absorb=True` uses the matrix-absorbed form
    (q projected into latent space; no per-step K/V materialization).
    `pos` may be a scalar or a (B,) per-row position vector."""
    B = x.shape[0]
    positions = _decode_positions(pos, B)
    q_nope, q_rope, c_new, k_rope_new = _mla_qkv(
        params, x, n_heads, nope, rope_dim, positions, rope_theta)
    c_cache, kr_cache = cache
    c_cache = _cache_write(c_cache, c_new, pos)
    kr_cache = _cache_write(kr_cache, k_rope_new, pos)
    c_cache = shard(c_cache, "cache_batch", "cache_seq", None)
    kr_cache = shard(kr_cache, "cache_batch", "cache_seq", None)
    Smax = c_cache.shape[1]
    scale = (nope + rope_dim) ** -0.5
    pos_a = jnp.asarray(pos)
    valid = jnp.broadcast_to(
        jnp.arange(Smax) <= (pos_a[:, None] if pos_a.ndim else pos_a),
        (B, Smax))

    if absorb:
        # q_nope (B,1,H,nope) @ wk_b^T -> latent space (B,1,H,kv_lora)
        q_lat = jnp.einsum("bqhk,lhk->bqhl", q_nope.astype(jnp.float32),
                           params["wk_b"].astype(jnp.float32))
        logits = (jnp.einsum("bqhl,bsl->bqhs", q_lat,
                             c_cache.astype(jnp.float32))
                  + jnp.einsum("bqhk,bsk->bqhs", q_rope.astype(jnp.float32),
                               kr_cache.astype(jnp.float32))) * scale
        logits = jnp.where(valid[:, None, None, :], logits, _NEG)
        p = jax.nn.softmax(logits, axis=-1)
        o_lat = jnp.einsum("bqhs,bsl->bqhl", p, c_cache.astype(jnp.float32))
        out = jnp.einsum("bqhl,lhk->bqhk", o_lat,
                         params["wv_b"].astype(jnp.float32)).astype(x.dtype)
    else:
        k_nope = jnp.einsum("bsl,lhk->bshk", c_cache, params["wk_b"])
        v = jnp.einsum("bsl,lhk->bshk", c_cache, params["wv_b"])
        k_rope_h = jnp.broadcast_to(
            kr_cache[:, :, None, :], kr_cache.shape[:2] + (n_heads, rope_dim))
        k_full = jnp.concatenate([k_nope, k_rope_h], axis=-1)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        logits = jnp.einsum("bqhk,bshk->bqhs", q_full.astype(jnp.float32),
                            k_full.astype(jnp.float32)) * scale
        logits = jnp.where(valid[:, None, None, :], logits, _NEG)
        p = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bqhs,bshk->bqhk", p,
                         v.astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("bqhk,hkd->bqd", out, params["wo"])
    return y, (c_cache, kr_cache)
