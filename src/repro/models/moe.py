"""Mixture-of-Experts layer: top-k router + sort-based dispatch/combine.

Dispatch is the capacity-bounded sort approach (MaxText-style): token-expert
assignments are sorted by expert id, bucketed into an (E, capacity, d)
buffer, processed with a single batched einsum over the (possibly
expert-sharded) stacked expert weights, and scatter-added back with the gate
weights. Overflowing tokens are dropped (capacity factor controls the rate).

Expert sharding: "ep" shards the leading expert dim over the `model` mesh
axis (deepseek, 160 experts); "tp" shards each expert's d_ff instead
(mixtral, 8 experts < mesh axis).

Aux outputs: switch-style load-balance loss + router z-loss.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from .param import PM
from .layers import mlp_layout, mlp_apply, scatter_residual
from ..dist.sharding import shard


def moe_layout(d: int, d_ff: int, n_experts: int, n_shared: int = 0,
               shared_ff: int = 0, expert_sharding: str = "ep",
               mlp_kind: str = "swiglu"):
    e_ax = "experts" if expert_sharding == "ep" else None
    ff_ax = None if expert_sharding == "ep" else "mlp"
    lay = {
        "router": PM((d, n_experts), (None, None), init="scaled",
                     dtype=jnp.float32),
        "w1": PM((n_experts, d, d_ff), (e_ax, "fsdp", ff_ax), init="scaled"),
        "w3": PM((n_experts, d, d_ff), (e_ax, "fsdp", ff_ax), init="scaled"),
        "w2": PM((n_experts, d_ff, d), (e_ax, ff_ax, "fsdp"), init="scaled"),
    }
    if n_shared:
        lay["shared"] = mlp_layout(d, shared_ff or d_ff * n_shared, mlp_kind)
    return lay


def _capacity(T: int, top_k: int, n_experts: int, factor: float) -> int:
    cap = int(math.ceil(T * top_k * factor / n_experts))
    return max(8, -(-cap // 8) * 8)  # round up to a multiple of 8


def moe_apply(params, x: jnp.ndarray, *, n_experts: int, top_k: int,
              capacity_factor: float = 1.25, mlp_kind: str = "swiglu",
              router_norm: bool = True, expert_sharding: str = "ep"
              ) -> Tuple[jnp.ndarray, dict]:
    """x: (B, S, d) -> (y, aux). Gate weights renormalized over the top-k."""
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)

    logits = (xf.astype(jnp.float32) @ params["router"])        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, top_k)                     # (T, k)
    if router_norm:
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # ---- sort-based dispatch ------------------------------------------
    cap = _capacity(T, top_k, n_experts, capacity_factor)
    flat_e = idx.reshape(-1)                                    # (T*k,)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    tok = order // top_k
    grp_start = jnp.searchsorted(sorted_e, jnp.arange(n_experts),
                                 side="left")
    pos = jnp.arange(T * top_k) - grp_start[sorted_e]
    keep = pos < cap
    dest = jnp.where(keep, sorted_e * cap + pos, n_experts * cap)

    # constraint axes per mode: EP shards the expert dim, TP shards d_ff
    e_ax = "experts" if expert_sharding == "ep" else None
    f_ax = None if expert_sharding == "ep" else "mlp"

    buf = jnp.zeros((n_experts * cap + 1, d), x.dtype)
    buf = buf.at[dest].set(xf[tok])
    buf = buf[:-1].reshape(n_experts, cap, d)
    buf = shard(buf, e_ax, "expert_cap", "embed")

    # ---- expert FFN (batched over E) -----------------------------------
    h1 = jnp.einsum("ecd,edf->ecf", buf, params["w1"])
    h3 = jnp.einsum("ecd,edf->ecf", buf, params["w3"])
    act = jax.nn.silu(h1) if mlp_kind == "swiglu" else jax.nn.gelu(h1)
    hidden = shard(act * h3, e_ax, "expert_cap", f_ax)
    out_buf = jnp.einsum("ecf,efd->ecd", hidden, params["w2"])
    # compact-serving path (DESIGN.md §10): expert w2 with residual-output
    # columns compiled out produces a narrow buffer; scatter back to d so
    # the combine below stays width-invariant (static shape test)
    if out_buf.shape[-1] != d:
        out_buf = scatter_residual(out_buf, params["w2_sel"], d)
    out_buf = shard(out_buf, e_ax, "expert_cap", "embed")

    # ---- combine --------------------------------------------------------
    flat_out = out_buf.reshape(n_experts * cap, d)
    gathered = jnp.where(keep[:, None],
                         flat_out[jnp.minimum(dest, n_experts * cap - 1)],
                         0.0)
    weights = gate.reshape(-1)[order][:, None].astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype).at[tok].add(gathered * weights)
    y = y.reshape(B, S, d)
    y = shard(y, "batch", "seq", "embed")

    # ---- shared experts (always-on dense path, deepseek) ----------------
    if "shared" in params:
        y = y + mlp_apply(params["shared"], x, mlp_kind)

    # ---- aux losses ------------------------------------------------------
    me = jnp.mean(probs, axis=0)                                 # (E,)
    one_hot = jax.nn.one_hot(idx, n_experts, dtype=jnp.float32)  # (T,k,E)
    ce = jnp.mean(jnp.sum(one_hot, axis=1), axis=0)              # frac routed
    lb_loss = n_experts * jnp.sum(me * ce) / top_k
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    aux = {"lb_loss": lb_loss, "z_loss": z_loss, "dropped_frac": dropped}
    return y, aux
