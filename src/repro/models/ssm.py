"""Mamba2 SSD (state-space duality) block — chunked training scan +
recurrent single-token decode. [arXiv:2405.21060]

Recurrence (per head h, head dim P, state dim N):
    h_t = exp(a_h dt_t) h_{t-1} + dt_t B_t x_t^T       (h_t in R^{P x N})
    y_t = h_t C_t + D_h x_t
Chunked form (Dao & Gu 2024): intra-chunk quadratic attention-like term +
inter-chunk recurrence over per-chunk states (lax.scan over chunks).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .param import PM
from .layers import rmsnorm_apply
from ..dist.sharding import shard

CONV_W = 4  # causal depthwise conv width


def ssm_layout(d: int, d_inner: int, n_state: int, headdim: int):
    H = d_inner // headdim
    return {
        "wz": PM((d, d_inner), ("fsdp", "mlp"), init="scaled"),
        "wx": PM((d, d_inner), ("fsdp", "mlp"), init="scaled"),
        "wB": PM((d, n_state), ("fsdp", None), init="scaled"),
        "wC": PM((d, n_state), ("fsdp", None), init="scaled"),
        "wdt": PM((d, H), ("fsdp", None), init="scaled"),
        "dt_bias": PM((H,), (None,), init="zeros"),
        "A_log": PM((H,), (None,), init="zeros"),
        "D": PM((H,), (None,), init="ones"),
        "conv_x": PM((CONV_W, d_inner), (None, "mlp"), init="scaled"),
        "conv_B": PM((CONV_W, n_state), (None, None), init="scaled"),
        "conv_C": PM((CONV_W, n_state), (None, None), init="scaled"),
        "norm": PM((d_inner,), (None,), init="ones"),
        "wo": PM((d_inner, d), ("mlp", "fsdp"), init="scaled"),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv, width CONV_W. x: (B, S, D); w: (CONV_W, D)."""
    pad = jnp.pad(x, ((0, 0), (CONV_W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1]] * w[i] for i in range(CONV_W))
    return jax.nn.silu(out)


def _causal_conv_step(x_new, tail, w):
    """x_new: (B, 1, D); tail: (B, CONV_W-1, D) previous inputs."""
    window = jnp.concatenate([tail, x_new], axis=1)       # (B, CONV_W, D)
    out = jnp.einsum("bwd,wd->bd", window, w)[:, None]
    return jax.nn.silu(out), window[:, 1:]


def _ssd_inputs(params, u):
    """u: (B, S, d) -> z, x (B,S,H,P), B/C (B,S,N), dt (B,S,H), a (H,)."""
    z = u @ params["wz"]
    x = u @ params["wx"]
    Bm = u @ params["wB"]
    Cm = u @ params["wC"]
    dt_raw = u @ params["wdt"]
    return z, x, Bm, Cm, dt_raw


def ssd_apply(params, u: jnp.ndarray, *, headdim: int, chunk: int = 64,
              tile_bf16: bool = False) -> jnp.ndarray:
    """Full-sequence chunked SSD. u: (B, S, d).

    tile_bf16: compute the quadratic intra-chunk tiles (L, G) in bf16 —
    halves the dominant HBM traffic; decay cumsums and the inter-chunk
    state scan stay f32 (§Perf lever)."""
    B_, S, d = u.shape
    z, x, Bm, Cm, dt_raw = _ssd_inputs(params, u)
    x = _causal_conv(x, params["conv_x"])
    Bm = _causal_conv(Bm, params["conv_B"])
    Cm = _causal_conv(Cm, params["conv_C"])
    x = shard(x, "batch", "seq", "mlp")

    H = params["A_log"].shape[0]
    P = headdim
    N = Bm.shape[-1]
    xh = x.reshape(B_, S, H, P).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,S,H)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))              # (H,) < 0
    da = dt * a[None, None, :]                                     # (B,S,H)

    nc = S // chunk
    assert S % chunk == 0, (S, chunk)
    Q = chunk
    da_c = da.reshape(B_, nc, Q, H)
    dt_c = dt.reshape(B_, nc, Q, H)
    x_c = xh.reshape(B_, nc, Q, H, P)
    B_c = Bm.reshape(B_, nc, Q, N).astype(jnp.float32)
    C_c = Cm.reshape(B_, nc, Q, N).astype(jnp.float32)

    cum = jnp.cumsum(da_c, axis=2)                                 # (B,nc,Q,H)
    seg_total = cum[:, :, -1]                                      # (B,nc,H)

    # ---- intra-chunk (quadratic within chunk) ------------------------------
    # L[b,c,h,i,j] = exp(cum_i - cum_j) for i >= j else 0
    tdt = jnp.bfloat16 if tile_bf16 else jnp.float32
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]           # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(tri[None, None, :, :, None], jnp.exp(diff), 0.0).astype(tdt)
    G = jnp.einsum("bcin,bcjn->bcij", C_c.astype(tdt),
                   B_c.astype(tdt))                                # (B,nc,Q,Q)
    M = G[..., None] * L                                           # (B,nc,Q,Q,H)
    intra = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", M, dt_c.astype(tdt),
                       x_c.astype(tdt)).astype(jnp.float32)

    # ---- chunk states + inter-chunk recurrence -----------------------------
    decay_to_end = jnp.exp(seg_total[:, :, None, :] - cum)         # (B,nc,Q,H)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn",
                        B_c, dt_c * decay_to_end, x_c)

    def scan_chunks(h_prev, inp):
        st, seg = inp                                              # (B,H,P,N), (B,H)
        h_new = h_prev * jnp.exp(seg)[:, :, None, None] + st
        return h_new, h_prev

    h0 = jnp.zeros((B_, H, P, N), jnp.float32)
    _, h_before = jax.lax.scan(
        scan_chunks, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(seg_total, 1, 0)))
    h_before = jnp.moveaxis(h_before, 0, 1)                        # (B,nc,H,P,N)

    inter = jnp.einsum("bcin,bcih,bchpn->bcihp",
                       C_c, jnp.exp(cum), h_before)

    y = (intra + inter).reshape(B_, S, H, P)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(B_, S, H * P).astype(u.dtype)

    # gated output norm (mamba2: RMSNorm(y * silu(z)))
    y = rmsnorm_apply({"scale": params["norm"]}, y * jax.nn.silu(z))
    return y @ params["wo"]


def ssm_init_cache(B: int, d_inner: int, n_state: int, headdim: int,
                   dtype=jnp.float32):
    H = d_inner // headdim
    return {
        "state": jnp.zeros((B, H, headdim, n_state), jnp.float32),
        "conv_x": jnp.zeros((B, CONV_W - 1, d_inner), dtype),
        "conv_B": jnp.zeros((B, CONV_W - 1, n_state), dtype),
        "conv_C": jnp.zeros((B, CONV_W - 1, n_state), dtype),
    }


def ssd_decode(params, u, cache, *, headdim: int):
    """Single-token recurrent step. u: (B, 1, d). Returns (y, new_cache)."""
    B_ = u.shape[0]
    z, x, Bm, Cm, dt_raw = _ssd_inputs(params, u)
    x, conv_x = _causal_conv_step(x, cache["conv_x"], params["conv_x"])
    Bm, conv_B = _causal_conv_step(Bm, cache["conv_B"], params["conv_B"])
    Cm, conv_C = _causal_conv_step(Cm, cache["conv_C"], params["conv_C"])

    H = params["A_log"].shape[0]
    P = headdim
    xh = x.reshape(B_, H, P).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # (B,H)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a[None, :])                               # (B,H)

    state = cache["state"]                                          # (B,H,P,N)
    state = (state * decay[:, :, None, None]
             + jnp.einsum("bh,bn,bhp->bhpn", dt, Bm[:, 0].astype(jnp.float32), xh))
    y = jnp.einsum("bhpn,bn->bhp", state, Cm[:, 0].astype(jnp.float32))
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B_, 1, H * P).astype(u.dtype)
    y = rmsnorm_apply({"scale": params["norm"]}, y * jax.nn.silu(z))
    y = y @ params["wo"]
    new_cache = {"state": state, "conv_x": conv_x, "conv_B": conv_B,
                 "conv_C": conv_C}
    return y, new_cache
