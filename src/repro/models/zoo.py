"""Architecture zoo: build models + input specs from ArchConfig.

``SHAPES`` are the assigned input-shape cells; ``input_specs`` returns
allocation-free ShapeDtypeStructs for every model input of a cell (the
dry-run path), and ``make_batch`` materializes small real batches for smoke
tests and CPU training.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .transformer import (ArchConfig, model_layout, forward, train_loss,
                          init_cache, decode_step)
from .param import abstract, materialize, partition_specs, count_params

SHAPES: Dict[str, Dict[str, Any]] = {
    "train_4k":    dict(seq=4096,   batch=256, kind="train"),
    "prefill_32k": dict(seq=32768,  batch=32,  kind="prefill"),
    "decode_32k":  dict(seq=32768,  batch=128, kind="decode"),
    "long_500k":   dict(seq=524288, batch=1,   kind="decode"),
}


def cell_supported(cfg: ArchConfig, shape_name: str) -> Tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic-attention archs."""
    if shape_name == "long_500k" and not cfg.sub_quadratic():
        return False, ("pure full-attention arch: long_500k skipped per "
                       "assignment (needs sub-quadratic attention)")
    return True, ""


def input_specs(cfg: ArchConfig, shape_name: str, dtype=jnp.bfloat16):
    """ShapeDtypeStructs for every input of (arch, shape) — no allocation."""
    sh = SHAPES[shape_name]
    B, S = sh["batch"], sh["seq"]
    i32 = jnp.int32
    if sh["kind"] in ("train", "prefill"):
        batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
        if sh["kind"] == "train":
            batch["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        if cfg.encdec:
            batch["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), dtype)
        if cfg.n_img_tokens:
            batch["image_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_img_tokens, cfg.d_model), dtype)
        return batch
    # decode: one new token + a cache of length S
    cache = jax.eval_shape(lambda: init_cache(cfg, B, S, dtype))
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
        "cache": cache,
    }


def make_batch(cfg: ArchConfig, B: int, S: int, key=None, kind="train",
               dtype=jnp.float32):
    """Small real batch for smoke tests / CPU training."""
    key = key if key is not None else jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab)}
    if kind == "train":
        batch["labels"] = jax.random.randint(k2, (B, S), 0, cfg.vocab)
    if cfg.encdec:
        batch["frames"] = jax.random.normal(k3, (B, S, cfg.d_model), dtype)
    if cfg.n_img_tokens:
        batch["image_embeds"] = jax.random.normal(
            k3, (B, cfg.n_img_tokens, cfg.d_model), dtype)
    return batch


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    layout: Any

    def abstract_params(self, dtype=jnp.bfloat16):
        return abstract(self.layout, dtype)

    def init(self, key, dtype=jnp.float32):
        return materialize(key, self.layout, dtype)

    def param_specs(self, rules: dict):
        return partition_specs(self.layout, rules)

    def n_params(self) -> int:
        return count_params(self.layout)

    # functional entry points
    def loss(self, params, batch):
        return train_loss(params, batch, self.cfg)

    def forward(self, params, batch):
        return forward(params, batch, self.cfg)

    def init_cache(self, B, Smax, dtype=jnp.bfloat16):
        return init_cache(self.cfg, B, Smax, dtype)

    def decode(self, params, cache, tokens, pos):
        return decode_step(params, cache, tokens, pos, self.cfg)


def build(cfg: ArchConfig) -> Model:
    return Model(cfg=cfg, layout=model_layout(cfg))


def reduce_config(cfg: ArchConfig, **over) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    n_layers = max(len(cfg.pattern), 2 if len(cfg.pattern) == 1 else len(cfg.pattern))
    red = dict(
        n_layers=over.pop("n_layers", n_layers),
        d_model=64, n_heads=4, n_kv_heads=2, head_dim=16, vocab=128,
        d_ff=0 if cfg.d_ff == 0 else 128,
        window=min(cfg.window, 16) if cfg.window else 0,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        n_shared_experts=min(cfg.n_shared_experts, 1),
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        q_lora=32 if cfg.q_lora else 0,
        kv_lora=16 if cfg.kv_lora else 0,
        qk_nope=16 if cfg.qk_nope else 0,
        qk_rope=8 if cfg.qk_rope else 0,
        v_head_dim=16 if cfg.v_head_dim else 0,
        ssm_state=8 if cfg.ssm_state else 0,
        ssm_headdim=8 if cfg.ssm_state else 64,
        ssm_chunk=8 if cfg.ssm_state else 64,
        n_enc_layers=2 if cfg.encdec else 0,
        enc_seq=16,
        n_img_tokens=8 if cfg.n_img_tokens else 0,
        q_chunk=16, kv_chunk=16, remat=False,
    )
    if cfg.q_lora:  # MLA family: heads decoupled from head_dim
        red.update(n_heads=4, n_kv_heads=4)
    red.update(over)
    return dataclasses.replace(cfg, **red)
