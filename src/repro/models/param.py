"""Parameter layout system: a single source of truth for parameter shapes,
dtypes, initializers, and *logical* sharding axes.

``param_layout(cfg)`` (per arch, in transformer.py/zoo.py) builds a pytree of
``PM`` leaves. From it we derive:
  * ``materialize(key, layout)``   — real initialized params (smoke tests,
                                      real training),
  * ``abstract(layout)``           — ShapeDtypeStructs (dry-run: the 236B
                                      configs are never allocated),
  * ``partition_specs(layout, rules)`` — PartitionSpec pytree from logical
                                      axis names via the mesh rules
                                      (dist/sharding.py).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class PM(NamedTuple):
    """Parameter metadata: shape, logical axes (one name or None per dim),
    initializer, dtype."""
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | scaled
    dtype: Any = None              # None -> layout default
    scale: float = 0.02

    def __repr__(self):
        return f"PM{self.shape}@{self.axes}"


def is_pm(x) -> bool:
    return isinstance(x, PM)


def _tree_map_pm(fn, layout):
    return jax.tree_util.tree_map(fn, layout,
                                  is_leaf=lambda x: isinstance(x, PM))


def abstract(layout, default_dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree — zero allocation (dry-run path)."""
    return _tree_map_pm(
        lambda pm: jax.ShapeDtypeStruct(pm.shape, pm.dtype or default_dtype),
        layout)


def materialize(key: jax.Array, layout, default_dtype=jnp.float32):
    """Initialize real parameters. Keys are split deterministically by a
    pre-order walk so layouts are reproducible."""
    leaves, treedef = jax.tree_util.tree_flatten(
        layout, is_leaf=lambda x: isinstance(x, PM))
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    for pm, k in zip(leaves, keys):
        dt = pm.dtype or default_dtype
        if pm.init == "zeros":
            arr = jnp.zeros(pm.shape, dt)
        elif pm.init == "ones":
            arr = jnp.ones(pm.shape, dt)
        elif pm.init == "scaled":  # fan-in scaled normal
            fan_in = pm.shape[0] if pm.shape else 1
            arr = (jax.random.normal(k, pm.shape, jnp.float32)
                   * np.sqrt(1.0 / max(fan_in, 1))).astype(dt)
        else:  # "normal"
            arr = (jax.random.normal(k, pm.shape, jnp.float32)
                   * pm.scale).astype(dt)
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def partition_specs(layout, rules: dict):
    """Logical axes -> PartitionSpec via `rules` (name -> mesh axis or None).
    Unknown names map to None (replicated)."""
    def one(pm: PM):
        return P(*[rules.get(a) if a is not None else None for a in pm.axes])
    return _tree_map_pm(one, layout)


def stack_layout(layout, n: int, axis_name: Optional[str] = None):
    """Prepend a leading `layers` dim of size n to every PM (scan stacking)."""
    def one(pm: PM):
        return PM((n,) + pm.shape, (axis_name,) + pm.axes, pm.init,
                  pm.dtype, pm.scale)
    return _tree_map_pm(one, layout)


def count_params(layout) -> int:
    leaves = jax.tree_util.tree_leaves(
        layout, is_leaf=lambda x: isinstance(x, PM))
    return int(sum(int(np.prod(pm.shape)) for pm in leaves))
