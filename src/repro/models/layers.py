"""Shared neural layers for the LM zoo (pure functional JAX).

Every layer is a (layout, apply) pair: ``*_layout`` returns a PM pytree
(shapes + logical sharding axes), ``*_apply`` consumes the materialized
params. Norm/softmax arithmetic is f32 regardless of param dtype.
"""
from __future__ import annotations

from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from .param import PM
from ..dist.sharding import shard


# ----------------------------- norms ---------------------------------------

def rmsnorm_layout(d: int):
    return {"scale": PM((d,), (None,), init="ones")}


def rmsnorm_apply(params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def layernorm_layout(d: int):
    return {"scale": PM((d,), (None,), init="ones"),
            "bias": PM((d,), (None,), init="zeros")}


def layernorm_apply(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = ((xf - mu) * jax.lax.rsqrt(var + eps)
           * params["scale"].astype(jnp.float32)
           + params["bias"].astype(jnp.float32))
    return out.astype(x.dtype)


def norm_layout(d: int, kind: str = "rmsnorm"):
    return layernorm_layout(d) if kind == "layernorm" else rmsnorm_layout(d)


def norm_apply(params, x, kind: str = "rmsnorm", eps: float = 1e-6):
    if kind == "layernorm":
        return layernorm_apply(params, x, eps)
    return rmsnorm_apply(params, x, eps)


# ----------------------------- RoPE -----------------------------------------

def rope_freqs(head_dim: int, theta: float, rope_frac: float = 1.0):
    """Frequency table for (the first rope_frac of) a head dim."""
    rot = int(head_dim * rope_frac) // 2 * 2
    inv = 1.0 / (theta ** (np.arange(0, rot, 2, dtype=np.float32) / rot))
    return jnp.asarray(inv), rot


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               rope_frac: float = 1.0) -> jnp.ndarray:
    """x: (..., S, heads..., head_dim); positions: (..., S) int32."""
    hd = x.shape[-1]
    inv, rot = rope_freqs(hd, theta, rope_frac)
    if rot == 0:
        return x
    ang = positions.astype(jnp.float32)[..., None] * inv   # (..., S, rot/2)
    # broadcast over any head dims between S and head_dim
    for _ in range(x.ndim - ang.ndim):
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rotated = jnp.stack([r1, r2], axis=-1).reshape(xr.shape)
    out = jnp.concatenate([rotated.astype(x.dtype), x[..., rot:]], axis=-1)
    return out


def sinusoidal_positions(S: int, d: int, offset=0) -> jnp.ndarray:
    pos = np.arange(S)[:, None] + 0
    dim = np.arange(0, d, 2)[None, :]
    ang = pos / np.power(10000.0, dim / d)
    out = np.zeros((S, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return jnp.asarray(out)


# ----------------------------- MLP ------------------------------------------

def scatter_residual(y: jnp.ndarray, sel: jnp.ndarray,
                     width: int) -> jnp.ndarray:
    """Scatter a compact residual contribution back to full width.

    ``y``: (..., J) — a GEMM output computed only on the J surviving
    residual-output columns of a compacted ``w2`` (serve layer, DESIGN.md
    §10); ``sel``: int32 (J,) column indices; ``width``: the full residual
    width. Returns (..., width) with ``y[..., j]`` placed at column
    ``sel[j]`` and exact zeros elsewhere — exactly what the dense GEMM
    produces, because a structurally-dead output column contributes exact
    zero. Uses ``.add`` (not ``.set``) so the padded slots a live
    re-compaction leaves behind — duplicate indices pointing at one dead
    column — accumulate their exact-zero contributions harmlessly.

    >>> y_full = scatter_residual(h @ w2_compact, sel, d_model)
    """
    out = jnp.zeros(y.shape[:-1] + (width,), y.dtype)
    return out.at[..., sel].add(y)


def mlp_layout(d: int, ff: int, kind: str = "swiglu"):
    if kind in ("swiglu", "geglu"):
        return {"w1": PM((d, ff), ("fsdp", "mlp"), init="scaled"),
                "w3": PM((d, ff), ("fsdp", "mlp"), init="scaled"),
                "w2": PM((ff, d), ("mlp", "fsdp"), init="scaled")}
    return {"w1": PM((d, ff), ("fsdp", "mlp"), init="scaled"),
            "w2": PM((ff, d), ("mlp", "fsdp"), init="scaled")}


def mlp_apply(params, x, kind: str = "swiglu"):
    if kind in ("swiglu", "geglu"):
        gate = x @ params["w1"]
        up = x @ params["w3"]
        act = jax.nn.silu(gate) if kind == "swiglu" else jax.nn.gelu(gate)
        h = act * up
    else:
        h = jax.nn.gelu(x @ params["w1"])
    h = shard(h, "batch", "seq", "mlp")
    out = h @ params["w2"]
    # compact-serving path (DESIGN.md §10): a w2 whose residual-output
    # columns were compiled out yields a narrow GEMM; the shape mismatch is
    # static, so the dense path compiles with zero overhead
    if out.shape[-1] != x.shape[-1]:
        out = scatter_residual(out, params["w2_sel"], x.shape[-1])
    return out


# ----------------------------- embeddings -----------------------------------

def embed_layout(vocab: int, d: int):
    return {"table": PM((vocab, d), ("vocab", "embed"), init="normal")}


def embed_apply(params, tokens: jnp.ndarray, scale: Optional[float] = None):
    out = jnp.take(params["table"], tokens, axis=0)
    if scale:
        out = out * jnp.asarray(scale, out.dtype)
    return shard(out, "batch", "seq", "embed")


def unembed_apply(params, x: jnp.ndarray,
                  true_vocab: Optional[int] = None) -> jnp.ndarray:
    """Logits in the activation dtype (f32 accumulation); padded vocab
    columns (>= true_vocab) are masked to -inf so CE and sampling are exact."""
    logits = jnp.einsum("...d,vd->...v", x, params["table"],
                        preferred_element_type=jnp.float32).astype(x.dtype)
    vp = params["table"].shape[0]
    if true_vocab is not None and true_vocab < vp:
        pad = jax.lax.broadcasted_iota(jnp.int32, (vp,), 0) >= true_vocab
        logits = jnp.where(pad, jnp.asarray(-1e30, logits.dtype), logits)
    return shard(logits, "batch", "seq", "vocab")
