"""Transformer stacks for the zoo: pattern-based block composition.

An architecture is a *pattern* — a short cycle of block kinds repeated over
the depth (scan-over-layers keeps compiles tractable at 512-way GSPMD):

  global    causal full attention + MLP/MoE
  local     causal sliding-window attention + MLP/MoE
  cross     cross-attention to provided memory + MLP      (llama-vision)
  mla       multi-head latent attention + MoE             (deepseek-v2)
  ssm       Mamba2 SSD block (no MLP when d_ff == 0)      (mamba2)
  hybrid    parallel local-attention + SSD heads + MLP    (hymba)
  enc       bidirectional attention + MLP                 (whisper encoder)
  dec_cross causal self-attn + cross-attn + MLP           (whisper decoder)

Entry points: ``forward`` (train/prefill logits), ``train_loss``,
``init_cache`` / ``decode_step`` (serving).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .param import PM, stack_layout
from . import layers as L
from . import attention as A
from . import ssm as SSMOD
from . import moe as MOE
from ..dist.sharding import shard

# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | hybrid | vlm | audio | ssm | moe
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    pattern: Tuple[str, ...] = ("global",)
    window: int = 0                 # sliding window for "local"/"hybrid"
    mlp_kind: str = "swiglu"        # swiglu | geglu | gelu
    norm_kind: str = "rmsnorm"
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rope_frac: float = 1.0
    embed_scale: bool = False       # gemma: embeddings * sqrt(d)
    tie_embeddings: bool = True
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    expert_sharding: str = "ep"     # ep | tp
    # MLA
    q_lora: int = 0
    kv_lora: int = 0
    qk_nope: int = 0
    qk_rope: int = 0
    v_head_dim: int = 0
    # SSM
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 64
    # enc-dec / cross
    encdec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500             # whisper encoder length for decode cells
    n_img_tokens: int = 0           # vlm stub memory length
    # runtime
    norm_eps: float = 1e-6
    q_chunk: int = 512
    kv_chunk: int = 512
    remat: bool = True
    # perf levers (§Perf; default off = paper-faithful/naive baseline)
    sliced_window: bool = False     # O(S*window) lowering for local attn
    mla_absorb: bool = False        # matrix-absorbed MLA decode
    ssd_bf16: bool = False          # bf16 SSD tile intermediates
    moe_impl: str = "gspmd"         # gspmd | shardmap (manual EP)
    remat_policy: str = "full"      # full (save nothing) | dots
    # sharding nuances: logical-rule overrides for dims that do not divide
    # the mesh (e.g. 25 heads, vocab 32001) — ("heads", None) replicates.
    rules_overrides: Tuple = ()
    # paper integration: structured-sparsity constraint specs
    projection_specs: Tuple = ()

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 128 so the vocab dim always
        shards over the 16-way model axis (pad logits are masked to -inf)."""
        return -(-self.vocab // 128) * 128

    # None -> derive from the pattern; explicit override for mixed patterns
    # (gemma3: 5 local : 1 global still qualifies for long-context serving)
    long_context_capable: Optional[bool] = None

    def sub_quadratic(self) -> bool:
        if self.long_context_capable is not None:
            return self.long_context_capable
        kinds = set(self.pattern)
        return kinds <= {"local", "ssm", "hybrid"} or "ssm" in kinds


# ---------------------------------------------------------------------------
# block layout / apply
# ---------------------------------------------------------------------------

def _mlp_part_layout(cfg: ArchConfig):
    if cfg.d_ff <= 0:
        return {}
    lay = {"mlp_norm": L.norm_layout(cfg.d_model, cfg.norm_kind)}
    if cfg.n_experts:
        lay["moe"] = MOE.moe_layout(
            cfg.d_model, cfg.d_ff, cfg.n_experts,
            n_shared=cfg.n_shared_experts,
            shared_ff=cfg.d_ff * max(cfg.n_shared_experts, 1),
            expert_sharding=cfg.expert_sharding, mlp_kind=cfg.mlp_kind)
    else:
        lay["mlp"] = L.mlp_layout(cfg.d_model, cfg.d_ff, cfg.mlp_kind)
    return lay


def block_layout(cfg: ArchConfig, kind: str):
    d = cfg.d_model
    lay: Dict[str, Any] = {}
    if kind in ("global", "local", "enc", "dec_cross"):
        lay["attn_norm"] = L.norm_layout(d, cfg.norm_kind)
        lay["attn"] = A.attn_layout(d, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.head_dim, cfg.qkv_bias)
    if kind in ("cross", "dec_cross"):
        lay["cross_norm"] = L.norm_layout(d, cfg.norm_kind)
        lay["cross"] = A.cross_attn_layout(d, cfg.n_heads, cfg.head_dim, d)
    if kind == "mla":
        lay["attn_norm"] = L.norm_layout(d, cfg.norm_kind)
        lay["mla"] = A.mla_layout(d, cfg.n_heads, cfg.q_lora, cfg.kv_lora,
                                  cfg.qk_nope, cfg.qk_rope, cfg.v_head_dim)
    if kind in ("ssm", "hybrid"):
        lay["ssm_norm"] = L.norm_layout(d, cfg.norm_kind)
        lay["ssm"] = SSMOD.ssm_layout(d, cfg.d_inner, cfg.ssm_state,
                                      cfg.ssm_headdim)
    if kind == "hybrid":
        lay["attn_norm"] = L.norm_layout(d, cfg.norm_kind)
        lay["attn"] = A.attn_layout(d, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.head_dim, cfg.qkv_bias)
    lay.update(_mlp_part_layout(cfg))
    return lay


def _mlp_part_apply(params, x, cfg: ArchConfig, aux_acc):
    if cfg.d_ff <= 0:
        return x, aux_acc
    h = L.norm_apply(params["mlp_norm"], x, cfg.norm_kind, cfg.norm_eps)
    if cfg.n_experts:
        if cfg.moe_impl == "shardmap" and cfg.expert_sharding == "ep":
            from .moe_shardmap import moe_apply_shardmap
            y, aux = moe_apply_shardmap(
                params["moe"], h, n_experts=cfg.n_experts, top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor, mlp_kind=cfg.mlp_kind)
        else:
            y, aux = MOE.moe_apply(
                params["moe"], h, n_experts=cfg.n_experts, top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor, mlp_kind=cfg.mlp_kind,
                expert_sharding=cfg.expert_sharding)
        aux_acc = {k: aux_acc.get(k, 0.0) + v for k, v in aux.items()}
    else:
        y = L.mlp_apply(params["mlp"], h, cfg.mlp_kind)
    return x + y, aux_acc


def block_apply_full(params, x, kind: str, cfg: ArchConfig, positions,
                     memory=None, aux_acc=None):
    """Full-sequence block application (train / prefill)."""
    aux_acc = aux_acc if aux_acc is not None else {}
    common = dict(n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                  head_dim=cfg.head_dim, positions=positions,
                  rope_theta=cfg.rope_theta, rope_frac=cfg.rope_frac,
                  q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                  sliced_window=cfg.sliced_window)
    if kind in ("global", "local", "enc", "dec_cross"):
        h = L.norm_apply(params["attn_norm"], x, cfg.norm_kind, cfg.norm_eps)
        y = A.attn_apply(params["attn"], h, causal=(kind != "enc"),
                         window=cfg.window if kind == "local" else 0, **common)
        x = x + y
    if kind in ("cross", "dec_cross"):
        h = L.norm_apply(params["cross_norm"], x, cfg.norm_kind, cfg.norm_eps)
        y = A.cross_attn_apply(params["cross"], h, memory,
                               n_heads=cfg.n_heads, head_dim=cfg.head_dim,
                               q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
        x = x + y
    if kind == "mla":
        h = L.norm_apply(params["attn_norm"], x, cfg.norm_kind, cfg.norm_eps)
        y = A.mla_apply(params["mla"], h, n_heads=cfg.n_heads,
                        nope=cfg.qk_nope, rope_dim=cfg.qk_rope,
                        v_dim=cfg.v_head_dim, positions=positions,
                        rope_theta=cfg.rope_theta, q_chunk=cfg.q_chunk,
                        kv_chunk=cfg.kv_chunk)
        x = x + y
    if kind == "ssm":
        h = L.norm_apply(params["ssm_norm"], x, cfg.norm_kind, cfg.norm_eps)
        x = x + SSMOD.ssd_apply(params["ssm"], h, headdim=cfg.ssm_headdim,
                                chunk=cfg.ssm_chunk, tile_bf16=cfg.ssd_bf16)
    if kind == "hybrid":
        h = L.norm_apply(params["ssm_norm"], x, cfg.norm_kind, cfg.norm_eps)
        y_ssm = SSMOD.ssd_apply(params["ssm"], h, headdim=cfg.ssm_headdim,
                                chunk=cfg.ssm_chunk, tile_bf16=cfg.ssd_bf16)
        ha = L.norm_apply(params["attn_norm"], x, cfg.norm_kind, cfg.norm_eps)
        y_attn = A.attn_apply(params["attn"], ha, causal=True,
                              window=cfg.window, **common)
        x = x + 0.5 * (y_ssm + y_attn)
    return _mlp_part_apply(params, x, cfg, aux_acc)


# ---------------------------------------------------------------------------
# full-model layout
# ---------------------------------------------------------------------------

def _split_pattern(cfg: ArchConfig):
    p = len(cfg.pattern)
    return cfg.n_layers // p, cfg.n_layers % p


def _remat(cfg: ArchConfig, fn):
    if not cfg.remat:
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def model_layout(cfg: ArchConfig):
    cycles, rem = _split_pattern(cfg)
    lay: Dict[str, Any] = {
        "embed": L.embed_layout(cfg.vocab_padded, cfg.d_model)}
    if cycles:
        lay["blocks"] = {
            f"p{i}_{kind}": stack_layout(block_layout(cfg, kind), cycles,
                                         "layers")
            for i, kind in enumerate(cfg.pattern)}
    for r in range(rem):
        lay[f"rem{r}_{cfg.pattern[r]}"] = block_layout(cfg, cfg.pattern[r])
    lay["final_norm"] = L.norm_layout(cfg.d_model, cfg.norm_kind)
    if not cfg.tie_embeddings:
        lay["unembed"] = L.embed_layout(cfg.vocab_padded, cfg.d_model)
    if cfg.encdec:
        lay["enc_blocks"] = stack_layout(block_layout(cfg, "enc"),
                                         cfg.n_enc_layers, "layers")
        lay["enc_norm"] = L.norm_layout(cfg.d_model, cfg.norm_kind)
    return lay


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _encode(params, frames, cfg: ArchConfig):
    """Whisper-style encoder over precomputed frame embeddings (stub)."""
    S = frames.shape[1]
    x = frames + L.sinusoidal_positions(S, cfg.d_model).astype(frames.dtype)
    x = shard(x, "batch", "seq", "embed")
    positions = jnp.broadcast_to(jnp.arange(S), frames.shape[:2])

    def body(x, blk):
        x, _ = block_apply_full(blk, x, "enc", cfg, positions)
        return x, None

    x, _ = jax.lax.scan(_remat(cfg, body), x, params["enc_blocks"])
    return L.norm_apply(params["enc_norm"], x, cfg.norm_kind, cfg.norm_eps)


def forward(params, batch: Dict[str, jnp.ndarray], cfg: ArchConfig):
    """Logits for a full sequence. batch keys: tokens (B,S) [, frames,
    image_embeds]. Returns (logits (B,S,V) f32, aux dict)."""
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = L.embed_apply(params["embed"], tokens,
                      scale=np.sqrt(cfg.d_model) if cfg.embed_scale else None)
    if not cfg.rope_theta:  # absolute sinusoidal (whisper decoder)
        x = x + L.sinusoidal_positions(S, cfg.d_model).astype(x.dtype)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    memory = None
    if cfg.encdec:
        memory = _encode(params, batch["frames"], cfg)
    elif cfg.n_img_tokens:
        memory = batch["image_embeds"]

    cycles, rem = _split_pattern(cfg)
    aux0 = {"lb_loss": jnp.zeros((), jnp.float32),
            "z_loss": jnp.zeros((), jnp.float32),
            "dropped_frac": jnp.zeros((), jnp.float32)} if cfg.n_experts else {}

    if cycles:
        def cycle_body(carry, cyc_params):
            x, aux = carry
            for i, kind in enumerate(cfg.pattern):
                x, aux = block_apply_full(cyc_params[f"p{i}_{kind}"], x, kind,
                                          cfg, positions, memory=memory,
                                          aux_acc=aux)
            return (x, aux), None

        (x, aux0), _ = jax.lax.scan(_remat(cfg, cycle_body), (x, aux0),
                                    params["blocks"])
    for r in range(rem):
        kind = cfg.pattern[r]
        x, aux0 = block_apply_full(params[f"rem{r}_{kind}"], x, kind, cfg,
                                   positions, memory=memory, aux_acc=aux0)

    x = L.norm_apply(params["final_norm"], x, cfg.norm_kind, cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = L.unembed_apply(table, x, true_vocab=cfg.vocab)
    return logits, aux0


def train_loss(params, batch, cfg: ArchConfig):
    """Mean next-token CE (+ MoE aux). labels: (B, S) int32, -1 = ignore.

    CE is computed streaming (logsumexp - gather) in f32 without
    materializing a full log-softmax copy of the logits."""
    logits, aux = forward(params, batch, cfg)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    # label logit via one-hot reduce: a gather along the (model-sharded)
    # vocab dim would force an all-gather of the full logits — the one-hot
    # product reduces shard-locally and all-reduces only (B, S).
    vp = logits.shape[-1]
    onehot = jax.nn.one_hot(jnp.maximum(labels, 0), vp, dtype=logits.dtype)
    take = jnp.sum(logits * onehot, axis=-1).astype(jnp.float32)
    mask = (labels >= 0).astype(jnp.float32)
    ce = jnp.sum((lse - take) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    loss = ce
    metrics = {"ce": ce}
    if cfg.n_experts:
        loss = loss + 0.01 * aux["lb_loss"] + 1e-3 * aux["z_loss"]
        metrics.update(aux)
    return loss, metrics


# ---------------------------------------------------------------------------
# decode (serving)
# ---------------------------------------------------------------------------

def _block_cache_shape(cfg: ArchConfig, kind: str, B: int, Smax: int,
                       dtype) -> Dict[str, Any]:
    hd = cfg.head_dim
    if kind in ("global", "local", "dec_cross"):
        c = {"k": jnp.zeros((B, Smax, cfg.n_kv_heads, hd), dtype),
             "v": jnp.zeros((B, Smax, cfg.n_kv_heads, hd), dtype)}
        if kind == "dec_cross":
            c["ck"] = jnp.zeros((B, cfg.enc_seq, cfg.n_heads, hd), dtype)
            c["cv"] = jnp.zeros((B, cfg.enc_seq, cfg.n_heads, hd), dtype)
        return c
    if kind == "cross":
        m = cfg.n_img_tokens
        return {"ck": jnp.zeros((B, m, cfg.n_heads, hd), dtype),
                "cv": jnp.zeros((B, m, cfg.n_heads, hd), dtype)}
    if kind == "mla":
        return {"c": jnp.zeros((B, Smax, cfg.kv_lora), dtype),
                "kr": jnp.zeros((B, Smax, cfg.qk_rope), dtype)}
    if kind == "ssm":
        return SSMOD.ssm_init_cache(B, cfg.d_inner, cfg.ssm_state,
                                    cfg.ssm_headdim, dtype)
    if kind == "hybrid":
        c = SSMOD.ssm_init_cache(B, cfg.d_inner, cfg.ssm_state,
                                 cfg.ssm_headdim, dtype)
        c["k"] = jnp.zeros((B, Smax, cfg.n_kv_heads, hd), dtype)
        c["v"] = jnp.zeros((B, Smax, cfg.n_kv_heads, hd), dtype)
        return c
    raise ValueError(kind)


def init_cache(cfg: ArchConfig, B: int, Smax: int, dtype=jnp.bfloat16):
    """Zeroed decode cache pytree (stacked per pattern position)."""
    cycles, rem = _split_pattern(cfg)

    def stacked(kind):
        one = _block_cache_shape(cfg, kind, B, Smax, dtype)
        return jax.tree_util.tree_map(
            lambda a: jnp.zeros((cycles,) + a.shape, a.dtype), one)

    cache: Dict[str, Any] = {}
    if cycles:
        cache["blocks"] = {f"p{i}_{kind}": stacked(kind)
                           for i, kind in enumerate(cfg.pattern)}
    for r in range(rem):
        cache[f"rem{r}_{cfg.pattern[r]}"] = _block_cache_shape(
            cfg, cfg.pattern[r], B, Smax, dtype)
    return cache


def _block_decode(params, x, kind: str, cfg: ArchConfig, cache, pos):
    aux: Dict[str, Any] = {}
    if kind in ("global", "local", "dec_cross", "hybrid"):
        h = L.norm_apply(params["attn_norm"], x, cfg.norm_kind, cfg.norm_eps)
        y, (k, v) = A.attn_decode(
            params["attn"], h, (cache["k"], cache["v"]), pos,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.head_dim,
            window=cfg.window if kind in ("local", "hybrid") else 0,
            rope_theta=cfg.rope_theta, rope_frac=cfg.rope_frac)
        cache = {**cache, "k": k, "v": v}
        if kind == "hybrid":
            hs = L.norm_apply(params["ssm_norm"], x, cfg.norm_kind,
                              cfg.norm_eps)
            ssm_cache = {k2: cache[k2] for k2 in
                         ("state", "conv_x", "conv_B", "conv_C")}
            y2, new_ssm = SSMOD.ssd_decode(params["ssm"], hs, ssm_cache,
                                           headdim=cfg.ssm_headdim)
            cache = {**cache, **new_ssm}
            x = x + 0.5 * (y + y2)
        else:
            x = x + y
    if kind in ("cross", "dec_cross"):
        h = L.norm_apply(params["cross_norm"], x, cfg.norm_kind, cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, params["cross"]["wq"])
        B = x.shape[0]
        qg = q.reshape(B, 1, cfg.n_heads, 1, cfg.head_dim)
        out = A.decode_attention(qg, cache["ck"], cache["cv"],
                                 jnp.asarray(cache["ck"].shape[1] - 1))
        out = out.reshape(B, 1, cfg.n_heads, cfg.head_dim)
        x = x + jnp.einsum("bshk,hkd->bsd", out, params["cross"]["wo"])
    if kind == "mla":
        h = L.norm_apply(params["attn_norm"], x, cfg.norm_kind, cfg.norm_eps)
        y, (c, kr) = A.mla_decode(params["mla"], h, (cache["c"], cache["kr"]),
                                  pos, n_heads=cfg.n_heads, nope=cfg.qk_nope,
                                  rope_dim=cfg.qk_rope, v_dim=cfg.v_head_dim,
                                  rope_theta=cfg.rope_theta,
                                  absorb=cfg.mla_absorb)
        cache = {**cache, "c": c, "kr": kr}
        x = x + y
    if kind == "ssm":
        h = L.norm_apply(params["ssm_norm"], x, cfg.norm_kind, cfg.norm_eps)
        y, new_ssm = SSMOD.ssd_decode(params["ssm"], h, cache,
                                      headdim=cfg.ssm_headdim)
        cache = {**cache, **new_ssm} if isinstance(cache, dict) else new_ssm
        x = x + y
    x, _ = _mlp_part_apply(params, x, cfg, aux)
    return x, cache


def decode_step(params, cache, tokens, pos, cfg: ArchConfig):
    """One serving step: tokens (B, 1) int32 at position `pos` — a scalar
    (cohort decode: the whole batch sits at one depth) or a (B,) int32
    vector of per-row positions (continuous batching, DESIGN.md §13).
    Returns (logits (B, 1, V) f32, new_cache)."""
    x = L.embed_apply(params["embed"], tokens,
                      scale=np.sqrt(cfg.d_model) if cfg.embed_scale else None)
    if not cfg.rope_theta:
        table = L.sinusoidal_positions(cache_max_len(cache, cfg), cfg.d_model)
        pos_a = jnp.asarray(pos)
        if pos_a.ndim:                    # per-row absolute positions
            x = x + jnp.take(table, pos_a, axis=0).astype(x.dtype)[:, None]
        else:
            x = x + jax.lax.dynamic_slice_in_dim(table, pos, 1, axis=0
                                                 ).astype(x.dtype)[None]
    cycles, rem = _split_pattern(cfg)
    new_cache: Dict[str, Any] = {}
    if cycles:
        def body(x, xs):
            blk, blk_cache = xs
            outs = []
            for i, kind in enumerate(cfg.pattern):
                key = f"p{i}_{kind}"
                x, c = _block_decode(blk[key], x, kind, cfg, blk_cache[key],
                                     pos)
                outs.append((key, c))
            return x, dict(outs)

        x, nc = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))
        new_cache["blocks"] = nc
    for r in range(rem):
        kind = cfg.pattern[r]
        key = f"rem{r}_{kind}"
        x, c = _block_decode(params[key], x, kind, cfg, cache[key], pos)
        new_cache[key] = c
    x = L.norm_apply(params["final_norm"], x, cfg.norm_kind, cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = L.unembed_apply(table, x, true_vocab=cfg.vocab)
    return logits, new_cache


def cache_max_len(cache, cfg: ArchConfig) -> int:
    """Max sequence capacity of the self-attention caches (for absolute
    position tables). Looks at the stacked 'k' leaves: (cycles, B, Smax, ...)."""
    blocks = cache.get("blocks", cache)
    flat = jax.tree_util.tree_flatten_with_path(blocks)[0]
    dims = [leaf.shape[-3] for path, leaf in flat
            if any(getattr(p, "key", None) == "k" for p in path)]
    return max(dims) if dims else cfg.enc_seq
