"""Checkpoint lifecycle for compact serving: hot refresh + live re-compaction.

Two operations, both shape-preserving so the jit'd serving step NEVER
retraces across checkpoints (DESIGN.md §10):

  * ``refresh_model`` — replay the frozen gather recipe on a new dense
    checkpoint: same ``sel``, same shapes, new values. Exact as long as the
    new support is a subset of the slot set (guaranteed under the training
    mask freeze, verified by default);
  * ``recompact_model`` — periodic live re-compaction: derive the NEW
    support (it can only have shrunk under the frozen mask — a growth is
    a contract violation and raises), pack it into the ascending prefix of
    the SAME slot width, and point the tail at an already-dead column so
    the padded gathers read exact zeros. A monotone incremental gather:
    no shape changes, no recompile; recompacting an unchanged support is
    the identity.

Shrinking the slot width itself (reclaiming the padded FLOPs) is a
deliberate recompile: call ``compact_model`` again and swap the step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import numpy as np

from .compact import CompactModel, support_selection, _materialize

__all__ = ["refresh_model", "recompact_model"]


def _new_supports(compact: CompactModel, new_params: Any):
    sups = support_selection(new_params, compact.specs)
    missing = set(compact.sels) - set(sups)
    if missing:
        raise ValueError(
            f"new checkpoint lost constrained leaves {sorted(missing)} — "
            f"refresh/recompact require the same tree structure")
    return sups


def refresh_model(compact: CompactModel, new_params: Any,
                  validate: bool = True) -> CompactModel:
    """Hot-refresh a ``CompactModel`` from a new dense checkpoint.

    ``compact``: the serving model whose gather recipe (sels, slot widths,
    sel-leaf layout) is FROZEN; ``new_params``: the new dense checkpoint
    (same tree structure). Returns a new ``CompactModel`` with identical
    shapes — a serving step jit'd on the old ``params`` accepts the new
    ones without retracing, and the riding sel leaves mean it also gathers
    with the refreshed (not a stale closed-over) support. Exactness needs
    the new checkpoint's support to still be covered by the slot set;
    under the training mask freeze support only shrinks, so this holds —
    ``validate=True`` (default) checks it and raises on violation rather
    than serve silently-wrong logits.

    >>> cm = refresh_model(cm, new_checkpoint_params)
    """
    if validate:
        for path, sup in _new_supports(compact, new_params).items():
            if path not in compact.sels:
                continue        # skipped leaf: served dense, any support ok
            if not np.isin(sup.sel, compact.sels[path]).all():
                raise ValueError(
                    f"checkpoint support of {path!r} grew outside the "
                    f"compact slot set — the frozen-mask contract is "
                    f"violated; rebuild with compact_model")
    params = _materialize(new_params, compact.gathers, compact.sel_leaves,
                          compact.sels)
    return dataclasses.replace(compact, params=params)


def recompact_model(compact: CompactModel, new_params: Any) -> CompactModel:
    """Live re-compaction: adopt a (monotonically smaller) fresh support.

    ``compact``: the serving model; ``new_params``: a new dense checkpoint.
    Derives the new support per primary leaf and asserts it is a SUBSET of
    the current live support (under the frozen training mask support can
    only shrink — growth raises ``ValueError``). The new sel keeps the slot
    width J_slot: live indices in the ascending prefix, the tail pointed at
    one already-dead column so padded gathers read exact zeros (and padded
    scatter-back slots add exact zeros). Shapes are unchanged, so the jit'd
    step does not retrace; an unchanged support returns the exact same sel
    (identity). ``CompactModel.live`` tracks the shrink for operators
    deciding when a full (recompiling) ``compact_model`` re-slot pays off.

    >>> cm = recompact_model(cm, new_checkpoint_params)
    """
    new_sups = _new_supports(compact, new_params)
    sels: Dict[str, np.ndarray] = {}
    liv: Dict[str, int] = {}
    supports = dict(compact.supports)
    for path, old_sel in compact.sels.items():
        sup = new_sups[path]
        new_idx = np.asarray(sup.sel, np.int32)
        old_live = old_sel[: compact.live[path]]
        if not np.isin(new_idx, old_live).all():
            raise ValueError(
                f"support of {path!r} grew (monotonicity violated): "
                f"{int((~np.isin(new_idx, old_live)).sum())} new column(s) "
                f"outside the live set — the training mask freeze must "
                f"keep dead columns dead")
        if new_idx.size == old_live.size:
            sel = old_sel.copy()            # unchanged support -> identity
        else:
            pad = old_sel.size - new_idx.size
            dead = np.setdiff1d(old_sel, new_idx)   # nonempty: pad > 0
            sel = np.concatenate(
                [new_idx, np.full((pad,), dead[0], np.int32)])
        sels[path] = sel
        liv[path] = int(new_idx.size)
        supports[path] = sup
    params = _materialize(new_params, compact.gathers, compact.sel_leaves,
                          sels)
    return dataclasses.replace(compact, params=params, sels=sels, live=liv,
                               supports=supports)
