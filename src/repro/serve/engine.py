"""Continuous-batching fleet serving engine (DESIGN.md §13).

The compact zoo path (DESIGN.md §10) made one decode step ~6x cheaper at
the paper's ~99% column-sparsity regime — but a cohort batching loop only
realizes that under closed-loop traffic where all prompts arrive together
and finish together. Under real churn (ragged arrivals, ragged lengths)
cohort slots idle from the moment their row finishes until the whole
batch drains. This module keeps the ONE compiled decode step hot:

  * **per-slot state lives on device** — position, prompt buffer, prompt
    length, tokens-remaining budget, active mask, feed token, and the
    per-request sample key are (B,)-shaped leaves of a ``slots`` pytree
    that rides through the jitted step;
  * **sampling and next-feed selection run inside the step** — the host
    never sees logits; each step returns only four (B,) arrays (sampled
    token, emitted/finished/truncated flags) that the host drains with a
    one-step lag so bookkeeping overlaps device compute;
  * **admission is a masked merge at the top of the SAME step** — freed
    slots take queued prompts between steps through a ``(mask, prompt,
    plen, budget, key)`` argument, so admit/evict/refresh/recompact all
    reuse the one trace (``n_traces`` extends the PR-6 contract);
  * **the KV cache and slot state are donated** — steady-state decode
    performs no per-step HBM copy of the cache (asserted via the
    ``input_output_alias`` entries of the compiled step's HLO).

Rows are independent through the decode step (per-row positions, per-row
cache masks), so a request admitted into a freed slot mid-flight produces
exactly the tokens a solo run of its prompt would — the continuous==solo
regression in tests/test_fleet_engine.py. The one exception is
capacity-factor MoE routing, which couples rows through expert capacity;
dense-MLP archs (the zoo's serving configs) are exactly row-independent.

Scan-state (SSM/hybrid) cache leaves are recurrent rather than
position-indexed, so slot reuse zeroes the admitted rows of those leaves
inside the step; position-indexed KV leaves are self-cleaning (the
attention mask reads only positions the current request wrote).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models.transformer import decode_step, init_cache
from .compact import CompactModel, compact_model, support_selection
from .refresh import refresh_model, recompact_model

__all__ = ["EngineConfig", "Request", "Completion", "LatencyStats",
           "RecompactScheduler", "FleetEngine"]

# cache leaves carrying recurrent (non-position-indexed) state: stale rows
# WOULD leak into a newly admitted request, so the step zeroes them under
# the admit mask. Position-indexed leaves (k/v/c/kr) are self-cleaning.
_RECURRENT_CACHE_KEYS = frozenset({"state", "conv_x", "conv_B", "conv_C"})


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static serving-engine configuration (one compiled step per config).

    ``max_seq``: KV-cache slot depth Smax — a request stops (and is flagged
    ``truncated``) when its next position would reach it. ``max_prompt``:
    on-device prompt buffer width (defaults to ``max_seq``); longer prompts
    are refused at submit. ``temperature``: 0 = greedy argmax inside the
    step; > 0 samples with a per-request key folded with the row position
    (so continuous and solo runs of the same request draw the same
    stream). ``cache_dtype``: KV-cache dtype — ``None`` matches the first
    floating param leaf (bf16 checkpoints decode through bf16 caches
    instead of the old hard-coded f32). ``pipeline``: drain step outputs
    with a one-step lag so host bookkeeping overlaps device compute.

    >>> cfg = EngineConfig(max_seq=256, temperature=0.0)
    """
    max_seq: int = 256
    max_prompt: Optional[int] = None
    temperature: float = 0.0     # 0 = greedy
    seed: int = 0
    cache_dtype: Any = None      # None -> match the checkpoint's param dtype
    pipeline: bool = True

    @property
    def prompt_width(self) -> int:
        """The (B, Pmax) on-device prompt buffer width (static)."""
        return self.max_seq if self.max_prompt is None else self.max_prompt


@dataclasses.dataclass
class Request:
    """One queued generation request (host-side bookkeeping).

    ``rid``: engine-assigned id; ``prompt``: token ids (1 <= len <=
    ``EngineConfig.prompt_width``); ``max_new``: generation budget;
    ``key``: (2,) uint32 per-request sample key; ``arrival``: wall-clock
    submit time (or the caller-provided open-loop arrival instant) that
    TTFT is measured from.

    >>> req = Request(rid=0, prompt=[1, 2], max_new=8,
    ...               key=np.zeros(2, np.uint32), arrival=0.0)
    """
    rid: int
    prompt: List[int]
    max_new: int
    key: np.ndarray
    arrival: float


@dataclasses.dataclass
class Completion:
    """One finished request: tokens plus per-request service telemetry.

    ``tokens`` is prompt + generated (the cohort ``generate`` convention);
    ``truncated`` is True when the row ran out of cache depth (``max_seq``)
    before emitting its full ``max_new`` budget — the silent-truncation
    fix: callers can now SEE that ``len(generated) < max_new`` was a
    capacity decision, not model behavior. ``ttft``: seconds from arrival
    to the first generated token; ``token_times``: drain timestamp per
    generated token (inter-token gaps feed the latency percentiles);
    ``evicted``: cancelled before finishing.

    >>> done = Completion(rid=0, tokens=[1, 2, 9], prompt_len=2,
    ...                   truncated=False, evicted=False, ttft=0.01,
    ...                   token_times=[0.01])
    """
    rid: int
    tokens: List[int]
    prompt_len: int
    truncated: bool
    evicted: bool
    ttft: Optional[float]
    token_times: List[float]

    @property
    def generated(self) -> List[int]:
        """The generated suffix (``tokens`` without the prompt)."""
        return self.tokens[self.prompt_len:]


@dataclasses.dataclass
class LatencyStats:
    """Percentile summary of a latency sample set (seconds).

    >>> LatencyStats.from_samples([0.1, 0.2, 0.3]).p50
    0.2
    """
    count: int
    mean: float
    p50: float
    p95: float
    p99: float

    @classmethod
    def from_samples(cls, samples: Sequence[float]) -> "LatencyStats":
        """Build from raw samples; empty input yields all-zero stats."""
        if not samples:
            return cls(count=0, mean=0.0, p50=0.0, p95=0.0, p99=0.0)
        a = np.asarray(samples, np.float64)
        return cls(count=int(a.size), mean=float(a.mean()),
                   p50=float(np.percentile(a, 50)),
                   p95=float(np.percentile(a, 95)),
                   p99=float(np.percentile(a, 99)))

    def as_dict(self) -> Dict[str, float]:
        """Plain-dict form for JSON benchmark artifacts."""
        return dataclasses.asdict(self)


@dataclasses.dataclass
class RecompactScheduler:
    """Hysteretic trigger for live re-compaction under checkpoint churn.

    Projected training only kills columns, so the live/slot ratio of a
    served ``CompactModel`` decays monotonically across refreshed
    checkpoints. Re-compacting (``recompact_model``) keeps the ``live``
    bookkeeping honest and re-packs the ascending prefix, but it costs a
    host-side re-gather — doing it on every refresh while the ratio
    hovers at a threshold would thrash. The rule: fire when the ratio
    first crosses below ``threshold``, then again only after it has
    dropped a further ``hysteresis`` since the LAST fire. A ratio
    oscillation narrower than ``hysteresis`` can never re-trigger.
    ``reslot_threshold``: below this ratio the padded slots dominate the
    GEMMs and a full (recompiling) ``compact_model`` re-slot pays off —
    surfaced as ``reslot_recommended``, never done implicitly.

    >>> sched = RecompactScheduler(threshold=0.9, hysteresis=0.05)
    """
    threshold: float = 0.9
    hysteresis: float = 0.05
    reslot_threshold: float = 0.5
    last_fired_ratio: float = 1.0 + 1e-9
    fires: int = 0

    def decide(self, ratio: float) -> bool:
        """True iff a recompact should run at this live/slot ratio."""
        if ratio >= self.threshold:
            return False
        if ratio > self.last_fired_ratio - self.hysteresis:
            return False
        self.last_fired_ratio = ratio
        self.fires += 1
        return True

    def reslot_recommended(self, ratio: float) -> bool:
        """True when the ratio is low enough that a recompiling re-slot
        (fresh ``compact_model`` + step swap) would pay for itself."""
        return ratio < self.reslot_threshold


def _request_key(seed: int, sample_seed: int) -> np.ndarray:
    """Host-side per-request PRNG key: splitmix64 of (engine seed,
    request seed) as a (2,) uint32 key. Pure python — a jax.random call
    here would dispatch a device computation per submit, which under
    open-loop load costs more than the decode steps themselves."""
    mask = (1 << 64) - 1
    x = ((seed & 0xFFFFFFFF) << 32) | (sample_seed & 0xFFFFFFFF)
    x = (x + 0x9E3779B97F4A7C15) & mask
    z = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & mask
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & mask
    z = z ^ (z >> 31)
    return np.array([z >> 32, z & 0xFFFFFFFF], np.uint32)


def _param_dtype(params) -> Any:
    """Dtype of the first floating leaf (sel leaves are int32 riders)."""
    for leaf in jax.tree_util.tree_leaves(params):
        if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating):
            return jnp.asarray(leaf).dtype
    return jnp.float32


def _cache_specs(cache, batch_axes):
    """Per-leaf PartitionSpecs sharding the batch dim of a decode cache:
    axis 1 for scan-stacked block caches (leading dim = cycles), axis 0
    for unstacked remainder blocks."""
    out = {}
    for key, sub in cache.items():
        spec = P(None, batch_axes) if key == "blocks" else P(batch_axes)
        out[key] = jax.tree_util.tree_map(lambda _: spec, sub)
    return out


def _batch0_specs(tree, batch_axes):
    """PartitionSpecs for pytrees whose every leaf has batch on axis 0
    (slot state, admit args, step outputs)."""
    return jax.tree_util.tree_map(
        lambda a: P(*((batch_axes,) + (None,) * (jnp.asarray(a).ndim - 1))),
        tree)


def _reset_recurrent(cache, mask):
    """Zero the admitted rows of recurrent cache leaves (SSM conv/state):
    unlike position-indexed KV leaves, their stale values WOULD leak into
    a new request. mask: (B,) bool, True = slot (re)admitted this step."""
    keep = ~mask

    def _sub(sub, batch_axis):
        def one(path, leaf):
            name = getattr(path[-1], "key", None)
            if name in _RECURRENT_CACHE_KEYS:
                shape = [1] * leaf.ndim
                shape[batch_axis] = keep.shape[0]
                return leaf * keep.astype(leaf.dtype).reshape(shape)
            return leaf
        return jax.tree_util.tree_map_with_path(one, sub)

    return {k: _sub(sub, 1 if k == "blocks" else 0)
            for k, sub in cache.items()}


class FleetEngine:
    """Continuous-batching decode engine over one compiled step.

    ``model``: a zoo ``Model``; ``batch_slots``: fixed decode width B;
    ``cfg``: ``EngineConfig``; ``mesh``/``rules`` (optional): shard_map
    the step over the mesh axes the sharding rules assign to "batch"
    (params replicated, cache + slot state batch-sharded; rows are
    independent, so the step body contains zero collectives).

    Lifecycle: ``load``/``load_compact`` a checkpoint, ``submit`` requests,
    call ``step`` per decode step (or ``drain`` to run the backlog dry).
    ``refresh``/``recompact`` hot-swap checkpoints mid-flight without
    retracing; a ``RecompactScheduler`` (``scheduler=``) turns refreshes
    into recompactions when the live/slot ratio decays past its
    threshold. ``n_traces`` counts jit traces of the step — admission,
    eviction, refresh and recompaction all reuse trace #1.

    >>> eng = FleetEngine(model, batch_slots=4, cfg=EngineConfig())
    """

    def __init__(self, model, batch_slots: int, cfg: EngineConfig,
                 mesh=None, rules=None,
                 scheduler: Optional[RecompactScheduler] = None):
        if model.cfg.encdec or model.cfg.n_img_tokens:
            raise ValueError(
                "FleetEngine serves decoder-only archs; enc-dec / vision "
                "memory caches need per-request prefill plumbing")
        self.model = model
        self.cfg = cfg
        self.B = batch_slots
        self.scheduler = scheduler
        self.params = None
        self.compact: Optional[CompactModel] = None
        self.n_traces = 0            # bumps at TRACE time only (jit)
        self._mesh = mesh
        self._rules = rules
        self._step_fn = None         # built lazily: cache specs need shapes
        self._cache = None
        self._slots = None
        # host-side bookkeeping
        self._next_rid = 0
        self._queue: collections.Deque[Request] = collections.deque()
        self._reqs: Dict[int, Request] = {}
        self._slot_rid: List[Optional[int]] = [None] * batch_slots
        self._gen: Dict[int, List[int]] = {}
        self._times: Dict[int, List[float]] = {}
        self._cancelled: set = set()
        self._evict_pending: List[int] = []
        self._pending: collections.Deque = collections.deque()
        self._completions: List[Completion] = []
        self._retired: List[Completion] = []
        self._steps = 0
        self._tokens_out = 0

    # ---------------------- checkpoint lifecycle -------------------------

    def load(self, params) -> None:
        """Serve a dense checkpoint (drops any compact state)."""
        self.params = params
        self.compact = None

    def load_compact(self, compact: Optional[CompactModel] = None, *,
                     params=None) -> None:
        """Serve a compacted checkpoint: a prebuilt ``serve.CompactModel``
        or a dense ``params`` tree compacted here under the model's own
        ``projection_specs``."""
        if compact is None:
            compact = compact_model(params, self.model.cfg.projection_specs)
        self.compact = compact
        self.params = compact.params

    def _live_ratio(self, new_params) -> float:
        """Prospective min live/slot ratio of a new checkpoint against the
        frozen slot widths (host-side; checkpoint-rate, not step-rate)."""
        sups = support_selection(new_params, self.compact.specs)
        ratios = [sups[p].n_selected / max(self.compact.slot_width(p), 1)
                  for p in self.compact.sels]
        return min(ratios) if ratios else 1.0

    def refresh(self, new_dense_params) -> bool:
        """Hot refresh: new checkpoint values through the frozen compact
        recipe (or a plain param swap when serving dense). Shapes are
        unchanged, so the compiled step never retraces — safe mid-flight.
        With a ``scheduler``, decaying live/slot ratios upgrade the
        refresh to a live re-compaction; returns True when that fired."""
        if self.compact is None:
            self.params = new_dense_params
            return False
        if self.scheduler is not None and \
                self.scheduler.decide(self._live_ratio(new_dense_params)):
            self.recompact(new_dense_params)
            return True
        self.compact = refresh_model(self.compact, new_dense_params)
        self.params = self.compact.params
        return False

    def recompact(self, new_dense_params) -> None:
        """Live re-compaction: adopt the new checkpoint's (monotonically
        smaller) support inside the frozen slot widths. No retrace; exact
        mid-flight (surviving columns keep their ascending order, so the
        re-gathered GEMMs sum the same nonzero terms — DESIGN.md §13)."""
        self.compact = recompact_model(self.compact, new_dense_params)
        self.params = self.compact.params

    def reslot_recommended(self) -> bool:
        """True when the scheduler judges the live/slot ratio low enough
        that a full (recompiling) ``compact_model`` re-slot pays off."""
        if self.scheduler is None or self.compact is None:
            return False
        live = [self.compact.live[p] / max(self.compact.slot_width(p), 1)
                for p in self.compact.sels] or [1.0]
        return self.scheduler.reslot_recommended(min(live))

    # ---------------------- request intake -------------------------------

    def submit(self, prompt: Sequence[int], max_new: int,
               arrival: Optional[float] = None,
               sample_seed: Optional[int] = None) -> int:
        """Queue one request; returns its rid. ``arrival`` backdates the
        TTFT clock for open-loop load generators; ``sample_seed`` pins the
        per-request sample key (defaults to the rid) so a temperature>0
        request reproduces across solo and batched runs."""
        if not 0 < len(prompt) <= self.cfg.prompt_width:
            raise ValueError(
                f"prompt length {len(prompt)} outside (0, "
                f"{self.cfg.prompt_width}] — raise EngineConfig.max_prompt")
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        rid = self._next_rid
        self._next_rid += 1
        key = _request_key(
            self.cfg.seed, sample_seed if sample_seed is not None else rid)
        req = Request(rid=rid, prompt=list(prompt), max_new=max_new,
                      key=key,
                      arrival=time.perf_counter() if arrival is None
                      else arrival)
        self._queue.append(req)
        self._reqs[rid] = req
        return rid

    def cancel(self, rid: int) -> bool:
        """Evict a queued or in-flight request (its slot frees next step);
        returns False when the rid is unknown or already finished."""
        for i, q in enumerate(self._queue):
            if q.rid == rid:
                del self._queue[i]
                self._finalize(rid, evicted=True)
                return True
        for slot, srid in enumerate(self._slot_rid):
            if srid == rid and rid not in self._cancelled:
                self._cancelled.add(rid)
                self._evict_pending.append(slot)
                return True
        return False

    # ---------------------- step construction ---------------------------

    def _init_slots(self):
        B, Pmax = self.B, self.cfg.prompt_width
        return {
            "feed": jnp.zeros((B,), jnp.int32),
            "pos": jnp.zeros((B,), jnp.int32),
            "plen": jnp.ones((B,), jnp.int32),
            "remaining": jnp.zeros((B,), jnp.int32),
            "active": jnp.zeros((B,), bool),
            "prompt": jnp.zeros((B, Pmax), jnp.int32),
            "key": jnp.zeros((B, 2), jnp.uint32),
        }

    def _traced_step(self, params, cache, slots, admit):
        """The ONE compiled step: evict + admit-merge -> decode at per-row
        positions -> in-step sampling -> next-feed/budget/truncation
        update. Returns ((B,)-shaped outputs, cache, slots)."""
        self.n_traces += 1           # python side effect: trace-time only
        mcfg = self.model.cfg
        Smax = self.cfg.max_seq
        Pmax = self.cfg.prompt_width
        m = admit["mask"]
        active = slots["active"] & ~admit["evict"]
        slots = {
            "feed": jnp.where(m, admit["prompt"][:, 0], slots["feed"]),
            "pos": jnp.where(m, 0, slots["pos"]),
            "plen": jnp.where(m, admit["plen"], slots["plen"]),
            "remaining": jnp.where(m, admit["budget"], slots["remaining"]),
            "active": active | m,
            "prompt": jnp.where(m[:, None], admit["prompt"],
                                slots["prompt"]),
            "key": jnp.where(m[:, None], admit["key"], slots["key"]),
        }
        cache = _reset_recurrent(cache, m)
        logits, cache = decode_step(params, cache, slots["feed"][:, None],
                                    slots["pos"], mcfg)
        lg = logits[:, -1, :]
        if self.cfg.temperature > 0:
            keys = jax.vmap(jax.random.fold_in)(slots["key"], slots["pos"])
            nxt = jax.vmap(jax.random.categorical)(
                keys, lg / self.cfg.temperature)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        nxt = nxt.astype(jnp.int32)

        active = slots["active"]
        pos, plen = slots["pos"], slots["plen"]
        rem = slots["remaining"]
        emitted = active & (pos >= plen - 1) & (rem > 0)
        new_rem = jnp.where(emitted, rem - 1, rem)
        done = active & (new_rem <= 0)
        want_more = active & ~done
        trunc = want_more & (pos + 1 >= Smax)
        new_active = want_more & ~trunc
        in_prompt = (pos + 1) < plen
        nxt_prompt = jnp.take_along_axis(
            slots["prompt"],
            jnp.clip(pos + 1, 0, Pmax - 1)[:, None], axis=1)[:, 0]
        new_feed = jnp.where(new_active & in_prompt, nxt_prompt,
                             jnp.where(new_active, nxt, slots["feed"]))
        out = {"token": nxt, "emitted": emitted,
               "finished": done | trunc, "truncated": trunc}
        slots = {**slots,
                 "feed": new_feed,
                 "pos": jnp.where(new_active, pos + 1, pos),
                 "remaining": new_rem,
                 "active": new_active}
        return out, cache, slots

    def _build_step(self, cache, slots, admit):
        if self._mesh is None:
            return jax.jit(self._traced_step, donate_argnums=(1, 2))

        from jax.experimental.shard_map import shard_map
        from ..dist.sharding import default_rules
        rules = dict(default_rules() if self._rules is None else self._rules)
        batch_axes = rules.get("batch")
        if batch_axes is None:
            raise ValueError(
                "FleetEngine: the sharding rules map 'batch' to None — "
                "every rank would redundantly serve the FULL batch; name a "
                "mesh axis for 'batch' (see dist.sharding.default_rules)")
        cspecs = _cache_specs(cache, batch_axes)
        sspecs = _batch0_specs(slots, batch_axes)
        aspecs = _batch0_specs(admit, batch_axes)
        ospecs = {k: P(batch_axes)
                  for k in ("token", "emitted", "finished", "truncated")}
        fn = shard_map(self._traced_step, mesh=self._mesh,
                       in_specs=(P(), cspecs, sspecs, aspecs),
                       out_specs=(ospecs, cspecs, sspecs),
                       check_rep=False)
        return jax.jit(fn, donate_argnums=(1, 2))

    def _ensure_ready(self):
        if self.params is None:
            raise RuntimeError("no checkpoint loaded: call load/load_compact")
        if self._cache is None:
            dtype = (self.cfg.cache_dtype
                     if self.cfg.cache_dtype is not None
                     else _param_dtype(self.params))
            self._cache = init_cache(self.model.cfg, self.B,
                                     self.cfg.max_seq, dtype)
            self._slots = self._init_slots()
        if self._step_fn is None:
            self._step_fn = self._build_step(
                self._cache, self._slots, self._admit_proto())

    def step_hlo(self) -> str:
        """Compiled-step HLO text (collective / donation-alias audits)."""
        self._ensure_ready()
        return self._step_fn.lower(
            self.params, self._cache, self._slots,
            self._admit_proto()).compile().as_text()

    # ---------------------- the serving loop -----------------------------

    def _admit_proto(self):
        """A no-op admission merge (the all-False masks every step reuses
        as its starting point; also the spec/lowering prototype)."""
        B, Pmax = self.B, self.cfg.prompt_width
        return {"mask": np.zeros((B,), bool),
                "evict": np.zeros((B,), bool),
                "prompt": np.zeros((B, Pmax), np.int32),
                "plen": np.ones((B,), np.int32),
                "budget": np.zeros((B,), np.int32),
                "key": np.zeros((B, 2), np.uint32)}

    def _admit_args(self):
        """Build this step's admission/eviction merge (host numpy)."""
        B = self.B
        proto = self._admit_proto()
        mask, evict = proto["mask"], proto["evict"]
        prompt, plen = proto["prompt"], proto["plen"]
        budget, key = proto["budget"], proto["key"]
        for slot in self._evict_pending:
            evict[slot] = True
            rid = self._slot_rid[slot]
            self._slot_rid[slot] = None
            if rid is not None:
                self._finalize(rid, evicted=True)
        self._evict_pending = []
        for i in range(B):
            if not self._queue:
                break
            if self._slot_rid[i] is None:
                req = self._queue.popleft()
                mask[i] = True
                prompt[i, : len(req.prompt)] = req.prompt
                plen[i] = len(req.prompt)
                budget[i] = req.max_new
                key[i] = req.key
                self._slot_rid[i] = req.rid
                self._gen[req.rid] = []
                self._times[req.rid] = []
        return {"mask": mask, "evict": evict, "prompt": prompt,
                "plen": plen, "budget": budget, "key": key}

    def _finalize(self, rid: int, truncated: bool = False,
                  evicted: bool = False):
        req = self._reqs.pop(rid)
        gen = self._gen.pop(rid, [])
        times = self._times.pop(rid, [])
        self._cancelled.discard(rid)
        done = Completion(
            rid=rid, tokens=list(req.prompt) + gen,
            prompt_len=len(req.prompt), truncated=truncated,
            evicted=evicted,
            ttft=(times[0] - req.arrival) if times else None,
            token_times=times)
        self._completions.append(done)
        self._retired.append(done)

    def _drain_one(self, pending) -> None:
        """Host-side drain of ONE step's (B,) outputs: append emitted
        tokens, retire finished rows, free their slots. ``pending`` pairs
        the outputs with the slot->rid map AT DISPATCH TIME — with the
        one-step drain lag a slot can be evicted and re-admitted before
        its old output drains, and the token must credit the old rid."""
        out, owners = pending
        now = time.perf_counter()
        token = np.asarray(out["token"])
        emitted = np.asarray(out["emitted"])
        finished = np.asarray(out["finished"])
        truncated = np.asarray(out["truncated"])
        for i in range(self.B):
            rid = owners[i]
            if rid is None or rid not in self._gen:
                continue             # empty slot, or evicted + finalized
            if emitted[i]:
                self._gen[rid].append(int(token[i]))
                self._times[rid].append(now)
                self._tokens_out += 1
            if finished[i]:
                if self._slot_rid[i] == rid:
                    self._slot_rid[i] = None
                self._finalize(rid, truncated=bool(truncated[i]))

    def step(self) -> List[Completion]:
        """One engine step: admit queued prompts into freed slots, run the
        compiled decode step, drain the previous step's outputs (one-step
        pipeline lag; ``pipeline=False`` drains synchronously). Returns
        the requests that finished at the drained step."""
        self._ensure_ready()
        admit = self._admit_args()
        out, self._cache, self._slots = self._step_fn(
            self.params, self._cache, self._slots, admit)
        self._pending.append((out, tuple(self._slot_rid)))
        self._steps += 1
        lag = 1 if self.cfg.pipeline else 0
        while len(self._pending) > lag:
            self._drain_one(self._pending.popleft())
        return self._pop_completions()

    def flush(self) -> List[Completion]:
        """Drain every undrained step output (no new device step)."""
        while self._pending:
            self._drain_one(self._pending.popleft())
        return self._pop_completions()

    def drain(self, max_steps: Optional[int] = None) -> List[Completion]:
        """Run steps until the queue and all slots are empty (or
        ``max_steps`` is hit); returns all completions, rid-ordered."""
        done: List[Completion] = []
        steps = 0
        while self._queue or any(r is not None for r in self._slot_rid):
            done += self.step()
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        done += self.flush()
        return sorted(done, key=lambda c: c.rid)

    def _pop_completions(self) -> List[Completion]:
        out, self._completions = self._completions, []
        return out

    # ---------------------- telemetry ------------------------------------

    def latency_report(self) -> Dict[str, Any]:
        """TTFT and inter-token latency percentiles over every finished
        request since construction (seconds)."""
        ttft = [c.ttft for c in self._done_log if c.ttft is not None]
        gaps: List[float] = []
        for c in self._done_log:
            ts = c.token_times
            gaps += [b - a for a, b in zip(ts, ts[1:])]
        return {"ttft": LatencyStats.from_samples(ttft).as_dict(),
                "per_token": LatencyStats.from_samples(gaps).as_dict()}

    @property
    def _done_log(self) -> List[Completion]:
        return self._retired

    def stats(self) -> Dict[str, Any]:
        """Engine counters: steps run, tokens emitted, slot occupancy,
        queue depth, traces, live compaction ratios."""
        busy = sum(r is not None for r in self._slot_rid)
        out: Dict[str, Any] = {
            "steps": self._steps, "tokens": self._tokens_out,
            "busy_slots": busy, "queue": len(self._queue),
            "n_traces": self.n_traces,
            "slot_utilization": (self._tokens_out / (self._steps * self.B)
                                 if self._steps else 0.0),
        }
        if self.compact is not None:
            out["live_ratio"] = {
                p: self.compact.live[p] / max(self.compact.slot_width(p), 1)
                for p in self.compact.sels}
            out["reslot_recommended"] = self.reslot_recommended()
        return out
