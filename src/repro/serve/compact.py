"""Model-generic compaction: derive support, gather, serve (DESIGN.md §10).

After l1,inf-projected training most constrained columns are STRUCTURAL
zeros (the projected step writes the projection output into the weight, so
a dead column is exact zero, not a small number — DESIGN.md §9). PR 5
compiled those zeros out for the 2-layer SAE only; this module is the
generic subsystem: any param tree, any ``ProjectionSpec`` list.

Three pieces compose:

  * ``support_selection`` derives the per-leaf surviving-column sets from
    ``core.constraints.column_masks`` — the SAME mask the double-descent
    freeze uses, so training and serving can never disagree;
  * a ``CompactRule`` says what a dead column of one leaf MEANS for the
    rest of the tree: which sibling leaves co-compact with the same index
    vector (``coupled``), and whether the compact output feeds the
    residual stream and must scatter back to full width (``scatter``);
  * ``compact_model`` executes the rules with ``core.compact_columns``
    (the single host-side gather primitive — ``sae/serve.compact_leaf``
    is a one-line shim over it) and returns a ``CompactModel`` whose
    param tree carries ``*_sel`` index leaves, so the support TRAVELS
    WITH the checkpoint and refreshed params serve through an old jit'd
    step without retracing.

``ZOO_RULES`` covers the model zoo's constrained leaves (configs/*.py):
MLP/MoE ``w1`` hidden-unit compaction (dead ff column => act(0) * up = 0
exactly, so ``w3`` columns and ``w2`` rows co-compact) and MLP/MoE ``w2``
residual-output compaction (dead output column => that residual feature
receives exact zero, so the compact GEMM scatters into full width —
``models/layers.scatter_residual``). Spec-matched leaves no rule covers
(e.g. ``ssm/wx``) are left dense and reported in ``CompactModel.skipped``.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from ..core.constraints import (ProjectionSpec, column_masks, leaf_path_str,
                                _first_match, _stacked_axis)
from ..core.l1inf import compact_columns, support_indices

# ZOO_RULES (a module-level constant, so outside the docstring audit) is
# re-exported as public API by repro.serve.__init__ alongside these.
__all__ = ["LeafSupport", "support_selection", "CompactRule",
           "CompactModel", "compact_model"]


@dataclasses.dataclass(frozen=True)
class LeafSupport:
    """Surviving-column set of one constrained leaf (all fields static).

    ``sel``: int32 (J,) surviving canonical-column indices (ascending);
    ``col_axis``: the axis of the ORIGINAL leaf the columns live on (the
    non-max axis of the trailing 2-D slice — stacked leading dims shift it);
    ``n_cols``: the full column count m, so ``ratio = J / m``.

    >>> LeafSupport(sel=np.array([0, 2], np.int32), col_axis=0, n_cols=4).ratio
    0.5
    """
    sel: np.ndarray
    col_axis: int
    n_cols: int

    @property
    def n_selected(self) -> int:
        """J — the number of surviving columns (static Python int)."""
        return int(self.sel.size)

    @property
    def ratio(self) -> float:
        """Compaction ratio J / m in [0, 1] (1.0 = nothing pruned)."""
        return self.n_selected / max(self.n_cols, 1)


def support_selection(params: Any, specs: Sequence[ProjectionSpec]
                      ) -> Dict[str, LeafSupport]:
    """Derive {leaf path: LeafSupport} for every spec-matching leaf.

    ``params``: param pytree (leaves of any float dtype); ``specs``: the
    SAME ProjectionSpec tuple the model trained under. The support comes
    from ``column_masks`` — the structural-zero contract (DESIGN.md §9): a
    column the projection killed is an exact-zero slice, so the mask test
    is exact, not a tolerance. A stacked (ndim > 2) leaf keeps the UNION
    of its slices' supports (a column dropped only where it is zero in
    EVERY slice — the gather stays exact and the compact leaf stays
    rectangular; for scan-stacked zoo blocks this means one shared support
    across all layers of the stack). Host-side: call at compaction time,
    not inside jit.

    >>> sup = support_selection(params, specs)["blocks/p0_global/mlp/w1"]
    """
    masks = column_masks(params, specs)
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    mflat = jax.tree_util.tree_flatten_with_path(masks)[0]
    out: Dict[str, LeafSupport] = {}
    for (path, leaf), (_, mask) in zip(flat, mflat):
        spec = _first_match(specs, leaf_path_str(path), leaf)
        if spec is None:
            continue
        max_axis = _stacked_axis(spec.axis, leaf.ndim)
        col_axis = leaf.ndim - 2 if spec.axis in (1, -1) else leaf.ndim - 1
        # one representative row per column (the mask is constant along the
        # max axis), then union over any stacked leading dims
        alive = np.asarray(jnp.take(mask, 0, axis=max_axis)) != 0
        alive = alive.reshape(-1, leaf.shape[col_axis]).any(axis=0)
        out[leaf_path_str(path)] = LeafSupport(
            sel=support_indices(alive), col_axis=col_axis,
            n_cols=int(leaf.shape[col_axis]))
    return out


@dataclasses.dataclass(frozen=True)
class CompactRule:
    """How one constrained leaf kind compacts (all fields static).

    ``primary``: regex on the full '/'-joined leaf path of the constrained
    leaf. ``col_axis``: the NEGATIVE axis its prunable columns must live on
    — a spec pruning any other axis of a matching leaf is refused (serving
    silently-wrong results is worse than refusing; cf. the SAE hidden-axis
    refusal, DESIGN.md §9). ``coupled``: (relative path, negative axis)
    pairs naming sibling leaves that gather with the SAME index vector
    (paths resolve from the primary's parent; ``..`` climbs; missing
    siblings are skipped — e.g. no ``w3`` in a non-gated MLP).
    ``scatter``: True when the compact output feeds the residual stream and
    the forward path must scatter it back to full width. ``base_ndim``: the
    unstacked rank of the primary (2 for ``mlp/w1``, 3 for stacked-expert
    ``moe/w1``) — leading dims beyond it are scan stacking, and the emitted
    sel leaf broadcasts over them so ``lax.scan`` can slice it per layer.
    ``sel_key``: where the int32 sel leaf lands, relative to the primary's
    parent (default ``"<leafname>_sel"`` beside the primary).

    >>> rule = CompactRule(primary=r"(^|/)mlp/w1$", coupled=(("w2", -2),))
    """
    primary: str
    col_axis: int = -1
    coupled: Tuple[Tuple[str, int], ...] = ()
    scatter: bool = False
    base_ndim: int = 2
    sel_key: Optional[str] = None


# The model zoo's compaction contract (configs/*.py declare the specs):
#   w1 hidden-unit pruning — a dead ff column makes the gate pre-activation
#   exactly 0, silu/gelu(0) = 0, so the unit's whole channel is exact zero:
#   w3 loses the same columns and w2 the same rows, output width unchanged;
#   w2 residual-output pruning — a dead output column contributes exact 0
#   to that residual feature, so the compact GEMM computes only the (J,)
#   support and scatter_residual places it back at full width.
ZOO_RULES: Tuple[CompactRule, ...] = (
    CompactRule(primary=r"(^|/)mlp/w1$", col_axis=-1,
                coupled=(("w3", -1), ("w2", -2))),
    CompactRule(primary=r"(^|/)mlp/w2$", col_axis=-1, scatter=True),
    CompactRule(primary=r"(^|/)moe/w1$", col_axis=-1,
                coupled=(("w3", -1), ("w2", -2)), base_ndim=3),
    CompactRule(primary=r"(^|/)moe/w2$", col_axis=-1, scatter=True,
                base_ndim=3),
)


@dataclasses.dataclass(frozen=True)
class _Gather:
    """One static re-gather: leaf ``path`` loses ``axis`` columns outside
    the sel of ``primary`` (axis negative; applies to dense checkpoints)."""
    path: str
    axis: int
    primary: str


@dataclasses.dataclass(frozen=True)
class _SelLeaf:
    """One emitted sel leaf: int32 sel of ``primary`` broadcast to
    ``lead + (J,)`` at tree position ``path`` (lead = scan-stack dims)."""
    path: str
    primary: str
    lead: Tuple[int, ...]


def _flatten(params: Any) -> Dict[str, Any]:
    """Nested-dict pytree -> {path: leaf}. Refuses non-mapping nodes
    (sequence indices have no stable string path to rebuild from)."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    out: Dict[str, Any] = {}
    for path, leaf in flat:
        if not all(hasattr(p, "key") for p in path):
            raise ValueError(
                "compact_model supports nested-dict param trees; got a "
                f"non-mapping node on path {leaf_path_str(path)!r}")
        out[leaf_path_str(path)] = leaf
    return out


def _unflatten(flat: Dict[str, Any]) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for path, leaf in flat.items():
        parts = path.split("/")
        node = out
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return out


def _resolve(base: str, rel: str) -> str:
    """Resolve a rule-relative path against the primary's parent path."""
    parts = base.split("/") if base else []
    for seg in rel.split("/"):
        if seg == "..":
            if not parts:
                raise ValueError(f"relative path {rel!r} climbs above the "
                                 f"param-tree root (base {base!r})")
            parts.pop()
        else:
            parts.append(seg)
    return "/".join(parts)


def _materialize(dense_params: Any, gathers: Tuple[_Gather, ...],
                 sel_leaves: Tuple[_SelLeaf, ...],
                 sels: Dict[str, np.ndarray]) -> Dict[str, Any]:
    """Apply the static gather records to a dense checkpoint and insert
    the sel leaves — the shared body of compact/refresh/recompact."""
    flat = _flatten(dense_params)
    for g in gathers:
        flat[g.path] = compact_columns(flat[g.path], sels[g.primary],
                                       axis=g.axis)
    for s in sel_leaves:
        sel = jnp.asarray(sels[s.primary], jnp.int32)
        flat[s.path] = jnp.broadcast_to(sel, s.lead + sel.shape)
    return _unflatten(flat)


@dataclasses.dataclass(frozen=True)
class CompactModel:
    """A projected-trained param tree with its structural zeros compiled out.

    ``params``: the compact pytree — constrained leaves gathered to their
    (J,)-support, coupled leaves co-gathered, plus one int32 ``*_sel`` leaf
    per compacted group riding IN the tree (broadcast over scan-stack dims)
    so a refreshed checkpoint serves through an old jit'd step without
    retracing. ``sels``/``live``: per-primary slot index vector (length
    J_slot, host numpy) and live count — after ``recompact_model`` the live
    support occupies the ascending prefix and the tail re-gathers an
    already-dead column (exact zeros), keeping shapes frozen. ``supports``:
    full-width ``LeafSupport`` per primary; ``skipped``: spec-matched
    leaves no rule covers (served dense); ``specs``/``rules``/``gathers``/
    ``sel_leaves``: the static recipe ``refresh_model``/``recompact_model``
    replay on new checkpoints.

    >>> cm = compact_model(params, cfg.projection_specs)   # then cm.params
    """
    params: Dict[str, Any]
    specs: Tuple[ProjectionSpec, ...]
    rules: Tuple[CompactRule, ...]
    supports: Dict[str, LeafSupport]
    sels: Dict[str, np.ndarray]
    live: Dict[str, int]
    gathers: Tuple[_Gather, ...]
    sel_leaves: Tuple[_SelLeaf, ...]
    skipped: Tuple[str, ...]

    def compaction_ratios(self) -> Dict[str, float]:
        """{primary leaf path: J_live / m} — the width fraction each
        constrained leaf still serves (slot padding not counted live)."""
        return {p: self.live[p] / max(s.n_cols, 1)
                for p, s in self.supports.items()}

    def slot_width(self, path: str) -> int:
        """J_slot of one primary — the frozen compact width (>= live)."""
        return int(self.sels[path].size)


def compact_model(params: Any, specs: Sequence[ProjectionSpec],
                  rules: Sequence[CompactRule] = ZOO_RULES) -> CompactModel:
    """Compact a projected-trained param tree for serving.

    ``params``: dense checkpoint (nested-dict pytree, any float dtypes);
    ``specs``: the ProjectionSpec tuple it trained under (typically
    ``cfg.projection_specs``); ``rules``: the compaction contract (first
    matching rule wins per constrained leaf; defaults to the zoo's MLP/MoE
    rules). Returns a ``CompactModel`` whose forward outputs equal the
    dense model's to fp summation order (DESIGN.md §10). Raises
    ``ValueError`` if a spec prunes an axis its rule cannot serve exactly.
    Host-side, one-off: run once per checkpoint, then hand
    ``CompactModel.params`` to the jit'd forward / ``BatchServer``.

    >>> cm = compact_model(params, cfg.projection_specs)
    """
    sups_all = support_selection(params, specs)
    flat = _flatten(params)
    gathers: list = []
    sel_leaves: list = []
    sels: Dict[str, np.ndarray] = {}
    live: Dict[str, int] = {}
    supports: Dict[str, LeafSupport] = {}
    skipped: list = []
    seen_gathers = set()
    for path, sup in sups_all.items():
        rule = next((r for r in rules if re.search(r.primary, path)), None)
        if rule is None:
            skipped.append(path)
            continue
        leaf = flat[path]
        if sup.col_axis - leaf.ndim != rule.col_axis:
            raise ValueError(
                f"spec prunes axis {sup.col_axis - leaf.ndim} of {path!r} "
                f"but rule {rule.primary!r} serves axis {rule.col_axis} "
                f"compaction only — no exactness argument covers the "
                f"requested axis (DESIGN.md §10)")
        parent, _, name = path.rpartition("/")
        group = [(path, rule.col_axis)]
        for rel, ax in rule.coupled:
            cpath = _resolve(parent, rel)
            if cpath in flat:           # e.g. no w3 in a non-gated MLP
                group.append((cpath, ax))
        for gpath, gax in group:
            if (gpath, gax) in seen_gathers:
                raise ValueError(
                    f"two rules gather axis {gax} of {gpath!r} — "
                    f"overlapping CompactRules are ambiguous")
            seen_gathers.add((gpath, gax))
            gathers.append(_Gather(path=gpath, axis=gax, primary=path))
        sel_path = _resolve(parent, rule.sel_key or f"{name}_sel")
        if sel_path in flat:
            raise ValueError(f"sel leaf path {sel_path!r} already exists "
                             f"in the param tree")
        lead = tuple(int(d) for d in leaf.shape[: leaf.ndim - rule.base_ndim])
        sel_leaves.append(_SelLeaf(path=sel_path, primary=path, lead=lead))
        sels[path] = np.asarray(sup.sel, np.int32)
        live[path] = sup.n_selected
        supports[path] = sup
    compact = _materialize(params, tuple(gathers), tuple(sel_leaves), sels)
    return CompactModel(
        params=compact, specs=tuple(specs), rules=tuple(rules),
        supports=supports, sels=sels, live=live, gathers=tuple(gathers),
        sel_leaves=tuple(sel_leaves), skipped=tuple(skipped))
