"""Model-generic compact serving: structural zeros compiled out of any
projected-trained param tree (DESIGN.md §10).

``compact.py`` owns the static side — support derivation from
``ProjectionSpec`` lists (the same ``column_masks`` contract the training
freeze uses), ``CompactRule`` coupling (which sibling leaves co-compact,
which outputs scatter back into the residual stream), and ``compact_model``
which gathers a dense checkpoint into a ``CompactModel``. ``refresh.py``
owns the checkpoint lifecycle — ``refresh_model`` (hot value refresh
through the frozen ``sel``, never recompiles) and ``recompact_model``
(periodic live re-compaction: support only shrinks under the frozen mask,
so the re-gather is monotone and shape-preserving).

``engine.py`` owns the serving loop itself — ``FleetEngine``, the
continuous-batching engine (DESIGN.md §13) that keeps one compiled decode
step hot under churn: on-device slot state, in-step sampling, masked
admission, donated cache, and a ``RecompactScheduler`` that turns
checkpoint refreshes into live re-compactions with hysteresis.

The SAE path (``sae/serve.py``) and the LM zoo path (``train/serve.py``'s
``BatchServer``) are both thin adapters over this layer.
"""
from .compact import (LeafSupport, support_selection, CompactRule, ZOO_RULES,
                      CompactModel, compact_model)
from .refresh import refresh_model, recompact_model
from .engine import (EngineConfig, Request, Completion, LatencyStats,
                     RecompactScheduler, FleetEngine)

__all__ = ["LeafSupport", "support_selection", "CompactRule", "ZOO_RULES",
           "CompactModel", "compact_model", "refresh_model",
           "recompact_model", "EngineConfig", "Request", "Completion",
           "LatencyStats", "RecompactScheduler", "FleetEngine"]
