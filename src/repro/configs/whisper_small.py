"""whisper-small [audio] — enc-dec, 12L each, d_model=768 12H (kv=12)
d_ff=3072 vocab=51865; conv frontend stubbed as precomputed frame
embeddings (assignment). [arXiv:2212.04356; unverified]"""
from ..models.transformer import ArchConfig
from ..core.constraints import ProjectionSpec

CONFIG = ArchConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab=51865,
    pattern=("dec_cross",), encdec=True, n_enc_layers=12, enc_seq=1500,
    mlp_kind="gelu", norm_kind="layernorm", rope_theta=0.0,  # sinusoidal
    tie_embeddings=True,
    rules_overrides=(("heads", None), ("kv_heads", None)),
    projection_specs=(
        ProjectionSpec(pattern=r"(blocks|enc_blocks)/.*/mlp/w1$",
                       norm="l1inf", radius=24.0, axis=0, every_k=10),
    ),
)
