"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16; parallel attention + mamba heads per layer,
sliding-window attention. [arXiv:2411.13676; hf]

Deviations (DESIGN.md §6): the 3 full-attention layers of the released model
are approximated as sliding-window like the rest; meta-tokens are omitted
(frontend-level detail)."""
from ..models.transformer import ArchConfig
from ..core.constraints import ProjectionSpec

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, head_dim=64,
    d_ff=5504, vocab=32001,
    pattern=("hybrid",), window=1024,
    ssm_state=16, ssm_expand=2, ssm_headdim=64,
    tie_embeddings=True,
    # 25 heads / 5 kv don't divide the 16-way model axis (vocab 32001 is
    # padded to 32128 by the layout and shards normally)
    rules_overrides=(("heads", None), ("kv_heads", None)),
    projection_specs=(
        ProjectionSpec(pattern=r"blocks/.*/(mlp/w1|ssm/wx)$", norm="l1inf",
                       radius=32.0, axis=0, every_k=10),
    ),
)
