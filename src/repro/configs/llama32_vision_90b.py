"""llama-3.2-vision-90b [vlm] — 100L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256; cross-attn image layers (every 5th layer), vision
frontend stubbed as precomputed patch embeddings (assignment).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from ..models.transformer import ArchConfig
from ..core.constraints import ProjectionSpec

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b", family="vlm",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab=128256,
    pattern=("global", "global", "global", "global", "cross"),
    n_img_tokens=1600, tie_embeddings=False, rope_theta=500_000.0,
    rules_overrides=(("kv_heads", None),),   # kv=8 < 16-way model axis
    projection_specs=(
        ProjectionSpec(pattern=r"blocks/.*/mlp/w1$", norm="l1inf",
                       radius=96.0, axis=0, every_k=10),
    ),
)
