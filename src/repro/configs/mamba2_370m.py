"""mamba2-370m [ssm] — 48L d_model=1024 (attention-free) vocab=50280,
ssm_state=128, SSD (state-space duality). [arXiv:2405.21060; unverified]"""
from ..models.transformer import ArchConfig
from ..core.constraints import ProjectionSpec

CONFIG = ArchConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=1, n_kv_heads=1, head_dim=64,
    d_ff=0, vocab=50280,
    pattern=("ssm",), ssm_state=128, ssm_expand=2, ssm_headdim=64,
    ssm_chunk=64, tie_embeddings=True,   # vocab pads 50280 -> 50304
    # attention-free: the paper's technique applies to the SSM in/out
    # projections (DESIGN.md §5) — not inapplicable.
    projection_specs=(
        ProjectionSpec(pattern=r"blocks/.*/ssm/wx$", norm="l1inf",
                       radius=24.0, axis=0, every_k=10),
    ),
)
