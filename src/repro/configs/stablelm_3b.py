"""stablelm-3b [dense] — 32L d_model=2560 32H (kv=32) d_ff=6912
vocab=50304, LayerNorm, partial rotary (25%).
[hf:stabilityai/stablelm-2-1_6b; unverified]"""
from ..models.transformer import ArchConfig
from ..core.constraints import ProjectionSpec

CONFIG = ArchConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=6912, vocab=50304,
    pattern=("global",), mlp_kind="swiglu", norm_kind="layernorm",
    rope_frac=0.25, tie_embeddings=False,
    projection_specs=(
        ProjectionSpec(pattern=r"blocks/.*/mlp/w1$", norm="l1inf",
                       radius=48.0, axis=0, every_k=10),
    ),
)
