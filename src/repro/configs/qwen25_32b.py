"""qwen2.5-32b [dense] — 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064, GQA, QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]"""
from ..models.transformer import ArchConfig
from ..core.constraints import ProjectionSpec

CONFIG = ArchConfig(
    name="qwen2.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=27648, vocab=152064,
    pattern=("global",), mlp_kind="swiglu", qkv_bias=True,
    tie_embeddings=False, rope_theta=1_000_000.0,
    # 40 heads / 8 kv do not divide the 16-way model axis -> replicate heads,
    # TP lives on d_ff and vocab.
    rules_overrides=(("heads", None), ("kv_heads", None)),
    projection_specs=(
        ProjectionSpec(pattern=r"blocks/.*/mlp/w1$", norm="l1inf",
                       radius=64.0, axis=0, every_k=10),
    ),
)
