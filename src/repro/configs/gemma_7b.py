"""gemma-7b [dense] — 28L d_model=3072 16H (GQA kv=16) d_ff=24576
vocab=256000, GeGLU, head_dim=256. [arXiv:2403.08295; hf]"""
from ..models.transformer import ArchConfig
from ..core.constraints import ProjectionSpec

CONFIG = ArchConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
    d_ff=24576, vocab=256000,
    pattern=("global",), mlp_kind="geglu", norm_kind="rmsnorm",
    embed_scale=True, tie_embeddings=True, rope_theta=10000.0,
    projection_specs=(
        ProjectionSpec(pattern=r"blocks/.*/mlp/w1$", norm="l1inf",
                       radius=64.0, axis=0, every_k=10),
    ),
)
