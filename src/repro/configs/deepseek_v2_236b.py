"""deepseek-v2-236b [moe] — 60L d_model=5120 128H d_ff=1536 vocab=102400,
MLA (kv_lora=512, q_lora=1536, nope 128 + rope 64, v 128),
2 shared + 160 routed experts top-6. [arXiv:2405.04434; hf]

Deviation (DESIGN.md §6): the released model's first dense layer is modeled
as MoE like the rest (uniform scan stack)."""
from ..models.transformer import ArchConfig
from ..core.constraints import ProjectionSpec

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, head_dim=192,
    d_ff=1536, vocab=102400,
    pattern=("mla",),
    q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64, v_head_dim=128,
    n_experts=160, n_shared_experts=2, top_k=6, capacity_factor=1.25,
    expert_sharding="ep", tie_embeddings=False,
    projection_specs=(
        ProjectionSpec(pattern=r"blocks/.*/moe/w1$", norm="l1inf",
                       radius=16.0, axis=0, every_k=10),
    ),
)
