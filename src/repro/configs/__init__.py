"""Config registry: one module per assigned architecture (+ SAE configs).

``get_config(name)`` returns the exact assigned ArchConfig;
``get_reduced(name)`` the same-family CPU smoke config.
"""
from __future__ import annotations

import importlib
from typing import Dict

from ..models.transformer import ArchConfig
from ..models.zoo import reduce_config

ARCH_IDS = [
    "gemma_7b",
    "qwen25_32b",
    "gemma3_4b",
    "stablelm_3b",
    "hymba_15b",
    "llama32_vision_90b",
    "whisper_small",
    "mamba2_370m",
    "mixtral_8x7b",
    "deepseek_v2_236b",
]

# assignment-id <-> module-name mapping
ALIASES = {
    "gemma-7b": "gemma_7b",
    "qwen2.5-32b": "qwen25_32b",
    "gemma3-4b": "gemma3_4b",
    "stablelm-3b": "stablelm_3b",
    "hymba-1.5b": "hymba_15b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "whisper-small": "whisper_small",
    "mamba2-370m": "mamba2_370m",
    "mixtral-8x7b": "mixtral_8x7b",
    "deepseek-v2-236b": "deepseek_v2_236b",
}


def get_config(name: str) -> ArchConfig:
    mod_name = ALIASES.get(name, name).replace("-", "_").replace(".", "")
    mod = importlib.import_module(f".{mod_name}", __package__)
    return mod.CONFIG


def get_reduced(name: str) -> ArchConfig:
    return reduce_config(get_config(name))


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
