"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, 8 experts top-2, sliding-window attention (4096).
[arXiv:2401.04088; hf]"""
from ..models.transformer import ArchConfig
from ..core.constraints import ProjectionSpec

CONFIG = ArchConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=32000,
    pattern=("local",), window=4096,
    n_experts=8, top_k=2, capacity_factor=1.25,
    expert_sharding="tp",     # 8 experts < 16-way axis: TP inside experts
    tie_embeddings=False, rope_theta=1_000_000.0,
    rules_overrides=(("kv_heads", None),),
    projection_specs=(
        # expert-structured sparsity: per-expert column pruning (vmapped)
        ProjectionSpec(pattern=r"blocks/.*/moe/w1$", norm="l1inf",
                       radius=64.0, axis=0, every_k=10),
    ),
)
