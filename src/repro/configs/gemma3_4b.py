"""gemma3-4b [dense] — 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144, 5:1 local:global (window 1024), 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
from ..models.transformer import ArchConfig
from ..core.constraints import ProjectionSpec

CONFIG = ArchConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=10240, vocab=262144,
    pattern=("local", "local", "local", "local", "local", "global"),
    window=1024, mlp_kind="geglu", embed_scale=True, tie_embeddings=True,
    rope_theta=1_000_000.0,
    long_context_capable=True,   # 5:1 local:global -> long_500k runs

    rules_overrides=(("heads", None), ("kv_heads", None)),
    projection_specs=(
        ProjectionSpec(pattern=r"blocks/.*/mlp/w1$", norm="l1inf",
                       radius=48.0, axis=0, every_k=10),
    ),
)
