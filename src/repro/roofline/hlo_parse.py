"""Trip-count-aware cost extraction from optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE — a scan over 60
layers under-reports flops/bytes/collectives by 60x. This parser rebuilds
the costs from the HLO itself:

  * each computation is parsed with a local symbol table (operand shapes are
    resolved from defining lines — modern HLO prints operands by name only);
  * the call graph (while body/condition, fusion calls, conditional
    branches, reduce lambdas) propagates an execution multiplier: a while
    body's costs are multiplied by the trip count parsed from its condition
    (max integer constant — exact for lax.scan/fori_loop, an upper bound
    for early-exit while_loops like the projection Newton solver);
  * conditional branches are counted as always-taken (upper bound — the
    causal-attention tile skip means real traffic is lower);
  * HBM bytes are a per-op proxy: operands+result for compute ops, result
    only for slicing/gather/broadcast, 2x update for dynamic-update-slice,
    zero for plumbing (parameter/tuple/gte/bitcast/reshape/while/
    conditional) whose traffic is accounted at use sites;
  * dot flops = 2 * prod(result dims) * prod(lhs contracting dims);
  * collective bytes use ring-transfer factors over the replica-group size.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}
_SHAPE_RE = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=")
_OP_RE = re.compile(r"=\s*(?:\([^=]*?\)|[a-z0-9_]+\[[0-9,]*\][^ ]*)\s*"
                    r"([a-z][a-z0-9\-]*)\(")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^{]*)?\{\s*$")
_REF_RE = re.compile(
    r"(body|condition|calls|to_apply|true_computation|false_computation)="
    r"%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"\bconstant\((\d+)\)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERAND_RE = re.compile(r"(?<![=\w])%([\w.\-]+)")

_ZERO_BYTE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "reshape", "while", "conditional", "after-all", "optimization-barrier",
    "copy-done", "all-gather-done", "all-reduce-done", "partition-id",
    "replica-id",
}
_RESULT_ONLY_OPS = {"dynamic-slice", "gather", "slice",
                    "pad", "concatenate", "reverse"}
# ops whose operand/result traffic is counted; anything else (standalone
# elementwise) is treated as fused into a neighboring anchor op — the
# CPU-backend HLO we analyze fuses far less than a TPU compile would, so
# counting every elementwise op would inflate the memory term ~20x.
_BYTE_ANCHOR_OPS = {
    "dot", "convolution", "reduce", "reduce-window", "fusion", "sort",
    "scatter", "select-and-scatter", "cholesky", "triangular-solve",
    "rng", "rng-bit-generator", "map",
} | _RESULT_ONLY_OPS | {"dynamic-update-slice", "transpose", "copy"}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _dims(s: str) -> List[int]:
    return [int(d) for d in s.split(",")] if s else []


def _nbytes(dt: str, dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n * _DTYPE_BYTES[dt]


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    tile_bytes: float = 0.0     # attention/SSD tile traffic a fused kernel
    #                             (flash / SSD Pallas) keeps in VMEM
    collective_moved: Dict[str, float] = dataclasses.field(default_factory=dict)
    collective_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    refs: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    max_const: int = 1


@dataclasses.dataclass
class HloCost:
    dot_flops: float
    bytes_proxy: float
    tile_bytes: float
    collective_moved: Dict[str, float]
    collective_counts: Dict[str, float]
    trips: Dict[str, int]

    @property
    def collective_bytes(self) -> float:
        return sum(self.collective_moved.values())

    @property
    def bytes_fused(self) -> float:
        """HBM proxy assuming tile-expansion intermediates (S x S attention
        probabilities, SSD Q x Q decay tiles) stay in VMEM — the traffic the
        production Pallas kernels (kernels/flash_attention, kernels/l1inf)
        actually generate."""
        return max(self.bytes_proxy - self.tile_bytes, 0.0)


def _parse_computation(lines: List[str]) -> CompCost:
    comp = CompCost()
    # pass A: symbol table name -> list[(dtype, dims)] (result shapes)
    sym: Dict[str, List[Tuple[str, List[int]]]] = {}
    parsed = []
    for line in lines:
        d = _DEF_RE.match(line)
        m = _OP_RE.search(line)
        op = m.group(1) if m else None
        op_at = m.start(1) if m else len(line)
        res_shapes = [(mm.group(1), _dims(mm.group(2)))
                      for mm in _SHAPE_RE.finditer(line)
                      if mm.start() < op_at]
        if d:
            sym[d.group(1)] = res_shapes
        parsed.append((line, op, op_at, res_shapes))
        for c in _CONST_RE.finditer(line):
            comp.max_const = max(comp.max_const, int(c.group(1)))
        for r in _REF_RE.finditer(line):
            kind = r.group(1)
            if kind == "calls":
                kind = "fusion_calls" if op == "fusion" else "calls"
            comp.refs.append((kind, r.group(2)))
        bm = _BRANCHES_RE.search(line)
        if bm:
            for name in bm.group(1).split(","):
                comp.refs.append(("branch", name.strip().lstrip("%")))

    # pass B: costs with operand shapes resolved
    for line, op, op_at, res_shapes in parsed:
        if op is None:
            continue
        tail = line[op_at:]
        # cut attribute tail containing computation refs (to_apply=%x etc.)
        operand_names = [n for n in _OPERAND_RE.findall(tail)]
        opd_shapes: List[Tuple[str, List[int]]] = []
        for n in operand_names:
            opd_shapes.extend(sym.get(n, []))

        res_b = sum(_nbytes(dt, dims) for dt, dims in res_shapes)
        opd_b = sum(_nbytes(dt, dims) for dt, dims in opd_shapes)

        # ---- flops ------------------------------------------------------
        if op == "dot" and res_shapes and opd_shapes:
            cm = _CONTRACT_RE.search(line)
            contract = _dims(cm.group(1)) if cm else []
            lhs = opd_shapes[0][1]
            k = 1
            for ci in contract:
                if ci < len(lhs):
                    k *= lhs[ci]
            out_n = 1
            for d2 in res_shapes[0][1]:
                out_n *= d2
            comp.flops += 2.0 * out_n * k

        # ---- collectives --------------------------------------------------
        base_op = op[:-len("-start")] if op.endswith("-start") else op
        if base_op in _COLLECTIVES:
            n = 1
            g = _GROUPS_RE.search(line)
            if g:
                n = len(g.group(1).split(","))
            else:
                gi = _GROUPS_IOTA_RE.search(line)
                if gi:
                    n = int(gi.group(2))
            n = max(n, 2)
            ring = (n - 1) / n
            ob = opd_b or res_b
            if base_op == "all-reduce":
                moved = 2.0 * ring * ob
            elif base_op == "all-gather":
                moved = ring * res_b
            elif base_op == "reduce-scatter":
                moved = ring * ob
            elif base_op == "all-to-all":
                moved = ring * res_b
            else:  # collective-permute
                moved = float(res_b)
            comp.collective_moved[base_op] = (
                comp.collective_moved.get(base_op, 0.0) + moved)
            comp.collective_counts[base_op] = (
                comp.collective_counts.get(base_op, 0) + 1)

        # ---- bytes proxy ---------------------------------------------------
        if op in _ZERO_BYTE_OPS or op.endswith("-start"):
            continue
        if base_op in _COLLECTIVES:
            comp.bytes += res_b + opd_b
            continue
        if op not in _BYTE_ANCHOR_OPS:
            continue  # standalone elementwise: assumed fused on TPU
        if op == "dynamic-update-slice":
            upd = opd_shapes[1] if len(opd_shapes) > 1 else None
            comp.bytes += 2.0 * _nbytes(*upd) if upd else float(res_b)
            continue
        if op in _RESULT_ONLY_OPS:
            comp.bytes += 2.0 * res_b
            continue
        if op in ("transpose", "copy"):
            contrib = 2.0 * res_b
        else:
            contrib = float(res_b + opd_b)
        comp.bytes += contrib
        # tile-traffic classification — what a fused Pallas kernel keeps in
        # VMEM: (a) any op touching a rank>=5 tensor (attention tiles
        # (B,cq,KV,R,ck), online-softmax accumulators, SSD (B,nc,Q,Q,H)
        # decay tiles) is flash-interior; (b) a rank>=4 tensor dwarfing
        # everything else on its line (tile expansion/consumption dots).
        tensors = ([(dt, dims) for dt, dims in res_shapes]
                   + [(dt, dims) for dt, dims in opd_shapes])
        if tensors:
            sizes = [(_nbytes(dt, dims), len(dims)) for dt, dims in tensors]
            max_rank = max(r for _, r in sizes)
            big_b, big_rank = max(sizes)
            rest = sum(b for b, _ in sizes) - big_b
            if max_rank >= 5:
                comp.tile_bytes += contrib
            elif big_rank >= 4 and big_b > 4 * max(rest, 1):
                comp.tile_bytes += (2.0 * big_b
                                    if op in ("transpose", "copy")
                                    else float(big_b))
    return comp


def parse_hlo(text: str) -> HloCost:
    comps: Dict[str, CompCost] = {}
    entry: Optional[str] = None
    cur_name: Optional[str] = None
    cur_lines: List[str] = []
    blocks: List[Tuple[str, List[str]]] = []
    for line in text.splitlines():
        stripped = line.strip()
        if cur_name is None:
            h = _HEADER_RE.match(stripped)
            if h and ("->" in stripped or h.group(1)):
                cur_name = h.group(2)
                cur_lines = []
                if h.group(1):
                    entry = cur_name
            continue
        if stripped == "}":
            blocks.append((cur_name, cur_lines))
            cur_name = None
            continue
        cur_lines.append(line)
    for name, lines in blocks:
        comps[name] = _parse_computation(lines)

    # propagate execution multipliers from ENTRY through the call graph;
    # while body/condition refs come from the same line, so pair in order
    mult: Dict[str, float] = {}
    fused: Dict[str, bool] = {}
    trips: Dict[str, int] = {}

    def visit(name: str, m: float, via_fusion: bool, depth: int = 0):
        if name not in comps or depth > 64:
            return
        mult[name] = mult.get(name, 0.0) + m
        fused[name] = fused.get(name, True) and via_fusion
        comp = comps[name]
        bodies = [r for k2, r in comp.refs if k2 == "body"]
        condis = [r for k2, r in comp.refs if k2 == "condition"]
        for b, c in zip(bodies, condis):
            trip = comps[c].max_const if c in comps else 1
            trips[b] = max(trips.get(b, 1), trip)
            visit(b, m * trip, False, depth + 1)
            visit(c, m * trip, False, depth + 1)
        for kind, ref in comp.refs:
            if kind in ("body", "condition"):
                continue
            if kind == "fusion_calls":
                visit(ref, m, True, depth + 1)
            else:
                visit(ref, m, via_fusion, depth + 1)

    if entry is None:
        entry = blocks[0][0] if blocks else None
    if entry is not None:
        visit(entry, 1.0, False)

    flops = 0.0
    byts = 0.0
    tile = 0.0
    coll_moved: Dict[str, float] = {}
    coll_counts: Dict[str, float] = {}
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m <= 0:
            continue
        flops += m * comp.flops
        if not fused.get(name, False):
            byts += m * comp.bytes
            tile += m * comp.tile_bytes
        for k, v in comp.collective_moved.items():
            coll_moved[k] = coll_moved.get(k, 0.0) + m * v
        for k, v in comp.collective_counts.items():
            coll_counts[k] = coll_counts.get(k, 0.0) + m * v
    return HloCost(dot_flops=flops, bytes_proxy=byts, tile_bytes=tile,
                   collective_moved=coll_moved, collective_counts=coll_counts,
                   trips=trips)
