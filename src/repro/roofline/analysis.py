"""Roofline analysis from compiled dry-run artifacts (TPU v5e targets).

Three terms per (arch x shape x mesh), all in seconds-per-step:

    compute    = HLO_FLOPs_per_device / PEAK_FLOPS
    memory     = HLO_bytes_per_device / HBM_BW
    collective = sum_ops bytes_moved_per_device(op) / LINK_BW

``cost_analysis()`` of the post-SPMD executable reports *per-device* flops
and bytes. Collective bytes are parsed from the optimized HLO text: for each
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op we take the shapes printed inline and apply ring-transfer factors over
the parsed replica-group size n:

    all-reduce       moved = 2 (n-1)/n * bytes(operand)
    all-gather       moved = (n-1)/n   * bytes(result)
    reduce-scatter   moved = (n-1)/n   * bytes(operand)  (operand = n*result)
    all-to-all       moved = (n-1)/n   * bytes(result)
    collective-permute moved = bytes(result)

Hardware constants (per assignment): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s per ICI link.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|tuple\([^)]*\)|"
    r"(?:" + "|".join(_DTYPE_BYTES) + r")\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(s: str) -> int:
    m = _SHAPE_RE.search(s)
    if not m:
        return 0
    dt, dims = m.group(1), m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def _all_shapes_bytes(s: str) -> List[int]:
    out = []
    for m in _SHAPE_RE.finditer(s):
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        out.append(n * _DTYPE_BYTES[m.group(1)])
    return out


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, float]    # bytes moved per device (ring model)
    raw_bytes_by_kind: Dict[str, float]

    @property
    def total_moved(self) -> float:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = {}
    moved: Dict[str, float] = {}
    raw: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        # group size n
        n = 1
        g = _GROUPS_RE.search(line)
        if g:
            n = len(g.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            if gi:
                n = int(gi.group(2))
        if n <= 1:
            n = 2  # degenerate print; assume at least a pair
        ring = (n - 1) / n

        # result shape = first shape on the line (lhs); operand shapes follow
        shapes = _all_shapes_bytes(line)
        if not shapes:
            continue
        result_b = shapes[0]
        operand_b = shapes[1] if len(shapes) > 1 else result_b

        if kind == "all-reduce":
            b = 2.0 * ring * operand_b
            r = operand_b
        elif kind == "all-gather":
            b = ring * result_b
            r = result_b
        elif kind == "reduce-scatter":
            b = ring * operand_b
            r = operand_b
        elif kind == "all-to-all":
            b = ring * result_b
            r = result_b
        else:  # collective-permute
            b = float(result_b)
            r = result_b
        counts[kind] = counts.get(kind, 0) + 1
        moved[kind] = moved.get(kind, 0.0) + b
        raw[kind] = raw.get(kind, 0.0) + float(r)
    return CollectiveStats(counts=counts, bytes_by_kind=moved,
                           raw_bytes_by_kind=raw)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops_per_device: float           # trip-count-corrected HLO dot flops
    bytes_per_device: float           # trip-count-corrected HBM proxy
    collective_bytes: float           # ring-model bytes moved per device
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float                # 6*N*D (active params) global
    useful_ratio: float               # model_flops / (flops_per_device*chips)
    collective_counts: Dict[str, float]
    memory_analysis: Dict[str, float]
    roofline_fraction: float          # ideal/dominant-term efficiency
    flops_xla_raw: float = 0.0        # cost_analysis() (body counted once)
    bytes_xla_raw: float = 0.0
    while_trips: Dict[str, int] = dataclasses.field(default_factory=dict)
    # kernelized view: tile-expansion intermediates (attention probs, SSD
    # decay tiles) kept in VMEM by the Pallas kernels
    tile_bytes: float = 0.0
    memory_fused_s: float = 0.0
    dominant_fused: str = ""
    roofline_fraction_fused: float = 0.0
    collective_moved: Dict[str, float] = dataclasses.field(
        default_factory=dict)   # bytes moved per device, by op kind

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def analyze(arch: str, shape: str, mesh_name: str, n_chips: int,
            cost: dict, hlo_text: str, model_flops: float,
            memory_analysis: Optional[dict] = None) -> Roofline:
    from .hlo_parse import parse_hlo
    hc = parse_hlo(hlo_text)
    flops = hc.dot_flops
    byts = hc.bytes_proxy
    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = hc.collective_bytes / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    total_flops = flops * n_chips
    useful = model_flops / total_flops if total_flops else 0.0
    # fraction of the ideal (compute-only at useful FLOPs) step time that the
    # dominant term allows: ideal = model_flops/(chips*peak); achieved step
    # >= max(terms) -> fraction = ideal / max(terms)
    ideal = model_flops / (n_chips * PEAK_FLOPS)
    frac = ideal / max(max(terms.values()), 1e-30)
    memory_fused_s = hc.bytes_fused / HBM_BW
    terms_fused = {"compute": compute_s, "memory": memory_fused_s,
                   "collective": collective_s}
    dominant_fused = max(terms_fused, key=terms_fused.get)
    frac_fused = ideal / max(max(terms_fused.values()), 1e-30)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name,
        flops_per_device=flops, bytes_per_device=byts,
        collective_bytes=hc.collective_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops, useful_ratio=useful,
        collective_counts=hc.collective_counts,
        memory_analysis=memory_analysis or {},
        roofline_fraction=frac,
        flops_xla_raw=float(cost.get("flops", 0.0)),
        bytes_xla_raw=float(cost.get("bytes accessed", 0.0)),
        while_trips={k: v for k, v in sorted(hc.trips.items())[:20]
                     if v > 1},
        tile_bytes=hc.tile_bytes,
        memory_fused_s=memory_fused_s,
        dominant_fused=dominant_fused,
        roofline_fraction_fused=frac_fused,
        collective_moved=hc.collective_moved,
    )


def model_flops_for(cfg, shape_name: str, n_params_total: int,
                    n_params_active: Optional[int] = None) -> float:
    """6*N*D with D = tokens processed per step (decode: one per batch row).
    For training D counts fwd+bwd via the 6x factor; for inference 2*N*D."""
    from ..models.zoo import SHAPES
    sh = SHAPES[shape_name]
    n = n_params_active or n_params_total
    if sh["kind"] == "train":
        return 6.0 * n * sh["batch"] * sh["seq"]
    if sh["kind"] == "prefill":
        return 2.0 * n * sh["batch"] * sh["seq"]
    return 2.0 * n * sh["batch"]  # decode: 1 token per row


def active_params(cfg, n_total: int) -> int:
    """Rough active-parameter count for MoE archs (top-k of routed)."""
    if not cfg.n_experts:
        return n_total
    # routed expert params per layer
    per_layer_routed = 3 * cfg.n_experts * cfg.d_model * cfg.d_ff
    cycles = cfg.n_layers
    routed_total = per_layer_routed * cycles
    active_routed = routed_total * cfg.top_k / cfg.n_experts
    return int(n_total - routed_total + active_routed)
