from .loop import TrainConfig, train, build_accum_step
