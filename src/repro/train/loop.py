"""Training runner: the production loop with every fault-tolerance feature
wired in (checkpoint/restart, straggler watchdog, deterministic data,
projection constraints, microbatch gradient accumulation).

Runs unchanged on 1 CPU device (examples) and on the production meshes
(launch/train.py) — the mesh/rules are injected, not assumed.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, Optional

import numpy as np
import jax
import jax.numpy as jnp

from ..models.zoo import Model
from ..optim import AdamConfig, adam_init
from ..core import ProjectionEngine, sparsity_report
from ..checkpoint import AsyncCheckpointer, latest_step, restore_tree
from ..dist.sharding import axis_rules
from ..dist.watchdog import StepWatchdog
from ..data.pipeline import LMBatcher


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep_ckpts: int = 3
    microbatches: int = 1          # gradient accumulation
    lr: float = 3e-4
    warmup: int = 20
    with_projection: bool = True
    proj_solver: str = "fused"     # engine solver; "fused" = two-HBM-pass
                                   # step where the family supports it,
                                   # bit-equal Newton fallback elsewhere
    seed: int = 0


def build_accum_step(model: Model, acfg: AdamConfig, tcfg: TrainConfig,
                     mesh=None, rules=None, engine: ProjectionEngine = None):
    """jit'd train step with optional microbatch accumulation via lax.scan.
    The update half is the shared ``ProjectionEngine.projected_update`` step
    core (Adam + packed warm-started projection + every_k gate)."""
    cfg = model.cfg
    if engine is None:
        engine = ProjectionEngine(
            cfg.projection_specs if tcfg.with_projection else (),
            solver=tcfg.proj_solver)

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def step(params, opt_state, proj_state, batch, lr):
        with axis_rules(mesh, rules):
            if tcfg.microbatches > 1:
                def micro(carry, mb):
                    (g_acc, l_acc) = carry
                    (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                        params, mb)
                    g_acc = jax.tree_util.tree_map(jnp.add, g_acc, g)
                    return (g_acc, l_acc + l), None

                mbs = jax.tree_util.tree_map(
                    lambda x: x.reshape((tcfg.microbatches,
                                         x.shape[0] // tcfg.microbatches)
                                        + x.shape[1:]), batch)
                g0 = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, loss), _ = jax.lax.scan(micro, (g0, 0.0), mbs)
                grads = jax.tree_util.tree_map(
                    lambda g: g / tcfg.microbatches, grads)
                loss = loss / tcfg.microbatches
            else:
                (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, batch)
            params, opt_state, proj_state = engine.projected_update(
                grads, opt_state, params, acfg, lr=lr, state=proj_state)
        return params, opt_state, proj_state, loss

    return jax.jit(step, donate_argnums=(0, 1, 2))


def lr_at(tcfg: TrainConfig, step: int) -> float:
    warm = min(1.0, (step + 1) / max(tcfg.warmup, 1))
    return tcfg.lr * warm


def train(model: Model, batcher: LMBatcher, tcfg: TrainConfig,
          mesh=None, rules=None, resume: bool = True,
          on_step: Optional[Callable[[int, float, float], None]] = None
          ) -> Dict[str, Any]:
    """Run the loop; auto-resumes from the latest checkpoint if present."""
    acfg = AdamConfig(lr=tcfg.lr)
    params = model.init(jax.random.PRNGKey(tcfg.seed))
    opt_state = adam_init(params, acfg)
    start_step = 0

    engine = ProjectionEngine(
        model.cfg.projection_specs if tcfg.with_projection else (),
        solver=tcfg.proj_solver)
    proj_state = engine.init_state(params)

    ckpt = None
    if tcfg.ckpt_dir:
        ckpt = AsyncCheckpointer(tcfg.ckpt_dir, keep=tcfg.keep_ckpts)
        if resume and latest_step(tcfg.ckpt_dir) is not None:
            # the projection theta state rides in the checkpoint so a resume
            # stays warm-started; pre-engine checkpoints lack it — fall back
            # to a cold Newton start rather than refusing the restore
            try:
                state = {"params": params, "opt": opt_state,
                         "proj": proj_state}
                state, start_step = restore_tree(state, tcfg.ckpt_dir)
                proj_state = state["proj"]
            except KeyError:
                state = {"params": params, "opt": opt_state}
                state, start_step = restore_tree(state, tcfg.ckpt_dir)
                print("[train] checkpoint has no projection state; "
                      "cold-starting Newton")
            params, opt_state = state["params"], state["opt"]
            print(f"[train] resumed from step {start_step}")

    step_fn = build_accum_step(model, acfg, tcfg, mesh, rules, engine=engine)
    watchdog = StepWatchdog(on_straggler=lambda s, dt, ew: print(
        f"[watchdog] straggler step {s}: {dt:.3f}s vs EWMA {ew:.3f}s"))

    losses = []
    step_metrics = []   # per-step watchdog snapshots (dist/watchdog.py)
    for step in range(start_step, tcfg.steps):
        batch = jax.tree_util.tree_map(jnp.asarray, batcher.get(step))
        watchdog.start()
        params, opt_state, proj_state, loss = step_fn(
            params, opt_state, proj_state, batch, lr_at(tcfg, step))
        loss_f = float(loss)
        dt = watchdog.stop(step)
        step_metrics.append(watchdog.metrics())
        losses.append(loss_f)
        if on_step:
            on_step(step, loss_f, dt)
        if step % tcfg.log_every == 0:
            print(f"[train] step {step:5d} loss {loss_f:.4f} "
                  f"({dt*1e3:.0f} ms)", flush=True)
        if ckpt and (step + 1) % tcfg.ckpt_every == 0:
            ckpt.save({"params": params, "opt": opt_state,
                       "proj": proj_state}, step + 1)
    if ckpt:
        ckpt.save({"params": params, "opt": opt_state, "proj": proj_state},
                  tcfg.steps)
        ckpt.wait()

    report = {}
    if model.cfg.projection_specs:
        report = sparsity_report(params, model.cfg.projection_specs)
    return {"params": params, "opt_state": opt_state, "losses": losses,
            "proj_state": proj_state, "sparsity": report,
            "straggler_events": watchdog.events,
            "step_metrics": step_metrics,
            "watchdog": watchdog.metrics()}
