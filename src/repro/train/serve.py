"""Batched serving loop: prefill (via teacher-forced cache fill) + decode.

The decode step is the same jit'd ``decode_step`` the dry-run lowers; the
server adds greedy/temperature sampling and a simple continuous-batching
slot manager (finished rows are replaced by queued requests without
recompiling — the cache is a fixed-shape ring of slots).

Ragged prompts run CONTINUOUSLY per row: every row feeds its own next
token at every position — prompt tokens while the prompt lasts, then its
own samples — so a short row never feeds pad tokens into its cache and a
ragged batch reproduces the single-prompt outputs exactly (regression:
tests/test_zoo_serve.py).

Compact serving (DESIGN.md §10): ``load_compact`` serves a
``serve.CompactModel`` through the SAME jit'd step (the sel index leaves
ride in the param tree, and the compact widths are just different static
shapes); ``refresh`` hot-swaps a new dense checkpoint through the frozen
gather recipe and ``recompact`` runs live re-compaction — both are
shape-preserving, so neither retraces (``n_traces`` exposes the counter
the no-retrace tests assert on).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models.zoo import Model
from ..models.transformer import init_cache, decode_step
from ..serve import CompactModel, compact_model, refresh_model, \
    recompact_model


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 256
    temperature: float = 0.0     # 0 = greedy
    seed: int = 0


def _cache_specs(cache, batch_axes):
    """Per-leaf PartitionSpecs sharding the batch dim of a decode cache:
    axis 1 for scan-stacked block caches (leading dim = cycles), axis 0
    for unstacked remainder blocks."""
    out = {}
    for key, sub in cache.items():
        spec = P(None, batch_axes) if key == "blocks" else P(batch_axes)
        out[key] = jax.tree_util.tree_map(lambda _: spec, sub)
    return out


class BatchServer:
    """Fixed B decode slots; requests are prompts (lists of token ids).

    ``mesh`` (optional) turns the decode step into a shard_map over the
    mesh axes the sharding rules assign to "batch" (params replicated,
    cache + tokens batch-sharded; rows are independent, so the step body
    contains zero collectives — asserted in tests/test_multidevice.py).
    """

    def __init__(self, model: Model, batch_slots: int, scfg: ServeConfig,
                 mesh=None, rules=None):
        self.model = model
        self.cfg = model.cfg
        self.scfg = scfg
        self.B = batch_slots
        self.params = None
        self.compact: Optional[CompactModel] = None
        self.n_traces = 0            # bumps at TRACE time only (jit)
        self._mesh = mesh
        self._rules = rules
        self._step = None            # built lazily: cache specs need shapes

    # ---------------------- checkpoint lifecycle -------------------------

    def load(self, params):
        """Serve a dense checkpoint (drops any compact state)."""
        self.params = params
        self.compact = None

    def load_compact(self, compact: Optional[CompactModel] = None, *,
                     params=None):
        """Serve a compacted checkpoint. Pass a prebuilt
        ``serve.CompactModel``, or a dense ``params`` tree to compact here
        under the model's own ``projection_specs``."""
        if compact is None:
            compact = compact_model(params, self.cfg.projection_specs)
        self.compact = compact
        self.params = compact.params

    def refresh(self, new_dense_params):
        """Hot refresh: re-gather a NEW dense checkpoint through the frozen
        compact recipe. Shapes unchanged — the jit'd step never retraces."""
        self.compact = refresh_model(self.compact, new_dense_params)
        self.params = self.compact.params

    def recompact(self, new_dense_params):
        """Live re-compaction: adopt the new checkpoint's (monotonically
        smaller) support inside the frozen slot widths. No retrace."""
        self.compact = recompact_model(self.compact, new_dense_params)
        self.params = self.compact.params

    # ---------------------- step construction ---------------------------

    def _build_step(self, cache):
        def traced(p, c, t, pos):
            self.n_traces += 1       # python side effect: trace-time only
            return decode_step(p, c, t, pos, self.cfg)

        if self._mesh is None:
            return jax.jit(traced)

        from jax.experimental.shard_map import shard_map
        from ..dist.sharding import default_rules
        rules = dict(default_rules() if self._rules is None else self._rules)
        batch_axes = rules.get("batch")
        if batch_axes is None:
            raise ValueError(
                "BatchServer: the sharding rules map 'batch' to None — "
                "every rank would redundantly serve the FULL batch; name a "
                "mesh axis for 'batch' (see dist.sharding.default_rules)")
        cspecs = _cache_specs(cache, batch_axes)
        fn = shard_map(traced, mesh=self._mesh,
                       in_specs=(P(), cspecs, P(batch_axes), P()),
                       out_specs=(P(batch_axes), cspecs),
                       check_rep=False)
        return jax.jit(fn)

    # ---------------------- generation ----------------------------------

    def generate(self, prompts: List[List[int]],
                 max_new: int = 32) -> List[List[int]]:
        """Greedy/temperature generation for up to B prompts.
        Prefill is performed by stepping the cache through the prompt tokens
        (teacher forcing) — exactly the decode path, so serving exercises the
        same compiled step as the dry-run. Rows advance independently: row i
        samples its first token the step its LAST prompt token goes in, and
        feeds its own samples from then on, so ragged batches never see pad
        tokens and match the uniform-length outputs exactly."""
        assert len(prompts) <= self.B
        B = self.B
        Smax = self.scfg.max_seq
        cache = init_cache(self.cfg, B, Smax, jnp.float32)
        if self._step is None:
            self._step = self._build_step(cache)
        key = jax.random.PRNGKey(self.scfg.seed)

        lens = [len(p) for p in prompts] + [1] * (B - len(prompts))
        maxlen = max(lens)
        out = [list(p) for p in prompts] + [[] for _ in range(B - len(prompts))]
        done = [len(prompts) <= i for i in range(B)]
        feed = np.zeros((B,), np.int32)
        for i, p in enumerate(prompts):
            feed[i] = p[0]

        n_new = [0] * B
        for pos in range(min(Smax, maxlen + max_new - 1)):
            logits, cache = self._step(self.params, cache,
                                       jnp.asarray(feed)[:, None],
                                       jnp.asarray(pos))
            if self.scfg.temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(
                    sub, logits[:, -1, :] / self.scfg.temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits[:, -1, :], axis=-1)
            nxt = np.asarray(nxt, np.int32)
            for i in range(B):
                if pos + 1 < lens[i]:
                    feed[i] = out[i][pos + 1]      # still feeding the prompt
                elif not done[i] and n_new[i] < max_new:
                    out[i].append(int(nxt[i]))     # row i's own sample
                    feed[i] = nxt[i]
                    n_new[i] += 1
                    if n_new[i] >= max_new:
                        done[i] = True
        return out[: len(prompts)]
