"""Batched serving loop: prefill (via teacher-forced cache fill) + decode.

The decode step is the same jit'd ``decode_step`` the dry-run lowers; the
server adds greedy/temperature sampling and a simple continuous-batching
slot manager (finished rows are replaced by queued requests without
recompiling — the cache is a fixed-shape ring of slots).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from ..models.zoo import Model
from ..models.transformer import init_cache, decode_step


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 256
    temperature: float = 0.0     # 0 = greedy
    seed: int = 0


class BatchServer:
    """Fixed B decode slots; requests are prompts (lists of token ids)."""

    def __init__(self, model: Model, batch_slots: int, scfg: ServeConfig):
        self.model = model
        self.cfg = model.cfg
        self.scfg = scfg
        self.B = batch_slots
        self.params = None
        self._step = jax.jit(
            lambda p, c, t, pos: decode_step(p, c, t, pos, self.cfg))

    def load(self, params):
        self.params = params

    def generate(self, prompts: List[List[int]],
                 max_new: int = 32) -> List[List[int]]:
        """Greedy/temperature generation for up to B prompts (padded batch).
        Prefill is performed by stepping the cache through the prompt tokens
        (teacher forcing) — exactly the decode path, so serving exercises the
        same compiled step as the dry-run."""
        assert len(prompts) <= self.B
        B = self.B
        Smax = self.scfg.max_seq
        cache = init_cache(self.cfg, B, Smax, jnp.float32)
        key = jax.random.PRNGKey(self.scfg.seed)

        maxlen = max(len(p) for p in prompts)
        toks = np.zeros((B, maxlen), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p  # left-aligned; short prompts re-feed pads

        logits = None
        for pos in range(maxlen):
            t = jnp.asarray(toks[:, pos:pos + 1])
            logits, cache = self._step(self.params, cache, t,
                                       jnp.asarray(pos))

        out = [list(p) for p in prompts] + [[] for _ in range(B - len(prompts))]
        cur = None
        for j in range(max_new):
            pos = maxlen + j
            if pos >= Smax:
                break
            if self.scfg.temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(
                    sub, logits[:, -1, :] / self.scfg.temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits[:, -1, :], axis=-1)
            cur = np.asarray(nxt, np.int32)
            for i in range(len(prompts)):
                out[i].append(int(cur[i]))
            logits, cache = self._step(self.params, cache,
                                       jnp.asarray(cur)[:, None],
                                       jnp.asarray(pos))
        return out[: len(prompts)]
