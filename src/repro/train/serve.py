"""Batched serving: the cohort ``generate`` API over the fleet engine.

``BatchServer`` keeps the PR-6 surface (``load`` / ``load_compact`` /
``refresh`` / ``recompact`` / ``generate`` / ``n_traces``) but is now a
thin adapter over ``serve.engine.FleetEngine`` (DESIGN.md §13): per-slot
state (position, budget, active mask, feed token) lives on device,
sampling and next-feed selection run inside the ONE jitted step, and the
KV cache + slot state are donated — ``generate`` is just "submit the
cohort, drain the engine". That removes the old per-token host↔device
round-trip and the per-``generate`` cache allocation, and fixes two
long-standing issues:

* the KV cache is allocated in ``cache_dtype`` (default: the
  checkpoint's param dtype) instead of hard-coded ``float32`` — bf16
  checkpoints decode through bf16 caches;
* a row whose prompt is long relative to ``max_seq`` no longer truncates
  silently: ``generate(..., with_meta=True)`` returns the per-request
  ``Completion`` records whose ``truncated`` flag says the row ran out
  of cache depth before emitting its full ``max_new`` budget.

Ragged prompts still run CONTINUOUSLY per row (each row feeds its own
next token — prompt tokens while the prompt lasts, then its own
samples), so a ragged batch reproduces the single-prompt outputs exactly
(regression: tests/test_zoo_serve.py). The old one-cohort-at-a-time
limit is gone: ``generate`` accepts more prompts than slots and the
engine streams them through freed slots.

Compact serving (DESIGN.md §10) is unchanged in contract: sel leaves
ride in the param tree, ``refresh``/``recompact`` are shape-preserving,
and ``n_traces`` counts exactly one trace across the whole lifecycle.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

from ..models.zoo import Model
from ..serve import CompactModel
from ..serve.engine import Completion, EngineConfig, FleetEngine, \
    RecompactScheduler


@dataclasses.dataclass
class ServeConfig:
    """Cohort-API serving knobs (a subset of ``serve.EngineConfig``)."""
    max_seq: int = 256
    temperature: float = 0.0     # 0 = greedy
    seed: int = 0
    cache_dtype: Any = None      # None -> match the checkpoint's dtype


class BatchServer:
    """Fixed B decode slots; requests are prompts (lists of token ids).

    ``mesh`` (optional) turns the decode step into a shard_map over the
    mesh axes the sharding rules assign to "batch" (params replicated,
    cache + slot state batch-sharded; rows are independent, so the step
    body contains zero collectives — asserted in tests/test_multidevice.py).
    ``scheduler`` (optional ``serve.RecompactScheduler``) lets ``refresh``
    upgrade itself to a live re-compaction when the live/slot ratio of a
    new checkpoint decays past the scheduler's threshold.
    """

    def __init__(self, model: Model, batch_slots: int, scfg: ServeConfig,
                 mesh=None, rules=None,
                 scheduler: Optional[RecompactScheduler] = None):
        self.model = model
        self.cfg = model.cfg
        self.scfg = scfg
        self.B = batch_slots
        self.engine = FleetEngine(
            model, batch_slots,
            EngineConfig(max_seq=scfg.max_seq,
                         temperature=scfg.temperature,
                         seed=scfg.seed,
                         cache_dtype=scfg.cache_dtype),
            mesh=mesh, rules=rules, scheduler=scheduler)

    # ---------------------- checkpoint lifecycle -------------------------

    @property
    def params(self):
        """The currently-served param tree (dense or compact)."""
        return self.engine.params

    @property
    def compact(self) -> Optional[CompactModel]:
        """The served ``CompactModel`` (None when serving dense)."""
        return self.engine.compact

    @property
    def n_traces(self) -> int:
        """Jit traces of the decode step (the no-retrace contract)."""
        return self.engine.n_traces

    def load(self, params):
        """Serve a dense checkpoint (drops any compact state)."""
        self.engine.load(params)

    def load_compact(self, compact: Optional[CompactModel] = None, *,
                     params=None):
        """Serve a compacted checkpoint. Pass a prebuilt
        ``serve.CompactModel``, or a dense ``params`` tree to compact here
        under the model's own ``projection_specs``."""
        self.engine.load_compact(compact, params=params)

    def refresh(self, new_dense_params):
        """Hot refresh: re-gather a NEW dense checkpoint through the frozen
        compact recipe. Shapes unchanged — the jit'd step never retraces."""
        self.engine.refresh(new_dense_params)

    def recompact(self, new_dense_params):
        """Live re-compaction: adopt the new checkpoint's (monotonically
        smaller) support inside the frozen slot widths. No retrace."""
        self.engine.recompact(new_dense_params)

    # ---------------------- generation ----------------------------------

    def generate(self, prompts: List[List[int]], max_new: int = 32,
                 with_meta: bool = False):
        """Greedy/temperature generation for the given prompts (any count —
        beyond B they stream through freed slots). Prefill steps the cache
        through the prompt tokens (teacher forcing) — exactly the decode
        path, so serving exercises the same compiled step as the dry-run.
        Rows advance independently, so ragged batches never see pad tokens
        and match solo outputs exactly. Returns prompt+generated token
        lists; with ``with_meta=True`` also the per-request ``Completion``
        records (TTFT, per-token times, the ``truncated`` flag)."""
        rids = [self.engine.submit(p, max_new, sample_seed=i)
                for i, p in enumerate(prompts)]
        by_rid = {c.rid: c for c in self.engine.drain()}
        comps = [by_rid[r] for r in rids]
        outs = [c.tokens for c in comps]
        return (outs, comps) if with_meta else outs
