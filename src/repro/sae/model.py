"""Supervised autoencoder (paper §5, Fig. 4).

Symmetric fully-connected net: encoder d -> h -(ReLU)-> k (latent = #classes),
decoder k -> h -(ReLU)-> d. Loss phi = lambda * Huber(X, Xhat) + CE(Y, Z).

The l1,inf constraint is applied to the first encoder weight W1 (d, h):
zeroing a *row group*... in our storage x @ W1, input feature i is row i of
W1, so the prunable "column" of the paper is our row => max-axis = 1.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["SAEConfig", "sae_init", "sae_apply", "sae_loss", "accuracy"]


@dataclasses.dataclass(frozen=True)
class SAEConfig:
    n_features: int
    n_hidden: int = 96
    n_classes: int = 2
    lam: float = 1.0          # reconstruction weight (paper's lambda)
    huber_delta: float = 1.0


def _linear_init(key, d_in, d_out, dtype=jnp.float32):
    scale = jnp.sqrt(2.0 / d_in)
    wkey, bkey = jax.random.split(key)
    return {
        "w": (jax.random.normal(wkey, (d_in, d_out)) * scale).astype(dtype),
        "b": jnp.zeros((d_out,), dtype),
    }


def sae_init(key: jax.Array, cfg: SAEConfig) -> Dict[str, Any]:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "enc1": _linear_init(k1, cfg.n_features, cfg.n_hidden),
        "enc2": _linear_init(k2, cfg.n_hidden, cfg.n_classes),
        "dec1": _linear_init(k3, cfg.n_classes, cfg.n_hidden),
        "dec2": _linear_init(k4, cfg.n_hidden, cfg.n_features),
    }


def sae_apply(params, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (latent logits Z, reconstruction Xhat)."""
    h = jax.nn.relu(x @ params["enc1"]["w"] + params["enc1"]["b"])
    z = h @ params["enc2"]["w"] + params["enc2"]["b"]
    hd = jax.nn.relu(z @ params["dec1"]["w"] + params["dec1"]["b"])
    xhat = hd @ params["dec2"]["w"] + params["dec2"]["b"]
    return z, xhat


def huber(err: jnp.ndarray, delta: float = 1.0) -> jnp.ndarray:
    a = jnp.abs(err)
    return jnp.where(a <= delta, 0.5 * a * a, delta * (a - 0.5 * delta))


def sae_loss(params, x, y, cfg: SAEConfig):
    z, xhat = sae_apply(params, x)
    recon = jnp.mean(huber(xhat - x, cfg.huber_delta))
    logp = jax.nn.log_softmax(z, axis=-1)
    ce = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
    return cfg.lam * recon + ce, {"recon": recon, "ce": ce}


def accuracy(params, x, y) -> jnp.ndarray:
    z, _ = sae_apply(params, x)
    return jnp.mean((jnp.argmax(z, axis=-1) == y).astype(jnp.float32))
