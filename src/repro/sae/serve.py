"""Compacted SAE serving — the paper's feature-selection payoff at inference.

After projected training (Algorithm 3) the l1,inf constraint leaves fewer
than ~2% of the encoder's input-feature columns alive at the paper's ~99%
column-sparsity regime; the rest are STRUCTURAL zeros (the gated projected
step writes the projection output into the weight, so a dead column is an
exact-zero row of ``enc1/w``, not a small number). Serving the dense encoder
then wastes ~100x the GEMM FLOPs on rows that contribute exact zeros.

This module is the serving path (DESIGN.md §9):

  * ``support_selection(params, specs)`` derives the per-leaf surviving
    column sets from ``core.constraints.column_masks`` — the SAME mask the
    double-descent freeze uses, so training and serving can never disagree
    on the support;
  * ``compact_leaf`` gathers the surviving columns of one leaf into a dense
    compact matrix (``core.support_indices`` + ``core.compact_columns`` —
    the host-side twins of the engine's ``active_compaction``);
  * ``compact_sae(params, specs)`` builds a ``CompactSAE``: the encoder's
    surviving feature rows gathered into a dense (J, h) matrix, the decoder
    OUTPUT columns co-compacted with the same index vector (so the served
    reconstruction covers exactly the selected features), biases/interior
    layers untouched;
  * ``CompactSAE.apply`` is bit-exact (to fp summation order) with the dense
    ``sae_apply`` on the support: logits Z match everywhere, the
    reconstruction matches on the selected features;
  * ``make_serve_step`` wires the batched jit serving step — full-width
    inputs in, one static gather, compact GEMMs — optionally shard_map'd
    over a mesh with the batch laid out by ``dist.sharding.default_rules``.

Why only the FEATURE axis compacts: a dead feature row of ``enc1/w``
removes its input exactly because ``x @ W1`` is linear in the rows. The
hidden axis does NOT share this property — a dead hidden COLUMN still
contributes ``relu(b1_j)`` through its bias — so ``compact_sae`` refuses
specs whose column axis is the hidden one (DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.constraints import (ProjectionSpec, column_masks, leaf_path_str,
                                _first_match, _stacked_axis)
from ..core.l1inf import compact_columns, support_indices
from .model import sae_apply

__all__ = ["LeafSupport", "support_selection", "compact_leaf", "CompactSAE",
           "compact_sae", "make_serve_step"]


@dataclasses.dataclass(frozen=True)
class LeafSupport:
    """Surviving-column set of one constrained leaf (all fields static).

    ``sel``: int32 (J,) surviving canonical-column indices (ascending);
    ``col_axis``: the axis of the ORIGINAL leaf the columns live on (the
    non-max axis of the trailing 2-D slice — stacked leading dims shift it);
    ``n_cols``: the full column count m, so ``ratio = J / m``.

    >>> LeafSupport(sel=np.array([0, 2], np.int32), col_axis=0, n_cols=4).ratio
    0.5
    """
    sel: np.ndarray
    col_axis: int
    n_cols: int

    @property
    def n_selected(self) -> int:
        """J — the number of surviving columns (static Python int)."""
        return int(self.sel.size)

    @property
    def ratio(self) -> float:
        """Compaction ratio J / m in [0, 1] (1.0 = nothing pruned)."""
        return self.n_selected / max(self.n_cols, 1)


def support_selection(params: Any, specs: Sequence[ProjectionSpec]
                      ) -> Dict[str, LeafSupport]:
    """Derive {leaf path: LeafSupport} for every spec-matching leaf.

    ``params``: param pytree (leaves of any float dtype); ``specs``: the
    SAME ProjectionSpec tuple the model trained under. The support comes
    from ``column_masks`` — the structural-zero contract (DESIGN.md §9): a
    column the projection killed is an exact-zero slice, so the mask test
    is exact, not a tolerance. A stacked (ndim > 2) leaf keeps the UNION
    of its slices' supports (a column dropped only where it is zero in
    EVERY slice — the gather stays exact and the compact leaf stays
    rectangular). Host-side: call at compaction time, not inside jit.

    >>> sup = support_selection(params, specs)["enc1/w"]
    """
    masks = column_masks(params, specs)
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    mflat = jax.tree_util.tree_flatten_with_path(masks)[0]
    out: Dict[str, LeafSupport] = {}
    for (path, leaf), (_, mask) in zip(flat, mflat):
        spec = _first_match(specs, leaf_path_str(path), leaf)
        if spec is None:
            continue
        max_axis = _stacked_axis(spec.axis, leaf.ndim)
        col_axis = leaf.ndim - 2 if spec.axis in (1, -1) else leaf.ndim - 1
        # one representative row per column (the mask is constant along the
        # max axis), then union over any stacked leading dims
        alive = np.asarray(jnp.take(mask, 0, axis=max_axis)) != 0
        alive = alive.reshape(-1, leaf.shape[col_axis]).any(axis=0)
        out[leaf_path_str(path)] = LeafSupport(
            sel=support_indices(alive), col_axis=col_axis,
            n_cols=int(leaf.shape[col_axis]))
    return out


def compact_leaf(leaf: jnp.ndarray, sup: LeafSupport) -> jnp.ndarray:
    """Gather one leaf's surviving columns into a dense compact array.

    ``leaf``: (..., n, m)-shaped (any float dtype, stacked dims allowed);
    ``sup``: its ``LeafSupport``. Returns the leaf with ``sup.col_axis``
    reduced from m to J, dtype preserved. Zero-dead support is the
    identity gather; an all-dead support returns a zero-width axis (jax
    matmuls against it produce exact zeros, so serving still works).

    >>> w_c = compact_leaf(params["enc1"]["w"], sup)   # (d, h) -> (J, h)
    """
    return compact_columns(leaf, sup.sel, axis=sup.col_axis)


@dataclasses.dataclass(frozen=True)
class CompactSAE:
    """A projected-trained SAE with the dead encoder columns compiled out.

    ``params``: the compact param pytree — ``enc1/w`` is (J, h) (surviving
    feature rows, original dtype), ``dec2/w`` is (h, J) and ``dec2/b`` (J,)
    (decoder OUTPUT co-compacted by the same index vector), all other
    weight leaves untouched, plus a ``"sel"`` leaf (int32 (J,)) so the
    support TRAVELS WITH the checkpoint — a serving step fed a refreshed
    ``CompactSAE.params`` gathers with the refreshed support, never a
    stale closure; ``sel``: the same indices as a host array;
    ``n_features``: the original d. Built by ``compact_sae``.

    >>> z, xhat_sel = compact.apply(compact.select(x))
    """
    params: Dict[str, Any]
    sel: np.ndarray
    n_features: int

    @property
    def n_selected(self) -> int:
        """J — the number of surviving input features."""
        return int(self.sel.size)

    @property
    def compaction_ratio(self) -> float:
        """J / d: the fraction of encoder GEMM FLOPs serving still pays."""
        return self.n_selected / max(self.n_features, 1)

    def select(self, x: jnp.ndarray) -> jnp.ndarray:
        """Gather the selected features of full-width ``x``: (..., d) ->
        (..., J). The only full-width op left on the serving path."""
        return compact_columns(x, self.sel, axis=-1)

    def apply(self, x_sel: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Forward pass on pre-selected inputs ``x_sel``: (B, J) -> logits
        (B, k) and reconstruction (B, J) of the SELECTED features. Equals
        dense ``sae_apply(params, x)`` as (Z, Xhat[:, sel]) to fp order —
        dead rows of enc1/w only ever add exact zeros to the pre-ReLU sums
        (DESIGN.md §9)."""
        return sae_apply(self.params, x_sel)


def compact_sae(params: Dict[str, Any],
                specs: Sequence[ProjectionSpec]) -> CompactSAE:
    """Compact a projected-trained SAE param tree for serving.

    ``params``: the ``sae_init`` pytree after projected training (any float
    dtype); ``specs``: the training ProjectionSpec tuple — it must
    constrain ``enc1/w`` along the FEATURE axis (the paper's axis=1 on the
    (d, h) encoder; the hidden axis cannot compact exactly because dead
    hidden units still emit relu(b) — refused with ValueError). Returns a
    ``CompactSAE`` whose ``apply`` matches dense ``sae_apply`` on the
    support. Host-side, one-off: run once per checkpoint, then serve the
    result via ``make_serve_step``.

    >>> compact = compact_sae(result.params, (spec,))
    """
    sups = support_selection(params, specs)
    enc_key = next((k for k in sups if re.search(r"enc1/w$", k)), None)
    if enc_key is None:
        raise ValueError(
            f"specs select no enc1/w leaf (matched: {sorted(sups)} — "
            f"compact_sae serves the paper's encoder feature selection)")
    sup = sups[enc_key]
    d, h = params["enc1"]["w"].shape
    if sup.col_axis != 0:
        raise ValueError(
            "compact_sae: spec prunes the hidden axis of enc1/w — dead "
            "hidden units still contribute relu(b1) so compaction would "
            "not be exact; the serving contract covers the feature axis "
            "(spec.axis in (1, -1) on the (d, h) encoder)")
    sel = sup.sel
    out = {
        "enc1": {"w": compact_leaf(params["enc1"]["w"], sup),
                 "b": params["enc1"]["b"]},
        "enc2": params["enc2"],
        "dec1": params["dec1"],
        # decoder-row co-compaction: the reconstruction head's OUTPUT
        # features are the same index space as the encoder's input features
        "dec2": {"w": compact_columns(params["dec2"]["w"], sel, axis=1),
                 "b": compact_columns(params["dec2"]["b"], sel, axis=0)},
        # the support rides in the param tree (sae_apply ignores it): a
        # checkpoint refresh hands the serving step its own gather indices
        "sel": jnp.asarray(sel, jnp.int32),
    }
    return CompactSAE(params=out, sel=sel, n_features=int(d))


def make_serve_step(compact: CompactSAE, *, mesh=None, rules=None):
    """Build the batched, jit-compiled serving step for a ``CompactSAE``.

    Returns ``step(params, x) -> (z, xhat_sel)`` taking FULL-width inputs
    ``x`` (B, d) — one gather selects the J surviving features, then every
    GEMM runs at compact width. Pass ``compact.params`` as ``params``: it
    stays a step argument (no recompile on checkpoint refresh) and carries
    its own ``"sel"`` leaf, so a refreshed ``CompactSAE`` with a DIFFERENT
    surviving set of the same size J serves correctly through an old step
    (a different J retraces — shapes changed). With ``mesh`` given the
    step is shard_map'd: the batch is laid out over the mesh axes
    ``dist.sharding`` rules assign to "batch" (``default_rules()`` when
    ``rules`` is None — B must divide, and rules that map "batch" to None
    are rejected rather than silently replicating the whole batch per
    rank), params replicated, no collectives in the body (rows are
    independent).

    >>> step = make_serve_step(compact)   # then: z, xr = step(compact.params, x)
    """

    def _apply(params, x):
        x_sel = jnp.take(x, params["sel"], axis=-1)
        return sae_apply(params, x_sel)

    if mesh is None:
        return jax.jit(_apply)

    from ..dist.sharding import default_rules
    from jax.experimental.shard_map import shard_map
    rules = default_rules() if rules is None else rules
    batch_axes = rules.get("batch")
    if batch_axes is None:
        raise ValueError(
            "make_serve_step: the sharding rules map 'batch' to None — "
            "every rank would redundantly compute the FULL batch; name a "
            "mesh axis for 'batch' (see dist.sharding.default_rules)")
    fn = shard_map(_apply, mesh=mesh,
                   in_specs=(P(), P(batch_axes, None)),
                   out_specs=P(batch_axes, None),
                   check_rep=False)
    return jax.jit(fn)
