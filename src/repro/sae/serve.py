"""Compacted SAE serving — the paper's feature-selection payoff at inference.

After projected training (Algorithm 3) the l1,inf constraint leaves fewer
than ~2% of the encoder's input-feature columns alive at the paper's ~99%
column-sparsity regime; the rest are STRUCTURAL zeros (the gated projected
step writes the projection output into the weight, so a dead column is an
exact-zero row of ``enc1/w``, not a small number). Serving the dense encoder
then wastes ~100x the GEMM FLOPs on rows that contribute exact zeros.

Since PR 6 this module is a thin ADAPTER over the model-generic compaction
layer (``repro.serve``, DESIGN.md §10): the SAE's coupling — encoder
feature rows primary, decoder output columns + bias co-compacted, the
``sel`` leaf at the tree root — is one ``CompactRule``, and
``compact_sae`` is ``serve.compact.compact_model`` under that rule.
``support_selection``/``LeafSupport`` live in ``repro.serve.compact`` and
are re-imported here for compatibility; ``compact_leaf`` is a one-line
shim over the single core gather primitive ``core.compact_columns``.

Why only the FEATURE axis compacts: a dead feature row of ``enc1/w``
removes its input exactly because ``x @ W1`` is linear in the rows. The
hidden axis does NOT share this property — a dead hidden COLUMN still
contributes ``relu(b1_j)`` through its bias — so ``compact_sae`` refuses
specs whose column axis is the hidden one (DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..core.constraints import ProjectionSpec
from ..core.l1inf import compact_columns
from ..serve.compact import (CompactRule, LeafSupport, compact_model,
                             support_selection)
from .model import sae_apply

__all__ = ["compact_leaf", "CompactSAE", "compact_sae", "make_serve_step"]

# The SAE's compaction coupling under the generic contract (DESIGN.md §10):
# enc1/w's FEATURE rows are the primary columns (canonical axis -2 of the
# (d, h) encoder); the reconstruction head addresses the same feature index
# space, so dec2/w output columns and dec2/b co-gather; the sel leaf rides
# at the tree root (the PR-5 checkpoint contract).
_SAE_RULES: Tuple[CompactRule, ...] = (
    CompactRule(primary=r"(^|/)enc1/w$", col_axis=-2,
                coupled=(("../dec2/w", -1), ("../dec2/b", -1)),
                sel_key="../sel"),
)


def compact_leaf(leaf: jnp.ndarray, sup: LeafSupport) -> jnp.ndarray:
    """Gather one leaf's surviving columns into a dense compact array.

    One-line shim over the single core gather primitive
    ``core.compact_columns`` (kept for API compatibility — the generic
    layer and this adapter share that primitive, so there is exactly one
    compaction implementation). ``leaf``: (..., n, m)-shaped (any float
    dtype, stacked dims allowed); ``sup``: its ``LeafSupport``. Returns the
    leaf with ``sup.col_axis`` reduced from m to J, dtype preserved.

    >>> w_c = compact_leaf(params["enc1"]["w"], sup)   # (d, h) -> (J, h)
    """
    return compact_columns(leaf, sup.sel, axis=sup.col_axis)


@dataclasses.dataclass(frozen=True)
class CompactSAE:
    """A projected-trained SAE with the dead encoder columns compiled out.

    ``params``: the compact param pytree — ``enc1/w`` is (J, h) (surviving
    feature rows, original dtype), ``dec2/w`` is (h, J) and ``dec2/b`` (J,)
    (decoder OUTPUT co-compacted by the same index vector), all other
    weight leaves untouched, plus a ``"sel"`` leaf (int32 (J,)) so the
    support TRAVELS WITH the checkpoint — a serving step fed a refreshed
    ``CompactSAE.params`` gathers with the refreshed support, never a
    stale closure; ``sel``: the same indices as a host array;
    ``n_features``: the original d. Built by ``compact_sae``.

    >>> z, xhat_sel = compact.apply(compact.select(x))
    """
    params: Dict[str, Any]
    sel: np.ndarray
    n_features: int

    @property
    def n_selected(self) -> int:
        """J — the number of surviving input features."""
        return int(self.sel.size)

    @property
    def compaction_ratio(self) -> float:
        """J / d: the fraction of encoder GEMM FLOPs serving still pays."""
        return self.n_selected / max(self.n_features, 1)

    def select(self, x: jnp.ndarray) -> jnp.ndarray:
        """Gather the selected features of full-width ``x``: (..., d) ->
        (..., J). The only full-width op left on the serving path."""
        return compact_columns(x, self.sel, axis=-1)

    def apply(self, x_sel: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Forward pass on pre-selected inputs ``x_sel``: (B, J) -> logits
        (B, k) and reconstruction (B, J) of the SELECTED features. Equals
        dense ``sae_apply(params, x)`` as (Z, Xhat[:, sel]) to fp order —
        dead rows of enc1/w only ever add exact zeros to the pre-ReLU sums
        (DESIGN.md §9)."""
        return sae_apply(self.params, x_sel)


def compact_sae(params: Dict[str, Any],
                specs: Sequence[ProjectionSpec]) -> CompactSAE:
    """Compact a projected-trained SAE param tree for serving.

    ``params``: the ``sae_init`` pytree after projected training (any float
    dtype); ``specs``: the training ProjectionSpec tuple — it must
    constrain ``enc1/w`` along the FEATURE axis (the paper's axis=1 on the
    (d, h) encoder; the hidden axis cannot compact exactly because dead
    hidden units still emit relu(b) — refused with ValueError). Returns a
    ``CompactSAE`` whose ``apply`` matches dense ``sae_apply`` on the
    support. Host-side, one-off: run once per checkpoint, then serve the
    result via ``make_serve_step``. Implementation: the generic
    ``serve.compact.compact_model`` under the SAE coupling rule.

    >>> compact = compact_sae(result.params, (spec,))
    """
    sups = support_selection(params, specs)
    enc_key = next((k for k in sups if re.search(r"enc1/w$", k)), None)
    if enc_key is None:
        raise ValueError(
            f"specs select no enc1/w leaf (matched: {sorted(sups)} — "
            f"compact_sae serves the paper's encoder feature selection)")
    if sups[enc_key].col_axis != params["enc1"]["w"].ndim - 2:
        raise ValueError(
            "compact_sae: spec prunes the hidden axis of enc1/w — dead "
            "hidden units still contribute relu(b1) so compaction would "
            "not be exact; the serving contract covers the feature axis "
            "(spec.axis in (1, -1) on the (d, h) encoder)")
    cm = compact_model(params, specs, rules=_SAE_RULES)
    d = int(params["enc1"]["w"].shape[params["enc1"]["w"].ndim - 2])
    return CompactSAE(params=cm.params, sel=cm.sels[enc_key], n_features=d)


def make_serve_step(compact: CompactSAE, *, mesh=None, rules=None):
    """Build the batched, jit-compiled serving step for a ``CompactSAE``.

    Returns ``step(params, x) -> (z, xhat_sel)`` taking FULL-width inputs
    ``x`` (B, d) — one gather selects the J surviving features, then every
    GEMM runs at compact width. Pass ``compact.params`` as ``params``: it
    stays a step argument (no recompile on checkpoint refresh) and carries
    its own ``"sel"`` leaf, so a refreshed ``CompactSAE`` with a DIFFERENT
    surviving set of the same size J serves correctly through an old step
    (a different J retraces — shapes changed). With ``mesh`` given the
    step is shard_map'd: the batch is laid out over the mesh axes
    ``dist.sharding`` rules assign to "batch" (``default_rules()`` when
    ``rules`` is None — B must divide, and rules that map "batch" to None
    are rejected rather than silently replicating the whole batch per
    rank), params replicated, no collectives in the body (rows are
    independent).

    >>> step = make_serve_step(compact)   # then: z, xr = step(compact.params, x)
    """

    def _apply(params, x):
        x_sel = jnp.take(x, params["sel"], axis=-1)
        return sae_apply(params, x_sel)

    if mesh is None:
        return jax.jit(_apply)

    from ..dist.sharding import default_rules
    from jax.experimental.shard_map import shard_map
    rules = default_rules() if rules is None else rules
    batch_axes = rules.get("batch")
    if batch_axes is None:
        raise ValueError(
            "make_serve_step: the sharding rules map 'batch' to None — "
            "every rank would redundantly compute the FULL batch; name a "
            "mesh axis for 'batch' (see dist.sharding.default_rules)")
    fn = shard_map(_apply, mesh=mesh,
                   in_specs=(P(), P(batch_axes, None)),
                   out_specs=P(batch_axes, None),
                   check_rep=False)
    return jax.jit(fn)
