"""Datasets for the SAE experiments (paper §6).

  * ``make_classification`` — numpy port of the scikit-learn generator the
    paper uses for its synthetic benchmark (clusters on hypercube vertices,
    n_informative features carrying signal, the rest pure noise).
  * ``make_lung_surrogate`` — the LUNG metabolomics dataset (Mathe et al.) is
    not redistributable/offline; this generator matches its published
    statistics (1005 samples: 469 NSCLC + 536 controls, 2944 features,
    ~40 informative, multiplicative log-normal noise). Every reported number
    on it is labeled "LUNG-surrogate" in EXPERIMENTS.md.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = ["make_classification", "make_lung_surrogate", "train_test_split"]


def make_classification(n_samples: int = 1000, n_features: int = 10_000,
                        n_informative: int = 64, n_classes: int = 2,
                        class_sep: float = 0.8, flip_y: float = 0.01,
                        n_clusters_per_class: int = 1, seed: int = 0
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Port of sklearn.datasets.make_classification (hypercube mode).

    Returns (X, y, informative_idx) — the ground-truth informative feature
    indices let benchmarks score feature-selection quality.
    """
    rng = np.random.default_rng(seed)
    n_clusters = n_classes * n_clusters_per_class

    # cluster centroids on hypercube vertices, scaled by 2*class_sep
    def hypercube_vertices(k, d):
        if d < 30:
            # distinct binary vertices
            idx = rng.choice(2 ** min(d, 62), size=k, replace=False)
            return np.array([[(i >> b) & 1 for b in range(d)] for i in idx],
                            dtype=np.float64)
        return rng.integers(0, 2, size=(k, d)).astype(np.float64)

    centroids = hypercube_vertices(n_clusters, n_informative)
    centroids *= 2 * class_sep
    centroids -= class_sep

    counts = np.full(n_clusters, n_samples // n_clusters)
    counts[: n_samples % n_clusters] += 1

    X_inf = np.empty((n_samples, n_informative))
    y = np.empty(n_samples, dtype=np.int64)
    pos = 0
    for c in range(n_clusters):
        k = counts[c]
        block = rng.normal(size=(k, n_informative))
        # random linear mixing within the cluster (sklearn's covariance trick)
        A = rng.uniform(-1, 1, size=(n_informative, n_informative))
        X_inf[pos:pos + k] = block @ A * 0.5 + centroids[c]
        y[pos:pos + k] = c % n_classes
        pos += k

    X = rng.normal(size=(n_samples, n_features))
    informative_idx = rng.choice(n_features, size=n_informative, replace=False)
    X[:, informative_idx] = X_inf

    # label noise
    flip = rng.uniform(size=n_samples) < flip_y
    y[flip] = rng.integers(0, n_classes, size=flip.sum())

    perm = rng.permutation(n_samples)
    return X[perm].astype(np.float32), y[perm], np.sort(informative_idx)


def make_lung_surrogate(n_samples: int = 1005, n_features: int = 2944,
                        n_informative: int = 40, effect: float = 0.6,
                        seed: int = 0):
    # effect=0.6 calibrated so the unconstrained SAE baseline lands at the
    # paper's LUNG baseline (~77% accuracy)
    """Metabolomics-like data: multiplicative log-normal noise; informative
    features shift the log-mean between cases (469) and controls (536).
    Returns raw intensities — apply the classical log-transform (as the paper
    does) before training."""
    rng = np.random.default_rng(seed)
    n_cases = 469 if n_samples == 1005 else n_samples // 2
    y = np.zeros(n_samples, dtype=np.int64)
    y[:n_cases] = 1

    base_mean = rng.uniform(2.0, 6.0, size=n_features)       # per-metabolite
    log_X = base_mean[None, :] + rng.normal(scale=1.0,
                                            size=(n_samples, n_features))
    informative_idx = rng.choice(n_features, size=n_informative, replace=False)
    signs = rng.choice([-1.0, 1.0], size=n_informative)
    log_X[:, informative_idx] += (y[:, None] * signs[None, :] * effect)

    X = np.exp(log_X)                                         # intensities
    perm = rng.permutation(n_samples)
    return X[perm].astype(np.float32), y[perm], np.sort(informative_idx)


def train_test_split(X, y, test_frac: float = 0.2, seed: int = 0):
    rng = np.random.default_rng(seed)
    n = len(X)
    perm = rng.permutation(n)
    n_test = int(round(n * test_frac))
    te, tr = perm[:n_test], perm[n_test:]
    return X[tr], y[tr], X[te], y[te]
