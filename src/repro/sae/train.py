"""Projected training of the supervised autoencoder — the paper's Algorithm 3.

Double descent (Frankle-Carbin style, as adapted by the paper):
  descent 1: projected Adam (projection applied after every update);
  mask:      M0 = surviving column support of the constrained weight;
  rewind:    weights back to their initial values, masked by M0;
  descent 2: retrain with gradients masked by M0 (zero columns stay frozen),
             projection kept active.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from ..core import (ProjectionEngine, ProjectionSpec, column_masks,
                    family_for_norm, sparsity_report)
from ..optim import AdamConfig, adam_init
from .model import SAEConfig, sae_init, sae_loss, accuracy

__all__ = ["SAETrainConfig", "train_sae", "SAEResult"]


@dataclasses.dataclass(frozen=True)
class SAETrainConfig:
    epochs: int = 30
    batch_size: int = 128
    lr: float = 1e-3
    seed: int = 0
    double_descent: bool = True
    projection: Optional[ProjectionSpec] = None   # None => unconstrained baseline


@dataclasses.dataclass
class SAEResult:
    params: dict
    test_accuracy: float
    column_sparsity: float     # % of feature columns of enc1/w fully zero
    selected: np.ndarray       # indices of surviving features
    history: list
    # serving-eval path: per-epoch surviving-column fraction of the
    # constrained leaves (J/m — what compact_sae would keep at that epoch),
    # mirrored by history entries; compaction_ratio is the final value
    compaction_history: list = dataclasses.field(default_factory=list)
    compaction_ratio: float = 1.0


def _make_step(cfg: SAEConfig, tcfg: SAETrainConfig, acfg: AdamConfig):
    specs = (tcfg.projection,) if tcfg.projection else ()
    # the shared projected-update step core: Adam (grads masked), packed
    # warm-started projection, then the mask freeze (Algorithm 3); "fused"
    # runs the two-HBM-pass megakernel where the constraint family streams
    # its statistics and falls back to the identical Newton path elsewhere
    engine = ProjectionEngine(specs, solver="fused")

    @jax.jit
    def step(params, opt_state, proj_state, x, y, mask):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: sae_loss(p, x, y, cfg), has_aux=True)(params)
        params, opt_state, proj_state = engine.projected_update(
            grads, opt_state, params, acfg, mask=mask, state=proj_state)
        return params, opt_state, proj_state, loss, aux

    return step, engine


def _compaction_ratio(params, specs) -> float:
    """Mean surviving-column fraction J/m of the constrained leaves — the
    width ``compact_sae`` would serve at (1.0 when nothing is constrained)."""
    rep = sparsity_report(params, specs)
    if not rep:
        return 1.0
    return float(np.mean([1.0 - v / 100.0 for v in rep.values()]))


def _run_descent(params, step_fn, engine, X, y, tcfg, mask, rng, specs=()):
    acfg = AdamConfig(lr=tcfg.lr)
    opt_state = adam_init(params, acfg)
    proj_state = engine.init_state(params)
    n = X.shape[0]
    history, compaction = [], []
    for epoch in range(tcfg.epochs):
        perm = rng.permutation(n)
        for s in range(0, n, tcfg.batch_size):
            idx = perm[s:s + tcfg.batch_size]
            params, opt_state, proj_state, loss, aux = step_fn(
                params, opt_state, proj_state, X[idx], y[idx], mask)
        history.append(float(loss))
        compaction.append(_compaction_ratio(params, specs))
    return params, history, compaction


def train_sae(X_train: np.ndarray, y_train: np.ndarray,
              X_test: np.ndarray, y_test: np.ndarray,
              cfg: SAEConfig, tcfg: SAETrainConfig) -> SAEResult:
    key = jax.random.PRNGKey(tcfg.seed)
    rng = np.random.default_rng(tcfg.seed)
    X_train = jnp.asarray(X_train)
    y_train_j = jnp.asarray(y_train)

    params0 = sae_init(key, cfg)
    ones_mask = jax.tree_util.tree_map(jnp.ones_like, params0)
    acfg = AdamConfig(lr=tcfg.lr)

    # masked variant (Eq. 20 / torch-pruning semantics): descent 1 uses the
    # TRUE projection to find the support; descent 2 keeps only the frozen
    # mask — magnitudes unbounded ("maximum value of the columns is not
    # bounded"). Applying the unclipped masked projection every step instead
    # makes theta run away and over-prunes (support collapses; see
    # EXPERIMENTS.md §Paper-validation).
    fam = (family_for_norm(tcfg.projection.norm)
           if tcfg.projection is not None else None)
    masked_mode = fam is not None and fam.name == "l1inf_masked"
    if masked_mode:
        import dataclasses as _dc
        tcfg1 = _dc.replace(tcfg, projection=_dc.replace(
            tcfg.projection, norm="l1inf"))
    else:
        tcfg1 = tcfg
    step_fn, step_engine = _make_step(cfg, tcfg1, acfg)

    eval_specs = (tcfg1.projection,) if tcfg1.projection else ()

    # ---- descent 1: projected training --------------------------------
    params, hist1, comp1 = _run_descent(params0, step_fn, step_engine,
                                        X_train, y_train_j, tcfg, ones_mask,
                                        rng, specs=eval_specs)
    history = [("descent1", hist1)]
    compaction_history = [("descent1", comp1)]

    # ---- double descent: mask, rewind, retrain -------------------------
    if tcfg.projection and tcfg.double_descent:
        specs = (tcfg1.projection,)
        masks = column_masks(params, specs)
        rewound = jax.tree_util.tree_map(lambda p0, m: p0 * m, params0, masks)
        if masked_mode:  # retrain mask-only, no clipping
            import dataclasses as _dc
            step_fn, step_engine = _make_step(
                cfg, _dc.replace(tcfg, projection=None), acfg)
        params, hist2, comp2 = _run_descent(rewound, step_fn, step_engine,
                                            X_train, y_train_j, tcfg, masks,
                                            rng, specs=eval_specs)
        history.append(("descent2", hist2))
        compaction_history.append(("descent2", comp2))

    test_acc = float(accuracy(params, jnp.asarray(X_test), jnp.asarray(y_test)))
    w1 = np.asarray(params["enc1"]["w"])
    live = np.any(w1 != 0, axis=1)
    colsp = 100.0 * (1.0 - live.mean())
    return SAEResult(params=params, test_accuracy=test_acc,
                     column_sparsity=float(colsp),
                     selected=np.nonzero(live)[0], history=history,
                     compaction_history=compaction_history,
                     compaction_ratio=_compaction_ratio(params, eval_specs))
