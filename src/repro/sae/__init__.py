from .model import SAEConfig, sae_init, sae_apply, sae_loss, accuracy
from .data import make_classification, make_lung_surrogate, train_test_split
from .train import SAETrainConfig, train_sae, SAEResult
from .serve import (CompactSAE, LeafSupport, compact_sae, compact_leaf,
                    support_selection, make_serve_step)
