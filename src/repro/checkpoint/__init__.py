from .ckpt import (save, restore, restore_tree, latest_step, gc_keep_last,
                   AsyncCheckpointer)
