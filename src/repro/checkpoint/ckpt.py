"""Fault-tolerant checkpointing.

Design for 1000+-node operation:
  * checkpoints are *sharding-agnostic*: leaves are saved as full host numpy
    arrays keyed by pytree path, so a restore may land on a different mesh /
    device count (elastic restart) — the trainer re-device_puts with the new
    shardings;
  * atomic: written to ``<dir>/.tmp-<step>`` then os.rename'd; a manifest
    with per-leaf crc32 checksums validates integrity on restore;
  * async: ``AsyncCheckpointer`` snapshots to host memory synchronously
    (cheap) and writes to disk on a worker thread so the train loop never
    blocks on I/O;
  * keep-last-k garbage collection;
  * multi-host note: on a real cluster each host saves only the shards it
    owns (addressable_shards) under ``shard-<host>``; this container is
    single-host so the full-array path is exercised and the per-shard path
    is unit-tested with host-device meshes.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
import zlib
from typing import Any, Callable, List, Optional, Tuple

import numpy as np
import jax

_MANIFEST = "manifest.json"


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save(tree: Any, directory: str, step: int) -> str:
    """Synchronous atomic save. Returns the final checkpoint path."""
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    tmp = directory / f".tmp-{step}"
    final = directory / f"step-{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    manifest = {"step": step, "leaves": {}}
    for key, leaf in _flatten(tree):
        arr = np.asarray(leaf)
        orig_dtype = str(arr.dtype)
        if arr.dtype.kind not in "fiub" or orig_dtype == "bfloat16":
            # non-native dtypes (bfloat16, fp8) stored widened; the manifest
            # records the original for restore-time cast
            arr = np.asarray(arr, np.float32)
        fname = key.replace("/", "__") + ".npy"
        np.save(tmp / fname, arr)
        manifest["leaves"][key] = {
            "file": fname, "shape": list(arr.shape), "dtype": orig_dtype,
            "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
        }
    (tmp / _MANIFEST).write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return str(final)


def restore(directory: str, step: Optional[int] = None,
            verify: bool = True) -> Tuple[dict, int]:
    """Restore a flat {path: np.ndarray} dict + step. Raises on corruption."""
    directory = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = directory / f"step-{step:08d}"
    manifest = json.loads((path / _MANIFEST).read_text())
    out = {}
    for key, meta in manifest["leaves"].items():
        arr = np.load(path / meta["file"])
        if verify:
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != meta["crc32"]:
                raise IOError(f"checkpoint corruption in {key} "
                              f"(crc {crc} != {meta['crc32']})")
        out[key] = arr
    return out, manifest["step"]


def restore_tree(template: Any, directory: str, step: Optional[int] = None,
                 shardings: Any = None) -> Tuple[Any, int]:
    """Restore into the structure of `template`. `shardings` (optional pytree
    of NamedSharding) re-sharding onto ANY mesh — elastic restarts."""
    flat_np, step = restore(directory, step)
    flat_t = _flatten(template)
    leaves = []
    for key, tmpl in flat_t:
        if key not in flat_np:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat_np[key]
        tdt = getattr(tmpl, "dtype", None)
        if tdt is not None and str(arr.dtype) != str(tdt):
            # jnp handles bfloat16/fp8 casts that plain numpy cannot
            import jax.numpy as jnp
            arr = np.asarray(jnp.asarray(arr).astype(tdt))
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(template)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, step


def latest_step(directory) -> Optional[int]:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    steps = []
    for p in directory.iterdir():
        m = re.fullmatch(r"step-(\d+)", p.name)
        if m and (p / _MANIFEST).exists():
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def gc_keep_last(directory, k: int = 3):
    directory = pathlib.Path(directory)
    steps = sorted(
        int(re.fullmatch(r"step-(\d+)", p.name).group(1))
        for p in directory.iterdir()
        if re.fullmatch(r"step-(\d+)", p.name))
    for s in steps[:-k]:
        shutil.rmtree(directory / f"step-{s:08d}", ignore_errors=True)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, write-to-disk on a worker thread."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, tree: Any, step: int):
        self.wait()  # one outstanding write at a time
        host = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save(host, self.directory, step)
                gc_keep_last(self.directory, self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
