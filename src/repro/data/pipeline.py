"""Deterministic sharded token pipeline.

Production posture: each data-parallel host reads only its shard of the
global batch (``host_batch_slice``), the stream is a pure function of
(seed, step) so any restart/elastic-resize resumes exactly (no state to
checkpoint beyond the step counter), and backing sources are pluggable:

  * SyntheticLM   — zipf-ish token stream (default for benches/smoke)
  * MemmapSource  — packed uint16/uint32 token file (np.memmap), the
                    standard on-disk format for real corpora
"""
from __future__ import annotations

import dataclasses
import pathlib
from typing import Iterator, Optional, Tuple

import numpy as np

__all__ = ["SyntheticLM", "MemmapSource", "LMBatcher", "host_batch_slice"]


def host_batch_slice(global_batch: int, n_hosts: int, host_id: int
                     ) -> Tuple[int, int]:
    """[start, stop) rows of the global batch owned by this host."""
    assert global_batch % n_hosts == 0, (global_batch, n_hosts)
    per = global_batch // n_hosts
    return host_id * per, (host_id + 1) * per


class SyntheticLM:
    """Deterministic synthetic LM tokens: stateless function of (seed, step).

    Tokens follow a zipf-like marginal with short-range structure so losses
    are non-trivial and decreasing under training."""

    def __init__(self, vocab: int, seed: int = 0):
        self.vocab = vocab
        self.seed = seed

    def batch(self, step: int, batch: int, seq: int,
              rows: Optional[Tuple[int, int]] = None) -> np.ndarray:
        lo, hi = rows or (0, batch)
        out = np.empty((hi - lo, seq + 1), np.int32)
        for i, row in enumerate(range(lo, hi)):
            rng = np.random.default_rng(
                (self.seed * 1_000_003 + step) * 131_071 + row)
            base = rng.zipf(1.4, size=seq + 1).astype(np.int64)
            tok = (base + rng.integers(0, 7, size=seq + 1)) % self.vocab
            # inject copy structure: second half repeats first half shifted
            half = (seq + 1) // 2
            tok[half:half * 2] = tok[:half]
            out[i] = tok.astype(np.int32)
        return out


class MemmapSource:
    """Packed token file: flat uint16/uint32 stream, sampled by (seed, step)."""

    def __init__(self, path: str, vocab: int, dtype=np.uint16, seed: int = 0):
        self.arr = np.memmap(path, dtype=dtype, mode="r")
        self.vocab = vocab
        self.seed = seed

    def batch(self, step: int, batch: int, seq: int,
              rows: Optional[Tuple[int, int]] = None) -> np.ndarray:
        lo, hi = rows or (0, batch)
        n = len(self.arr) - (seq + 1)
        out = np.empty((hi - lo, seq + 1), np.int32)
        for i, row in enumerate(range(lo, hi)):
            rng = np.random.default_rng(
                (self.seed * 1_000_003 + step) * 131_071 + row)
            start = int(rng.integers(0, n))
            out[i] = self.arr[start:start + seq + 1].astype(np.int32)
        return out


@dataclasses.dataclass
class LMBatcher:
    """Turns a source into next-token-prediction batches."""
    source: object
    batch: int
    seq: int
    rows: Optional[Tuple[int, int]] = None

    def get(self, step: int) -> dict:
        tokens = self.source.batch(step, self.batch, self.seq, self.rows)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.get(step)
            step += 1
