"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Default scale completes on a
single CPU core in ~20-30 min; ``--full`` uses the paper's exact sizes;
``--only PREFIX`` filters benches; ``--quick`` trims to a smoke pass.

The ``proj_engine`` bench additionally writes machine-readable
``BENCH_proj.json`` (sparsity-adaptive engine trajectory: warm-start Newton
counts, J-proportional work counter, packed-batch vs per-matrix) — CI
uploads it as an artifact and ``scripts/check.sh --bench-smoke`` gates on
it.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def bench_meta(mesh=None, **extra) -> dict:
    """The shared environment block every ``BENCH_*.json`` emitter stamps
    into its ``meta``: backend, device count/kind, and (when the bench ran
    on one) the mesh topology — so an artifact pulled off CI says what
    hardware produced its numbers without consulting the build log.
    Bench-specific keys (quick flags, shapes) ride along via ``extra``.
    """
    import jax

    meta = {
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "device_kind": jax.devices()[0].device_kind,
        "mesh_shape": (list(mesh.devices.shape)
                       if mesh is not None else None),
        "mesh_axes": (list(mesh.axis_names)
                      if mesh is not None else None),
    }
    meta.update(extra)
    return meta


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-exact sizes (slow on 1 CPU core)")
    ap.add_argument("--quick", action="store_true",
                    help="minimal smoke pass")
    ap.add_argument("--only", default=None)
    args, _ = ap.parse_known_args()

    from . import (fleet_serve_bench, fused_step_bench, proj_bench,
                   sae_bench, serve_bench, zoo_serve_bench)

    benches = []
    if args.quick:
        benches = [
            ("fig1", lambda: proj_bench.fig1_radius_sweep(
                n=200, m=200, radii=(0.01, 1.0))),
            ("jaxvar", lambda: proj_bench.jax_variants(n=128, m=128)),
            ("proj_engine", lambda: proj_bench.engine_report(quick=True)),
            ("proj_families", lambda: proj_bench.families_report(quick=True)),
            ("proj_dist", lambda: proj_bench.dist_engine_report(quick=True)),
            ("dist_fused",
             lambda: proj_bench.dist_fused_report(quick=True)),
            ("fused_step",
             lambda: fused_step_bench.fused_step_report(quick=True)),
            ("serve", lambda: serve_bench.serve_report(quick=True)),
            ("zoo_serve",
             lambda: zoo_serve_bench.zoo_serve_report(quick=True)),
            ("fleet_serve",
             lambda: fleet_serve_bench.fleet_serve_report(quick=True)),
        ]
    else:
        benches = [
            ("fig1", lambda: proj_bench.fig1_radius_sweep()),
            ("fig2", proj_bench.fig2_shape_sweep),
            ("fig3", proj_bench.fig3_size_growth),
            ("jaxvar", proj_bench.jax_variants),
            ("proj_engine", lambda: proj_bench.engine_report(quick=False)),
            ("proj_families",
             lambda: proj_bench.families_report(quick=False)),
            ("proj_dist", lambda: proj_bench.dist_engine_report(quick=False)),
            ("dist_fused",
             lambda: proj_bench.dist_fused_report(quick=False)),
            ("fused_step",
             lambda: fused_step_bench.fused_step_report(quick=False)),
            ("serve", lambda: serve_bench.serve_report(quick=False)),
            ("zoo_serve",
             lambda: zoo_serve_bench.zoo_serve_report(quick=False)),
            ("fleet_serve",
             lambda: fleet_serve_bench.fleet_serve_report(quick=False)),
            ("table1", lambda: sae_bench.table1_synthetic(full=args.full)),
            ("table2", sae_bench.table2_lung),
            ("fig5-8", sae_bench.fig_radius_curves),
        ]
    if args.only:
        benches = [(n, f) for n, f in benches if n.startswith(args.only)]

    print("name,us_per_call,derived")
    for bname, fn in benches:
        t0 = time.time()
        try:
            rows = fn()
        except Exception:
            traceback.print_exc()
            print(f"{bname}/ERROR,0,failed", flush=True)
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}", flush=True)
        print(f"# {bname} wall {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
