"""Compact-vs-dense LM zoo serving benchmark -> ``BENCH_zoo_serve.json``.

Builds a zoo decode config whose MLP dominates the step (the production
regime — d_ff >> d_model), projects its ``mlp/w1`` to the paper's ~99%
column-sparsity regime (radius bisected, no training needed: the support
structure is the projection's) plus a residual-output ``mlp/w2`` spec so
the scatter-back path is on the measured path, and gates:

  * decode throughput: tokens/sec of the jit'd ``decode_step`` dense vs
    compact — gated compact >= 2x dense (at ~99% colsp the MLP GEMMs
    shrink ~100x, so the gate holds large headroom even with the
    attention + unembed overhead left dense);
  * exactness: full-sequence forward logits, compact (including
    scatter-back) vs dense — gated <= 1e-4 (structural zeros make the
    gathered GEMMs sum the same nonzero terms, measured diff is 0.0);
  * lifecycle: hot refresh (``refresh_model``) and one live re-compaction
    (``recompact_model``) through the same jit'd step — gated ZERO extra
    traces (shapes frozen by the slot design, DESIGN.md §10).

Schema documented in benchmarks/README.md; CI uploads the JSON artifact
and ``scripts/check.sh --bench-smoke`` enforces the gates.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import List, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.core import ProjectionSpec, apply_constraints
from repro.models.zoo import build, make_batch
from repro.models.transformer import forward, init_cache, decode_step
from repro.serve import compact_model, refresh_model, recompact_model

from .run import bench_meta

Row = Tuple[str, float, str]

_W1 = "blocks/.*/mlp/w1$"
_W2 = "blocks/.*/mlp/w2$"


def _leaf(params):
    return params["blocks"]["p0_global"]["mlp"]


def _alive_frac(arr) -> float:
    """Fraction of surviving columns of a stacked (C, n, m) leaf with the
    max axis on n (union support over the stack, as serving uses)."""
    a = np.asarray(arr)
    return float(np.any(a != 0, axis=(0, 1)).mean())


def _bisect_regime(params, pattern: str, name: str, target_alive: float,
                   iters: int = 18):
    """Bisect the l1,inf radius of one MLP leaf until <= ``target_alive``
    of its columns survive; returns (projected params, spec)."""
    arr = np.asarray(_leaf(params)[name])
    hi = float(np.abs(arr).max(axis=1).sum(axis=-1).max())  # inside-ball
    lo, spec = 0.0, None
    for _ in range(iters):
        C = 0.5 * (lo + hi)
        cand = ProjectionSpec(pattern=pattern, norm="l1inf", radius=C,
                              axis=0)
        projected = apply_constraints(params, (cand,))
        if _alive_frac(_leaf(projected)[name]) > target_alive:
            hi = C
        else:
            lo, spec = C, cand
    if spec is None:  # degenerate tiny shapes: keep the last candidate
        spec = cand
    return apply_constraints(params, (spec,)), spec


def _time_call(fn, reps: int) -> float:
    fn()  # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def zoo_serve_report(quick: bool = True, out: str = "BENCH_zoo_serve.json"
                     ) -> List[Row]:
    d_ff = 4096 if quick else 8192
    B = 8 if quick else 16
    reps = 10 if quick else 30
    cfg = dataclasses.replace(get_reduced("gemma_7b"), n_layers=2,
                              d_model=128, d_ff=d_ff)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # the paper's serving regime: ~99% column sparsity on the hidden units,
    # plus a residual-output constraint so scatter-back is exercised
    params, spec_w1 = _bisect_regime(params, _W1, "w1", target_alive=0.01)
    params, spec_w2 = _bisect_regime(params, _W2, "w2", target_alive=0.5)
    specs = (spec_w1, spec_w2)

    cm = compact_model(params, specs)
    w1_path = "blocks/p0_global/mlp/w1"
    w2_path = "blocks/p0_global/mlp/w2"
    colsp = 100.0 * (1.0 - cm.supports[w1_path].ratio)
    J = cm.supports[w1_path].n_selected

    # ---- exactness: full forward (prefill path), scatter-back included ----
    batch = make_batch(cfg, 2, 16, kind="train")
    logits_d, _ = forward(params, batch, cfg)
    logits_c, _ = forward(cm.params, batch, cfg)
    max_diff = float(jnp.max(jnp.abs(logits_d - logits_c)))

    # ---- decode throughput, dense vs compact through ONE jit'd step ------
    traces = [0]

    def _step(p, c, t, pos):
        traces[0] += 1  # python side effect: bumps at trace time only
        return decode_step(p, c, t, pos, cfg)

    step = jax.jit(_step)
    cache = init_cache(cfg, B, 64, jnp.float32)
    toks = jnp.ones((B, 1), jnp.int32)
    pos = jnp.asarray(3)

    us_dense = _time_call(
        lambda: jax.block_until_ready(step(params, cache, toks, pos)), reps)
    us_compact = _time_call(
        lambda: jax.block_until_ready(step(cm.params, cache, toks, pos)),
        reps)
    tok_s_dense = B / (us_dense / 1e6)
    tok_s_compact = B / (us_compact / 1e6)
    traces_baseline = traces[0]  # dense + compact shapes = 2

    # ---- lifecycle: hot refresh + one live re-compaction, zero retraces --
    params2 = jax.tree_util.tree_map(lambda a: a * 1.5, params)
    cm = refresh_model(cm, params2)
    jax.block_until_ready(step(cm.params, cache, toks, pos))
    # kill one more live hidden unit -> support shrinks inside the slot
    victim = int(cm.sels[w1_path][0])
    arr = np.array(_leaf(params2)["w1"])
    arr[:, :, victim] = 0.0
    _leaf(params2)["w1"] = jnp.asarray(arr)
    cm = recompact_model(cm, params2)
    jax.block_until_ready(step(cm.params, cache, toks, pos))
    extra_traces = traces[0] - traces_baseline

    report = {
        "meta": bench_meta(quick=quick),
        "regime": {"arch": cfg.name, "d_model": cfg.d_model, "d_ff": d_ff,
                   "n_layers": cfg.n_layers, "batch": B,
                   "column_sparsity_pct": colsp,
                   "radius_w1": spec_w1.radius, "radius_w2": spec_w2.radius},
        "compaction": {"ratios": cm.compaction_ratios(),
                       "J_hidden": J,
                       "slot_w1": cm.slot_width(w1_path),
                       "live_w1": cm.live[w1_path],
                       "slot_w2": cm.slot_width(w2_path)},
        "throughput": {"dense_tok_s": tok_s_dense,
                       "compact_tok_s": tok_s_compact,
                       "speedup_compact_vs_dense":
                           tok_s_compact / tok_s_dense},
        "exactness": {"max_abs_diff_logits": max_diff},
        "recompiles": {"baseline_traces": traces_baseline,
                       "extra_after_refresh_and_recompact": extra_traces},
    }
    with open(out, "w") as f:
        json.dump(report, f, indent=2)

    ctx = f"colsp={colsp:.1f}%;J={J}/{d_ff}"
    return [
        ("zoo_serve/dense_decode", us_dense,
         f"{ctx};tok_s={tok_s_dense:.0f}"),
        ("zoo_serve/compact_decode", us_compact,
         f"{ctx};tok_s={tok_s_compact:.0f};"
         f"speedup={tok_s_compact / tok_s_dense:.1f}x;"
         f"extra_traces={extra_traces}"),
    ]
