"""Fused optimizer+projection step benchmark (DESIGN.md §11).

Measures the full projected train step — Adam update + l1,inf-family
projection — with the engine's two solvers on identical inputs:

  * ``unfused`` (solver="newton"): adam writes the updated params, the
    packer copies them into the packed buffer, the segmented Newton solves,
    the clip writes them again (>= 14 leaf-buffer visits per step);
  * ``fused``   (solver="fused"): pass 1 reads (grad, mu, nu, param) once
    and emits moments + per-column statistics, the Newton runs on
    O(num_segments) state, pass 2 recomputes the update and writes the
    clipped params directly (10 leaf-buffer visits, two HBM passes).

Writes ``BENCH_fused_step.json`` (schema in benchmarks/README.md): per
C_frac regime the measured wall times, the XLA-costed bytes of each step
(``compiled.cost_analysis()['bytes accessed']``) with their ideal HBM
times at the roofline bandwidth (``repro.roofline.analysis.HBM_BW``), the
analytic leaf-visit accounting, and the fused/unfused exactness check.
``scripts/check.sh --bench-smoke`` gates fused <= 0.8x unfused wall time
and fused bytes < unfused bytes.
"""
from __future__ import annotations

import json
import time
from typing import List, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.constraints import ProjectionSpec
from repro.core.engine import ProjectionEngine
from repro.optim.adam import AdamConfig, adam_init
from repro.roofline.analysis import HBM_BW

from .run import bench_meta

Row = Tuple[str, float, str]

# per-step leaf-buffer visits over the constrained leaves (DESIGN.md §11):
# fused   pass1 reads g/m/v/p + writes m/v (6), pass2 reads m/v/p + writes
#         p (4) = 10;
# unfused adam reads g/m/v/p + writes p/m/v (7), pack reads p + writes the
#         packed buffer (2), clip reads the buffer + p-sized write back,
#         plus the |.| statistics read inside the solve (>= 5) = >= 14.
FUSED_LEAF_VISITS = 10
UNFUSED_LEAF_VISITS = 14


def _time_pair(fn_a, fn_b, reps: int):
    """Interleaved A/B medians in us. The gate compares the two numbers, so
    the samples alternate — load drift on a shared machine hits both sides
    equally instead of biasing whichever ran second."""
    fn_a(); fn_b()  # compile + warm
    ta, tb = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn_a()
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        fn_b()
        tb.append(time.perf_counter() - t0)
    return float(np.median(ta)) * 1e6, float(np.median(tb)) * 1e6


def _step_bytes(jitted, *args):
    """'bytes accessed' of the compiled step per XLA's cost model, or None."""
    try:
        ca = jitted.lower(*args).compile().cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        if ca and "bytes accessed" in ca:
            return float(ca["bytes accessed"])
    except Exception:
        pass
    return None


def fused_step_report(quick: bool = True,
                      out_path: str = "BENCH_fused_step.json") -> List[Row]:
    """Fused vs unfused projected step at the three BENCH_proj.json
    sparsity regimes (C_frac in 0.5 / 0.1 / 0.01).

    The constrained pair mirrors the SAE: an encoder leaf (axis=0) and a
    decoder-style stack (axis=1). The axis=1 entry is where fusion pays
    most — the unfused packer materializes a physically TRANSPOSED copy of
    the leaf into the packed buffer and transposes it back on unpack
    (strided reads, the dominant cost at these sizes), while the fused
    passes stream the leaf in its native layout and reduce over the minor
    axis in-register.
    """
    n, m, lead = (256, 1024, 2) if quick else (512, 2048, 4)
    reps = 15 if quick else 20
    key = jax.random.PRNGKey(0)
    params = {
        "enc1": {"w": jax.random.normal(jax.random.fold_in(key, 0), (n, m))},
        "blocks": {"w": jax.random.normal(jax.random.fold_in(key, 1),
                                          (lead, n, m))},
    }
    grads = jax.tree_util.tree_map(
        lambda p: 0.01 * jax.random.normal(jax.random.fold_in(key, 2),
                                           p.shape), params)
    acfg = AdamConfig(lr=1e-3)
    norm = float(jnp.abs(params["enc1"]["w"]).max(axis=0).sum())
    leaf_bytes = sum(int(np.prod(p.shape)) * 4
                     for p in jax.tree_util.tree_leaves(params))

    rows: List[Row] = []
    regimes = []
    for C_frac in (0.5, 0.1, 0.01):
        specs = (ProjectionSpec(pattern=r"enc1/w", norm="bilevel",
                                radius=C_frac * norm),
                 ProjectionSpec(pattern=r"blocks/w", norm="bilevel",
                                radius=C_frac * norm, axis=1))
        out = {}
        for solver in ("newton", "fused"):
            eng = ProjectionEngine(specs, solver=solver)
            opt = adam_init(params, acfg)
            state = eng.init_state(params)
            step = jax.jit(lambda g, o, p, s, e=eng: e.projected_update(
                g, o, p, acfg, state=s))
            p1, o1, s1 = step(grads, opt, params, state)
            p1, o1, s1 = step(grads, o1, p1, s1)      # settle the warm start
            jax.block_until_ready(p1)
            out[solver] = {
                "call": (lambda g=grads, o=o1, p=p1, s=s1, f=step:
                         jax.block_until_ready(f(g, o, p, s))),
                "bytes": _step_bytes(step, grads, o1, p1, s1),
                "params": step(grads, o1, p1, s1)[0],
            }
        out["newton"]["us"], out["fused"]["us"] = _time_pair(
            out["newton"]["call"], out["fused"]["call"], reps)
        diff = max(float(jnp.max(jnp.abs(a - b)))
                   for a, b in zip(
                       jax.tree_util.tree_leaves(out["newton"]["params"]),
                       jax.tree_util.tree_leaves(out["fused"]["params"])))
        fb, ub = out["fused"]["bytes"], out["newton"]["bytes"]
        reg = {
            "C_frac": C_frac,
            "unfused_us": out["newton"]["us"],
            "fused_us": out["fused"]["us"],
            "ratio": out["fused"]["us"] / out["newton"]["us"],
            "unfused_bytes": ub,
            "fused_bytes": fb,
            "bytes_ratio": (fb / ub) if fb and ub else None,
            # ideal time of each step's costed bytes at the roofline HBM
            # bandwidth — what the two-pass structure buys on the TPU
            "unfused_hbm_ideal_us": (ub / HBM_BW * 1e6) if ub else None,
            "fused_hbm_ideal_us": (fb / HBM_BW * 1e6) if fb else None,
            "max_abs_diff": diff,
        }
        regimes.append(reg)
        rows.append((f"fused_step/unfused@{n}x{m}", reg["unfused_us"],
                     f"C_frac={C_frac}"))
        rows.append((f"fused_step/fused@{n}x{m}", reg["fused_us"],
                     f"C_frac={C_frac};ratio={reg['ratio']:.3f}"))

    payload = {
        "meta": bench_meta(quick=quick, shape=[n, m], lead=lead,
                           axes=[0, 1]),
        "regimes": regimes,
        "worst_ratio": max(r["ratio"] for r in regimes),
        "worst_bytes_ratio": max((r["bytes_ratio"] for r in regimes
                                  if r["bytes_ratio"] is not None),
                                 default=None),
        "worst_abs_diff": max(r["max_abs_diff"] for r in regimes),
        "hbm_accounting": {
            "fused_leaf_visits": FUSED_LEAF_VISITS,
            "unfused_leaf_visits": UNFUSED_LEAF_VISITS,
            "constrained_leaf_bytes": leaf_bytes,
            "fused_model_bytes": FUSED_LEAF_VISITS * leaf_bytes,
            "unfused_model_bytes": UNFUSED_LEAF_VISITS * leaf_bytes,
        },
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    return rows
