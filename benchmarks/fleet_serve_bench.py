"""Fleet serving benchmark -> ``BENCH_fleet_serve.json``.

The compact decode step (BENCH_zoo_serve.json) is ~6x cheaper at the
paper's ~99% column-sparsity regime — but a cohort batching loop only
converts that into service throughput when all requests arrive and
finish together. This bench measures the CONTINUOUS-batching engine
(serve/engine.py, DESIGN.md §13) under the north-star workload: a
synthetic open-loop arrival process with heavy-tailed generation lengths
and checkpoint churn, over the 2x2 of {continuous, cohort} x {compact,
dense}:

  * **throughput**: sustained tokens/sec, first dispatch to last drain.
    Gated: continuous >= 2x cohort at the ~99% regime on the compact
    path — the cohort barrier idles every slot whose row finished until
    the whole batch drains (one long request per cohort pins slot
    efficiency near (B-1)*s/(B*L)), while the engine re-admits freed
    slots immediately;
  * **latency**: per-request TTFT and inter-token percentiles
    (p50/p95/p99) from the engine's drain-time clock, both modes
    (admission-to-first-token — queueing delay ahead of admission is the
    arrival process's, not the server's);
  * **churn**: one mid-stream hot refresh plus one live re-compaction,
    fired at fixed request-completion fractions so BOTH disciplines pay
    the identical checkpoint-swap cost. The churn checkpoints carry the
    SAME values (refresh re-gather and identity recompact are exercised
    on-path with zero semantic change), so exactness is checked ACROSS
    the churn run. Gated: zero extra traces — admit/evict/refresh/
    recompact all reuse the one compiled step;
  * **exactness**: every request's continuous-compact tokens equal the
    continuous-dense tokens (structural zeros: bit-identical), and a
    sample is re-served solo — gated zero mismatches (the ragged==solo
    contract survives slot churn).

Schema documented in benchmarks/README.md; CI uploads the JSON artifact
and ``scripts/check.sh --bench-smoke`` enforces the gates.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Dict, List, Tuple

import jax

from repro.configs import get_reduced
from repro.models.zoo import build
from repro.serve import EngineConfig, FleetEngine, RecompactScheduler, \
    compact_model

from .run import bench_meta
from .zoo_serve_bench import _bisect_regime, _W1, _W2

Row = Tuple[str, float, str]

_SMAX = 64
_SHORT, _LONG = 4, 56     # heavy-tailed generation budgets
_PLENS = (2, 3, 4, 5)     # deterministic prompt-length cycle


def _workload(n_requests: int, batch: int):
    """Deterministic open-loop arrivals: four requests per step, one LONG
    request per ``batch`` arrivals (so every cohort of B contains exactly
    one — the worst honest case for cohort batching, not an adversarial
    clustering)."""
    reqs = []
    for i in range(n_requests):
        plen = _PLENS[i % len(_PLENS)]
        prompt = [(7 * i + j) % 97 + 1 for j in range(plen)]
        budget = _LONG if i % batch == batch // 2 else _SHORT
        reqs.append({"arrival_step": i // 4, "prompt": prompt,
                     "budget": budget})
    return reqs


def _run_continuous(eng: FleetEngine, reqs, churn=None):
    """Open-loop serve: admit each request at its arrival step, run until
    drained. ``churn(n_done, eng)`` fires after every step with the
    completed-request count — the same hook the cohort runner drives, so
    both disciplines pay identical checkpoint-swap costs. Returns
    (tokens by request index, sustained tok/s, steps)."""
    eng.step()
    eng.flush()               # compile + warm outside the timed window
    done: Dict[int, List[int]] = {}
    rid_of = {}
    i = step = 0
    t0 = time.perf_counter()
    while True:
        while i < len(reqs) and reqs[i]["arrival_step"] <= step:
            rid_of[eng.submit(reqs[i]["prompt"], reqs[i]["budget"])] = i
            i += 1
        for c in eng.step():
            done[rid_of[c.rid]] = c.tokens
        step += 1
        if churn is not None:
            churn(len(done), eng)
        st = eng.stats()
        if i >= len(reqs) and st["busy_slots"] == 0 and st["queue"] == 0:
            break
    for c in eng.flush():
        done[rid_of[c.rid]] = c.tokens
    wall = time.perf_counter() - t0
    n_tok = sum(r["budget"] for r in reqs)
    return done, n_tok / wall, step


def _run_cohort(eng: FleetEngine, reqs, batch: int, churn=None):
    """Cohort baseline: admit B requests, BARRIER until all finish, admit
    the next B — the pre-engine ``generate`` service discipline. Same
    compiled step, same requests, same churn hook."""
    eng.step()
    eng.flush()
    done: Dict[int, List[int]] = {}
    steps = 0
    t0 = time.perf_counter()
    for lo in range(0, len(reqs), batch):
        cohort = reqs[lo: lo + batch]
        rid_of = {eng.submit(r["prompt"], r["budget"]): lo + j
                  for j, r in enumerate(cohort)}
        pending = set(rid_of)
        while pending:
            for c in eng.step():
                done[rid_of[c.rid]] = c.tokens
                pending.discard(c.rid)
            steps += 1
            if churn is not None:
                churn(len(done), eng)
        for c in eng.flush():
            done[rid_of[c.rid]] = c.tokens
            pending.discard(c.rid)
    wall = time.perf_counter() - t0
    n_tok = sum(r["budget"] for r in reqs)
    return done, n_tok / wall, steps


def fleet_serve_report(quick: bool = True,
                       out: str = "BENCH_fleet_serve.json") -> List[Row]:
    d_ff = 4096 if quick else 8192
    B = 8 if quick else 16
    n_requests = 5 * B
    cfg = dataclasses.replace(get_reduced("gemma_7b"), n_layers=2,
                              d_model=128, d_ff=d_ff)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # the paper's serving regime (same bisection as BENCH_zoo_serve)
    params, spec_w1 = _bisect_regime(params, _W1, "w1", target_alive=0.01)
    params, spec_w2 = _bisect_regime(params, _W2, "w2", target_alive=0.5)
    cm = compact_model(params, (spec_w1, spec_w2))
    w1_path = "blocks/p0_global/mlp/w1"
    colsp = 100.0 * (1.0 - cm.supports[w1_path].ratio)

    reqs = _workload(n_requests, B)
    ecfg = EngineConfig(max_seq=_SMAX)

    # Checkpoint churn, fired at fixed request-completion fractions so
    # BOTH disciplines swap weights at the same workload progress: one hot
    # refresh at 35% done, one live re-compaction at 70%. Same-value
    # checkpoints — the refresh re-gather and an identity recompact run on
    # the measured path with zero semantic change, so exactness is checked
    # ACROSS the churn (see module docstring).
    def make_churn():
        log = {"refresh": 0, "recompact": 0}

        def churn(n_done, eng):
            if not log["refresh"] and n_done >= 0.35 * n_requests:
                eng.refresh(params)
                log["refresh"] += 1
            elif not log["recompact"] and n_done >= 0.7 * n_requests:
                eng.recompact(params)
                log["recompact"] += 1

        return churn, log

    # ---- continuous + compact, under checkpoint churn (headline) --------
    churn_cont, churn_log = make_churn()
    cont = FleetEngine(model, B, ecfg,
                       scheduler=RecompactScheduler(threshold=0.9))
    cont.load_compact(cm)
    tok_cont, tok_s_cont, steps_cont = _run_continuous(cont, reqs,
                                                       churn_cont)
    lat_cont = cont.latency_report()
    extra_traces = cont.n_traces - 1

    # ---- cohort + compact under the same churn (the 2x gate baseline) ---
    churn_coh, churn_log_coh = make_churn()
    coh = FleetEngine(model, B, ecfg,
                      scheduler=RecompactScheduler(threshold=0.9))
    coh.load_compact(cm)
    tok_coh, tok_s_coh, steps_coh = _run_cohort(coh, reqs, B, churn_coh)
    lat_coh = coh.latency_report()

    # ---- dense, both disciplines ----------------------------------------
    cont_d = FleetEngine(model, B, ecfg)
    cont_d.load(params)
    tok_cont_d, tok_s_cont_d, _ = _run_continuous(cont_d, reqs)
    coh_d = FleetEngine(model, B, ecfg)
    coh_d.load(params)
    _, tok_s_coh_d, _ = _run_cohort(coh_d, reqs, B)

    # ---- exactness ------------------------------------------------------
    mism_dense = sum(tok_cont[i] != tok_cont_d[i]
                     for i in range(n_requests))
    mism_cohort = sum(tok_cont[i] != tok_coh[i] for i in range(n_requests))
    solo = FleetEngine(model, 1, ecfg)
    solo.load_compact(cm)
    sample = list(range(0, n_requests, max(1, n_requests // 4)))[:4]
    mism_solo = 0
    for i in sample:
        solo.submit(reqs[i]["prompt"], reqs[i]["budget"])
        mism_solo += solo.drain()[0].tokens != tok_cont[i]

    n_tok = sum(r["budget"] for r in reqs)
    speedup = tok_s_cont / tok_s_coh
    report = {
        "meta": bench_meta(quick=quick),
        "regime": {"arch": cfg.name, "d_model": cfg.d_model, "d_ff": d_ff,
                   "n_layers": cfg.n_layers, "batch_slots": B,
                   "column_sparsity_pct": colsp, "max_seq": _SMAX},
        "workload": {"n_requests": n_requests, "total_new_tokens": n_tok,
                     "short_budget": _SHORT, "long_budget": _LONG,
                     "long_every": B, "arrivals_per_step": 4},
        "throughput": {
            "continuous_compact_tok_s": tok_s_cont,
            "cohort_compact_tok_s": tok_s_coh,
            "continuous_dense_tok_s": tok_s_cont_d,
            "cohort_dense_tok_s": tok_s_coh_d,
            "speedup_continuous_vs_cohort": speedup,
            "speedup_compact_vs_dense_continuous":
                tok_s_cont / tok_s_cont_d,
            "steps": {"continuous": steps_cont, "cohort": steps_coh},
            "slot_efficiency": {
                "continuous": n_tok / (B * steps_cont),
                "cohort": n_tok / (B * steps_coh)},
        },
        "latency": {"continuous": lat_cont, "cohort": lat_coh},
        "churn": {"continuous": churn_log, "cohort": churn_log_coh,
                  "extra_traces": extra_traces,
                  "traces": {"continuous": cont.n_traces,
                             "cohort": coh.n_traces}},
        "exactness": {"token_mismatches_vs_dense": int(mism_dense),
                      "token_mismatches_vs_cohort": int(mism_cohort),
                      "token_mismatches_vs_solo": int(mism_solo),
                      "n_solo_checked": len(sample)},
    }
    with open(out, "w") as f:
        json.dump(report, f, indent=2)

    ctx = f"colsp={colsp:.1f}%;B={B};N={n_requests}"
    return [
        ("fleet_serve/continuous_compact", 1e6 / tok_s_cont,
         f"{ctx};tok_s={tok_s_cont:.0f};speedup_vs_cohort={speedup:.2f}x;"
         f"extra_traces={extra_traces}"),
        ("fleet_serve/cohort_compact", 1e6 / tok_s_coh,
         f"{ctx};tok_s={tok_s_coh:.0f};"
         f"slot_eff={n_tok / (B * steps_coh):.2f}"),
        ("fleet_serve/continuous_dense", 1e6 / tok_s_cont_d,
         f"{ctx};tok_s={tok_s_cont_d:.0f}"),
        ("fleet_serve/cohort_dense", 1e6 / tok_s_coh_d,
         f"{ctx};tok_s={tok_s_coh_d:.0f}"),
    ]
