import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")
# The two lines above MUST run before jax is imported (device count locks at
# first init) — this module is its own entry point; ``proj_bench`` runs it in
# a subprocess so the parent's 1-device config stays untouched.
#
# Sharded-vs-replicated packed projection on a host-device mesh
# (``BENCH_dist_proj.json``): FSDP-sharded weight matrices projected by
#   * the replicated engine (the pack all-gathers every shard, every rank
#     runs the full segmented Newton), and
#   * the sharded engine (shards stay resident; an all-to-all moves columns,
#     one (num_segments,) psum crosses the link per Newton evaluation).
# ``scripts/check.sh --bench-smoke`` gates sharded <= 1.15x replicated and
# exactness; CI uploads the JSON artifact.
import argparse
import json
import re
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from benchmarks.run import bench_meta
from repro.core import ProjectionEngine, ProjectionSpec, init_projection_state


def _time_call(fn, reps: int) -> float:
    fn()  # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def _collective_counts(hlo: str) -> dict:
    return {op: len(re.findall(op, hlo))
            for op in ("all-gather", "all-to-all", "all-reduce",
                       "collective-permute")}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_dist_proj.json")
    args = ap.parse_args()

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",))
    reps = 10 if args.quick else 30
    k_mats, n, m = (4, 256, 1024) if args.quick else (6, 512, 4096)

    rng = np.random.default_rng(11)
    scale = np.exp(rng.normal(size=(1, m)))
    params = {f"w{i}": jnp.asarray(
        rng.uniform(0, 1, size=(n, m)) * scale, jnp.float32)
        for i in range(k_mats)}
    radius = float(0.1 * np.abs(np.asarray(params["w0"])).max(axis=0).sum())
    specs = (ProjectionSpec(pattern=r"w\d", norm="l1inf", radius=radius),)

    # FSDP layout: rows (the max axis) sharded — the worst case for the
    # replicated pack (a full all-gather per leaf per step)
    shardings = {k: NamedSharding(mesh, P("data", None)) for k in params}
    params_s = jax.device_put(params, shardings)
    state0 = init_projection_state(params, specs)

    rep_eng = ProjectionEngine(specs)                       # gathers
    shd_eng = ProjectionEngine(specs, solver="sharded", mesh=mesh)
    rep_fn = jax.jit(lambda p, s: rep_eng.apply(p, state=s),
                     in_shardings=(shardings, None))
    shd_fn = jax.jit(lambda p, s: shd_eng.apply(p, state=s),
                     in_shardings=(shardings, None))

    with mesh:
        hlo_rep = rep_fn.lower(params_s, state0).compile().as_text()
        hlo_shd = shd_fn.lower(params_s, state0).compile().as_text()
        out_r, state1 = rep_fn(params_s, state0)
        out_s, state1_s = shd_fn(params_s, state0)
        jax.block_until_ready((state1, state1_s))
        rep_us = _time_call(
            lambda: jax.block_until_ready(rep_fn(params_s, state1)), reps)
        shd_us = _time_call(
            lambda: jax.block_until_ready(shd_fn(params_s, state1_s)), reps)

    max_diff = max(float(jnp.max(jnp.abs(out_r[k] - out_s[k])))
                   for k in params)
    k0 = list(state1)[0]
    theta_diff = float(jnp.max(jnp.abs(state1[k0] - state1_s[k0])))

    payload = {
        "meta": bench_meta(mesh, quick=bool(args.quick),
                           matrices=k_mats, shape=[n, m]),
        "replicated_us": rep_us,
        "sharded_us": shd_us,
        "ratio_sharded_vs_replicated": shd_us / rep_us,
        "max_abs_diff": max_diff,
        "theta_max_abs_diff": theta_diff,
        "collectives": {"replicated": _collective_counts(hlo_rep),
                        "sharded": _collective_counts(hlo_shd)},
        "psum_bytes_per_newton_eval": 4 * k_mats,   # one f32 per segment
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(json.dumps(payload, indent=2))


if __name__ == "__main__":
    main()
