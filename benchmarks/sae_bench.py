"""SAE benchmarks — paper Tables 1-2 and Figs. 5-8.

Default scale is CPU-friendly (d=2000 synthetic); pass full=True for the
paper's exact d=10000. The LUNG table runs the surrogate at full feature
count (2944). `derived` reports accuracy/column-sparsity per method.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core import ProjectionSpec, theta_l1inf
from repro.sae import (SAEConfig, SAETrainConfig, make_classification,
                       make_lung_surrogate, train_test_split, train_sae)

Row = Tuple[str, float, str]


def _methods(C_l1inf: float, eta_l1: float, eta_l21: float):
    return [
        ("baseline", None),
        ("l1", ProjectionSpec(pattern=r"enc1/w", norm="l1",
                              radius=eta_l1, axis=1)),
        ("l21", ProjectionSpec(pattern=r"enc1/w", norm="l12",
                               radius=eta_l21, axis=1)),
        ("l1inf", ProjectionSpec(pattern=r"enc1/w", norm="l1inf",
                                 radius=C_l1inf, axis=1)),
        ("l1inf_masked", ProjectionSpec(pattern=r"enc1/w",
                                        norm="l1inf_masked",
                                        radius=C_l1inf, axis=1)),
    ]


def _run_table(X, y, d, name, C_l1inf, eta_l1, eta_l21, seeds=(0, 1, 2),
               epochs=20, hidden=96) -> List[Row]:
    mu, sd = X.mean(0), X.std(0) + 1e-6
    X = ((X - mu) / sd).astype(np.float32)
    rows: List[Row] = []
    for mname, spec in _methods(C_l1inf, eta_l1, eta_l21):
        accs, colsps, times = [], [], []
        for seed in seeds:
            Xtr, ytr, Xte, yte = train_test_split(X, y, 0.2, seed=seed)
            t0 = time.perf_counter()
            res = train_sae(
                Xtr, ytr, Xte, yte,
                SAEConfig(n_features=d, n_hidden=hidden, n_classes=2),
                SAETrainConfig(epochs=epochs, lr=2e-3, projection=spec,
                               seed=seed))
            times.append(time.perf_counter() - t0)
            accs.append(res.test_accuracy * 100)
            colsps.append(res.column_sparsity)
        rows.append((f"{name}/{mname}", float(np.mean(times)) * 1e6,
                     f"acc={np.mean(accs):.2f}+-{np.std(accs):.2f}%;"
                     f"colsp={np.mean(colsps):.1f}%"))
    return rows


def table1_synthetic(full: bool = False) -> List[Row]:
    """Table 1: synthetic data (paper: d=10000, 64 informative, sep 0.8)."""
    d = 10_000 if full else 2_000
    X, y, _ = make_classification(n_samples=1000, n_features=d,
                                  n_informative=64, class_sep=0.8, seed=0)
    # radius scales ~ with d kept at the paper's C=0.1 for full scale
    return _run_table(X, y, d, f"table1[d={d}]", C_l1inf=0.1,
                      eta_l1=10.0, eta_l21=10.0,
                      seeds=(0, 1, 2), epochs=25 if not full else 30)


def table2_lung() -> List[Row]:
    """Table 2 on the LUNG-surrogate (2944 features; log-transform)."""
    X, y, _ = make_lung_surrogate(seed=0)
    X = np.log1p(X)
    return _run_table(X, y, 2944, "table2[lung-surrogate]", C_l1inf=0.5,
                      eta_l1=50.0, eta_l21=50.0, seeds=(0, 1, 2), epochs=25)


def fig_radius_curves() -> List[Row]:
    """Figs. 5-8: accuracy / column sparsity / theta as functions of C.

    theta is evaluated by projecting the *unconstrained* trained weight at
    each radius (the paper's Figs. 6/8-right: theta decreases with C)."""
    d = 1_000
    X, y, _ = make_classification(n_samples=600, n_features=d,
                                  n_informative=32, class_sep=0.8, seed=1)
    mu, sd = X.mean(0), X.std(0) + 1e-6
    X = ((X - mu) / sd).astype(np.float32)
    rows: List[Row] = []
    Xtr, ytr, Xte, yte = train_test_split(X, y, 0.25, seed=0)
    base = train_sae(Xtr, ytr, Xte, yte,
                     SAEConfig(n_features=d, n_hidden=64, n_classes=2),
                     SAETrainConfig(epochs=15, lr=2e-3, projection=None,
                                    seed=0))
    W_free = jnp.asarray(np.asarray(base.params["enc1"]["w"]).T)
    for C in (0.02, 0.05, 0.1, 0.3, 1.0, 3.0):
        spec = ProjectionSpec(pattern=r"enc1/w", norm="l1inf",
                              radius=C, axis=1)
        t0 = time.perf_counter()
        res = train_sae(Xtr, ytr, Xte, yte,
                        SAEConfig(n_features=d, n_hidden=64, n_classes=2),
                        SAETrainConfig(epochs=15, lr=2e-3, projection=spec,
                                       seed=0))
        dt = time.perf_counter() - t0
        th = float(theta_l1inf(W_free, C))
        rows.append((f"fig5-8/C={C}", dt * 1e6,
                     f"acc={res.test_accuracy*100:.2f}%;"
                     f"colsp={res.column_sparsity:.1f}%;theta={th:.4f}"))
    return rows
