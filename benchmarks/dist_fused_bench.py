import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")
# The two lines above MUST run before jax is imported (device count locks at
# first init) — this module is its own entry point; ``proj_bench`` runs it in
# a subprocess so the parent's 1-device config stays untouched.
#
# Fused-sharded vs unfused-sharded projected step on a host-device mesh
# (``BENCH_dist_fused.json``): column-sharded weights updated+projected by
#   * solver="sharded"        — Adam update, then pack (all-to-all reshard +
#     physical transposes into the lane-padded buffer), shard_map Newton,
#     unpack, and
#   * solver="fused_sharded"  — the PR-7 two-HBM-pass megakernel rank-local
#     inside shard_map: no packed buffer exists, the only cross-rank traffic
#     is ONE stacked (2, num_segments) f32 psum per Newton evaluation
#     (DESIGN.md §12).
# Both sides take the SAME column-sharded inputs (the canonical layout), so
# the A/B isolates the fused dataflow, not a resharding artifact. Timing is
# interleaved A/B (medians) to cancel machine drift.
# ``scripts/check.sh --bench-smoke`` gates fused_sharded <= 0.85x unfused
# wall time and params exact to <= 1e-5; CI uploads the JSON artifact.
import argparse
import json
import re
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from benchmarks.run import bench_meta
from repro.core import ProjectionEngine, ProjectionSpec
from repro.optim.adam import AdamConfig, adam_init


def _time_pair(fn_a, fn_b, reps: int):
    """Interleaved A/B medians (us): alternating reps cancel thermal and
    scheduler drift that back-to-back loops fold into one side."""
    fn_a()
    fn_b()
    ta, tb = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn_a()
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        fn_b()
        tb.append(time.perf_counter() - t0)
    med = lambda xs: sorted(xs)[len(xs) // 2]  # noqa: E731
    return med(ta) * 1e6, med(tb) * 1e6


def _collective_counts(hlo: str) -> dict:
    return {op: len(re.findall(op, hlo))
            for op in ("all-gather", "all-to-all", "all-reduce",
                       "collective-permute")}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_dist_fused.json")
    args = ap.parse_args()

    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",))
    reps = 10 if args.quick else 30
    # enc: 2-D, columns = last axis; blocks: stacked with axis=1 (transpose
    # entries — where the unfused pack pays physical transposes per step)
    if args.quick:
        (n_e, m_e), (lead, r_b, c_b) = (256, 1024), (4, 256, 512)
    else:
        (n_e, m_e), (lead, r_b, c_b) = (512, 4096), (6, 512, 2048)

    rng = np.random.default_rng(11)
    params = {
        "enc": {"w": jnp.asarray(rng.normal(size=(n_e, m_e)), jnp.float32)},
        "blocks": {"w": jnp.asarray(rng.normal(size=(lead, r_b, c_b)),
                                    jnp.float32)},
    }
    grads = jax.tree_util.tree_map(
        lambda p: 0.01 * jnp.asarray(
            rng.normal(size=p.shape), jnp.float32), params)
    norm = float(jnp.abs(params["enc"]["w"]).max(axis=0).sum())
    specs = (ProjectionSpec(pattern=r"enc/w", norm="bilevel",
                            radius=0.1 * norm),
             ProjectionSpec(pattern=r"blocks/w", norm="bilevel",
                            radius=0.05 * norm, axis=1))
    acfg = AdamConfig(lr=1e-3)

    # canonical column layout for BOTH sides: the constrained axis sharded
    sh = {"enc": {"w": NamedSharding(mesh, P(None, "data"))},
          "blocks": {"w": NamedSharding(mesh, P(None, "data", None))}}
    params_s = jax.device_put(params, sh)
    grads_s = jax.device_put(grads, sh)
    opt = adam_init(params, acfg)

    shd_eng = ProjectionEngine(specs, solver="sharded", mesh=mesh)
    fus_eng = ProjectionEngine(specs, solver="fused_sharded", mesh=mesh)
    state0 = shd_eng.init_state(params)
    shd_fn = jax.jit(lambda g, o, p, s: shd_eng.projected_update(
        g, o, p, acfg, state=s))
    fus_fn = jax.jit(lambda g, o, p, s: fus_eng.projected_update(
        g, o, p, acfg, state=s))

    with mesh:
        hlo_shd = shd_fn.lower(grads_s, opt, params_s,
                               state0).compile().as_text()
        hlo_fus = fus_fn.lower(grads_s, opt, params_s,
                               state0).compile().as_text()
        p_shd, o_shd, s_shd = shd_fn(grads_s, opt, params_s, state0)
        p_fus, o_fus, s_fus = fus_fn(grads_s, opt, params_s, state0)
        jax.block_until_ready((s_shd, s_fus))
        # steady state: warm theta, step-2 moments
        shd_us, fus_us = _time_pair(
            lambda: jax.block_until_ready(
                shd_fn(grads_s, o_shd, p_shd, s_shd)),
            lambda: jax.block_until_ready(
                fus_fn(grads_s, o_fus, p_fus, s_fus)),
            reps)

    max_diff = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree_util.tree_leaves(p_shd),
                        jax.tree_util.tree_leaves(p_fus)))
    k0 = list(s_shd)[0]
    theta_diff = float(jnp.max(jnp.abs(s_shd[k0] - s_fus[k0])))
    G = 1 + lead  # enc segment + one per stacked blocks slice

    payload = {
        "meta": bench_meta(mesh, quick=bool(args.quick),
                           enc_shape=[n_e, m_e],
                           blocks_shape=[lead, r_b, c_b]),
        "sharded_us": shd_us,
        "fused_sharded_us": fus_us,
        "ratio_fused_vs_sharded": fus_us / shd_us,
        "max_abs_diff": max_diff,
        "theta_max_abs_diff": theta_diff,
        "collectives": {"sharded": _collective_counts(hlo_shd),
                        "fused_sharded": _collective_counts(hlo_fus)},
        "num_segments": G,
        # the stacked (2, G) f32 Eq.-(19) psum — all the projection moves
        "newton_psum_bytes_per_eval": 2 * 4 * G,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(json.dumps(payload, indent=2))


if __name__ == "__main__":
    main()
