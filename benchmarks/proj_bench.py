"""Projection benchmarks — paper Figs. 1-3 (+ JAX/TPU-variant comparison)
and the sparsity-adaptive engine report (``engine_report`` -> BENCH_proj.json).

Each function returns rows: (name, us_per_call, derived) where `derived`
carries the figure's x-axis context (radius, sparsity, size).
"""
from __future__ import annotations

import json
import time
from typing import Callable, List, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (project_l1inf_heap, project_l1inf_naive,
                        project_l1inf_quattoni, project_l1inf_bejar,
                        project_l1inf_newton_np, project_l1inf_newton,
                        project_l1inf_sorted)
from repro.core.l1inf import project_l1inf_newton_stats
from repro.core.constraints import (ProjectionSpec, apply_constraints,
                                    engine_counters, engine_counters_reset)
from repro.core.engine import (apply_constraints_packed,
                               init_projection_state)
from repro.kernels.l1inf import project_l1inf_pallas

from .run import bench_meta

Row = Tuple[str, float, str]


def _time_np(fn: Callable, Y, C, reps: int = 3) -> float:
    fn(Y, C)  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        fn(Y, C)
    return (time.perf_counter() - t0) / reps * 1e6


def _time_jax(fn: Callable, Y, C, reps: int = 5) -> float:
    Yj = jnp.asarray(Y, jnp.float32)
    fn(Yj, C).block_until_ready()  # compile+warm
    t0 = time.perf_counter()
    for _ in range(reps):
        fn(Yj, C).block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def _sparsity(X) -> float:
    X = np.asarray(X)
    return 100.0 * float((np.abs(X).max(axis=0) <= 1e-12).mean())


CPU_METHODS = [
    ("heap[paper-Alg2]", project_l1inf_heap),
    ("newton_np[Chu-class]", project_l1inf_newton_np),
    ("quattoni[total-order]", project_l1inf_quattoni),
    ("bejar[elim+naive]", project_l1inf_bejar),
]

JAX_METHODS = [
    ("jax_newton", lambda Y, C: project_l1inf_newton(Y, C)),
    ("jax_sorted", lambda Y, C: project_l1inf_sorted(Y, C)),
]


def fig1_radius_sweep(n: int = 1000, m: int = 1000,
                      radii=(0.001, 0.01, 0.1, 1.0, 4.0, 8.0),
                      include_slow: bool = False) -> List[Row]:
    """Fig. 1: projection time vs radius (sparsity decreases with radius)."""
    rng = np.random.default_rng(0)
    Y = rng.uniform(0, 1, size=(n, m))
    rows: List[Row] = []
    for C in radii:
        Xref = project_l1inf_heap(Y, C)
        sp = _sparsity(Xref)
        for name, fn in CPU_METHODS:
            if fn is project_l1inf_naive and not include_slow:
                continue
            us = _time_np(fn, Y, C)
            rows.append((f"fig1/{name}", us, f"C={C};colsp={sp:.1f}%"))
        for name, fn in JAX_METHODS:
            us = _time_jax(fn, Y, C)
            rows.append((f"fig1/{name}", us, f"C={C};colsp={sp:.1f}%"))
    return rows


def fig2_shape_sweep() -> List[Row]:
    """Fig. 2: 1000x10000 and 10000x1000 at a few radii."""
    rng = np.random.default_rng(1)
    rows: List[Row] = []
    for (n, m) in ((1000, 10000), (10000, 1000)):
        Y = rng.uniform(0, 1, size=(n, m))
        for C in (0.1, 1.0, 4.0):
            sp = _sparsity(project_l1inf_heap(Y, C))
            for name, fn in CPU_METHODS:
                us = _time_np(fn, Y, C, reps=2)
                rows.append((f"fig2/{name}@{n}x{m}", us,
                             f"C={C};colsp={sp:.1f}%"))
    return rows


def fig3_size_growth() -> List[Row]:
    """Fig. 3: growth with fixed n (left) and fixed m (right), C=1."""
    rng = np.random.default_rng(2)
    rows: List[Row] = []
    for m in (500, 1000, 2000, 4000):
        Y = rng.uniform(0, 1, size=(1000, m))
        for name, fn in CPU_METHODS:
            rows.append((f"fig3/fixed_n/{name}@1000x{m}",
                         _time_np(fn, Y, 1.0, reps=2), "C=1"))
    for n in (500, 1000, 2000, 4000):
        Y = rng.uniform(0, 1, size=(n, 1000))
        for name, fn in CPU_METHODS:
            rows.append((f"fig3/fixed_m/{name}@{n}x1000",
                         _time_np(fn, Y, 1.0, reps=2), "C=1"))
    return rows


def _time_call(fn, reps: int) -> float:
    fn()  # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def engine_report(quick: bool = True,
                  out_path: str = "BENCH_proj.json") -> List[Row]:
    """Sparsity-adaptive engine trajectory: before/after timings at three
    sparsity regimes, warm-start Newton counts on a simulated SGD sequence,
    the J-proportional work counter (interpret mode), and packed-vs-
    per-matrix batching. Writes machine-readable ``out_path`` for CI.
    """
    rng = np.random.default_rng(7)
    reps = 20 if quick else 50
    n, m = (128, 256) if quick else (512, 1024)
    payload: dict = {"meta": bench_meta(quick=quick, shape=[n, m])}
    rows: List[Row] = []

    def _hetero(rows_, cols_):
        """Heterogeneous column scales (lognormal), the paper's sparse
        regime: column l1 norms spread over decades, so the three C_frac
        settings land in genuinely different column-sparsity regimes."""
        scale = np.exp(rng.normal(size=(1, cols_)))
        return jnp.asarray(rng.uniform(0, 1, size=(rows_, cols_)) * scale,
                           jnp.float32)

    # ---- (timings) cold vs warm Newton at three sparsity regimes ---------
    Y = _hetero(n, m)
    regimes = []
    for C_frac in (0.5, 0.1, 0.01):
        C = float(C_frac * np.abs(np.asarray(Y)).max(axis=0).sum())
        X, st = project_l1inf_newton_stats(Y, C)
        X.block_until_ready()
        colsp = _sparsity(X)
        theta = st["theta"]
        cold_us = _time_call(
            lambda: project_l1inf_newton(Y, C).block_until_ready(), reps)
        warm_us = _time_call(
            lambda: project_l1inf_newton(Y, C,
                                         theta0=theta).block_until_ready(),
            reps)
        _, st_w = project_l1inf_newton_stats(Y, C, theta0=theta)
        regimes.append({
            "C_frac": C_frac, "colsp_pct": colsp,
            "cold_us": cold_us, "warm_us": warm_us,
            "cold_iters": int(st["iters"]), "warm_iters": int(st_w["iters"]),
        })
        rows.append((f"engine/newton_cold@{n}x{m}", cold_us,
                     f"C_frac={C_frac};colsp={colsp:.1f}%"))
        rows.append((f"engine/newton_warm@{n}x{m}", warm_us,
                     f"C_frac={C_frac};colsp={colsp:.1f}%"))
    payload["regimes"] = regimes

    # ---- (a) warm-started Newton on a simulated SGD sequence -------------
    # Iteration accounting: the engine always spends 2 bootstrap Eq.-(19)
    # evaluations (overshoot repair + monotone re-entry, which double as the
    # convergence certificate); "extra evals" = iters - 2 counts the
    # monotone refinement steps beyond that floor — 0 for a perfect warm
    # start, ~4-8 for a cold start.
    C = float(0.1 * np.abs(np.asarray(Y)).max(axis=0).sum())
    steps = 12
    scale = np.abs(np.asarray(Y)).max(axis=0, keepdims=True)
    Yt = np.asarray(Y)
    theta = None
    warm_steps, cold_steps = [], []
    for t in range(steps):
        Yj = jnp.asarray(Yt, jnp.float32)
        _, st_c = project_l1inf_newton_stats(Yj, C)
        Xw, st_w = (project_l1inf_newton_stats(Yj, C) if theta is None
                    else project_l1inf_newton_stats(Yj, C, theta0=theta))
        cold_steps.append(int(st_c["iters"]) - 2)
        warm_steps.append(int(st_w["iters"]) - 2)
        theta = st_w["theta"]
        # SGD-ish drift: small column-scaled step off the projected point
        Yt = np.asarray(Xw) + 1e-5 * scale * rng.normal(size=Yt.shape)
    # steady state: skip the first 2 steps (one-time cold -> on-ball
    # transition where theta* collapses from the initial projection)
    steady = warm_steps[2:]
    payload["warm_start"] = {
        "sgd_steps": steps, "cold_extra_evals": cold_steps,
        "warm_extra_evals": warm_steps,
        "steady_state_newton_steps": float(np.median(steady)),
        "steady_state_max_extra_evals": int(max(steady)),
    }
    rows.append(("engine/warm_start_steady_newton_steps",
                 float(np.median(steady)),
                 f"cold={cold_steps};warm={warm_steps}"))

    # ---- (b) J-proportional work counter (Pallas engine, interpret) ------
    wn, wm = (64, 512) if quick else (128, 1024)
    Yw = _hetero(wn, wm)
    work = []
    for C_frac in (0.5, 0.1, 0.01):
        Cw = float(C_frac * np.abs(np.asarray(Yw)).max(axis=0).sum())
        Xs, st = project_l1inf_pallas(Yw, Cw, interpret=True,
                                      return_stats=True)
        _, st0 = project_l1inf_pallas(Yw, Cw, interpret=True, shrink=False,
                                      return_stats=True)
        n_pad = ((wn + 7) // 8) * 8
        iters = int(st["newton_iters"])
        work.append({
            "C_frac": C_frac, "colsp_pct": _sparsity(Xs),
            "num_active_after_pass1": int(st["num_active"]),
            "full_cols": int(st["full_cols"]),
            "active_cols_final_step": int(st["active_cols_per_step"]),
            "newton_iters": iters,
            "work_cols": int(st["work_cols"]),
            "work_cols_no_shrink": int(st0["work_cols"]),
            "avg_cols_per_step": int(st["work_cols"]) / iters,
            "bytes_final_step": int(st["active_cols_per_step"]) * n_pad * 4,
            "bytes_per_step_no_shrink": int(st0["full_cols"]) * n_pad * 4,
        })
        rows.append((f"engine/work_cols@{wn}x{wm}", float(st["work_cols"]),
                     f"C_frac={C_frac};no_shrink={int(st0['work_cols'])}"))
    payload["work_counter"] = work

    # ---- (c) packed multi-tensor batching vs per-matrix launches ---------
    pm = {f"w{i}": jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)
          for i in range(6)}
    specs = (ProjectionSpec(pattern=r"w\d", norm="l1inf", radius=1.0),)
    state0 = init_projection_state(pm, specs)

    engine_counters_reset()
    ref = apply_constraints(pm, specs)
    packed, _ = apply_constraints_packed(pm, specs, state=state0)
    counts = engine_counters()
    max_diff = max(float(jnp.max(jnp.abs(ref[k] - packed[k]))) for k in pm)

    per_fn = jax.jit(lambda p: apply_constraints(p, specs))
    packed_fn = jax.jit(lambda p, s: apply_constraints_packed(p, specs,
                                                              state=s))
    # production configurations: the per-matrix path has no warm-start
    # threading (the "before"); the packed path runs warm-started from the
    # previous step's theta state (the "after"). Cold packed also reported.
    _, state1 = packed_fn(pm, state0)
    jax.block_until_ready(state1)
    per_us = _time_call(
        lambda: jax.block_until_ready(per_fn(pm)), reps)
    packed_cold_us = _time_call(
        lambda: jax.block_until_ready(packed_fn(pm, state0)), reps)
    packed_warm_us = _time_call(
        lambda: jax.block_until_ready(packed_fn(pm, state1)), reps)
    payload["packed"] = {
        "matrices": len(pm),
        "launches_per_step_per_matrix": counts.get("per_leaf", 0),
        "launches_per_step_packed": sum(
            v for k, v in counts.items() if k != "per_leaf"),
        "max_abs_diff": max_diff,
        "per_matrix_us": per_us,
        "packed_cold_us": packed_cold_us,
        "packed_warm_us": packed_warm_us,
        "ratio_packed_vs_per_matrix": packed_warm_us / per_us,
    }
    rows.append(("engine/packed_ratio", packed_warm_us / per_us,
                 f"per_matrix_us={per_us:.1f};packed_warm_us="
                 f"{packed_warm_us:.1f};max_diff={max_diff:.2e}"))

    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    return rows


def families_report(quick: bool = True,
                    out_path: str = "BENCH_families.json") -> List[Row]:
    """Per-constraint-family sweep (PR 4, extended in PR 10): plain vs
    weighted vs bilevel vs l1,2 at the three sparsity regimes, a Hoyer
    per-leaf timing row, the mixed-family packed contract (one engine
    launch per family sub-buffer), and the fused-vs-unfused l1,2 projected
    step (the scale-mode two-pass fold, DESIGN.md §14). Writes ``out_path``
    for CI; ``scripts/check.sh --bench-smoke`` gates bilevel <= 1.0x and
    l1,2 <= 1.0x plain at the high-sparsity regime (both solves are
    sort-free, so they must never lose to the exact solver where columns
    die in droves) and the fused l1,2 step <= 0.85x its unfused twin.
    """
    from repro.core import (hoyer_sparseness, project_bilevel, project_hoyer,
                            project_l1inf_weighted, project_l12_newton,
                            ProjectionEngine)
    from repro.optim.adam import AdamConfig, adam_init
    from .fused_step_bench import _time_pair

    rng = np.random.default_rng(17)
    reps = 30 if quick else 80
    n, m = (256, 512) if quick else (1024, 2048)
    payload: dict = {"meta": bench_meta(quick=quick, shape=[n, m])}
    rows: List[Row] = []

    scale = np.exp(rng.normal(size=(1, m)))
    Y = jnp.asarray(rng.uniform(0, 1, size=(n, m)) * scale, jnp.float32)
    w = jnp.asarray(np.exp(0.3 * rng.normal(size=(m,))), jnp.float32)
    norm = float(np.abs(np.asarray(Y)).max(axis=0).sum())
    norm_l12 = float(np.linalg.norm(np.asarray(Y), axis=0).sum())

    regimes = []
    for C_frac in (0.5, 0.1, 0.01):
        C = C_frac * norm
        C12 = C_frac * norm_l12
        plain_us = _time_call(
            lambda: project_l1inf_newton(Y, C).block_until_ready(), reps)
        weighted_us = _time_call(
            lambda: project_l1inf_weighted(Y, w, C).block_until_ready(),
            reps)
        bilevel_us = _time_call(
            lambda: project_bilevel(Y, C).block_until_ready(), reps)
        l12_us = _time_call(
            lambda: project_l12_newton(Y, C12).block_until_ready(), reps)
        colsp_plain = _sparsity(project_l1inf_newton(Y, C))
        colsp_weighted = _sparsity(project_l1inf_weighted(Y, w, C))
        colsp_bi = _sparsity(project_bilevel(Y, C))
        colsp_l12 = _sparsity(project_l12_newton(Y, C12))
        regimes.append({
            "C_frac": C_frac,
            "colsp_plain_pct": colsp_plain,
            "colsp_weighted_pct": colsp_weighted,
            "colsp_bilevel_pct": colsp_bi,
            "colsp_l12_pct": colsp_l12,
            "plain_us": plain_us, "weighted_us": weighted_us,
            "bilevel_us": bilevel_us, "l12_us": l12_us,
            "ratio_bilevel_vs_plain": bilevel_us / plain_us,
            "ratio_weighted_vs_plain": weighted_us / plain_us,
            "ratio_l12_vs_plain": l12_us / plain_us,
        })
        for fam, us, sp in (("plain", plain_us, colsp_plain),
                            ("weighted", weighted_us, colsp_weighted),
                            ("bilevel", bilevel_us, colsp_bi),
                            ("l12", l12_us, colsp_l12)):
            rows.append((f"families/{fam}@{n}x{m}", us,
                         f"C_frac={C_frac};colsp={sp:.1f}%"))
    payload["regimes"] = regimes

    # ---- Hoyer (per-leaf only, DESIGN.md §14): no packed/ratio gate, a
    # timing row keeps the alternating solve's cost visible in CI history
    hoyer_s = 0.75
    hoyer_us = _time_call(
        lambda: project_hoyer(Y, hoyer_s).block_until_ready(), reps)
    Xh = project_hoyer(Y, hoyer_s)
    payload["hoyer"] = {
        "s": hoyer_s, "us": hoyer_us,
        "min_sigma": float(jnp.min(hoyer_sparseness(Xh))),
    }
    rows.append((f"families/hoyer@{n}x{m}", hoyer_us,
                 f"s={hoyer_s};min_sigma={payload['hoyer']['min_sigma']:.3f}"))

    # ---- mixed-family packed contract: one launch per family sub-buffer --
    params = {
        "a": jnp.asarray(rng.normal(size=(64, 128)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(4, 32, 128)), jnp.float32),
        "c": jnp.asarray(rng.normal(size=(64, 128)), jnp.float32),
    }
    specs = (ProjectionSpec(pattern=r"^a$", norm="l1inf", radius=2.0),
             ProjectionSpec(pattern=r"^b$", norm="bilevel", radius=1.5),
             ProjectionSpec(pattern=r"^c$", norm="l1inf_weighted",
                            radius=3.0))
    eng = ProjectionEngine(specs)
    state0 = eng.init_state(params)
    engine_counters_reset()
    out, state1 = eng.apply(params, state=state0)
    counts = engine_counters()
    ref = apply_constraints(params, specs)
    max_diff = max(float(jnp.max(jnp.abs(ref[k] - out[k]))) for k in params)
    mixed_fn = jax.jit(lambda p, s: eng.apply(p, state=s))
    jax.block_until_ready(mixed_fn(params, state1))
    mixed_us = _time_call(
        lambda: jax.block_until_ready(mixed_fn(params, state1)), reps)
    payload["mixed"] = {
        "families": sorted(p.family for p in eng.plans(params)[0]),
        "launches": {k: v for k, v in counts.items() if k != "per_leaf"},
        "one_launch_per_family": all(
            v == 1 for k, v in counts.items() if k != "per_leaf"),
        "max_abs_diff_vs_per_leaf": max_diff,
        "mixed_packed_warm_us": mixed_us,
    }
    rows.append(("families/mixed_packed", mixed_us,
                 f"launches={len(payload['mixed']['launches'])};"
                 f"max_diff={max_diff:.2e}"))

    # ---- fused l1,2 projected step: scale-mode two-pass fold vs the
    # unfused adam -> pack -> solve -> unpack step on the same SAE-shaped
    # pair (encoder leaf + axis=1 stack, where the packer's physical
    # transpose hurts most). Same interleaved-timing methodology as
    # BENCH_fused_step.json; check.sh gates ratio <= 0.85.
    fn_, fm_, lead = (256, 1024, 2) if quick else (512, 2048, 4)
    freps = 15 if quick else 20
    key = jax.random.PRNGKey(7)
    fparams = {
        "enc1": {"w": jax.random.normal(jax.random.fold_in(key, 0),
                                        (fn_, fm_))},
        "blocks": {"w": jax.random.normal(jax.random.fold_in(key, 1),
                                          (lead, fn_, fm_))},
    }
    fgrads = jax.tree_util.tree_map(
        lambda p: 0.01 * jax.random.normal(jax.random.fold_in(key, 2),
                                           p.shape), fparams)
    acfg = AdamConfig(lr=1e-3)
    fC = 0.1 * float(jnp.linalg.norm(fparams["enc1"]["w"], axis=0).sum())
    fspecs = (ProjectionSpec(pattern=r"enc1/w", norm="l12", radius=fC),
              ProjectionSpec(pattern=r"blocks/w", norm="l12", radius=fC,
                             axis=1))
    fout = {}
    for solver in ("newton", "fused"):
        feng = ProjectionEngine(fspecs, solver=solver)
        opt = adam_init(fparams, acfg)
        fst = feng.init_state(fparams)
        fstep = jax.jit(lambda g, o, p, s, e=feng: e.projected_update(
            g, o, p, acfg, state=s))
        p1, o1, s1 = fstep(fgrads, opt, fparams, fst)
        p1, o1, s1 = fstep(fgrads, o1, p1, s1)    # settle the warm start
        jax.block_until_ready(p1)
        fout[solver] = {
            "call": (lambda g=fgrads, o=o1, p=p1, s=s1, f=fstep:
                     jax.block_until_ready(f(g, o, p, s))),
            "params": fstep(fgrads, o1, p1, s1)[0],
        }
    unfused_us, fused_us = _time_pair(fout["newton"]["call"],
                                      fout["fused"]["call"], freps)
    fused_diff = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(
        jax.tree_util.tree_leaves(fout["newton"]["params"]),
        jax.tree_util.tree_leaves(fout["fused"]["params"])))
    payload["l12_fused"] = {
        "shape": [lead, fn_, fm_], "C_frac": 0.1,
        "unfused_us": unfused_us, "fused_us": fused_us,
        "ratio": fused_us / unfused_us,
        "max_abs_diff": fused_diff,
    }
    rows.append(("families/l12_fused_step", fused_us,
                 f"ratio={fused_us / unfused_us:.3f};"
                 f"max_diff={fused_diff:.2e}"))

    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    return rows


def dist_engine_report(quick: bool = True,
                       out_path: str = "BENCH_dist_proj.json") -> List[Row]:
    """Sharded-vs-replicated packed projection on an 8-way host-device mesh.

    Runs ``benchmarks.dist_proj_bench`` in a subprocess (the device count
    must be set before jax initializes; the parent stays 1-device), loads
    the JSON it writes, and reports the headline rows. CI uploads
    ``out_path`` and ``scripts/check.sh --bench-smoke`` gates on it.
    """
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=8").strip()
    cmd = [sys.executable, "-m", "benchmarks.dist_proj_bench",
           "--out", out_path] + (["--quick"] if quick else [])
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(
            f"dist_proj_bench failed (exit {proc.returncode}):\n"
            f"{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")
    with open(out_path) as f:
        d = json.load(f)
    rows: List[Row] = [
        ("dist/replicated", d["replicated_us"],
         f"devices={d['meta']['device_count']};"
         f"allgather={d['collectives']['replicated']['all-gather']}"),
        ("dist/sharded", d["sharded_us"],
         f"ratio={d['ratio_sharded_vs_replicated']:.2f};"
         f"allgather={d['collectives']['sharded']['all-gather']};"
         f"max_diff={d['max_abs_diff']:.2e}"),
    ]
    return rows


def dist_fused_report(quick: bool = True,
                      out_path: str = "BENCH_dist_fused.json") -> List[Row]:
    """Fused-sharded vs unfused-sharded projected step on an 8-way
    host-device mesh (DESIGN.md §12).

    Runs ``benchmarks.dist_fused_bench`` in a subprocess (the device count
    must be set before jax initializes; the parent stays 1-device), loads
    the JSON it writes, and reports the headline rows. CI uploads
    ``out_path`` and ``scripts/check.sh --bench-smoke`` gates on it
    (fused_sharded <= 0.85x unfused wall time, params <= 1e-5).
    """
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=8").strip()
    cmd = [sys.executable, "-m", "benchmarks.dist_fused_bench",
           "--out", out_path] + (["--quick"] if quick else [])
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(
            f"dist_fused_bench failed (exit {proc.returncode}):\n"
            f"{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")
    with open(out_path) as f:
        d = json.load(f)
    rows: List[Row] = [
        ("dist/unfused_sharded", d["sharded_us"],
         f"devices={d['meta']['device_count']};"
         f"alltoall={d['collectives']['sharded']['all-to-all']}"),
        ("dist/fused_sharded", d["fused_sharded_us"],
         f"ratio={d['ratio_fused_vs_sharded']:.2f};"
         f"allgather={d['collectives']['fused_sharded']['all-gather']};"
         f"max_diff={d['max_abs_diff']:.2e}"),
    ]
    return rows


def jax_variants(n: int = 512, m: int = 512) -> List[Row]:
    """Beyond-paper: the TPU-adapted variants incl. the Pallas sort-free path
    (interpret mode on CPU — structural comparison, not TPU wall-time)."""
    rng = np.random.default_rng(3)
    Y = rng.uniform(0, 1, size=(n, m))
    rows: List[Row] = []
    for C in (0.1, 2.0):
        sp = _sparsity(project_l1inf_heap(Y, C))
        for name, fn in JAX_METHODS:
            rows.append((f"jaxvar/{name}", _time_jax(fn, Y, C),
                         f"C={C};colsp={sp:.1f}%"))
        us = _time_jax(lambda Yj, C=C: project_l1inf_pallas(
            Yj, C, interpret=True), Y, C, reps=1)
        rows.append((f"jaxvar/pallas_interp", us, f"C={C};colsp={sp:.1f}%"))
    return rows
