"""Projection benchmarks — paper Figs. 1-3 (+ JAX/TPU-variant comparison).

Each function returns rows: (name, us_per_call, derived) where `derived`
carries the figure's x-axis context (radius, sparsity, size).
"""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (project_l1inf_heap, project_l1inf_naive,
                        project_l1inf_quattoni, project_l1inf_bejar,
                        project_l1inf_newton_np, project_l1inf_newton,
                        project_l1inf_sorted)
from repro.kernels.l1inf import project_l1inf_pallas

Row = Tuple[str, float, str]


def _time_np(fn: Callable, Y, C, reps: int = 3) -> float:
    fn(Y, C)  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        fn(Y, C)
    return (time.perf_counter() - t0) / reps * 1e6


def _time_jax(fn: Callable, Y, C, reps: int = 5) -> float:
    Yj = jnp.asarray(Y, jnp.float32)
    fn(Yj, C).block_until_ready()  # compile+warm
    t0 = time.perf_counter()
    for _ in range(reps):
        fn(Yj, C).block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def _sparsity(X) -> float:
    X = np.asarray(X)
    return 100.0 * float((np.abs(X).max(axis=0) <= 1e-12).mean())


CPU_METHODS = [
    ("heap[paper-Alg2]", project_l1inf_heap),
    ("newton_np[Chu-class]", project_l1inf_newton_np),
    ("quattoni[total-order]", project_l1inf_quattoni),
    ("bejar[elim+naive]", project_l1inf_bejar),
]

JAX_METHODS = [
    ("jax_newton", lambda Y, C: project_l1inf_newton(Y, C)),
    ("jax_sorted", lambda Y, C: project_l1inf_sorted(Y, C)),
]


def fig1_radius_sweep(n: int = 1000, m: int = 1000,
                      radii=(0.001, 0.01, 0.1, 1.0, 4.0, 8.0),
                      include_slow: bool = False) -> List[Row]:
    """Fig. 1: projection time vs radius (sparsity decreases with radius)."""
    rng = np.random.default_rng(0)
    Y = rng.uniform(0, 1, size=(n, m))
    rows: List[Row] = []
    for C in radii:
        Xref = project_l1inf_heap(Y, C)
        sp = _sparsity(Xref)
        for name, fn in CPU_METHODS:
            if fn is project_l1inf_naive and not include_slow:
                continue
            us = _time_np(fn, Y, C)
            rows.append((f"fig1/{name}", us, f"C={C};colsp={sp:.1f}%"))
        for name, fn in JAX_METHODS:
            us = _time_jax(fn, Y, C)
            rows.append((f"fig1/{name}", us, f"C={C};colsp={sp:.1f}%"))
    return rows


def fig2_shape_sweep() -> List[Row]:
    """Fig. 2: 1000x10000 and 10000x1000 at a few radii."""
    rng = np.random.default_rng(1)
    rows: List[Row] = []
    for (n, m) in ((1000, 10000), (10000, 1000)):
        Y = rng.uniform(0, 1, size=(n, m))
        for C in (0.1, 1.0, 4.0):
            sp = _sparsity(project_l1inf_heap(Y, C))
            for name, fn in CPU_METHODS:
                us = _time_np(fn, Y, C, reps=2)
                rows.append((f"fig2/{name}@{n}x{m}", us,
                             f"C={C};colsp={sp:.1f}%"))
    return rows


def fig3_size_growth() -> List[Row]:
    """Fig. 3: growth with fixed n (left) and fixed m (right), C=1."""
    rng = np.random.default_rng(2)
    rows: List[Row] = []
    for m in (500, 1000, 2000, 4000):
        Y = rng.uniform(0, 1, size=(1000, m))
        for name, fn in CPU_METHODS:
            rows.append((f"fig3/fixed_n/{name}@1000x{m}",
                         _time_np(fn, Y, 1.0, reps=2), "C=1"))
    for n in (500, 1000, 2000, 4000):
        Y = rng.uniform(0, 1, size=(n, 1000))
        for name, fn in CPU_METHODS:
            rows.append((f"fig3/fixed_m/{name}@{n}x1000",
                         _time_np(fn, Y, 1.0, reps=2), "C=1"))
    return rows


def jax_variants(n: int = 512, m: int = 512) -> List[Row]:
    """Beyond-paper: the TPU-adapted variants incl. the Pallas sort-free path
    (interpret mode on CPU — structural comparison, not TPU wall-time)."""
    rng = np.random.default_rng(3)
    Y = rng.uniform(0, 1, size=(n, m))
    rows: List[Row] = []
    for C in (0.1, 2.0):
        sp = _sparsity(project_l1inf_heap(Y, C))
        for name, fn in JAX_METHODS:
            rows.append((f"jaxvar/{name}", _time_jax(fn, Y, C),
                         f"C={C};colsp={sp:.1f}%"))
        us = _time_jax(lambda Yj, C=C: project_l1inf_pallas(
            Yj, C, interpret=True), Y, C, reps=1)
        rows.append((f"jaxvar/pallas_interp", us, f"C={C};colsp={sp:.1f}%"))
    return rows
