"""Compacted-vs-dense SAE serving benchmark -> ``BENCH_serve.json``.

Builds a projected SAE checkpoint at the paper's ~99% column-sparsity
regime (the radius is bisected until ~1% of the encoder's feature columns
survive — no training needed, the support structure is the projection's),
compacts it with ``repro.sae.serve.compact_sae``, and measures:

  * GEMM FLOPs, analytic: the encoder GEMM shrinks from 2*B*d*h to
    2*B*J*h, i.e. exactly the compaction ratio J/d (the decoder output
    GEMM co-compacts identically). ``scripts/check.sh --bench-smoke``
    gates compact/dense encoder FLOPs <= 0.25x — at the ~99% regime the
    measured ratio is ~0.01, so the gate holds ~25x headroom;
  * GEMM FLOPs as XLA costs them (``compiled.cost_analysis()``), reported
    when the backend exposes them (informational — backends differ);
  * wall latency of the jit'd dense vs compact serving step (reported,
    not gated: CPU timing noise at smoke scale);
  * exactness: logits everywhere and reconstruction on the support must
    match to fp summation order (gated <= 1e-4).

Schema documented in benchmarks/README.md; CI uploads the JSON artifact.
"""
from __future__ import annotations

import json
import time
from typing import List, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ProjectionSpec, apply_constraints
from repro.sae import sae_init, sae_apply, SAEConfig, compact_sae
from repro.sae.serve import make_serve_step

from .run import bench_meta

Row = Tuple[str, float, str]


def _alive_frac(params, spec) -> float:
    """Fraction of surviving columns (reduction axis = the spec's max axis)."""
    w = np.asarray(params["enc1"]["w"])
    return float(np.any(w != 0, axis=spec.axis).mean())


def _project_to_regime(params, target_alive: float, *, axis: int = 1,
                       iters: int = 18):
    """Bisect the l1,inf radius until <= ``target_alive`` of the encoder's
    feature columns survive the projection (paper's ~99% colsp regime)."""
    w = params["enc1"]["w"]
    hi = float(jnp.sum(jnp.max(jnp.abs(w), axis=axis)))  # inside-ball bound
    probe = ProjectionSpec(pattern=r"enc1/w", norm="l1inf", radius=hi,
                           axis=axis)
    assert _alive_frac(params, probe) > target_alive, "regime trivially met"
    lo = 0.0
    spec = None
    for _ in range(iters):
        C = 0.5 * (lo + hi)
        cand = ProjectionSpec(pattern=r"enc1/w", norm="l1inf", radius=C,
                              axis=axis)
        projected = apply_constraints(params, (cand,))
        if _alive_frac(projected, cand) > target_alive:
            hi = C
        else:
            lo, spec = C, cand
    if spec is None:  # degenerate tiny shapes: keep the last candidate
        spec = cand
    return apply_constraints(params, (spec,)), spec


def _time_call(fn, reps: int) -> float:
    fn()  # compile + warm
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def _xla_flops(jitted, *args):
    """FLOPs as the backend's cost model reports them, or None."""
    try:
        ca = jitted.lower(*args).compile().cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return float(ca["flops"]) if ca and "flops" in ca else None
    except Exception:
        return None


def serve_report(quick: bool = True, out: str = "BENCH_serve.json"
                 ) -> List[Row]:
    d, h, k, B = (2048, 64, 2, 256) if quick else (10_000, 96, 2, 1024)
    reps = 20 if quick else 50
    cfg = SAEConfig(n_features=d, n_hidden=h, n_classes=k)
    params = sae_init(jax.random.PRNGKey(0), cfg)
    params, spec = _project_to_regime(params, target_alive=0.01)

    compact = compact_sae(params, (spec,))
    J = compact.n_selected
    colsp = 100.0 * (1.0 - J / d)

    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(B, d)), jnp.float32)

    dense_step = jax.jit(sae_apply)
    compact_step = make_serve_step(compact)

    # exactness on the support
    z_d, xh_d = dense_step(params, x)
    z_c, xh_c = compact_step(compact.params, x)
    diff_z = float(jnp.abs(z_d - z_c).max())
    diff_xh = float(jnp.abs(xh_d[:, compact.sel] - xh_c).max())

    us_dense = _time_call(
        lambda: jax.block_until_ready(dense_step(params, x)), reps)
    us_compact = _time_call(
        lambda: jax.block_until_ready(compact_step(compact.params, x)), reps)

    enc_dense = 2.0 * B * d * h
    enc_compact = 2.0 * B * J * h
    total_dense = 2.0 * B * (d * h + 2 * h * k + h * d)
    total_compact = 2.0 * B * (J * h + 2 * h * k + h * J)

    report = {
        "meta": bench_meta(quick=quick),
        "regime": {"d": d, "n_hidden": h, "n_classes": k, "batch": B,
                   "radius": spec.radius, "column_sparsity_pct": colsp},
        "compaction": {"n_selected": J, "ratio": compact.compaction_ratio},
        "flops": {
            "dense_encoder_gemm": enc_dense,
            "compact_encoder_gemm": enc_compact,
            "ratio_compact_vs_dense_encoder": enc_compact / enc_dense,
            "dense_total_gemm": total_dense,
            "compact_total_gemm": total_compact,
            "ratio_compact_vs_dense_total": total_compact / total_dense,
            "xla_dense": _xla_flops(dense_step, params, x),
            "xla_compact": _xla_flops(compact_step, compact.params, x),
        },
        "latency_us": {"dense": us_dense, "compact": us_compact,
                       "ratio_compact_vs_dense": us_compact / us_dense},
        "exactness": {"max_abs_diff_z": diff_z,
                      "max_abs_diff_xhat_on_support": diff_xh},
    }
    with open(out, "w") as f:
        json.dump(report, f, indent=2)

    ctx = f"colsp={colsp:.1f}%;J={J}/{d}"
    return [
        ("serve/dense_apply", us_dense, ctx),
        ("serve/compact_apply", us_compact,
         f"{ctx};flop_ratio={enc_compact / enc_dense:.4f}"),
    ]
