"""Continuous-batching fleet engine (serve/engine.py, DESIGN.md §13).

Covers the PR-9 contract: requests admitted into freed slots mid-flight
are token-identical to solo generation (extending the PR-6 ragged==solo
regression), the cache/slot donation no-copy argument, the bf16 cache
dtype fix and the truncation flag (satellites 1–2), the re-compaction
scheduler's hysteresis (no thrash at the threshold) and mid-flight
re-compaction bit-exactness (satellite 3), and the zero-retrace
lifecycle across admit/evict/refresh/recompact.
"""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.models.zoo import build
from repro.models.transformer import decode_step, init_cache
from repro.serve import (EngineConfig, FleetEngine, LatencyStats,
                         RecompactScheduler, compact_model)
from repro.train.serve import BatchServer, ServeConfig


def _tiny(n_layers=2, **over):
    """A gemma variant small enough for the single-core CI box: the same
    block layout (p0_global MLP) the compact specs match, tiny widths."""
    cfg = dataclasses.replace(
        get_reduced("gemma_7b"), n_layers=n_layers, d_model=64, d_ff=128,
        n_heads=2, n_kv_heads=1, head_dim=32, **over)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _kill_w1_columns(params, cols):
    """Zero the given w1 hidden columns (simulated projected training)."""
    out = jax.tree_util.tree_map(lambda a: a, params)
    mlp = out["blocks"]["p0_global"]["mlp"]
    arr = np.array(mlp["w1"])
    arr[:, :, list(cols)] = 0.0
    mlp["w1"] = jnp.asarray(arr)
    return out


def _solo(model, prompt, max_new, max_seq=32, **ecfg):
    """Solo reference: a fresh 1-slot engine serving one prompt."""
    eng = FleetEngine(model, 1, EngineConfig(max_seq=max_seq, **ecfg))
    eng.load(_solo.params)
    eng.submit(prompt, max_new)
    return eng.drain()[0].tokens


def test_midflight_admission_matches_solo():
    """Satellite 4: requests admitted into freed slots mid-flight produce
    token-identical outputs to solo generation — slot reuse must not leak
    the previous occupant's cache rows."""
    cfg, model, params = _tiny()
    _solo.params = params
    eng = FleetEngine(model, 2, EngineConfig(max_seq=32))
    eng.load(params)
    prompts = [[1, 2, 3], [4, 5], [7], [8, 9, 3, 1], [3, 1]]
    budgets = [6, 2, 2, 5, 3]          # heavy-tailed: slots churn
    rids = [eng.submit(p, n) for p, n in zip(prompts, budgets)]
    got = {c.rid: c for c in eng.drain()}
    assert eng.n_traces == 1
    assert eng.stats()["busy_slots"] == 0 and eng.stats()["queue"] == 0
    for p, n, r in zip(prompts, budgets, rids):
        assert len(got[r].generated) == n
        assert got[r].tokens == _solo(model, p, n), \
            f"rid {r} diverges from solo serving"


def test_bf16_cache_dtype_and_decode_parity():
    """Satellite 1: the KV cache follows the checkpoint dtype (bf16
    checkpoints no longer decode through a hard-coded f32 cache), and the
    engine's bf16 decode reproduces a hand cohort loop token for token."""
    cfg, model, params = _tiny(n_layers=1)
    bf16 = jax.tree_util.tree_map(
        lambda a: a.astype(jnp.bfloat16)
        if jnp.issubdtype(a.dtype, jnp.floating) else a, params)
    srv = BatchServer(model, batch_slots=2, scfg=ServeConfig(max_seq=32))
    srv.load(bf16)
    prompts = [[1, 2, 3], [4, 5]]
    outs = srv.generate(prompts, max_new=5)
    dtypes = {a.dtype for a in jax.tree_util.tree_leaves(srv.engine._cache)}
    assert dtypes == {jnp.bfloat16.dtype}, dtypes

    # hand cohort loop: scalar-pos decode_step on a bf16 cache
    B, Smax = 2, 32
    cache = init_cache(cfg, B, Smax, jnp.bfloat16)
    step = jax.jit(lambda p, c, t, pos: decode_step(p, c, t, pos, cfg))
    lens = [len(p) for p in prompts]
    out = [list(p) for p in prompts]
    feed = np.asarray([p[0] for p in prompts], np.int32)
    n_new = [0, 0]
    for pos in range(max(lens) + 5 - 1):
        logits, cache = step(bf16, cache, jnp.asarray(feed)[:, None],
                             jnp.asarray(pos))
        nxt = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1), np.int32)
        for i in range(B):
            if pos + 1 < lens[i]:
                feed[i] = out[i][pos + 1]
            elif n_new[i] < 5:
                out[i].append(int(nxt[i]))
                feed[i] = nxt[i]
                n_new[i] += 1
    assert outs == out

    # explicit override still wins
    srv32 = BatchServer(model, batch_slots=2,
                        scfg=ServeConfig(max_seq=32, cache_dtype=jnp.float32))
    srv32.load(bf16)
    srv32.generate(prompts, max_new=2)
    dtypes = {a.dtype for a in jax.tree_util.tree_leaves(srv32.engine._cache)}
    assert dtypes == {jnp.float32.dtype}


def test_truncation_flag_at_cache_boundary():
    """Satellite 2: a row whose prompt is long relative to max_seq gets
    fewer than max_new tokens — previously silent, now flagged. Boundary:
    maxlen + max_new - 1 > Smax."""
    cfg, model, params = _tiny(n_layers=1)
    srv = BatchServer(model, batch_slots=2, scfg=ServeConfig(max_seq=8))
    srv.load(params)
    outs, comps = srv.generate([[1, 2, 3, 4, 5], [1, 2]], max_new=6,
                               with_meta=True)
    # row 0: emits at pos 4..7 then runs out of cache depth -> 4 of 6
    assert len(outs[0]) == 5 + 4 and comps[0].truncated
    # row 1: emits at pos 1..6 -> full budget, no flag
    assert len(outs[1]) == 2 + 6 and not comps[1].truncated
    # flag is per-row: a fitting cohort-mate is never flagged by a
    # truncating neighbour, and the flagged row's tokens match the solo
    # prefix (truncation drops the tail, never corrupts the head)
    _solo.params = params
    assert outs[1] == _solo(model, [1, 2], 6, max_seq=8)


def test_scheduler_hysteresis_no_thrash():
    """Satellite 3a: a live/slot ratio hovering at the threshold fires the
    scheduler exactly once; re-firing needs a further `hysteresis` drop."""
    sched = RecompactScheduler(threshold=0.9, hysteresis=0.05)
    assert not sched.decide(0.95)          # above threshold: never
    assert sched.decide(0.89)              # first crossing fires
    hover = [0.895, 0.885, 0.89, 0.887, 0.893, 0.886]
    assert not any(sched.decide(r) for r in hover), "thrash at threshold"
    assert sched.decide(0.83)              # a real further drop re-fires
    assert sched.fires == 2
    assert sched.reslot_recommended(0.4)
    assert not sched.reslot_recommended(0.6)


def test_scheduler_drives_engine_recompact():
    """The engine's refresh upgrades itself to a recompact exactly when
    the scheduler fires, and the lifecycle never retraces."""
    cfg, model, params = _tiny()
    params = _kill_w1_columns(params, range(96))      # 32/128 live
    sched = RecompactScheduler(threshold=0.99, hysteresis=1 / 32)
    eng = FleetEngine(model, 2, EngineConfig(max_seq=32), scheduler=sched)
    eng.load_compact(params=params)
    w1 = "blocks/p0_global/mlp/w1"
    assert eng.compact.live[w1] == 32
    eng.submit([1, 2, 3], 4)
    eng.drain()
    assert eng.n_traces == 1

    # one more dead column -> ratio 31/32 crosses the threshold: recompact
    victim = int(eng.compact.sels[w1][0])
    params2 = _kill_w1_columns(params, [victim])
    assert eng.refresh(params2) is True
    assert sched.fires == 1 and eng.compact.live[w1] == 31

    # same checkpoint again: ratio unchanged -> plain refresh, no thrash
    assert eng.refresh(params2) is False
    assert sched.fires == 1
    eng.submit([4, 5], 4)
    eng.drain()
    assert eng.n_traces == 1


def test_midflight_recompact_bit_exact():
    """Satellite 3b: recompacting between steps with requests in flight is
    bit-exact vs pausing cohort-style — the ascending-prefix re-gather
    keeps the surviving GEMM terms in the same order, so the solo run
    that switches checkpoints at the same local depth matches exactly."""
    cfg, model, params = _tiny()
    params = _kill_w1_columns(params, range(96))
    w1 = "blocks/p0_global/mlp/w1"
    cm = compact_model(params, cfg.projection_specs)
    victim = int(cm.sels[w1][0])
    params2 = _kill_w1_columns(
        jax.tree_util.tree_map(lambda a: a * 1.25, params), [victim])

    switch_at = 3
    eng = FleetEngine(model, 3, EngineConfig(max_seq=32))
    eng.load_compact(params=params)
    prompts = [[1, 2, 3], [4, 5], [8, 9, 3, 1]]
    rids = [eng.submit(p, 6) for p in prompts]
    for _ in range(switch_at):
        eng.step()
    eng.recompact(params2)
    assert eng.compact.live[w1] == 31
    got = {c.rid: c.tokens for c in eng.drain()}
    assert eng.n_traces == 1, "mid-flight recompact must not retrace"

    for p, r in zip(prompts, rids):
        solo = FleetEngine(model, 1, EngineConfig(max_seq=32))
        solo.load_compact(params=params)
        solo.submit(p, 6)
        for _ in range(switch_at):
            solo.step()
        solo.recompact(params2)
        assert solo.drain()[0].tokens == got[r], f"rid {r} diverges"


def test_cache_and_slots_are_donated():
    """Tentpole no-copy argument: the compiled step aliases the cache and
    slot-state inputs to its outputs (donation), so steady-state decode
    performs no per-step HBM copy — the old buffers are invalidated."""
    cfg, model, params = _tiny(n_layers=1)
    eng = FleetEngine(model, 2, EngineConfig(max_seq=16))
    eng.load(params)
    assert "input_output_alias" in eng.step_hlo()
    eng.submit([1, 2, 3], 2)
    eng.step()
    old_leaf = jax.tree_util.tree_leaves(eng._cache)[0]
    eng.step()
    assert old_leaf.is_deleted(), "cache buffer survived donation"
    eng.flush()


def test_cancel_evicts_and_frees_slot():
    """cancel() retires an in-flight request (evicted=True, partial
    tokens) and its slot is re-admitted without a retrace."""
    cfg, model, params = _tiny(n_layers=1)
    _solo.params = params
    eng = FleetEngine(model, 1, EngineConfig(max_seq=32))
    eng.load(params)
    r0 = eng.submit([1, 2, 3], 8)
    r1 = eng.submit([4, 5], 3)          # queued behind the only slot
    for _ in range(4):
        eng.step()
    assert eng.cancel(r0)
    comps = {c.rid: c for c in eng.drain()}
    assert comps[r0].evicted and len(comps[r0].generated) < 8
    assert not comps[r1].evicted and len(comps[r1].generated) == 3
    assert comps[r1].tokens == _solo(model, [4, 5], 3)
    assert eng.n_traces == 1
    assert not eng.cancel(r1)           # already finished


def test_latency_stats_and_report():
    """LatencyStats percentiles and the engine's latency_report shape."""
    s = LatencyStats.from_samples([0.1, 0.2, 0.3])
    assert s.count == 3 and abs(s.p50 - 0.2) < 1e-12
    assert LatencyStats.from_samples([]).count == 0
    cfg, model, params = _tiny(n_layers=1)
    eng = FleetEngine(model, 2, EngineConfig(max_seq=16))
    eng.load(params)
    eng.submit([1, 2], 3)
    eng.drain()
    rep = eng.latency_report()
    assert rep["ttft"]["count"] == 1
    assert rep["per_token"]["count"] == 2      # 3 tokens -> 2 gaps
    assert rep["ttft"]["p50"] > 0


def test_submit_validation():
    """Prompt length and budget validation fail loudly at submit."""
    cfg, model, params = _tiny(n_layers=1)
    eng = FleetEngine(model, 1, EngineConfig(max_seq=8))
    eng.load(params)
    with pytest.raises(ValueError, match="prompt length"):
        eng.submit([], 4)
    with pytest.raises(ValueError, match="prompt length"):
        eng.submit(list(range(9)), 4)
    with pytest.raises(ValueError, match="max_new"):
        eng.submit([1], 0)
