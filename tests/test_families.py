"""Constraint-family registry: bi-level + weighted/masked families through
the ProjectionEngine.

Covers: registry semantics (lookup, norm ownership, re-registration), the
bi-level projection exact vs its sort-based reference on adversarial shapes
(n=1, m=1, ragged, ties, bf16) in the Newton, packed-segmented, and Pallas
solvers, weighted-family property tests (w=1 degeneracy, joint (w, C)
scaling invariance, KKT residuals), the masked family's single-solve
mask/projection consistency, and the mixed-family packing contract: one
engine invocation per (family, every_k) sub-buffer with per-family theta
warm starts threading through ``projected_update``.

The sharded twins of these checks (zero all-gathers, sharded == gathered
theta for bilevel/weighted) live in tests/test_multidevice.py.
"""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (ConstraintFamily, ProjectionEngine, ProjectionSpec,
                        apply_constraints, apply_constraints_packed,
                        build_packed_plans, engine_counters,
                        engine_counters_reset, family_for_norm, family_names,
                        get_family, init_projection_state, l1inf_norm,
                        l1inf_column_mask, l1inf_weighted_norm,
                        packable_norms, project_bilevel, project_bilevel_ref,
                        project_bilevel_stats, project_l1inf_masked,
                        project_l1inf_newton, project_l1inf_weighted,
                        project_segmented_family, register_family)
from repro.core.families import _REGISTRY, _NORM_TO_FAMILY


def _tol(a, b, tol=5e-6):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------

def test_registry_builtin_families_and_norms():
    assert set(family_names()) >= {"l1inf", "l1inf_weighted", "l1inf_masked",
                                   "bilevel"}
    assert family_for_norm("l1inf").name == "l1inf"
    assert family_for_norm("l1inf_sorted").name == "l1inf"   # alias norm
    assert family_for_norm("bilevel").name == "bilevel"
    assert family_for_norm("l1") is None                     # per-leaf only
    assert {"l1inf", "l1inf_sorted", "l1inf_weighted", "l1inf_masked",
            "bilevel"} <= packable_norms()
    with pytest.raises(ValueError, match="unknown constraint family"):
        get_family("nope")


def test_registry_norm_collision_rejected():
    fam = get_family("l1inf")
    thief = dataclasses.replace(fam, name="thief")
    with pytest.raises(ValueError, match="already served"):
        register_family(thief)
    assert "thief" not in _REGISTRY


def test_registry_reregistration_replaces():
    snapshot_reg = dict(_REGISTRY)
    snapshot_norms = dict(_NORM_TO_FAMILY)
    try:
        fam = ConstraintFamily(
            name="test_fam", norms=("test_norm", "test_norm2"),
            seg_ops=get_family("l1inf").seg_ops,
            norm_fn=lambda Y, axis=0, w=None: l1inf_norm(Y, axis=axis),
            project_leaf=lambda Y, C, axis=0, w=None:
                project_l1inf_newton(Y, C, axis=axis),
            reference=lambda Y, C, axis=0, w=None:
                project_l1inf_newton(Y, C, axis=axis))
        register_family(fam)
        assert family_for_norm("test_norm").name == "test_fam"
        assert family_for_norm("test_norm2").name == "test_fam"
        # replacement that DROPS a norm unbinds it
        register_family(dataclasses.replace(fam, norms=("test_norm",)))
        assert "test_fam" in family_names()
        assert family_for_norm("test_norm").name == "test_fam"
        assert family_for_norm("test_norm2") is None
    finally:
        _REGISTRY.clear(); _REGISTRY.update(snapshot_reg)
        _NORM_TO_FAMILY.clear(); _NORM_TO_FAMILY.update(snapshot_norms)


def test_spec_rejects_weights_on_weightless_norms():
    with pytest.raises(ValueError, match="does not take"):
        ProjectionSpec(pattern=r"w", norm="l1inf", radius=1.0,
                       weights=(1.0, 2.0))
    with pytest.raises(ValueError, match="does not take"):
        ProjectionSpec(pattern=r"w", norm="bilevel", radius=1.0,
                       weights=(1.0,))
    spec = ProjectionSpec(pattern=r"w", norm="l1inf_weighted", radius=1.0,
                          weights=(1.0, 2.5))
    assert spec.weights == (1.0, 2.5)
    with pytest.raises(ValueError, match="> 0"):
        ProjectionSpec(pattern=r"w", norm="l1inf_weighted", radius=1.0,
                       weights=(1.0, -2.0))


# ---------------------------------------------------------------------------
# bilevel: exact vs the sort-based reference on adversarial shapes
# ---------------------------------------------------------------------------

ADVERSARIAL = [
    ("square", (32, 32), np.float32),
    ("wide", (8, 200), np.float32),
    ("tall", (200, 8), np.float32),
    ("n1", (1, 64), np.float32),            # single row: u == |Y|
    ("m1", (50, 1), np.float32),            # single column
    ("ragged", (13, 37), np.float32),       # nothing lane-aligned
    ("bf16", (24, 48), jnp.bfloat16),
]


@pytest.mark.parametrize("name,shape,dtype", ADVERSARIAL,
                         ids=[a[0] for a in ADVERSARIAL])
def test_bilevel_newton_matches_reference(name, shape, dtype):
    rng = np.random.default_rng(hash(name) % 2**32)
    Y = jnp.asarray(rng.normal(size=shape), dtype)
    norm = float(l1inf_norm(Y.astype(jnp.float32)))
    tol = 2e-2 if dtype == jnp.bfloat16 else 5e-6
    for C_frac in (0.05, 0.5, 1.5):          # outside twice, inside once
        C = C_frac * norm
        _tol(project_bilevel(Y, C), project_bilevel_ref(Y, C), tol=tol)


def test_bilevel_ties_at_threshold():
    """Many columns with IDENTICAL maxima: the simplex threshold lands on a
    tie plateau; Newton must agree with the sort-based reference exactly."""
    rng = np.random.default_rng(3)
    Y = np.abs(rng.normal(size=(10, 40))).astype(np.float32)
    Y = Y / Y.max(axis=0, keepdims=True)    # every column max == 1.0
    Yj = jnp.asarray(Y)
    for C in (2.0, 20.0, 39.5, 40.0):
        _tol(project_bilevel(Yj, C), project_bilevel_ref(Yj, C))


def test_bilevel_feasibility_structure_and_gating():
    rng = np.random.default_rng(4)
    Y = jnp.asarray(rng.normal(size=(30, 60)), jnp.float32)
    C = 0.2 * float(l1inf_norm(Y))
    X = project_bilevel(Y, C)
    assert float(l1inf_norm(X)) <= C * (1 + 1e-5)        # feasible
    # column-structured: a column is either dead or elementwise-clipped Y
    Xn, An = np.asarray(X), np.abs(np.asarray(Y))
    dead = np.all(Xn == 0, axis=0)
    assert dead.any() and not dead.all()
    v = np.abs(Xn).max(axis=0)
    keep = ~dead
    np.testing.assert_allclose(
        Xn[:, keep], (np.sign(np.asarray(Y)) *
                      np.minimum(An, v[None, :]))[:, keep], atol=1e-6)
    # inside-ball identity; C <= 0 -> zero
    np.testing.assert_array_equal(
        np.asarray(project_bilevel(Y, 1e9)), np.asarray(Y))
    np.testing.assert_array_equal(np.asarray(project_bilevel(Y, 0.0)), 0.0)


def test_bilevel_warm_start_contract():
    rng = np.random.default_rng(5)
    Y = jnp.asarray(rng.normal(size=(40, 80)), jnp.float32)
    C = 0.1 * float(l1inf_norm(Y))
    X, st = project_bilevel_stats(Y, C)
    assert int(st["iters"]) > 2
    X2, st2 = project_bilevel_stats(Y, C, theta0=st["theta"])
    _tol(X, X2)
    assert int(st2["iters"]) <= 2            # exact restart: bootstrap only
    # stale OVERSHOOTING theta0 self-repairs to the exact answer
    X3, _ = project_bilevel_stats(Y, C, theta0=st["theta"] * 10.0)
    _tol(X, X3)


@pytest.mark.parametrize("name,shape,dtype", ADVERSARIAL,
                         ids=[a[0] for a in ADVERSARIAL])
def test_bilevel_segmented_matches_reference(name, shape, dtype):
    """The packed segmented solver (the engine's newton path) on a buffer
    holding the adversarial case next to a second ball."""
    rng = np.random.default_rng(hash(name) % 2**31)
    Y1 = rng.normal(size=shape).astype(np.float32)
    Y2 = rng.normal(size=(shape[0], 24)).astype(np.float32)
    n = shape[0]
    Yp = jnp.asarray(np.concatenate([Y1, Y2], axis=1), jnp.float32)
    sids = jnp.asarray(np.array([0] * shape[1] + [1] * 24, np.int32))
    C1 = 0.3 * float(np.abs(Y1).max(axis=0).sum())
    C2 = 0.5 * float(np.abs(Y2).max(axis=0).sum())
    X, theta, _ = project_segmented_family(
        Yp, sids, jnp.asarray([C1, C2], jnp.float32), num_segments=2,
        family="bilevel")
    _tol(np.asarray(X)[:, :shape[1]],
         project_bilevel_ref(jnp.asarray(Y1), C1), tol=5e-5)
    _tol(np.asarray(X)[:, shape[1]:],
         project_bilevel_ref(jnp.asarray(Y2), C2), tol=5e-5)


def test_bilevel_pallas_matches_reference():
    """The fused-kernel path (interpret mode off-TPU) on ragged + tied
    segments, incl. an inside-ball and a dead-pad segment."""
    from repro.kernels.l1inf import project_bilevel_pallas_segmented
    rng = np.random.default_rng(7)
    Y1 = rng.normal(size=(13, 37)).astype(np.float32)
    Y2 = (rng.normal(size=(13, 20)) * 0.01).astype(np.float32)  # inside
    pad = np.zeros((13, 7), np.float32)
    Yp = jnp.asarray(np.concatenate([Y1, Y2, pad], axis=1))
    sids = jnp.asarray(np.array([0] * 37 + [1] * 20 + [2] * 7, np.int32))
    C1 = 0.2 * float(np.abs(Y1).max(axis=0).sum())
    X, theta = project_bilevel_pallas_segmented(
        Yp, sids, jnp.asarray([C1, 100.0], jnp.float32), num_segments=2,
        interpret=True)
    _tol(np.asarray(X)[:, :37], project_bilevel_ref(jnp.asarray(Y1), C1),
         tol=5e-5)
    np.testing.assert_array_equal(np.asarray(X)[:, 37:57], Y2)  # identity
    np.testing.assert_array_equal(np.asarray(X)[:, 57:], 0.0)   # dummy seg
    assert float(theta[1]) == 0.0
    # warm restart converges in the bootstrap pair
    _, th2, st = project_bilevel_pallas_segmented(
        Yp, sids, jnp.asarray([C1, 100.0], jnp.float32), num_segments=2,
        theta0=theta, interpret=True, return_stats=True)
    assert int(st["newton_iters"]) <= 2


def test_bilevel_never_denser_than_exact_projection():
    """Structured-sparsity claim: at equal radius the bi-level operator
    kills at least the columns the exact projection kills (theta_bilevel
    >= mu-weighted death is implied by k=1 mass concentration)."""
    rng = np.random.default_rng(8)
    Y = jnp.asarray(rng.normal(size=(50, 100)), jnp.float32)
    C = 0.15 * float(l1inf_norm(Y))
    dead_exact = ~np.any(np.asarray(project_l1inf_newton(Y, C)), axis=0)
    dead_bi = ~np.any(np.asarray(project_bilevel(Y, C)), axis=0)
    assert dead_bi.sum() >= dead_exact.sum()


# ---------------------------------------------------------------------------
# weighted family: property tests (satellite)
# ---------------------------------------------------------------------------

def test_weighted_unit_weights_match_plain_newton():
    rng = np.random.default_rng(10)
    for shape in ((1, 32), (40, 1), (17, 53), (64, 64)):
        Y = jnp.asarray(rng.normal(size=shape), jnp.float32)
        w = jnp.ones((shape[1],), jnp.float32)
        for C_frac in (0.05, 0.4, 2.0):
            C = C_frac * float(l1inf_norm(Y))
            _tol(project_l1inf_weighted(Y, w, C),
                 project_l1inf_newton(Y, C), tol=1e-5)


def test_weighted_joint_scaling_invariance():
    """(w, C) -> (a*w, a*C) leaves B_w — and hence the projection —
    unchanged for any a > 0."""
    rng = np.random.default_rng(11)
    Y = jnp.asarray(rng.normal(size=(30, 48)), jnp.float32)
    w = jnp.asarray(np.exp(rng.normal(size=(48,))), jnp.float32)
    C = 0.3 * float(l1inf_weighted_norm(Y, w))
    X = project_l1inf_weighted(Y, w, C)
    for a in (0.1, 3.0, 250.0):
        _tol(X, project_l1inf_weighted(Y, a * w, a * C), tol=2e-5)


def test_weighted_kkt_residuals_random_weights():
    """KKT of min ||X-Y||_F^2 s.t. sum_j w_j max_i |X_ij| <= C: on the
    boundary there is one theta >= 0 with (a) per-column removal mass
    sum_i (|y|-mu_j)_+ == theta * w_j for surviving clipped columns,
    (b) dead columns have ||y_j||_1 <= theta * w_j, and (c) the constraint
    is tight."""
    rng = np.random.default_rng(12)
    Y = np.abs(rng.normal(size=(40, 60))).astype(np.float32)
    w = np.exp(rng.normal(size=(60,))).astype(np.float32)
    C = 0.25 * float((w * Y.max(axis=0)).sum())
    X = np.asarray(project_l1inf_weighted(jnp.asarray(Y), jnp.asarray(w), C))
    # (c) tight constraint
    np.testing.assert_allclose((w * np.abs(X).max(axis=0)).sum(), C,
                               rtol=1e-5)
    mu = np.abs(X).max(axis=0)
    clipped = mu > 0
    mass = np.maximum(Y - mu[None, :], 0.0).sum(axis=0)
    # (a) one shared theta across surviving columns: mass_j / w_j constant.
    # Columns where nothing is clipped (mu == colmax) carry zero mass and
    # are interior to their segment — exclude them.
    really_clipped = clipped & (mass > 1e-6)
    thetas = mass[really_clipped] / w[really_clipped]
    assert thetas.size > 0
    theta = np.median(thetas)
    np.testing.assert_allclose(thetas, theta, rtol=1e-4)
    # (b) dead columns are dominated at that theta
    dead = ~clipped
    assert np.all(Y.sum(axis=0)[dead] <= theta * w[dead] * (1 + 1e-5))


def test_weighted_spec_weight_length_validation():
    params = {"w": jnp.zeros((8, 10), jnp.float32)}
    specs = (ProjectionSpec(pattern=r"w", norm="l1inf_weighted", radius=1.0,
                            weights=tuple([1.0] * 7)),)     # wrong length
    with pytest.raises(ValueError, match="7 weights"):
        build_packed_plans(params, specs)


def test_weighted_packed_with_heterogeneous_weights():
    """The packed weighted solve (engine path with a real w_col vector)
    matches the per-leaf weighted solver."""
    rng = np.random.default_rng(13)
    params = {"a": jnp.asarray(rng.normal(size=(24, 30)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(3, 10, 20)), jnp.float32)}
    wa = tuple(float(x) for x in np.exp(rng.normal(size=(30,))))
    wb = tuple(float(x) for x in np.exp(rng.normal(size=(20,))))
    specs = (ProjectionSpec(pattern=r"a", norm="l1inf_weighted", radius=4.0,
                            weights=wa),
             ProjectionSpec(pattern=r"b", norm="l1inf_weighted", radius=2.0,
                            weights=wb))
    ref = apply_constraints(params, specs)
    out, state = apply_constraints_packed(params, specs)
    _tol(ref["a"], out["a"], tol=1e-5)
    _tol(ref["b"], out["b"], tol=1e-5)
    assert set(state) == {"l1inf_weighted_packed/k1"}
    assert state["l1inf_weighted_packed/k1"].shape == (4,)   # 1 + 3 stacked


# ---------------------------------------------------------------------------
# masked family: single-solve dedupe (satellite)
# ---------------------------------------------------------------------------

def test_masked_projection_and_mask_consistent():
    rng = np.random.default_rng(20)
    Y = jnp.asarray(rng.normal(size=(30, 50)), jnp.float32)
    C = 0.2 * float(l1inf_norm(Y))
    X = np.asarray(project_l1inf_masked(Y, C))
    alive = np.asarray(l1inf_column_mask(Y, C))
    # the two entry points share one solve: identical support decisions
    np.testing.assert_array_equal(np.any(X != 0, axis=0), alive)
    # surviving columns keep their ORIGINAL magnitudes (Eq. 20: no clip)
    np.testing.assert_array_equal(X[:, alive], np.asarray(Y)[:, alive])
    # and the support equals the true projection's support
    P = np.asarray(project_l1inf_newton(jnp.abs(Y), C))
    np.testing.assert_array_equal(alive, np.any(P > 0, axis=0))


def test_masked_inside_ball_mask_is_column_support():
    Y = jnp.asarray([[1.0, 0.0, 2.0], [0.5, 0.0, 0.1]], jnp.float32)
    alive = np.asarray(l1inf_column_mask(Y, 100.0))
    np.testing.assert_array_equal(alive, [True, False, True])
    np.testing.assert_array_equal(
        np.asarray(project_l1inf_masked(Y, 100.0)), np.asarray(Y))


def test_masked_packed_matches_per_leaf():
    rng = np.random.default_rng(21)
    params = {"w": jnp.asarray(rng.normal(size=(20, 40)), jnp.float32)}
    specs = (ProjectionSpec(pattern=r"w", norm="l1inf_masked", radius=2.0),)
    ref = apply_constraints(params, specs)
    out, state = apply_constraints_packed(params, specs)
    _tol(ref["w"], out["w"])
    assert set(state) == {"l1inf_masked_packed/k1"}


# ---------------------------------------------------------------------------
# mixed-family packing through the engine (tentpole acceptance)
# ---------------------------------------------------------------------------

def _mixed_params(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "enc1": {"w": jnp.asarray(rng.normal(size=(24, 50)), jnp.float32)},
        "blocks": {"mlp_w1": jnp.asarray(rng.normal(size=(3, 16, 40)),
                                         jnp.float32)},
        "dec": {"w": jnp.asarray(rng.normal(size=(50, 24)), jnp.bfloat16)},
        "gate": {"w": jnp.asarray(rng.normal(size=(20, 30)), jnp.float32)},
    }


MIXED_SPECS = (
    ProjectionSpec(pattern=r"enc1/w", norm="l1inf", radius=2.0, axis=1),
    ProjectionSpec(pattern=r"mlp_w1", norm="bilevel", radius=1.5),
    ProjectionSpec(pattern=r"dec/w", norm="l1inf_weighted", radius=3.0,
                   weights=tuple(1.0 + 0.05 * i for i in range(24))),
    ProjectionSpec(pattern=r"gate/w", norm="bilevel", radius=1.0),
)


def test_mixed_family_plans_one_subbuffer_per_family():
    params = _mixed_params()
    plans, per_leaf = build_packed_plans(params, MIXED_SPECS)
    assert not per_leaf
    by_fam = {p.family: p for p in plans}
    assert set(by_fam) == {"l1inf", "bilevel", "l1inf_weighted"}
    # both bilevel leaves (3 stacked + 1 plain) share ONE sub-buffer
    assert by_fam["bilevel"].num_segments == 4
    assert by_fam["l1inf"].num_segments == 1
    assert by_fam["l1inf_weighted"].num_segments == 1
    w_col = by_fam["l1inf_weighted"].col_weights()
    np.testing.assert_allclose(w_col[:24], np.asarray(MIXED_SPECS[2].weights))
    np.testing.assert_array_equal(w_col[24:], 1.0)           # lane padding


def test_mixed_family_matches_per_leaf_reference():
    params = _mixed_params(1)
    ref = apply_constraints(params, MIXED_SPECS)
    out, state = apply_constraints_packed(params, MIXED_SPECS)
    for r, o in zip(jax.tree_util.tree_leaves(ref),
                    jax.tree_util.tree_leaves(out)):
        _tol(r, o, tol=1e-4)                  # bf16 leaf dominates the tol
    assert out["dec"]["w"].dtype == jnp.bfloat16
    assert set(state) == {"l1inf_packed/k1", "bilevel_packed/k1",
                          "l1inf_weighted_packed/k1"}


def test_engine_counters_one_solve_per_family_subbuffer():
    """Tier-1 regression (satellite): a mixed-family spec list at one
    every_k records EXACTLY one engine invocation per family sub-buffer —
    the packing refactor must never silently split into per-leaf solves."""
    params = _mixed_params(2)
    engine_counters_reset()
    apply_constraints_packed(params, MIXED_SPECS)
    assert engine_counters() == {
        "l1inf_packed/k1/newton": 1,
        "bilevel_packed/k1/newton": 1,
        "l1inf_weighted_packed/k1/newton": 1,
    }
    # two every_k groups -> one solve per (family, every_k) pair
    specs2 = MIXED_SPECS[:2] + tuple(
        dataclasses.replace(s, every_k=4) for s in MIXED_SPECS[2:])
    engine_counters_reset()
    apply_constraints_packed(_mixed_params(3), specs2, step=jnp.asarray(4))
    assert engine_counters() == {
        "l1inf_packed/k1/newton": 1,
        "bilevel_packed/k1/newton": 1,
        "l1inf_weighted_packed/k4/newton": 1,
        "bilevel_packed/k4/newton": 1,
    }
    engine_counters_reset()


def test_mixed_family_theta_threads_through_projected_update():
    """Acceptance: mixed-family specs thread per-family theta warm starts
    through the unchanged ``projected_update`` signature; steady-state
    solves hit the bootstrap floor for every family."""
    from repro.optim import AdamConfig, adam_init

    params = _mixed_params(4)
    acfg = AdamConfig(lr=1e-3)
    opt = adam_init(params, acfg)
    eng = ProjectionEngine(MIXED_SPECS)
    state = eng.init_state(params)
    assert set(state) == {"l1inf_packed/k1", "bilevel_packed/k1",
                          "l1inf_weighted_packed/k1"}
    grads = jax.tree_util.tree_map(lambda p: 0.01 * jnp.ones_like(p), params)
    extra = []
    for _ in range(5):
        params, opt, state, stats = eng.projected_update(
            grads, opt, params, acfg, state=state, with_stats=True)
        extra.append({k: int(v) for k, v in stats.items()})
    assert all(v > 0 for v in extra[0].values())
    for k, v in extra[-1].items():
        assert v <= 3, (k, extra)             # warm across every family


def test_mixed_family_pallas_engine_matches_newton():
    params = _mixed_params(5)
    ref, _ = apply_constraints_packed(params, MIXED_SPECS, engine="newton")
    out, _ = apply_constraints_packed(params, MIXED_SPECS, engine="pallas")
    for r, o in zip(jax.tree_util.tree_leaves(ref),
                    jax.tree_util.tree_leaves(out)):
        _tol(r, o, tol=5e-4)


def test_mixed_family_under_jit():
    params = _mixed_params(6)
    state0 = init_projection_state(params, MIXED_SPECS)
    f = jax.jit(lambda p, s: apply_constraints_packed(
        p, MIXED_SPECS, step=jnp.asarray(1), state=s))
    out, st = f(params, state0)
    ref = apply_constraints(params, MIXED_SPECS, step=jnp.asarray(1))
    for r, o in zip(jax.tree_util.tree_leaves(ref),
                    jax.tree_util.tree_leaves(out)):
        _tol(r, o, tol=1e-4)
