"""Flash attention Pallas kernel vs jnp oracle (interpret mode) and vs the
models' chunked attention — shape/dtype/mask sweeps."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention, ref
from repro.models.attention import chunked_attention


def _mk(B, Sq, Skv, H, KV, hd, dtype, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, Sq, H, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Skv, KV, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Skv, KV, hd)), dtype)
    return q, k, v


@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 64)])
@pytest.mark.parametrize("H,KV", [(4, 4), (8, 2)])
def test_flash_vs_ref(causal, window, H, KV):
    B, Sq, Skv, hd = 2, 256, 256, 32
    q, k, v = _mk(B, Sq, Skv, H, KV, hd, jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=128, block_kv=128, interpret=True)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, Skv, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, Skv, hd)
    expect = ref.attention_ref(qf, kf, vf, groups=H // KV, causal=causal,
                               window=window)
    expect = expect.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 3e-5),
                                       (jnp.bfloat16, 3e-2)])
def test_flash_dtypes(dtype, tol):
    B, S, H, KV, hd = 1, 128, 2, 2, 64
    q, k, v = _mk(B, S, S, H, KV, hd, dtype, seed=3)
    out = flash_attention(q, k, v, causal=True, interpret=True)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * KV, S, hd)
    expect = ref.attention_ref(qf, kf, vf, groups=1, causal=True)
    expect = expect.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


def test_flash_matches_model_chunked_attention():
    """The kernel and the models' jnp chunked attention implement the same
    math (kernel = TPU drop-in for the dry-run execution path)."""
    B, S, KV, R, hd = 2, 256, 2, 3, 32
    H = KV * R
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(B, S, KV, R, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, KV, hd)), jnp.float32)
    out_chunked = chunked_attention(q, k, v, causal=True, window=32,
                                    q_chunk=64, kv_chunk=64)
    q2 = q.reshape(B, S, H, hd)
    out_flash = flash_attention(q2, k, v, causal=True, window=32,
                                block_q=64, block_kv=64, interpret=True)
    np.testing.assert_allclose(np.asarray(out_flash),
                               np.asarray(out_chunked.reshape(B, S, H, hd)),
                               atol=3e-5, rtol=3e-5)
