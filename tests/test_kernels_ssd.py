"""SSD Pallas kernel vs the naive-recurrence oracle and the model's chunked
scan; plus full-sequence vs step-by-step decode equivalence of the SSM."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.ssd import ssd_fwd, ssd_attention, ref


def _mk(BH, S, P, N, BG, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(BH, S, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 0.6, size=(BH, S)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2.0, size=(BH,)), jnp.float32)
    d = jnp.asarray(rng.normal(size=(BH,)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(BG, S, N)) * 0.5, jnp.float32)
    C = jnp.asarray(rng.normal(size=(BG, S, N)) * 0.5, jnp.float32)
    return x, dt, a, d, B, C


@pytest.mark.parametrize("S,chunk", [(64, 16), (128, 32), (96, 32)])
@pytest.mark.parametrize("groups", [1, 4])
def test_ssd_kernel_vs_naive_recurrence(S, chunk, groups):
    BG, P, N = 2, 8, 16
    BH = BG * groups
    x, dt, a, d, B, C = _mk(BH, S, P, N, BG)
    y, state = ssd_fwd(x, dt, a, d, B, C, chunk=chunk, groups=groups,
                       interpret=True)
    y_ref, state_ref = ref.ssd_ref(x, dt, a, d, B, C, groups=groups)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(state), np.asarray(state_ref),
                               atol=2e-4, rtol=2e-4)


def test_ssd_kernel_model_shape_wrapper():
    Bb, S, H, P, N = 2, 64, 4, 8, 16
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(Bb, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 0.5, size=(Bb, S, H)), jnp.float32)
    A_log = jnp.asarray(rng.uniform(-1, 0.5, size=(H,)), jnp.float32)
    D = jnp.asarray(rng.normal(size=(H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(Bb, S, N)) * 0.5, jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(Bb, S, N)) * 0.5, jnp.float32)
    y = ssd_attention(x, dt, A_log, D, Bm, Cm, chunk=16, interpret=True)
    assert y.shape == (Bb, S, H, P)
    # oracle through the flat layout
    xf = x.transpose(0, 2, 1, 3).reshape(Bb * H, S, P)
    dtf = dt.transpose(0, 2, 1).reshape(Bb * H, S)
    a = jnp.tile(-jnp.exp(A_log), Bb)
    dflat = jnp.tile(D, Bb)
    y_ref, _ = ref.ssd_ref(xf, dtf, a, dflat, Bm, Cm, groups=H)
    y_ref = y_ref.reshape(Bb, H, S, P).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-4)


def test_model_ssd_full_vs_decode_steps():
    """models/ssm.py: the chunked full-sequence scan must equal running the
    recurrent decode step token by token (same params, same cache math)."""
    from repro.models import ssm as SS
    from repro.models.param import materialize

    d, d_inner, n_state, headdim = 32, 64, 8, 8
    lay = SS.ssm_layout(d, d_inner, n_state, headdim)
    params = materialize(jax.random.PRNGKey(0), lay, jnp.float32)
    rng = np.random.default_rng(0)
    S = 24
    u = jnp.asarray(rng.normal(size=(2, S, d)) * 0.5, jnp.float32)

    y_full = SS.ssd_apply(params, u, headdim=headdim, chunk=8)

    cache = SS.ssm_init_cache(2, d_inner, n_state, headdim, jnp.float32)
    ys = []
    for t in range(S):
        y_t, cache = SS.ssd_decode(params, u[:, t:t + 1], cache,
                                   headdim=headdim)
        ys.append(y_t)
    y_steps = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_steps),
                               atol=3e-4, rtol=3e-3)
