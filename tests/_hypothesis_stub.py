"""Deterministic fallback for the subset of hypothesis this suite uses.

Installed by conftest.py ONLY when the real hypothesis package is missing
(the pinned container has no network): @given draws `max_examples` examples
from a fixed-seed PRNG instead of hypothesis' adaptive search. Property
tests still run as deterministic fuzz tests; install the real package
(`pip install -e .[test]`) to get shrinking and the full search strategy.

Supported surface: given(**kwargs), settings(max_examples, deadline),
strategies.integers/floats/booleans/sampled_from/lists.
"""
from __future__ import annotations

import functools
import inspect
import random
import types

_DEFAULT_MAX_EXAMPLES = 50
_SEED = 0x5EED_1F1F


class _Strategy:
    def __init__(self, draw):
        self.draw = draw  # (random.Random) -> value

    def map(self, fn):
        return _Strategy(lambda rng: fn(self.draw(rng)))

    def filter(self, pred):
        def draw(rng):
            for _ in range(1000):
                v = self.draw(rng)
                if pred(v):
                    return v
            raise RuntimeError("filter predicate never satisfied")
        return _Strategy(draw)


def integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def floats(min_value, max_value):
    def draw(rng):
        # hit the endpoints occasionally: they are the usual edge cases
        r = rng.random()
        if r < 0.05:
            return float(min_value)
        if r < 0.10:
            return float(max_value)
        return rng.uniform(min_value, max_value)
    return _Strategy(draw)


def booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: rng.choice(elements))


def lists(elem, min_size=0, max_size=10):
    def draw(rng):
        k = rng.randint(min_size, max_size)
        return [elem.draw(rng) for _ in range(k)]
    return _Strategy(draw)


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(**strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = random.Random(_SEED)
            for i in range(n):
                example = {k: s.draw(rng) for k, s in strategies.items()}
                try:
                    fn(*args, **kwargs, **example)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (#{i}): {example!r}") from e
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        # hide the consumed params from pytest's fixture resolution (real
        # hypothesis does the same): drop __wrapped__ so inspect.signature
        # doesn't recover the original argument list
        del wrapper.__wrapped__
        orig = inspect.signature(fn)
        keep = [p for name, p in orig.parameters.items()
                if name not in strategies]
        wrapper.__signature__ = orig.replace(parameters=keep)
        return wrapper
    return deco


def install(sys_modules):
    """Register stub modules under the 'hypothesis' names."""
    strategies_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "lists"):
        setattr(strategies_mod, name, globals()[name])
    root = types.ModuleType("hypothesis")
    root.given = given
    root.settings = settings
    root.strategies = strategies_mod
    root.__is_repro_stub__ = True
    sys_modules["hypothesis"] = root
    sys_modules["hypothesis.strategies"] = strategies_mod
