"""Roofline HLO parser: trip-count-aware flops/bytes/collective extraction
validated against analytically-known programs."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.roofline.hlo_parse import parse_hlo
from repro.roofline.analysis import parse_collectives, model_flops_for


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_dot_flops():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 64), jnp.float32)
    hc = parse_hlo(_hlo(lambda x, y: x @ y, a, b))
    expect = 2 * 128 * 256 * 64
    assert abs(hc.dot_flops - expect) / expect < 0.01, hc.dot_flops


def test_scan_multiplies_trip_count():
    """A matmul inside lax.scan must count TRIPS times (the cost_analysis
    undercount this parser exists to fix)."""
    TRIPS = 13
    w = jnp.zeros((TRIPS, 64, 64), jnp.float32)
    x = jnp.zeros((8, 64), jnp.float32)

    def fn(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        out, _ = jax.lax.scan(body, x, w)
        return out

    hc = parse_hlo(_hlo(fn, x, w))
    expect = TRIPS * 2 * 8 * 64 * 64
    assert hc.dot_flops >= expect * 0.99, (hc.dot_flops, expect, hc.trips)
    assert hc.dot_flops <= expect * 1.5, (hc.dot_flops, expect)
    assert any(t == TRIPS for t in hc.trips.values()), hc.trips


def test_nested_scan_multiplies():
    T1, T2 = 5, 7
    x = jnp.zeros((4, 32), jnp.float32)
    w = jnp.zeros((32, 32), jnp.float32)

    def fn(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=T2)
            return c2, None
        out, _ = jax.lax.scan(outer, x, None, length=T1)
        return out

    hc = parse_hlo(_hlo(fn, x, w))
    expect = T1 * T2 * 2 * 4 * 32 * 32
    assert hc.dot_flops >= expect * 0.99, (hc.dot_flops, expect, hc.trips)
    # XLA may hoist/unroll a bit, allow 2x
    assert hc.dot_flops <= expect * 2.0


def test_bytes_proxy_anchored_on_dots():
    """Byte accounting is anchored on dots/fusions/reduces: a matmul counts
    its operand+result traffic (standalone elementwise is assumed fused)."""
    a = jnp.zeros((512, 512), jnp.float32)
    hc = parse_hlo(_hlo(lambda x: (x @ x).sum(), a))
    n = 512 * 512 * 4
    # dot reads 2 operands + writes result (+ reduce reads it back)
    assert 3 * n <= hc.bytes_proxy <= 10 * n, hc.bytes_proxy


def test_collective_parse_synthetic():
    txt = """
HloModule test

ENTRY %main (p: f32[1024]) -> f32[1024] {
  %p = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %p), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[4096]{0} all-gather(f32[1024]{0} %ar), replica_groups=[4,4]<=[16], dimensions={0}
  ROOT %out = f32[1024]{0} add(f32[1024]{0} %ar, f32[1024]{0} %ar)
}
"""
    stats = parse_collectives(txt)
    assert stats.counts["all-reduce"] == 1
    assert stats.counts["all-gather"] == 1
    # all-reduce: 2*(3/4)*4096 bytes
    assert abs(stats.bytes_by_kind["all-reduce"] - 2 * 0.75 * 4096) < 1
    hc = parse_hlo(txt)
    assert hc.collective_counts["all-reduce"] == 1
    assert abs(hc.collective_moved["all-reduce"] - 2 * 0.75 * 4096) < 1


def test_model_flops_for():
    from repro.configs import get_config
    cfg = get_config("gemma_7b")
    f = model_flops_for(cfg, "train_4k", 8_500_000_000)
    assert abs(f - 6 * 8.5e9 * 4096 * 256) / f < 1e-6
    f_dec = model_flops_for(cfg, "decode_32k", 8_500_000_000)
    assert abs(f_dec - 2 * 8.5e9 * 128) / f_dec < 1e-6
