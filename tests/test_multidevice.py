"""Multi-device GSPMD semantics, run in a subprocess with 8 host devices
(the main test process keeps the default 1-device config).

Verifies: (a) the sharded train step matches the single-device step
numerically, (b) the dry-run machinery (lower+compile+roofline parse) works
end-to-end on a small mesh, (c) sequence-parallel decode matches unsharded.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _run_subprocess(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_sharded_train_step_matches_single_device():
    out = _run_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_reduced
        from repro.models.zoo import build, make_batch
        from repro.launch.steps import (build_train_step, param_shardings,
                                        batch_shardings, opt_shardings)
        from repro.dist.sharding import default_rules
        from repro.optim import AdamConfig, adam_init

        cfg = get_reduced("gemma_7b")
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = make_batch(cfg, 4, 32, kind="train")
        acfg = AdamConfig(lr=1e-3)
        opt = adam_init(params, acfg)

        # single device reference
        step_ref = build_train_step(model, None, None, acfg,
                                    with_projection=True)
        loss_ref, _, p_ref, _ = jax.jit(step_ref)(params, opt, batch)

        # 2x4 mesh
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        rules = default_rules()
        rules.update(dict(cfg.rules_overrides))
        p_sh = param_shardings(model, mesh, rules)
        params_s = jax.device_put(params, p_sh)
        opt_s = jax.device_put(opt, opt_shardings(p_sh, mesh))
        batch_s = jax.device_put(batch, batch_shardings(
            jax.tree_util.tree_map(lambda x: x, batch), mesh, rules))
        step = build_train_step(model, mesh, rules, acfg,
                                with_projection=True)
        with mesh:
            loss_s, _, p_s, _ = jax.jit(step)(params_s, opt_s, batch_s)

        print("LOSS", float(loss_ref), float(loss_s))
        assert abs(float(loss_ref) - float(loss_s)) < 2e-2, (
            float(loss_ref), float(loss_s))
        d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree_util.tree_leaves(p_ref),
                                jax.tree_util.tree_leaves(p_s)))
        print("MAXDIFF", d)
        assert d < 5e-2, d
        print("OK")
    """)
    assert "OK" in out


def test_dryrun_machinery_small_mesh():
    out = _run_subprocess("""
        import jax
        from repro.configs import get_reduced
        from repro.models.zoo import build
        from repro.launch.steps import lower_cell
        from repro.roofline.analysis import parse_collectives
        import repro.models.zoo as zoo

        # shrink one shape cell so it lowers fast on 8 devices
        zoo.SHAPES["train_4k"] = dict(seq=64, batch=8, kind="train")
        zoo.SHAPES["decode_32k"] = dict(seq=64, batch=8, kind="decode")

        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        for arch in ("gemma_7b", "mixtral_8x7b", "mamba2_370m"):
            cfg = get_reduced(arch)
            model = build(cfg)
            for shape in ("train_4k", "decode_32k"):
                cell = lower_cell(model, shape, mesh, False)
                compiled = cell.compile()
                ma = compiled.memory_analysis()
                cost = compiled.cost_analysis()
                cost = cost[0] if isinstance(cost, (list, tuple)) else cost
                stats = parse_collectives(compiled.as_text())
                assert cost.get("flops", 0) > 0, (arch, shape)
                print(arch, shape, "collectives:", stats.counts)
        print("OK")
    """)
    assert "OK" in out
    # sharded cells must actually communicate
    assert "all-reduce" in out or "all-gather" in out
