"""Multi-device GSPMD semantics, run in a subprocess with 8 host devices
(the main test process keeps the default 1-device config).

Verifies: (a) the sharded train step matches the single-device step
numerically, (b) the dry-run machinery (lower+compile+roofline parse) works
end-to-end on a small mesh, (c) the sharded packed projection keeps FSDP
shards resident (zero all-gathers in its HLO; theta equals the gathered
solve) and turning projection on adds no full-weight all-gather to the
production train cell.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _run_subprocess(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_sharded_train_step_matches_single_device():
    out = _run_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_reduced
        from repro.models.zoo import build, make_batch
        from repro.launch.steps import (build_train_step, param_shardings,
                                        batch_shardings, opt_shardings,
                                        projection_engine_for)
        from repro.dist.sharding import default_rules
        from repro.optim import AdamConfig, adam_init

        cfg = get_reduced("gemma_7b")
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = make_batch(cfg, 4, 32, kind="train")
        acfg = AdamConfig(lr=1e-3)
        opt = adam_init(params, acfg)

        # single device reference (solver: newton)
        engine_ref = projection_engine_for(cfg, None)
        proj0 = engine_ref.init_state(params)
        step_ref = build_train_step(model, None, None, acfg,
                                    with_projection=True)
        loss_ref, _, p_ref, _, _ = jax.jit(step_ref)(params, opt, proj0,
                                                     batch)

        # 2x4 mesh (solver: sharded — shard_map segmented Newton)
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        rules = default_rules()
        rules.update(dict(cfg.rules_overrides))
        p_sh = param_shardings(model, mesh, rules)
        params_s = jax.device_put(params, p_sh)
        opt_s = jax.device_put(opt, opt_shardings(p_sh, mesh))
        proj_s = jax.device_put(proj0, jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P()), proj0))
        batch_s = jax.device_put(batch, batch_shardings(
            jax.tree_util.tree_map(lambda x: x, batch), mesh, rules))
        step = build_train_step(model, mesh, rules, acfg,
                                with_projection=True)
        with mesh:
            loss_s, _, p_s, _, th_s = jax.jit(step)(params_s, opt_s, proj_s,
                                                    batch_s)

        print("LOSS", float(loss_ref), float(loss_s))
        assert abs(float(loss_ref) - float(loss_s)) < 2e-2, (
            float(loss_ref), float(loss_s))
        d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree_util.tree_leaves(p_ref),
                                jax.tree_util.tree_leaves(p_s)))
        print("MAXDIFF", d)
        assert d < 5e-2, d
        print("OK")
    """)
    assert "OK" in out


def test_dryrun_machinery_small_mesh():
    out = _run_subprocess("""
        import jax
        from repro.configs import get_reduced
        from repro.models.zoo import build
        from repro.launch.steps import lower_cell
        from repro.roofline.analysis import parse_collectives
        import repro.models.zoo as zoo

        # shrink one shape cell so it lowers fast on 8 devices
        zoo.SHAPES["train_4k"] = dict(seq=64, batch=8, kind="train")
        zoo.SHAPES["decode_32k"] = dict(seq=64, batch=8, kind="decode")

        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        for arch in ("gemma_7b", "mixtral_8x7b", "mamba2_370m"):
            cfg = get_reduced(arch)
            model = build(cfg)
            for shape in ("train_4k", "decode_32k"):
                cell = lower_cell(model, shape, mesh, False)
                compiled = cell.compile()
                ma = compiled.memory_analysis()
                cost = compiled.cost_analysis()
                cost = cost[0] if isinstance(cost, (list, tuple)) else cost
                stats = parse_collectives(compiled.as_text())
                assert cost.get("flops", 0) > 0, (arch, shape)
                print(arch, shape, "collectives:", stats.counts)
        print("OK")
    """)
    assert "OK" in out
    # sharded cells must actually communicate
    assert "all-reduce" in out or "all-gather" in out


def test_sharded_projection_keeps_shards_resident():
    """The sharded packed projection of FSDP-sharded leaves must contain NO
    all-gather in its lowered HLO (the reshard to the canonical column
    layout is an all-to-all), and its theta / outputs must equal the
    gathered single-buffer solve."""
    out = _run_subprocess("""
        import re
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import (ProjectionSpec, ProjectionEngine,
                                init_projection_state)

        rng = np.random.default_rng(0)
        params = {
            # FSDP style: rows (the max axis) sharded over "data"
            "blocks": {"w1": jnp.asarray(rng.normal(size=(4, 64, 256)),
                                         jnp.float32)},
            "enc": {"w": jnp.asarray(rng.normal(size=(128, 512)),
                                     jnp.float32)},
        }
        specs = (ProjectionSpec(pattern=r"w1$", norm="l1inf", radius=16.0),
                 ProjectionSpec(pattern=r"enc/w", norm="l1inf", radius=8.0))
        mesh = jax.make_mesh((8,), ("data",))
        sh = {
            "blocks": {"w1": NamedSharding(mesh, P(None, "data", None))},
            "enc": {"w": NamedSharding(mesh, P("data", None))},
        }
        params_s = jax.device_put(params, sh)
        state0 = init_projection_state(params, specs)

        eng = ProjectionEngine(specs, solver="sharded", mesh=mesh)
        fn = jax.jit(lambda p, s: eng.apply(p, state=s))
        with mesh:
            lowered = fn.lower(params_s, state0)
            hlo = lowered.compile().as_text()
        ags = [l for l in hlo.splitlines() if re.search(r"all-gather", l)]
        assert not ags, "projection HLO contains all-gather:\\n" + \
            "\\n".join(ags[:5])
        assert "all-to-all" in hlo  # the reshard really is an all-to-all

        with mesh:
            out_s, st_s = fn(params_s, state0)
        ref_eng = ProjectionEngine(specs)  # gathered single-buffer solve
        out_r, st_r = ref_eng.apply(params, state=state0)
        for a, b in zip(jax.tree_util.tree_leaves(out_r),
                        jax.tree_util.tree_leaves(out_s)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5, rtol=1e-5)
        k = list(st_r)[0]
        np.testing.assert_allclose(np.asarray(st_r[k]), np.asarray(st_s[k]),
                                   rtol=1e-6, atol=1e-6)
        print("THETA", np.asarray(st_s[k])[:3])
        print("OK")
    """)
    assert "OK" in out


def test_sharded_mixed_families_zero_allgather_and_match_gathered():
    """Family-registry acceptance: a mixed-family spec list (plain +
    weighted + bilevel) solved by the SHARDED engine keeps zero all-gathers
    in its HLO and matches the gathered per-family solves (theta included),
    with the weighted family's per-column weights sliced rank-locally."""
    out = _run_subprocess("""
        import re
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import (ProjectionSpec, ProjectionEngine,
                                init_projection_state)

        rng = np.random.default_rng(0)
        params = {
            "blocks": {"w1": jnp.asarray(rng.normal(size=(4, 64, 256)),
                                         jnp.float32)},
            "enc": {"w": jnp.asarray(rng.normal(size=(128, 512)),
                                     jnp.float32)},
            "dec": {"w": jnp.asarray(rng.normal(size=(64, 256)),
                                     jnp.float32)},
        }
        specs = (
            ProjectionSpec(pattern=r"w1$", norm="bilevel", radius=16.0),
            ProjectionSpec(pattern=r"enc/w", norm="l1inf", radius=8.0),
            ProjectionSpec(pattern=r"dec/w", norm="l1inf_weighted",
                           radius=8.0,
                           weights=tuple(1.0 + 0.01 * i
                                         for i in range(256))),
        )
        mesh = jax.make_mesh((8,), ("data",))
        sh = {
            "blocks": {"w1": NamedSharding(mesh, P(None, "data", None))},
            "enc": {"w": NamedSharding(mesh, P("data", None))},
            "dec": {"w": NamedSharding(mesh, P(None, "data"))},
        }
        params_s = jax.device_put(params, sh)
        state0 = init_projection_state(params, specs)

        eng = ProjectionEngine(specs, solver="sharded", mesh=mesh)
        fn = jax.jit(lambda p, s: eng.apply(p, state=s))
        with mesh:
            hlo = fn.lower(params_s, state0).compile().as_text()
        ags = [l for l in hlo.splitlines() if re.search(r"all-gather", l)]
        assert not ags, "projection HLO contains all-gather:\\n" + \
            "\\n".join(ags[:5])

        with mesh:
            out_s, st_s = fn(params_s, state0)
        ref = ProjectionEngine(specs)       # gathered per-family solves
        out_r, st_r = ref.apply(params, state=state0)
        for a, b in zip(jax.tree_util.tree_leaves(out_r),
                        jax.tree_util.tree_leaves(out_s)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5, rtol=1e-5)
        assert set(st_s) == {"bilevel_packed/k1", "l1inf_packed/k1",
                             "l1inf_weighted_packed/k1"}, sorted(st_s)
        for k in st_r:
            np.testing.assert_allclose(np.asarray(st_r[k]),
                                       np.asarray(st_s[k]),
                                       rtol=1e-6, atol=1e-6)
        print("OK")
    """)
    assert "OK" in out


def test_sharded_l12_and_hoyer_families():
    """PR 10 families on a mesh: the l1,2 sharded solve keeps zero
    all-gathers with its Newton while body doing exactly ONE stacked
    f32[2, G] psum per evaluation, and its outputs/theta equal the gathered
    solve; the fused_sharded l1,2 step (stat="sq" pass 1, scale-mode pass 2)
    matches the gathered solver="fused" step; hoyer — per-leaf only —
    solves sharded-vs-gathered equal with no all-gather (columns are
    independent, so a column-sharded leaf never moves)."""
    out = _run_subprocess(_WHILE_HELPER + textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import (ProjectionSpec, ProjectionEngine,
                                init_projection_state)
        from repro.optim.adam import AdamConfig, adam_init

        rng = np.random.default_rng(0)
        params = {
            "blocks": {"w1": jnp.asarray(rng.normal(size=(4, 64, 256)),
                                         jnp.float32)},
            "enc": {"w": jnp.asarray(rng.normal(size=(128, 512)),
                                     jnp.float32)},
        }
        specs = (ProjectionSpec(pattern=r"w1$", norm="l12", radius=16.0),
                 ProjectionSpec(pattern=r"enc/w", norm="l12", radius=8.0))
        mesh = jax.make_mesh((8,), ("data",))
        sh = {
            "blocks": {"w1": NamedSharding(mesh, P(None, "data", None))},
            "enc": {"w": NamedSharding(mesh, P("data", None))},
        }
        params_s = jax.device_put(params, sh)
        state0 = init_projection_state(params, specs)

        # --- sharded packed Newton on column energies: zero all-gathers,
        # one stacked f32[2, G] psum per Eq.-(19) evaluation
        eng = ProjectionEngine(specs, solver="sharded", mesh=mesh)
        fn = jax.jit(lambda p, s: eng.apply(p, state=s))
        with mesh:
            hlo = fn.lower(params_s, state0).compile().as_text()
        ags = [l for l in hlo.splitlines() if re.search("all-gather", l)]
        assert not ags, "\\n".join(ags[:5])
        comm = {k: v for k, v in while_body_allreduces(hlo).items() if v}
        assert len(comm) == 1, comm   # only the Newton loop communicates
        (shapes,) = comm.values()
        G = 4 + 1                     # 4 stacked w1 segments + enc
        assert shapes == [f"f32[2,{G}]"], comm

        with mesh:
            out_s, st_s = fn(params_s, state0)
        ref = ProjectionEngine(specs)       # gathered packed Newton
        out_r, st_r = ref.apply(params, state=state0)
        for a, b in zip(jax.tree_util.tree_leaves(out_r),
                        jax.tree_util.tree_leaves(out_s)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5, rtol=1e-5)
        assert set(st_s) == {"l12_packed/k1"}
        np.testing.assert_allclose(np.asarray(st_r["l12_packed/k1"]),
                                   np.asarray(st_s["l12_packed/k1"]),
                                   rtol=1e-6, atol=1e-6)

        # --- fused_sharded: the two-pass megakernel with column energies
        # (pass 1 stat="sq") and the scale-mode write (pass 2), rank-local
        grads = jax.tree_util.tree_map(
            lambda p: 0.01 * jnp.asarray(rng.normal(size=p.shape),
                                         jnp.float32), params)
        grads_s = jax.device_put(grads, sh)
        acfg = AdamConfig(lr=1e-3)
        opt = adam_init(params, acfg)
        ref_eng = ProjectionEngine(specs, solver="fused")
        shd_eng = ProjectionEngine(specs, solver="fused_sharded", mesh=mesh)
        ref_step = jax.jit(lambda g, o, p, s: ref_eng.projected_update(
            g, o, p, acfg, state=s))
        shd_step = jax.jit(lambda g, o, p, s: shd_eng.projected_update(
            g, o, p, acfg, state=s))
        with mesh:
            hlo_f = shd_step.lower(grads_s, opt, params_s,
                                   state0).compile().as_text()
        ags = [l for l in hlo_f.splitlines() if re.search("all-gather", l)]
        assert not ags, "\\n".join(ags[:5])
        comm = {k: v for k, v in while_body_allreduces(hlo_f).items() if v}
        assert len(comm) == 1, comm
        (shapes,) = comm.values()
        assert shapes == [f"f32[2,{G}]"], comm
        p_r, o_r, s_r = ref_step(grads, opt, params, state0)
        with mesh:
            p_s, o_s, s_s = shd_step(grads_s, opt, params_s, state0)
        d = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree_util.tree_leaves(p_r),
                                jax.tree_util.tree_leaves(p_s)))
        td = float(jnp.max(jnp.abs(s_r["l12_packed/k1"]
                                   - s_s["l12_packed/k1"])))
        print("fused_sharded l12 param maxdiff", d, "theta maxdiff", td)
        assert d <= 1e-5 and td <= 1e-5, (d, td)

        # --- hoyer rides per-leaf under every solver: a column-sharded
        # leaf solves rank-local (columns independent), no all-gather
        hp = {"hoy": {"w": jnp.asarray(rng.normal(size=(64, 128)),
                                       jnp.float32)}}
        hspecs = (ProjectionSpec(pattern=r"hoy/w", norm="hoyer",
                                 radius=0.75),)
        hsh = {"hoy": {"w": NamedSharding(mesh, P(None, "data"))}}
        hp_s = jax.device_put(hp, hsh)
        heng = ProjectionEngine(hspecs, solver="sharded", mesh=mesh)
        hfn = jax.jit(lambda p: heng.apply(p)[0])
        with mesh:
            hlo_h = hfn.lower(hp_s).compile().as_text()
            out_h = hfn(hp_s)
        ags = [l for l in hlo_h.splitlines() if re.search("all-gather", l)]
        assert not ags, "\\n".join(ags[:5])
        out_hr = ProjectionEngine(hspecs).apply(hp)[0]
        np.testing.assert_allclose(np.asarray(out_hr["hoy"]["w"]),
                                   np.asarray(out_h["hoy"]["w"]),
                                   atol=1e-6, rtol=1e-6)
        from repro.core import hoyer_sparseness
        assert float(jnp.min(hoyer_sparseness(out_h["hoy"]["w"]))) \\
            >= 0.75 - 1e-4
        print("OK")
    """))
    assert "OK" in out


def test_train_cell_projection_adds_no_full_weight_allgather():
    """lower_cell train HLO on an FSDP mesh: turning the projection ON must
    not add any all-gather at full-weight size (the sharded engine moves
    shards with all-to-all and statistics with psum)."""
    out = _run_subprocess("""
        import re
        import numpy as np, jax
        from repro.configs import get_reduced
        from repro.models.zoo import build
        from repro.launch.steps import lower_cell
        import repro.models.zoo as zoo

        zoo.SHAPES["train_4k"] = dict(seq=64, batch=8, kind="train")
        cfg = get_reduced("gemma_7b")
        model = build(cfg)
        mesh = jax.make_mesh((8, 1), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)

        def ag_sizes(hlo):
            "multiset of all-gather result element counts"
            sizes = []
            for line in hlo.splitlines():
                m = re.search(r"= \\S*?(f32|bf16|f16|s32|u32)"
                              r"\\[([0-9,]*)\\][^ ]* all-gather", line)
                if m:
                    dims = [int(d) for d in m.group(2).split(",") if d]
                    sizes.append(int(np.prod(dims)) if dims else 1)
            return sizes

        hlo_off = lower_cell(model, "train_4k", mesh, False,
                             with_projection=False).compile().as_text()
        hlo_on = lower_cell(model, "train_4k", mesh, False,
                            with_projection=True).compile().as_text()
        # full size of the projected leaf (stacked mlp w1)
        from repro.core.constraints import leaf_path_str
        flat = jax.tree_util.tree_flatten_with_path(
            model.abstract_params())[0]
        w1 = [l for p, l in flat
              if re.search(r"mlp/w1$", leaf_path_str(p))][0]
        full = int(np.prod(w1.shape))
        big_off = sorted(s for s in ag_sizes(hlo_off) if s >= full)
        big_on = sorted(s for s in ag_sizes(hlo_on) if s >= full)
        print("big all-gathers off/on:", big_off, big_on)
        assert len(big_on) <= len(big_off), (big_off, big_on)
        print("OK")
    """)
    assert "OK" in out


# HLO introspection shared by the fused_sharded tests: map every while-loop
# body computation to the shapes of the all-reduces it contains. The
# projection's Newton loop is the only while body allowed to communicate,
# and it must do so exactly once per evaluation — one stacked
# (2, num_segments) f32 psum (DESIGN.md §12).
_WHILE_HELPER = r'''
import re

def while_body_allreduces(hlo):
    "{while-body computation name: [all-reduce result shapes]}"
    bodies = set(n.lstrip("%") for n in re.findall(
        r"while\(.*?\), condition=[^,]+, body=([%\w\.\-]+)", hlo))
    out = {}
    for comp in re.split(r"\n(?=%?[\w\.\-]+ \(|ENTRY )", hlo):
        lines = comp.splitlines()
        if not lines:
            continue
        name = lines[0].split(" ")[0].lstrip("%")
        if name in bodies:
            out[name] = [s.split("{")[0] for s in
                         re.findall(r"= (\S+) all-reduce", comp)]
    return out
'''


def test_fused_sharded_cell_one_psum_per_eval_and_matches_fused():
    """The tentpole contract: the fused_sharded train cell's HLO contains
    zero all-gathers and its Newton while body exactly ONE all-reduce,
    shaped f32[2, num_segments] (the stacked Eq.-(19) numerator/denominator
    psum); params match the gathered solver="fused" step to <= 1e-5; theta
    warm starts thread across a fused -> fused_sharded solver switch."""
    out = _run_subprocess(_WHILE_HELPER + textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import ProjectionSpec, ProjectionEngine
        from repro.optim.adam import AdamConfig, adam_init

        rng = np.random.default_rng(0)
        params = {
            "enc1": {"w": jnp.asarray(rng.normal(size=(64, 256)),
                                      jnp.float32)},
            "blocks": {"w": jnp.asarray(rng.normal(size=(3, 64, 256)),
                                        jnp.float32)},
        }
        grads = jax.tree_util.tree_map(
            lambda p: 0.01 * jnp.asarray(rng.normal(size=p.shape),
                                         jnp.float32), params)
        norm = float(jnp.abs(params["enc1"]["w"]).max(axis=0).sum())
        specs = (ProjectionSpec(pattern=r"enc1/w", norm="bilevel",
                                radius=0.1 * norm),
                 ProjectionSpec(pattern=r"blocks/w", norm="bilevel",
                                radius=0.05 * norm, axis=1))
        acfg = AdamConfig(lr=1e-3)

        mesh = jax.make_mesh((8,), ("data",))
        sh = {
            "enc1": {"w": NamedSharding(mesh, P("data", None))},   # FSDP
            "blocks": {"w": NamedSharding(mesh, P(None, None, "data"))},
        }
        params_s = jax.device_put(params, sh)
        grads_s = jax.device_put(grads, sh)

        ref_eng = ProjectionEngine(specs, solver="fused")
        shd_eng = ProjectionEngine(specs, solver="fused_sharded", mesh=mesh)
        opt = adam_init(params, acfg)
        state0 = ref_eng.init_state(params)
        ref_step = jax.jit(lambda g, o, p, s: ref_eng.projected_update(
            g, o, p, acfg, state=s, with_stats=True))
        shd_step = jax.jit(lambda g, o, p, s: shd_eng.projected_update(
            g, o, p, acfg, state=s, with_stats=True))

        # --- HLO: zero all-gathers; ONE f32[2,G] psum in the Newton body
        with mesh:
            hlo = shd_step.lower(grads_s, opt, params_s,
                                 state0).compile().as_text()
        ags = [l for l in hlo.splitlines() if re.search("all-gather", l)]
        assert not ags, "\\n".join(ags[:5])
        comm = {k: v for k, v in while_body_allreduces(hlo).items() if v}
        assert len(comm) == 1, comm   # only the Newton loop communicates
        (shapes,) = comm.values()
        G = 1 + 3                     # enc1 segment + 3 stacked blocks
        assert shapes == [f"f32[2,{G}]"], comm

        # --- step 1 (cold): params + theta match the gathered fused solve
        p_r, o_r, s_r, st_r = ref_step(grads, opt, params, state0)
        with mesh:
            p_s, o_s, s_s, st_s = shd_step(grads_s, opt, params_s, state0)
        d = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree_util.tree_leaves(p_r),
                                jax.tree_util.tree_leaves(p_s)))
        k = list(s_r)[0]
        td = float(jnp.max(jnp.abs(s_r[k] - s_s[k])))
        print("step1 param maxdiff", d, "theta maxdiff", td)
        assert d <= 1e-5 and td <= 1e-5, (d, td)
        iters_cold = int(st_s[k])

        # --- step 2: WARM-started across the solver switch — hand the
        # gathered fused solver's theta to the sharded engine and vice
        # versa; both must agree and take no more evals than the cold start
        with mesh:
            p_x, o_x, s_x, st_x = shd_step(grads_s, o_r, p_r, s_r)
        p_r2, o_r2, s_r2, st_r2 = ref_step(grads, o_r, p_r, s_r)
        d2 = max(float(jnp.max(jnp.abs(a - b)))
                 for a, b in zip(jax.tree_util.tree_leaves(p_r2),
                                 jax.tree_util.tree_leaves(p_x)))
        td2 = float(jnp.max(jnp.abs(s_r2[k] - s_x[k])))
        print("switch param maxdiff", d2, "theta maxdiff", td2,
              "iters cold/warm", iters_cold, int(st_x[k]))
        assert d2 <= 1e-5 and td2 <= 1e-5, (d2, td2)
        assert int(st_x[k]) <= iters_cold, (int(st_x[k]), iters_cold)
        print("OK")
    """))
    assert "OK" in out


def test_projection_engine_for_solver_selection_and_fallback():
    """Launch policy regression: projection_engine_for picks solver="fused"
    with no mesh / a 1-device mesh and solver="fused_sharded" on every
    >1-device mesh shape; plans the megakernel cannot take (plain l1inf —
    sorted prefix sums) fall back to the shard_map Newton bit-identically
    to solver="sharded"."""
    out = _run_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import get_reduced
        from repro.launch.steps import projection_engine_for
        from repro.core import ProjectionSpec, ProjectionEngine
        from repro.optim.adam import AdamConfig, adam_init

        cfg = get_reduced("gemma_7b")
        assert projection_engine_for(cfg, None).solver == "fused"
        m1 = jax.make_mesh((1,), ("data",))
        assert projection_engine_for(cfg, m1).solver == "fused"
        for shape, names in (((8,), ("data",)),
                             ((4, 2), ("data", "model"))):
            m = jax.make_mesh(shape, names)
            eng = projection_engine_for(cfg, m)
            assert eng.solver == "fused_sharded", (shape, eng.solver)
            assert eng.mesh is m

        # fallback bit-identity: plain l1inf never qualifies for the fused
        # family hook, so under solver="fused_sharded" it must replay the
        # solver="sharded" path exactly (same ops, same fp order)
        rng = np.random.default_rng(1)
        params = {"enc": {"w": jnp.asarray(rng.normal(size=(64, 256)),
                                           jnp.float32)}}
        grads = {"enc": {"w": 0.01 * jnp.asarray(
            rng.normal(size=(64, 256)), jnp.float32)}}
        specs = (ProjectionSpec(pattern=r"enc/w", norm="l1inf",
                                radius=8.0),)
        mesh = jax.make_mesh((8,), ("data",))
        sh = {"enc": {"w": NamedSharding(mesh, P("data", None))}}
        params_s = jax.device_put(params, sh)
        grads_s = jax.device_put(grads, sh)
        acfg = AdamConfig(lr=1e-3)
        opt = adam_init(params, acfg)

        outs = {}
        for solver in ("fused_sharded", "sharded"):
            eng = ProjectionEngine(specs, solver=solver, mesh=mesh)
            state0 = eng.init_state(params)
            step = jax.jit(lambda g, o, p, s, e=eng: e.projected_update(
                g, o, p, acfg, state=s))
            with mesh:
                outs[solver] = step(grads_s, opt, params_s, state0)
        for a, b in zip(jax.tree_util.tree_leaves(outs["fused_sharded"]),
                        jax.tree_util.tree_leaves(outs["sharded"])):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (
                "fallback diverged from solver='sharded'")
        print("OK")
    """)
    assert "OK" in out


def test_compressed_grad_reduce_composes_with_fused_sharded():
    """dist/compression composition: per-rank DP gradient partials reduced
    by compressed_psum inside a shard_map feed the fused_sharded
    projected_update through its grad_reduce hook in ONE jitted step. The
    projection's one-psum-per-Newton-evaluation contract must be unchanged
    by the compression mode, and the mode="none" step must match the
    gathered fused solve on the summed gradient."""
    out = _run_subprocess(_WHILE_HELPER + textwrap.dedent("""
        import functools
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.core import ProjectionSpec, ProjectionEngine
        from repro.dist.compression import compressed_psum
        from repro.optim.adam import AdamConfig, adam_init

        D = 8
        rng = np.random.default_rng(0)
        params = {"enc": {"w": jnp.asarray(rng.normal(size=(64, 256)),
                                           jnp.float32)}}
        specs = (ProjectionSpec(pattern=r"enc/w", norm="bilevel",
                                radius=20.0),)
        # per-rank gradient partials, stacked on a leading DP dim
        gstack = {"enc": {"w": 0.01 * jnp.asarray(
            rng.normal(size=(D, 64, 256)), jnp.float32)}}
        acfg = AdamConfig(lr=1e-3)
        mesh = jax.make_mesh((8,), ("data",))
        params_s = jax.device_put(
            params, {"enc": {"w": NamedSharding(mesh, P(None, "data"))}})
        gstack_s = jax.device_put(
            gstack,
            {"enc": {"w": NamedSharding(mesh, P("data", None, None))}})

        eng = ProjectionEngine(specs, solver="fused_sharded", mesh=mesh)
        opt = adam_init(params, acfg)
        state0 = eng.init_state(params)

        def make_step(mode):
            def reduce_fn(gs):
                def body(g):
                    r = compressed_psum(g, "data", mode=mode)
                    return jax.tree_util.tree_map(lambda x: x[0], r)
                return shard_map(
                    body, mesh=mesh,
                    in_specs=(P("data", None, None),), out_specs=P(),
                    check_rep=False)(gs)

            def step(gs, o, p, s):
                return eng.projected_update(gs, o, p, acfg, state=s,
                                            grad_reduce=reduce_fn)
            return jax.jit(step)

        hlos = {}
        for mode in ("none", "int8"):
            with mesh:
                hlos[mode] = make_step(mode).lower(
                    gstack_s, opt, params_s, state0).compile().as_text()
            comm = {k: v for k, v in while_body_allreduces(
                hlos[mode]).items() if v}
            assert len(comm) == 1, (mode, comm)
            (shapes,) = comm.values()
            assert shapes == ["f32[2,1]"], (mode, comm)
        # the uncompressed composition also keeps the zero-gather contract
        # (int8's shared-scale payload exchange is an all_gather by design,
        # outside the projection)
        assert "all-gather" not in hlos["none"]

        # mode="none" == plain psum: bit-for-bit the summed gradient, so
        # the composed step must match the gathered fused solve on it
        with mesh:
            p_c, o_c, s_c = make_step("none")(gstack_s, opt, params_s,
                                              state0)
        gsum = jax.tree_util.tree_map(lambda x: x.sum(0), gstack)
        ref = ProjectionEngine(specs, solver="fused")
        p_r, o_r, s_r = jax.jit(
            lambda g, o, p, s: ref.projected_update(g, o, p, acfg,
                                                    state=s))(
            gsum, opt, params, state0)
        d = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree_util.tree_leaves(p_r),
                                jax.tree_util.tree_leaves(p_c)))
        k = list(s_r)[0]
        td = float(jnp.max(jnp.abs(s_r[k] - s_c[k])))
        print("composed param maxdiff", d, "theta maxdiff", td)
        assert d <= 1e-5 and td <= 1e-5, (d, td)

        # int8 mode runs end to end and stays a sane approximation
        with mesh:
            p_q, _, _ = make_step("int8")(gstack_s, opt, params_s, state0)
        dq = max(float(jnp.max(jnp.abs(a - b)))
                 for a, b in zip(jax.tree_util.tree_leaves(p_r),
                                 jax.tree_util.tree_leaves(p_q)))
        print("int8 param maxdiff", dq)
        assert dq < 1e-2, dq
        print("OK")
    """))
    assert "OK" in out


def test_sharded_serve_step_matches_dense():
    """The shard_map'd compact serving step (sae/serve.make_serve_step with
    a mesh): batch laid out over the data axis by dist.sharding rules,
    params replicated, output equal to the dense single-device apply."""
    out = _run_subprocess("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import ProjectionSpec, apply_constraints
        from repro.sae import SAEConfig, sae_init, sae_apply, compact_sae
        from repro.sae.serve import make_serve_step

        cfg = SAEConfig(n_features=512, n_hidden=32, n_classes=2)
        params = sae_init(jax.random.PRNGKey(0), cfg)
        spec = ProjectionSpec(pattern=r"enc1/w", norm="l1inf", radius=0.2,
                              axis=1)
        params = apply_constraints(params, (spec,))
        compact = compact_sae(params, (spec,))
        assert 0 < compact.n_selected < 512

        mesh = jax.make_mesh((8,), ("data",))
        x = jnp.asarray(np.random.default_rng(0).normal(size=(64, 512)),
                        jnp.float32)
        step = make_serve_step(compact, mesh=mesh)
        z_c, xh_c = step(compact.params, x)
        z_d, xh_d = sae_apply(params, x)
        np.testing.assert_allclose(np.asarray(z_c), np.asarray(z_d),
                                   rtol=0, atol=1e-5)
        np.testing.assert_allclose(np.asarray(xh_c),
                                   np.asarray(xh_d)[:, compact.sel],
                                   rtol=0, atol=1e-5)

        # serving is embarrassingly row-parallel: the compiled step must
        # contain no cross-rank collectives at all
        import re
        hlo = step.lower(compact.params, x).compile().as_text()
        for op in ("all-gather", "all-reduce", "all-to-all",
                   "collective-permute"):
            assert not re.search(op, hlo), op
        print("OK")
    """)
    assert "OK" in out


def test_sharded_zoo_serve_matches_single_device():
    """BatchServer with a mesh: the shard_map'd compact decode step lays
    the batch over the data axis (params replicated), produces the same
    tokens as single-device serving, and — rows being independent —
    compiles to an HLO with zero cross-rank collectives."""
    out = _run_subprocess("""
        import dataclasses, re
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_reduced
        from repro.models.zoo import build
        from repro.models.transformer import init_cache
        from repro.core.constraints import ProjectionSpec
        from repro.train.serve import BatchServer, ServeConfig

        cfg = dataclasses.replace(get_reduced("gemma_7b"), n_layers=2)
        cfg = dataclasses.replace(cfg, projection_specs=cfg.projection_specs
            + (ProjectionSpec(pattern="blocks/.*/mlp/w2$", norm="l1inf",
                              radius=64.0, axis=0, every_k=10),))
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        mlp = params["blocks"]["p0_global"]["mlp"]
        for name, frac in (("w1", 0.75), ("w2", 0.5)):
            arr = np.array(mlp[name])
            dead = rng.choice(arr.shape[2], int(arr.shape[2]*frac),
                              replace=False)
            arr[:, :, dead] = 0.0
            mlp[name] = jnp.asarray(arr)

        prompts = [[1, 2, 3], [4, 5], [7], [8, 9]]
        ref = BatchServer(model, batch_slots=8, scfg=ServeConfig(max_seq=32))
        ref.load_compact(params=params)
        want = ref.generate(prompts, max_new=6)

        mesh = jax.make_mesh((8,), ("data",))
        srv = BatchServer(model, batch_slots=8, scfg=ServeConfig(max_seq=32),
                          mesh=mesh)
        srv.load_compact(params=params)
        got = srv.generate(prompts, max_new=6)
        assert got == want, (got, want)

        hlo = srv.engine.step_hlo()
        for op in ("all-gather", "all-reduce", "all-to-all",
                   "collective-permute"):
            assert not re.search(op, hlo), op
        assert "input_output_alias" in hlo   # donated cache + slot state
        print("OK")
    """)
    assert "OK" in out
