"""Packed multi-tensor constraint batching vs the per-leaf reference path.

The packed engine must be exact (up to fp accumulation order) against
per-matrix projection on every leaf shape: 2-D, stacked 3-D, transposed
axis, mixed radii, mixed norms (unpackable ones fall back), every_k gating,
and warm-start state threading — plus the train-loop integrations.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (ProjectionSpec, apply_constraints,
                        apply_constraints_packed, build_packed_plans,
                        init_projection_state, project_l1inf_newton,
                        project_l1inf_segmented)
from repro.core import constraints as constraints_mod


def _params(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "enc1": {"w": jnp.asarray(rng.normal(size=(24, 50)), jnp.float32)},
        "blocks": {"mlp_w1": jnp.asarray(rng.normal(size=(3, 16, 40)),
                                         jnp.float32)},
        "dec": {"w": jnp.asarray(rng.normal(size=(50, 24)), jnp.bfloat16)},
        "bias": jnp.asarray(rng.normal(size=(50,)), jnp.float32),
        "other": {"v": jnp.asarray(rng.normal(size=(12, 12)), jnp.float32)},
    }


SPECS = (
    ProjectionSpec(pattern=r"enc1/w", norm="l1inf", radius=2.0, axis=1),
    ProjectionSpec(pattern=r"mlp_w1", norm="l1inf", radius=1.5, axis=0),
    ProjectionSpec(pattern=r"dec/w", norm="l1inf_sorted", radius=3.0, axis=0),
    ProjectionSpec(pattern=r"other/v", norm="l12", radius=1.0, axis=0),
)


def _tol_equal(a, b, tol=5e-6):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=tol, rtol=tol)


def test_packed_matches_per_leaf():
    params = _params()
    ref = apply_constraints(params, SPECS)
    out, state = apply_constraints_packed(params, SPECS)
    for tree_ref, tree_out in [(ref, out)]:
        flat_r = jax.tree_util.tree_leaves(tree_ref)
        flat_o = jax.tree_util.tree_leaves(tree_out)
        for r, o in zip(flat_r, flat_o):
            _tol_equal(r, o)
    # dtype preserved per leaf
    assert out["dec"]["w"].dtype == jnp.bfloat16
    # two plans: l1inf with 1 + 3 + 1 = 5 segments (stacked leaf contributes
    # 3) and the l12 family's own single-segment plan (PR 10: l12 packs)
    plans, per_leaf = build_packed_plans(params, SPECS)
    by_key = {p.key: p for p in plans}
    assert set(by_key) == {"l1inf_packed/k1", "l12_packed/k1"}
    assert by_key["l1inf_packed/k1"].num_segments == 5
    assert by_key["l12_packed/k1"].num_segments == 1
    assert not per_leaf                  # nothing falls back any more
    assert set(state) == set(by_key)
    assert state["l1inf_packed/k1"].shape == (5,)


def test_packed_single_launch_per_step():
    params = _params(1)
    constraints_mod.engine_counters_reset()
    apply_constraints_packed(params, SPECS)
    counts = constraints_mod.engine_counters()
    # 3 l1inf leaves -> ONE packed invocation, the l12 leaf -> its own
    # family plan (one more), counted under per-plan keys so parallel
    # suites can't collide
    assert counts == {"l1inf_packed/k1/newton": 1,
                      "l12_packed/k1/newton": 1}
    constraints_mod.engine_counters_reset()
    apply_constraints(params, SPECS)
    assert constraints_mod.engine_counters() == {"per_leaf": 4}
    # reset really zeroes (no bleed into the next measured region)
    constraints_mod.engine_counters_reset()
    assert constraints_mod.engine_counters() == {}


def test_packed_warm_start_state_threading():
    params = _params(2)
    state0 = init_projection_state(params, SPECS)
    out1, st1 = apply_constraints_packed(params, SPECS, state=state0)
    out2, st2 = apply_constraints_packed(params, SPECS, state=st1)
    for r, o in zip(jax.tree_util.tree_leaves(out1),
                    jax.tree_util.tree_leaves(out2)):
        _tol_equal(r, o)
    # projecting the same params again: theta state is a fixed point
    k = list(st1)[0]
    np.testing.assert_allclose(np.asarray(st1[k]), np.asarray(st2[k]),
                               rtol=1e-5, atol=1e-6)


def test_packed_every_k_gating():
    params = _params(3)
    specs = (ProjectionSpec(pattern=r"enc1/w", norm="l1inf", radius=2.0,
                            axis=1, every_k=2),)
    state0 = init_projection_state(params, specs)
    # step 1: skipped -> identity, theta state keeps its previous value
    out, st = apply_constraints_packed(params, specs,
                                       step=jnp.asarray(1), state=state0)
    np.testing.assert_array_equal(np.asarray(out["enc1"]["w"]),
                                  np.asarray(params["enc1"]["w"]))
    k = list(st)[0]
    np.testing.assert_array_equal(np.asarray(st[k]), np.asarray(state0[k]))
    # step 2: applied
    out, st = apply_constraints_packed(params, specs,
                                       step=jnp.asarray(2), state=state0)
    ref = apply_constraints(params, specs)
    _tol_equal(ref["enc1"]["w"], out["enc1"]["w"])
    assert float(st[k][0]) > 0


def test_packed_under_jit_and_grouping_by_every_k():
    params = _params(4)
    specs = (ProjectionSpec(pattern=r"enc1/w", norm="l1inf", radius=2.0),
             ProjectionSpec(pattern=r"mlp_w1", norm="l1inf", radius=1.0,
                            every_k=3))
    plans, _ = build_packed_plans(params, specs)
    assert len(plans) == 2               # grouped by every_k
    state0 = init_projection_state(params, specs)
    f = jax.jit(lambda p, s: apply_constraints_packed(
        p, specs, step=jnp.asarray(3), state=s))
    out, st = f(params, state0)
    ref = apply_constraints(params, specs, step=jnp.asarray(3))
    _tol_equal(ref["enc1"]["w"], out["enc1"]["w"])
    _tol_equal(ref["blocks"]["mlp_w1"], out["blocks"]["mlp_w1"])


def test_packed_pallas_engine_matches():
    params = _params(5)
    specs = (ProjectionSpec(pattern=r"enc1/w", norm="l1inf", radius=2.0,
                            axis=1),
             ProjectionSpec(pattern=r"mlp_w1", norm="l1inf", radius=1.5))
    ref, _ = apply_constraints_packed(params, specs, engine="newton")
    out, _ = apply_constraints_packed(params, specs, engine="pallas")
    _tol_equal(ref["enc1"]["w"], out["enc1"]["w"], tol=5e-4)
    _tol_equal(ref["blocks"]["mlp_w1"], out["blocks"]["mlp_w1"], tol=5e-4)


def test_segmented_radius_heterogeneous():
    """Segments with very different radii in one packed solve."""
    rng = np.random.default_rng(6)
    Y1 = rng.normal(size=(20, 30))
    Y2 = rng.normal(size=(20, 18)) * 5.0
    Yp = jnp.asarray(np.concatenate([Y1, Y2], axis=1), jnp.float32)
    sids = jnp.asarray(np.array([0] * 30 + [1] * 18, np.int32))
    C1 = float(0.05 * np.abs(Y1).max(axis=0).sum())
    C2 = float(0.7 * np.abs(Y2).max(axis=0).sum())
    X, theta, iters = project_l1inf_segmented(
        Yp, sids, jnp.asarray([C1, C2], jnp.float32), num_segments=2)
    X1 = project_l1inf_newton(jnp.asarray(Y1, jnp.float32), C1)
    X2 = project_l1inf_newton(jnp.asarray(Y2, jnp.float32), C2)
    _tol_equal(np.asarray(X)[:, :30], X1)
    _tol_equal(np.asarray(X)[:, 30:], X2)
    # per-segment warm start: exact restart converges in the bootstrap pair
    _, _, it2 = project_l1inf_segmented(
        Yp, sids, jnp.asarray([C1, C2], jnp.float32), num_segments=2,
        theta0=theta)
    assert int(it2) <= 2


def test_segmented_inside_and_padding_columns():
    rng = np.random.default_rng(8)
    Y1 = rng.normal(size=(10, 12)) * 0.01   # inside its ball
    Y2 = rng.normal(size=(10, 9))
    pad = np.zeros((10, 3))
    Yp = jnp.asarray(np.concatenate([Y1, Y2, pad], axis=1), jnp.float32)
    sids = jnp.asarray(np.array([0] * 12 + [1] * 9 + [2] * 3, np.int32))
    C2 = float(0.3 * np.abs(Y2).max(axis=0).sum())
    X, theta, _ = project_l1inf_segmented(
        Yp, sids, jnp.asarray([100.0, C2], jnp.float32), num_segments=2)
    np.testing.assert_array_equal(np.asarray(X)[:, :12], np.asarray(Y1, np.float32))
    assert float(theta[0]) == 0.0
    X2 = project_l1inf_newton(jnp.asarray(Y2, jnp.float32), C2)
    _tol_equal(np.asarray(X)[:, 12:21], X2)
    np.testing.assert_array_equal(np.asarray(X)[:, 21:], 0.0)


def test_train_loop_packed_integration():
    """train/loop.py threads proj_state through the jitted step end-to-end."""
    from repro.configs import get_reduced
    from repro.models.zoo import build
    from repro.train.loop import TrainConfig, train
    from repro.data.pipeline import SyntheticLM, LMBatcher

    cfg = get_reduced("stablelm_3b")
    assert cfg.projection_specs, "reduced config should carry l1inf specs"
    model = build(cfg)
    batcher = LMBatcher(SyntheticLM(cfg.vocab, seed=1), 2, 16)
    out = train(model, batcher,
                TrainConfig(steps=3, log_every=100, with_projection=True),
                resume=False)
    assert all(np.isfinite(l) for l in out["losses"])
    assert out["sparsity"], "projection specs matched no parameters"
