"""Substrate tests: checkpointing (atomic/async/crash-resume/elastic),
data pipeline determinism, gradient compression, watchdog, train loop."""
import os
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint import (save, restore, restore_tree, latest_step,
                              gc_keep_last, AsyncCheckpointer)
from repro.data.pipeline import SyntheticLM, LMBatcher, host_batch_slice
from repro.dist.compression import (ef_step, int8_quantize, int8_dequantize,
                                    topk_compress, topk_decompress)
from repro.dist.watchdog import StepWatchdog


def _tree():
    return {"a": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)},
            "b": jnp.ones((5,), jnp.bfloat16),
            "count": jnp.asarray(7, jnp.int32)}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    save(t, tmp_path, 3)
    save(t, tmp_path, 10)
    assert latest_step(tmp_path) == 10
    flat, step = restore(tmp_path)
    assert step == 10
    restored, step = restore_tree(t, tmp_path)
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_integrity_and_gc(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4):
        save(t, tmp_path, s)
    gc_keep_last(tmp_path, 2)
    assert latest_step(tmp_path) == 4
    flat, _ = restore(tmp_path, 3)  # step 3 kept
    with pytest.raises(FileNotFoundError):
        restore(str(tmp_path) + "-missing")
    # corrupt a leaf -> crc failure
    import pathlib
    p = pathlib.Path(tmp_path) / "step-00000004"
    target = next(p.glob("*.npy"))
    arr = np.load(target)
    arr2 = arr.copy()
    arr2.flat[0] = arr2.flat[0] + 1
    np.save(target, arr2)
    with pytest.raises(IOError):
        restore(tmp_path, 4)


def test_async_checkpointer(tmp_path):
    c = AsyncCheckpointer(tmp_path, keep=2)
    t = _tree()
    for s in (5, 6, 7):
        c.save(t, s)
    c.wait()
    assert latest_step(tmp_path) == 7


def test_crash_resume_bitwise(tmp_path):
    """Train 6 steps; 'crash'; resume from step-3 ckpt; identical final
    params to an uninterrupted run (deterministic data + optimizer)."""
    from repro.configs import get_reduced
    from repro.models.zoo import build
    from repro.train.loop import TrainConfig, train
    from repro.data.pipeline import SyntheticLM, LMBatcher

    cfg = get_reduced("mamba2_370m")
    model = build(cfg)
    batcher = LMBatcher(SyntheticLM(cfg.vocab, seed=1), 2, 16)

    d1 = os.path.join(tmp_path, "a")
    full = train(model, batcher, TrainConfig(
        steps=6, ckpt_dir=d1, ckpt_every=3, log_every=100,
        with_projection=False), resume=False)

    d2 = os.path.join(tmp_path, "b")
    train(model, batcher, TrainConfig(steps=3, ckpt_dir=d2, ckpt_every=3,
                                      log_every=100, with_projection=False),
          resume=False)
    resumed = train(model, batcher, TrainConfig(
        steps=6, ckpt_dir=d2, ckpt_every=3, log_every=100,
        with_projection=False), resume=True)

    for a, b in zip(jax.tree_util.tree_leaves(full["params"]),
                    jax.tree_util.tree_leaves(resumed["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_elastic_restore_different_structure_dtype(tmp_path):
    t = {"w": jnp.ones((4, 4), jnp.float32)}
    save(t, tmp_path, 1)
    template = {"w": jnp.zeros((4, 4), jnp.bfloat16)}  # dtype change OK
    restored, _ = restore_tree(template, tmp_path)
    assert restored["w"].dtype == np.dtype("bfloat16") or \
        str(restored["w"].dtype) == "bfloat16"


def test_data_determinism_and_sharding():
    src = SyntheticLM(vocab=1000, seed=3)
    b1 = src.batch(step=5, batch=8, seq=32)
    b2 = src.batch(step=5, batch=8, seq=32)
    np.testing.assert_array_equal(b1, b2)
    # host slicing covers the global batch exactly
    lo0, hi0 = host_batch_slice(8, 2, 0)
    lo1, hi1 = host_batch_slice(8, 2, 1)
    sh0 = src.batch(step=5, batch=8, seq=32, rows=(lo0, hi0))
    sh1 = src.batch(step=5, batch=8, seq=32, rows=(lo1, hi1))
    np.testing.assert_array_equal(np.concatenate([sh0, sh1]), b1)
    batch = LMBatcher(src, 4, 16).get(0)
    np.testing.assert_array_equal(batch["tokens"][:, 1:],
                                  batch["labels"][:, :-1])


def test_compression_ef_topk():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    err = jnp.zeros_like(g)
    sparse, err = ef_step(g, err, k_frac=0.25)
    assert int(jnp.sum(sparse != 0)) == 16
    # error feedback: sparse + err == g
    np.testing.assert_allclose(np.asarray(sparse + err), np.asarray(g),
                               atol=1e-6)
    vals, idx = topk_compress(g, 0.25)
    rec = topk_decompress(vals, idx, g.shape, g.dtype)
    np.testing.assert_allclose(np.asarray(rec), np.asarray(sparse), atol=1e-6)


def test_compression_int8():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(128,)) * 3, jnp.float32)
    q, s = int8_quantize(x)
    xr = int8_dequantize(q, s)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(xr), np.asarray(x),
                               atol=float(s) * 0.51 + 1e-6)


def test_watchdog():
    import time
    events = []
    w = StepWatchdog(threshold=3.0, grace_steps=1,
                     on_straggler=lambda s, dt, ew: events.append(s))
    for i in range(5):
        w.start()
        time.sleep(0.002)
        w.stop(i)
    w.start()
    time.sleep(0.05)  # straggler
    w.stop(5)
    assert events == [5]
