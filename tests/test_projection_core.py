"""Correctness of every l1,inf projection implementation.

Strategy: all implementations must agree with each other AND satisfy the KKT
structure (ball membership, column clipping at a common removed mass theta,
non-expansiveness, idempotency). Small instances additionally verified against
a brute-force optimum.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import (
    l1inf_norm, project_l1inf_sorted, project_l1inf_newton,
    project_l1inf_newton_stats, project_l1inf_segmented, theta_l1inf,
    active_compaction,
    project_l1inf_heap, project_l1inf_naive, theta_l1inf_heap,
    project_l1inf_quattoni, project_l1inf_bejar, project_l1inf_newton_np,
    project_l1inf_masked, l1inf_column_mask,
    project_l1_ball, project_l12_ball, project_simplex_sort, prox_linf1,
    project_weighted_l1_ball,
)

ALL_IMPLS = {
    "heap": lambda Y, C: project_l1inf_heap(np.asarray(Y), C),
    "naive": lambda Y, C: project_l1inf_naive(np.asarray(Y), C),
    "quattoni": lambda Y, C: project_l1inf_quattoni(np.asarray(Y), C),
    "bejar": lambda Y, C: project_l1inf_bejar(np.asarray(Y), C),
    "newton_np": lambda Y, C: project_l1inf_newton_np(np.asarray(Y), C),
    "sorted_jax": lambda Y, C: np.asarray(project_l1inf_sorted(jnp.asarray(Y, jnp.float64 if jax.config.read('jax_enable_x64') else jnp.float32), C)),
    "newton_jax": lambda Y, C: np.asarray(project_l1inf_newton(jnp.asarray(Y, jnp.float64 if jax.config.read('jax_enable_x64') else jnp.float32), C)),
}


def _norm(X):
    return np.abs(X).max(axis=0).sum()


def _check_kkt(Y, X, C, tol=1e-5):
    """Structural optimality: X in ball; per-column clip at mu_j; active
    columns all shed the same mass theta; dominated columns are zero."""
    Y = np.asarray(Y, dtype=np.float64)
    X = np.asarray(X, dtype=np.float64)
    A = np.abs(Y)
    P = np.abs(X)
    scale = max(A.max(), 1.0)
    assert _norm(X) <= C * (1 + 1e-4) + 1e-6
    # signs preserved, |X| <= |Y|
    assert np.all(P <= A + tol * scale)
    assert np.all(X * Y >= -tol * scale)
    if _norm(Y) <= C:  # interior: identity
        np.testing.assert_allclose(X, Y, atol=tol * scale)
        return
    mu = P.max(axis=0)
    # clipping structure: X_ij = min(Y_ij, mu_j) on live columns
    live = mu > tol * scale
    np.testing.assert_allclose(
        P[:, live], np.minimum(A[:, live], mu[None, live]), atol=tol * scale)
    # equal removed mass theta on live columns
    removed = (A - P).sum(axis=0)
    if live.sum() > 1:
        th = removed[live]
        assert th.std() <= 10 * tol * scale * np.sqrt(A.shape[0]), th
    # dominated columns: colsum <= theta (+tol)
    if live.any():
        theta = removed[live].mean()
        dead = ~live
        assert np.all(A[:, dead].sum(axis=0) <= theta + 10 * tol * scale * A.shape[0] ** 0.5)
        # radius is tight when projecting from outside
        np.testing.assert_allclose(_norm(X), C, rtol=1e-4, atol=1e-6 * scale)


def _brute_force(Y, C, iters=60_000, lr=None):
    """Projected-subgradient polish of the naive solution is overkill; instead
    verify optimality by comparing distances against all impls."""
    return None


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("shape", [(5, 7), (20, 3), (1, 9), (16, 1), (30, 30)])
@pytest.mark.parametrize("Cfrac", [0.01, 0.3, 0.9, 1.5])
def test_all_impls_agree(seed, shape, Cfrac):
    rng = np.random.default_rng(seed + hash(shape) % 1000)
    Y = rng.normal(size=shape) * rng.choice([0.2, 1.0, 5.0])
    C = float(Cfrac * _norm(Y))
    if C <= 0:
        return
    results = {k: f(Y, C) for k, f in ALL_IMPLS.items()}
    ref = results["heap"]
    _check_kkt(Y, ref, C)
    for name, X in results.items():
        np.testing.assert_allclose(
            X, ref, atol=5e-5 * max(np.abs(Y).max(), 1), rtol=1e-4,
            err_msg=f"{name} disagrees with heap oracle")


@pytest.mark.parametrize("impl", list(ALL_IMPLS))
def test_distance_optimality_cross(impl):
    """No implementation may find a strictly better (closer) feasible point
    than another: all distances must match to fp tolerance."""
    rng = np.random.default_rng(42)
    Y = rng.uniform(0, 1, size=(40, 25))
    C = 2.0
    dists = {}
    for name, f in ALL_IMPLS.items():
        X = np.asarray(f(Y, C), dtype=np.float64)
        assert _norm(X) <= C * (1 + 1e-5)
        dists[name] = np.sum((X - Y) ** 2)
    d = dists[impl]
    dmin = min(dists.values())
    assert d <= dmin * (1 + 1e-6) + 1e-9


def test_special_cases():
    Y = np.zeros((4, 5))
    np.testing.assert_array_equal(project_l1inf_heap(Y, 1.0), Y)
    X = project_l1inf_heap(np.ones((3, 3)), 0.0)
    np.testing.assert_array_equal(X, np.zeros((3, 3)))
    # single column == simplex-style water filling on that column
    Y = np.array([[3.0], [2.0], [-1.0]])
    X = project_l1inf_heap(Y, 2.0)  # mu = C = 2 -> clip at 2
    np.testing.assert_allclose(X, [[2.0], [2.0], [-1.0]])
    # negative signs preserved
    Y = np.array([[-5.0, 1.0], [0.5, -2.0]])
    X = project_l1inf_heap(Y, 1.0)
    assert _norm(X) <= 1.0 + 1e-12
    assert X[0, 0] <= 0 and X[1, 1] <= 0


def test_theta_consistency():
    rng = np.random.default_rng(0)
    Y = rng.uniform(0, 1, size=(50, 60))
    for C in [0.5, 5.0, 20.0]:
        th_heap = theta_l1inf_heap(Y, C)
        th_jax = float(theta_l1inf(jnp.asarray(Y, jnp.float32), C))
        assert abs(th_heap - th_jax) <= 1e-3 * max(1.0, th_heap)
        # removed mass per live column equals theta
        X = project_l1inf_heap(Y, C)
        removed = (np.abs(Y) - np.abs(X)).sum(axis=0)
        live = np.abs(X).max(axis=0) > 1e-12
        np.testing.assert_allclose(removed[live], th_heap, rtol=1e-8)


def test_axis_transpose():
    rng = np.random.default_rng(3)
    Y = rng.normal(size=(6, 11)).astype(np.float32)
    X0 = np.asarray(project_l1inf_newton(jnp.asarray(Y), 1.7, axis=0))
    X1 = np.asarray(project_l1inf_newton(jnp.asarray(Y.T), 1.7, axis=1))
    np.testing.assert_allclose(X0, X1.T, atol=1e-6)


def test_idempotency_and_nonexpansiveness():
    rng = np.random.default_rng(7)
    Y1 = rng.normal(size=(12, 9)).astype(np.float32)
    Y2 = (Y1 + 0.1 * rng.normal(size=(12, 9))).astype(np.float32)
    C = 1.3
    P1 = np.asarray(project_l1inf_newton(jnp.asarray(Y1), C))
    P2 = np.asarray(project_l1inf_newton(jnp.asarray(Y2), C))
    # projection is firmly non-expansive
    assert np.linalg.norm(P1 - P2) <= np.linalg.norm(Y1 - Y2) * (1 + 1e-5)
    PP1 = np.asarray(project_l1inf_newton(jnp.asarray(P1), C))
    np.testing.assert_allclose(PP1, P1, atol=2e-6)


def test_masked_projection():
    rng = np.random.default_rng(9)
    Y = rng.normal(size=(8, 30)).astype(np.float32)
    C = 0.4 * _norm(Y)
    Xm = np.asarray(project_l1inf_masked(jnp.asarray(Y), C))
    X = np.asarray(project_l1inf_newton(jnp.asarray(Y), C))
    dead_m = np.all(Xm == 0, axis=0)
    dead_p = np.abs(X).max(axis=0) <= 1e-7
    np.testing.assert_array_equal(dead_m, dead_p)  # identical column support
    live = ~dead_m
    np.testing.assert_allclose(Xm[:, live], Y[:, live], atol=1e-7)  # unclipped
    mask = np.asarray(l1inf_column_mask(jnp.asarray(Y), C))
    np.testing.assert_array_equal(mask, live)
    # inside ball: identity
    Yin = Y * (0.5 * C / _norm(Y))
    np.testing.assert_allclose(
        np.asarray(project_l1inf_masked(jnp.asarray(Yin), C)), Yin, atol=0)


def test_moreau_identity():
    """prox of the dual norm: x = prox_{C||.||inf1}(y) + P_{B1inf}(y)."""
    rng = np.random.default_rng(11)
    Y = jnp.asarray(rng.normal(size=(10, 6)), jnp.float32)
    C = 2.1
    p = prox_linf1(Y, C)
    P = project_l1inf_newton(Y, C)
    np.testing.assert_allclose(np.asarray(p + P), np.asarray(Y), atol=1e-6)
    # prox output has linf,1 norm subgradient property: colsums of the
    # projection part equal theta for live columns (checked elsewhere);
    # here check the prox shrinks the dual norm
    from repro.core import linf1_norm
    assert float(linf1_norm(p)) <= float(linf1_norm(Y)) + 1e-5


def test_theta_nonpositive_radius_regression():
    """C <= 0: theta must be the norm-removal threshold max_j ||y_j||_1
    (consistent with project_l1inf_*'s C > 0 gating returning zeros), not a
    degenerate Newton iterate."""
    rng = np.random.default_rng(21)
    Y = jnp.asarray(rng.normal(size=(12, 17)), jnp.float32)
    want = float(jnp.max(jnp.sum(jnp.abs(Y), axis=0)))
    for C in (0.0, -1.0, -100.0):
        got = float(theta_l1inf(Y, C))
        assert abs(got - want) <= 1e-4 * want, (C, got, want)
        X = np.asarray(project_l1inf_newton(Y, C))
        np.testing.assert_array_equal(X, np.zeros_like(X))
    # sanity: positive radius unaffected
    assert float(theta_l1inf(Y, 1.0)) < want


def test_newton_warm_start():
    """theta0 warm start: any value >= 0 gives the identical projection;
    an exact restart converges in the two bootstrap evaluations."""
    rng = np.random.default_rng(22)
    Y = jnp.asarray(rng.normal(size=(30, 60)), jnp.float32)
    C = float(0.2 * _norm(np.asarray(Y)))
    X, st = project_l1inf_newton_stats(Y, C)
    for th0 in (0.0, float(st["theta"]) / 3, float(st["theta"]),
                float(st["theta"]) * 5, 1e6):
        Xw, stw = project_l1inf_newton_stats(Y, C, theta0=jnp.float32(th0))
        np.testing.assert_allclose(np.asarray(Xw), np.asarray(X), atol=1e-6)
    _, st_exact = project_l1inf_newton_stats(Y, C, theta0=st["theta"])
    assert int(st_exact["iters"]) == 2
    assert int(st["iters"]) > 2


def test_newton_warm_start_sgd_sequence():
    """Steady-state SGD: warm-started solves use (far) fewer Eq.-(19)
    evaluations than cold ones."""
    rng = np.random.default_rng(23)
    Y = np.asarray(rng.normal(size=(40, 80)), np.float32)
    C = float(0.15 * _norm(Y))
    theta = None
    warm, cold = [], []
    for t in range(6):
        Yj = jnp.asarray(Y, jnp.float32)
        _, st_c = project_l1inf_newton_stats(Yj, C)
        X, st_w = (project_l1inf_newton_stats(Yj, C) if theta is None else
                   project_l1inf_newton_stats(Yj, C, theta0=theta))
        cold.append(int(st_c["iters"]))
        warm.append(int(st_w["iters"]))
        theta = st_w["theta"]
        Y = np.asarray(X) + 1e-5 * rng.normal(size=Y.shape).astype(np.float32)
    assert sum(warm[2:]) < sum(cold[2:]), (warm, cold)


def test_max_iter_cap_keeps_theta_mu_consistent():
    """When the iteration cap cuts the ascent short, the returned X must be
    the clip at the water level of the RETURNED theta (not one iterate
    behind), and the cap must never make things worse than fewer
    iterations."""
    from repro.core.l1inf import _sorted_stats, _theta_state
    rng = np.random.default_rng(25)
    scale = np.exp(2 * rng.normal(size=(1, 512)))
    Y = jnp.asarray(rng.uniform(0, 1, size=(32, 512)) * scale, jnp.float32)
    C = float(0.001 * _norm(np.asarray(Y)))
    prev_norm = np.inf
    for cap in (3, 4, 6, 32):
        X, st = project_l1inf_newton_stats(Y, C, max_iter=cap)
        A = jnp.abs(Y)
        Z, S, b = _sorted_stats(A)
        k, S_k, act = _theta_state(S, b, st["theta"])
        mu = np.asarray(jnp.where(act, jnp.maximum(
            (S_k - st["theta"]) / k, 0.0), 0.0))
        mu_X = np.abs(np.asarray(X)).max(axis=0)
        clipped = mu < np.asarray(A).max(axis=0)
        np.testing.assert_allclose(mu_X[clipped], mu[clipped], atol=1e-6)
        norm = float(_norm(np.asarray(X)))
        assert norm <= prev_norm * (1 + 1e-6)   # monotone toward the ball
        prev_norm = norm
    np.testing.assert_allclose(prev_norm, C, rtol=1e-4)  # converged at 32


def test_active_compaction_roundtrip():
    rng = np.random.default_rng(24)
    mask = jnp.asarray(rng.random(37) < 0.4)
    perm, num = active_compaction(mask)
    perm = np.asarray(perm)
    assert int(num) == int(np.asarray(mask).sum())
    # active columns occupy the leading slots; scatter-back is exact
    assert np.asarray(mask)[perm][: int(num)].all()
    assert not np.asarray(mask)[perm][int(num):].any()
    x = rng.normal(size=37)
    packed = x[perm]
    out = np.zeros(37)
    out[perm] = packed
    np.testing.assert_array_equal(out, x)


@pytest.mark.parametrize("seed", [0, 1])
def test_segmented_matches_per_matrix(seed):
    """Packed segmented solve == per-matrix solve on every segment."""
    rng = np.random.default_rng(100 + seed)
    sizes = [(rng.integers(1, 30), rng.integers(1, 25)) for _ in range(4)]
    n_max = max(n for n, _ in sizes)
    cols, sids, Cs, mats = [], [], [], []
    for g, (n, m) in enumerate(sizes):
        Yg = rng.normal(size=(n, m)) * float(rng.choice([0.1, 1.0, 10.0]))
        nrm = _norm(Yg)
        pad = np.zeros((n_max, m), np.float32)
        pad[:n] = Yg
        cols.append(pad)
        sids += [g] * int(m)
        Cs.append(float(max(rng.uniform(0.05, 1.2) * nrm, 1e-3)))
        mats.append(Yg)
    Yp = jnp.asarray(np.concatenate(cols, axis=1))
    sids = np.array(sids, np.int32)
    X, theta, iters = project_l1inf_segmented(
        Yp, jnp.asarray(sids), jnp.asarray(np.array(Cs, np.float32)),
        num_segments=4)
    X = np.asarray(X)
    for g, (n, m) in enumerate(sizes):
        Xg = np.asarray(project_l1inf_newton(
            jnp.asarray(mats[g], jnp.float32), Cs[g]))
        scale = max(np.abs(mats[g]).max(), 1.0)
        np.testing.assert_allclose(X[:n, sids == g], Xg,
                                   atol=5e-5 * scale, rtol=1e-4,
                                   err_msg=f"segment {g}")
        # zero row padding projects to zero
        np.testing.assert_allclose(X[n:, sids == g], 0.0, atol=1e-7 * scale)


# ------------------------------ simplex / l1 -------------------------------

def test_simplex_matches_michelot():
    from repro.core.simplex import project_simplex_michelot_np
    rng = np.random.default_rng(1)
    for _ in range(20):
        y = rng.normal(size=37)
        z = float(rng.uniform(0.1, 3.0))
        a = project_simplex_michelot_np(y, z)
        b = np.asarray(project_simplex_sort(jnp.asarray(y, jnp.float64 if jax.config.read('jax_enable_x64') else jnp.float32), z))
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_l1_ball():
    rng = np.random.default_rng(2)
    y = rng.normal(size=(13,)).astype(np.float32)
    x = np.asarray(project_l1_ball(jnp.asarray(y), 1.0))
    assert np.abs(x).sum() <= 1.0 + 1e-5
    # inside: identity
    y2 = y / (np.abs(y).sum() * 2)
    np.testing.assert_allclose(np.asarray(project_l1_ball(jnp.asarray(y2), 1.0)), y2)
    # weighted with w=1 equals unweighted
    xw = np.asarray(project_weighted_l1_ball(jnp.asarray(y), jnp.ones(13), 1.0))
    np.testing.assert_allclose(xw, x, atol=1e-5)


def test_l12_ball():
    rng = np.random.default_rng(4)
    Y = rng.normal(size=(6, 9)).astype(np.float32)
    C = 2.0
    X = np.asarray(project_l12_ball(jnp.asarray(Y), C))
    assert np.sqrt((X ** 2).sum(axis=0)).sum() <= C * (1 + 1e-5)
    # direction of every surviving column preserved
    for j in range(9):
        nX, nY = np.linalg.norm(X[:, j]), np.linalg.norm(Y[:, j])
        if nX > 1e-7:
            cos = X[:, j] @ Y[:, j] / (nX * nY)
            assert cos > 1 - 1e-5


# ------------------------------ hypothesis ---------------------------------

@settings(max_examples=60, deadline=None)
@given(
    n=st.integers(1, 24), m=st.integers(1, 24),
    cfrac=st.floats(0.005, 1.4), seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([1e-2, 1.0, 1e3]),
)
def test_property_heap_vs_jax(n, m, cfrac, seed, scale):
    rng = np.random.default_rng(seed)
    Y = rng.normal(size=(n, m)) * scale
    nrm = _norm(Y)
    if nrm <= 0:
        return
    C = float(cfrac * nrm)
    Xh = project_l1inf_heap(Y, C)
    Xj = np.asarray(project_l1inf_sorted(jnp.asarray(Y, jnp.float32), C))
    _check_kkt(Y, Xh, C, tol=1e-7)
    np.testing.assert_allclose(Xj, Xh, atol=2e-4 * scale, rtol=2e-3)


@settings(max_examples=40, deadline=None)
@given(n=st.integers(1, 16), m=st.integers(1, 16),
       seed=st.integers(0, 2**31 - 1))
def test_property_sparse_inputs(n, m, seed):
    """Heavily sparse + tied inputs (the paper's regime + degenerate ties)."""
    rng = np.random.default_rng(seed)
    Y = rng.choice([0.0, 0.0, 1.0, -1.0, 2.0], size=(n, m))
    nrm = _norm(Y)
    if nrm == 0:
        return
    C = float(0.3 * nrm)
    Xh = project_l1inf_heap(Y, C)
    Xn = np.asarray(project_l1inf_newton(jnp.asarray(Y, jnp.float32), C))
    _check_kkt(Y, Xh, C, tol=1e-7)
    np.testing.assert_allclose(Xn, Xh, atol=5e-5, rtol=1e-4)
