"""Test-suite bootstrap.

* Puts src/ on sys.path so `python -m pytest` works without PYTHONPATH
  (pyproject's pythonpath ini handles pytest>=7; this covers direct runs).
* Falls back to the deterministic hypothesis stub (tests/_hypothesis_stub.py)
  when the real hypothesis package is not installed, so the property-test
  modules collect and run everywhere (the CI container has no network).
"""
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SRC = os.path.join(_ROOT, "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import _hypothesis_stub
    _hypothesis_stub.install(sys.modules)
