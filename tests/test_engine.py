"""ProjectionEngine: the unified projected-update step core.

Covers: solver dispatch + functional-shim equivalence, the shared
``projected_update`` core against the hand-rolled adam+project sequence,
warm-started Newton in the PRODUCTION train step (steady-state evals <= 2,
via the step's stats/metrics), theta-state checkpoint/restore in the runner
loop (incl. the pre-engine-checkpoint fallback), per-plan invocation
counters, and the ``column_masks``/``sparsity_report`` axis arithmetic on
stacked (ndim>2) leaves and axis=1 specs.
"""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (ProjectionEngine, ProjectionSpec, apply_constraints,
                        apply_constraints_packed, column_masks,
                        engine_counters, engine_counters_reset,
                        init_projection_state, sparsity_report)
from repro.optim import AdamConfig, adam_init, adam_update


def _params(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "enc1": {"w": jnp.asarray(rng.normal(size=(24, 50)), jnp.float32)},
        "blocks": {"mlp_w1": jnp.asarray(rng.normal(size=(3, 16, 40)),
                                         jnp.float32)},
    }


SPECS = (ProjectionSpec(pattern=r"enc1/w", norm="l1inf", radius=2.0, axis=1),
         ProjectionSpec(pattern=r"mlp_w1", norm="l1inf", radius=1.5, axis=0))


def _tol(a, b, tol=5e-6):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# engine dispatch
# ---------------------------------------------------------------------------

def test_engine_apply_matches_functional_shim():
    params = _params()
    eng = ProjectionEngine(SPECS)
    state0 = eng.init_state(params)
    shim0 = init_projection_state(params, SPECS)
    assert set(state0) == set(shim0)
    for k in state0:
        np.testing.assert_array_equal(np.asarray(state0[k]),
                                      np.asarray(shim0[k]))
    out_e, st_e = eng.apply(params, state=state0)
    out_f, st_f = apply_constraints_packed(params, SPECS, state=state0)
    for a, b in zip(jax.tree_util.tree_leaves(out_e),
                    jax.tree_util.tree_leaves(out_f)):
        _tol(a, b)
    k = list(st_e)[0]
    _tol(st_e[k], st_f[k])


def test_engine_unknown_solver_and_missing_mesh():
    with pytest.raises(ValueError):
        ProjectionEngine(SPECS, solver="magic")
    with pytest.raises(ValueError):
        ProjectionEngine(SPECS, solver="sharded")


def test_engine_with_stats_reports_warm_start_drop():
    params = _params(1)
    eng = ProjectionEngine(SPECS)
    state0 = eng.init_state(params)
    out, st, stats = eng.apply(params, state=state0, with_stats=True)
    key = list(st)[0]
    cold_iters = int(stats[key])
    assert cold_iters > 2                      # cold solve iterates
    _, _, stats2 = eng.apply(params, state=st, with_stats=True)
    assert int(stats2[key]) <= 2               # exact restart: bootstrap only


def test_engine_counters_per_plan_and_reset():
    params = _params(2)
    engine_counters_reset()
    eng = ProjectionEngine(SPECS)
    eng.apply(params, state=eng.init_state(params))
    counts = engine_counters()
    assert counts == {"l1inf_packed/k1/newton": 1}
    eng_p = ProjectionEngine(SPECS, solver="pallas")
    eng_p.apply(params)
    counts = engine_counters()
    assert counts["l1inf_packed/k1/pallas"] == 1
    assert counts["l1inf_packed/k1/newton"] == 1   # untouched by pallas run
    engine_counters_reset()
    assert engine_counters() == {}


# ---------------------------------------------------------------------------
# the shared projected-update step core
# ---------------------------------------------------------------------------

def test_projected_update_matches_hand_rolled_sequence():
    params = _params(3)
    grads = jax.tree_util.tree_map(
        lambda p: jnp.ones_like(p) * 0.01, params)
    acfg = AdamConfig(lr=1e-2)
    opt = adam_init(params, acfg)
    eng = ProjectionEngine(SPECS)
    state0 = eng.init_state(params)

    p1, o1, s1 = eng.projected_update(grads, opt, params, acfg, state=state0)

    p_ref, o_ref = adam_update(grads, opt, params, acfg)
    p_ref, s_ref = apply_constraints_packed(p_ref, SPECS, step=o_ref.count,
                                            state=state0)
    assert int(o1.count) == int(o_ref.count)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p_ref)):
        _tol(a, b)
    k = list(s1)[0]
    _tol(s1[k], s_ref[k])


def test_projected_update_mask_freeze():
    """The mask zeroes both the gradient AND the post-projection params
    (double-descent support freeze)."""
    params = _params(4)
    mask = jax.tree_util.tree_map(jnp.ones_like, params)
    mask["enc1"]["w"] = mask["enc1"]["w"].at[:, :10].set(0.0)
    grads = jax.tree_util.tree_map(lambda p: jnp.ones_like(p) * 0.1, params)
    acfg = AdamConfig(lr=1e-2)
    opt = adam_init(params, acfg)
    eng = ProjectionEngine(SPECS)
    p1, _, _ = eng.projected_update(grads, opt, params, acfg, mask=mask,
                                    state=eng.init_state(params))
    np.testing.assert_array_equal(np.asarray(p1["enc1"]["w"][:, :10]), 0.0)


def test_production_step_warm_start_steady_state():
    """Acceptance: the production train step (launch/steps.build_train_step)
    is warm-started — steady-state Newton evals <= 2, read from the step's
    metrics (the theta state threads through the step signature)."""
    from repro.configs import get_reduced
    from repro.models.zoo import build, make_batch
    from repro.launch.steps import build_train_step, projection_engine_for
    from repro.optim import adam_init as _init

    cfg = get_reduced("stablelm_3b")
    # every_k=1 so every step projects (the reduced spec gates at k=10)
    cfg = dataclasses.replace(cfg, projection_specs=tuple(
        dataclasses.replace(s, every_k=1) for s in cfg.projection_specs))
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 16, kind="train")
    acfg = AdamConfig(lr=1e-4)
    opt = _init(params, acfg)
    proj = projection_engine_for(cfg, None).init_state(params)
    assert proj, "reduced config should build at least one packed plan"

    step = jax.jit(build_train_step(model, None, None, acfg))
    extra = []
    for _ in range(6):
        loss, metrics, params, opt, proj = step(params, opt, proj, batch)
        extra.append(int(metrics["proj_newton_extra_evals"]))
    # "extra evals" = Eq.-(19) evaluations beyond the 2-eval bootstrap floor
    # (the accounting of BENCH_proj.json's warm_start section)
    assert extra[0] > 2, extra                  # cold start really is cold
    assert max(extra[3:]) <= 2, extra           # warm: steady state <= 2


def test_train_loop_checkpoints_theta_state(tmp_path):
    """Satellite: a resume restores the projection theta state instead of
    silently cold-starting Newton."""
    from repro.configs import get_reduced
    from repro.models.zoo import build
    from repro.train.loop import TrainConfig, train
    from repro.data.pipeline import SyntheticLM, LMBatcher
    from repro.checkpoint import restore

    cfg = get_reduced("stablelm_3b")
    cfg = dataclasses.replace(cfg, projection_specs=tuple(
        dataclasses.replace(s, every_k=1) for s in cfg.projection_specs))
    model = build(cfg)
    batcher = LMBatcher(SyntheticLM(cfg.vocab, seed=1), 2, 16)
    ckpt_dir = str(tmp_path / "ck")
    tcfg = TrainConfig(steps=2, log_every=100, ckpt_every=100,
                       ckpt_dir=ckpt_dir)
    out1 = train(model, batcher, tcfg, resume=False)
    theta1 = {k: np.asarray(v) for k, v in out1["proj_state"].items()}
    assert any(v.max() > 0 for v in theta1.values()), theta1

    # the checkpoint on disk carries the proj leaves
    flat, step = restore(ckpt_dir)
    assert step == 2
    assert any(k.startswith("proj/") for k in flat), sorted(flat)

    # resume: starts from step 2 with the saved theta (and trains on)
    out2 = train(model, batcher, dataclasses.replace(tcfg, steps=4),
                 resume=True)
    assert len(out2["losses"]) == 2             # steps 2..3 only
    assert all(np.isfinite(l) for l in out2["losses"])


def test_train_loop_restores_pre_engine_checkpoint(tmp_path):
    """Back-compat: checkpoints written before the proj state existed
    restore fine (cold Newton start instead of a crash)."""
    from repro.configs import get_reduced
    from repro.models.zoo import build
    from repro.train.loop import TrainConfig, train
    from repro.checkpoint import save
    from repro.data.pipeline import SyntheticLM, LMBatcher
    from repro.optim import adam_init as _init

    cfg = get_reduced("stablelm_3b")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = _init(params, AdamConfig(lr=3e-4))
    ckpt_dir = str(tmp_path / "old")
    save({"params": params, "opt": opt}, ckpt_dir, 1)   # no "proj" leaves

    batcher = LMBatcher(SyntheticLM(cfg.vocab, seed=1), 2, 16)
    out = train(model, batcher,
                TrainConfig(steps=3, log_every=100, ckpt_dir=ckpt_dir),
                resume=True)
    assert len(out["losses"]) == 2              # resumed from step 1
    assert all(np.isfinite(l) for l in out["losses"])


# ---------------------------------------------------------------------------
# optimizer regressions (optim/adam.py)
# ---------------------------------------------------------------------------

def test_adam_frozen_params_immobile_under_weight_decay():
    """Regression: decoupled weight decay must not move masked-out params —
    the mask zeroes the WHOLE step, not just the gradient. A frozen entry
    stays bit-identical across steps even with weight_decay > 0."""
    params = _params(8)
    mask = jax.tree_util.tree_map(jnp.ones_like, params)
    mask["enc1"]["w"] = mask["enc1"]["w"].at[:, :17].set(0.0)
    grads = jax.tree_util.tree_map(lambda p: 0.1 * jnp.ones_like(p), params)
    acfg = AdamConfig(lr=1e-2, weight_decay=0.1)
    opt = adam_init(params, acfg)
    frozen0 = np.asarray(params["enc1"]["w"][:, :17]).copy()
    p = params
    for _ in range(5):
        p, opt = adam_update(grads, opt, p, acfg, mask=mask)
    np.testing.assert_array_equal(np.asarray(p["enc1"]["w"][:, :17]),
                                  frozen0)
    # the unmasked region did move
    assert np.abs(np.asarray(p["enc1"]["w"][:, 17:])
                  - np.asarray(params["enc1"]["w"][:, 17:])).max() > 0


def test_adam_update_matches_naive_reference():
    """The single-tree_map restructure of ``adam_update`` changes no math:
    it must match an inline per-leaf transcription of the update bit-for-bit
    (fp32 moments, clip, schedule override, mask)."""
    from repro.optim.adam import clip_scale

    params = _params(9)
    rng = np.random.default_rng(11)
    grads = jax.tree_util.tree_map(
        lambda p: jnp.asarray(rng.normal(size=p.shape), jnp.float32), params)
    mask = jax.tree_util.tree_map(jnp.ones_like, params)
    mask["blocks"]["mlp_w1"] = mask["blocks"]["mlp_w1"].at[1].set(0.0)
    acfg = AdamConfig(lr=1e-2, weight_decay=0.03, clip_norm=0.5)
    opt = adam_init(params, acfg)
    lr = 7e-3

    new_p, new_opt = adam_update(grads, opt, params, acfg, lr=lr, mask=mask)

    count = opt.count + 1
    b1c = 1.0 - acfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - acfg.b2 ** count.astype(jnp.float32)
    scale = clip_scale(grads, acfg.clip_norm)
    flat_p = jax.tree_util.tree_leaves(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt.mu)
    flat_v = jax.tree_util.tree_leaves(opt.nu)
    flat_mk = jax.tree_util.tree_leaves(mask)
    for p0, g, m, v, mk, p1, m1, v1 in zip(
            flat_p, flat_g, flat_m, flat_v, flat_mk,
            jax.tree_util.tree_leaves(new_p),
            jax.tree_util.tree_leaves(new_opt.mu),
            jax.tree_util.tree_leaves(new_opt.nu)):
        g = (g * scale).astype(g.dtype) * mk
        m_ref = acfg.b1 * m + (1 - acfg.b1) * g
        v_ref = acfg.b2 * v + (1 - acfg.b2) * g * g
        step = lr * (m_ref / b1c) / (jnp.sqrt(v_ref / b2c) + acfg.eps)
        step = (step + lr * acfg.weight_decay * p0) * mk
        np.testing.assert_array_equal(np.asarray(p0 - step), np.asarray(p1))
        np.testing.assert_array_equal(np.asarray(m_ref), np.asarray(m1))
        np.testing.assert_array_equal(np.asarray(v_ref), np.asarray(v1))
    assert int(new_opt.count) == 1


# ---------------------------------------------------------------------------
# column_masks / sparsity_report axis arithmetic (previously untested)
# ---------------------------------------------------------------------------

def _stacked_leaf():
    """(2, 4, 6) stacked leaf: layer 0 has dead columns {1, 3} along the
    axis=0 convention (max over rows -> columns indexed by the last dim);
    layer 1 has dead column {5}."""
    x = np.ones((2, 4, 6), np.float32)
    x[0, :, 1] = 0.0
    x[0, :, 3] = 0.0
    x[1, :, 5] = 0.0
    return jnp.asarray(x)


def test_column_masks_stacked_axis0():
    params = {"blocks": {"w": _stacked_leaf()}}
    specs = (ProjectionSpec(pattern=r"blocks/w", norm="l1inf", radius=1.0,
                            axis=0),)
    m = np.asarray(column_masks(params, specs)["blocks"]["w"])
    assert m.shape == (2, 4, 6)
    np.testing.assert_array_equal(m[0, :, 1], 0.0)
    np.testing.assert_array_equal(m[0, :, 3], 0.0)
    np.testing.assert_array_equal(m[1, :, 5], 0.0)
    np.testing.assert_array_equal(m[0, :, 0], 1.0)
    np.testing.assert_array_equal(m[1, :, 3], 1.0)   # per-layer support
    assert float(m.sum()) == 2 * 4 * 6 - 3 * 4


def test_column_masks_stacked_axis1_and_negative():
    """axis=1 (and its negative alias -1): the max runs over the LAST dim,
    prunable structures are the rows of the trailing slice."""
    x = np.ones((2, 4, 6), np.float32)
    x[0, 2, :] = 0.0            # layer 0, row 2 dead
    params = {"w": jnp.asarray(x)}
    for ax in (1, -1):
        specs = (ProjectionSpec(pattern=r"w", norm="l1inf", radius=1.0,
                                axis=ax),)
        m = np.asarray(column_masks(params, specs)["w"])
        np.testing.assert_array_equal(m[0, 2, :], 0.0)
        assert float(m.sum()) == 2 * 4 * 6 - 6, f"axis={ax}"


def test_column_masks_2d_negative_axis():
    x = np.ones((4, 6), np.float32)
    x[:, 2] = 0.0
    params = {"w": jnp.asarray(x)}
    for ax in (0, -2):          # -2 aliases 0 on a 2-D leaf
        specs = (ProjectionSpec(pattern=r"w", norm="l1inf", radius=1.0,
                                axis=ax),)
        m = np.asarray(column_masks(params, specs)["w"])
        np.testing.assert_array_equal(m[:, 2], 0.0)
        assert float(m.sum()) == 4 * 6 - 4, f"axis={ax}"


def test_sparsity_report_stacked_and_axis1():
    params = {"blocks": {"w": _stacked_leaf()}}
    specs = (ProjectionSpec(pattern=r"blocks/w", norm="l1inf", radius=1.0,
                            axis=0),)
    rep = sparsity_report(params, specs)
    assert rep["blocks/w"] == pytest.approx(100.0 * 3 / 12)

    x = np.ones((2, 4, 6), np.float32)
    x[0, 2, :] = 0.0
    x[1, 0, :] = 0.0
    x[1, 3, :] = 0.0
    specs1 = (ProjectionSpec(pattern=r"w", norm="l1inf", radius=1.0,
                             axis=1),)
    rep1 = sparsity_report({"w": jnp.asarray(x)}, specs1)
    assert rep1["w"] == pytest.approx(100.0 * 3 / 8)

    # negative axis alias agrees
    repn = sparsity_report({"w": jnp.asarray(x)},
                           (dataclasses.replace(specs1[0], axis=-1),))
    assert repn["w"] == rep1["w"]


def test_masks_match_projection_support_after_projection():
    """End-to-end: project, then the mask's zero pattern equals the actual
    column support on every leaf shape (2-D, stacked, axis=1)."""
    params = _params(7)
    out, _ = apply_constraints_packed(
        params, tuple(dataclasses.replace(s, radius=0.5) for s in SPECS))
    specs = tuple(dataclasses.replace(s, radius=0.5) for s in SPECS)
    masks = column_masks(out, specs)
    w = np.asarray(out["blocks"]["mlp_w1"])
    m = np.asarray(masks["blocks"]["mlp_w1"])
    dead = np.all(w == 0, axis=1)               # (3, 40) per-layer columns
    np.testing.assert_array_equal(m.transpose(0, 2, 1).all(axis=2), ~dead)
    w2 = np.asarray(out["enc1"]["w"])
    m2 = np.asarray(masks["enc1"]["w"])
    dead2 = np.all(w2 == 0, axis=1)             # axis=1 spec: max over cols
    np.testing.assert_array_equal(m2.all(axis=1), ~dead2)
