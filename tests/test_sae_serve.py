"""Compacted SAE serving (sae/serve.py, DESIGN.md §9): support derivation,
compact-vs-dense exactness on the support, and the edge cases — all-dead
leaf, zero-dead leaf (identity compaction), bf16 params, stacked (ndim > 2)
encoder leaves, and equality under jit."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (ProjectionSpec, apply_constraints, compact_columns,
                        support_indices)
from repro.sae import (SAEConfig, sae_init, sae_apply, compact_sae,
                       compact_leaf, support_selection, make_serve_step,
                       make_classification, train_test_split, train_sae,
                       SAETrainConfig)
from repro.sae.serve import LeafSupport


def _projected_params(d=256, h=24, radius=0.25, seed=0, dtype=jnp.float32):
    cfg = SAEConfig(n_features=d, n_hidden=h, n_classes=2)
    params = sae_init(jax.random.PRNGKey(seed), cfg)
    params = jax.tree_util.tree_map(lambda p: p.astype(dtype), params)
    spec = ProjectionSpec(pattern=r"enc1/w", norm="l1inf", radius=radius,
                          axis=1)
    return apply_constraints(params, (spec,)), spec


def test_support_matches_structural_zeros():
    params, spec = _projected_params()
    sup = support_selection(params, (spec,))["enc1/w"]
    w = np.asarray(params["enc1"]["w"])
    alive = np.any(w != 0, axis=1)
    np.testing.assert_array_equal(sup.sel, np.nonzero(alive)[0])
    assert sup.col_axis == 0 and sup.n_cols == w.shape[0]
    assert 0 < sup.n_selected < sup.n_cols   # the radius actually prunes
    assert sup.ratio == sup.n_selected / sup.n_cols


def test_compact_vs_dense_exact_on_support():
    params, spec = _projected_params()
    compact = compact_sae(params, (spec,))
    x = jnp.asarray(np.random.default_rng(1).normal(size=(32, 256)),
                    jnp.float32)
    z_d, xh_d = sae_apply(params, x)
    z_c, xh_c = compact.apply(compact.select(x))
    np.testing.assert_allclose(np.asarray(z_c), np.asarray(z_d),
                               rtol=0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(xh_c),
                               np.asarray(xh_d)[:, compact.sel],
                               rtol=0, atol=1e-5)
    # decoder-row co-compaction: output width equals the selected count
    assert xh_c.shape == (32, compact.n_selected)


def test_compact_vs_dense_under_jit():
    params, spec = _projected_params()
    compact = compact_sae(params, (spec,))
    step = make_serve_step(compact)          # jit'd, takes FULL-width x
    x = jnp.asarray(np.random.default_rng(2).normal(size=(8, 256)),
                    jnp.float32)
    z_c, xh_c = step(compact.params, x)
    z_d, xh_d = sae_apply(params, x)
    np.testing.assert_allclose(np.asarray(z_c), np.asarray(z_d),
                               rtol=0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(xh_c),
                               np.asarray(xh_d)[:, compact.sel],
                               rtol=0, atol=1e-5)
    # second call with fresh params of the same shapes must not retrace
    step(compact.params, x + 1.0)


def test_all_columns_dead_leaf():
    params, spec = _projected_params()
    params["enc1"]["w"] = jnp.zeros_like(params["enc1"]["w"])
    compact = compact_sae(params, (spec,))
    assert compact.n_selected == 0 and compact.compaction_ratio == 0.0
    assert compact.params["enc1"]["w"].shape == (0, 24)
    x = jnp.ones((4, 256), jnp.float32)
    z_c, xh_c = compact.apply(compact.select(x))   # (4, 0) input: bias-only
    z_d, _ = sae_apply(params, x)
    np.testing.assert_allclose(np.asarray(z_c), np.asarray(z_d),
                               rtol=0, atol=1e-6)
    assert xh_c.shape == (4, 0)


def test_zero_dead_leaf_identity():
    params, spec = _projected_params(radius=1e9)   # inside the ball
    compact = compact_sae(params, (spec,))
    assert compact.n_selected == compact.n_features
    np.testing.assert_array_equal(compact.sel, np.arange(256))
    np.testing.assert_array_equal(np.asarray(compact.params["enc1"]["w"]),
                                  np.asarray(params["enc1"]["w"]))
    np.testing.assert_array_equal(np.asarray(compact.params["dec2"]["w"]),
                                  np.asarray(params["dec2"]["w"]))


def test_bf16_params_roundtrip():
    params, spec = _projected_params(dtype=jnp.bfloat16)
    compact = compact_sae(params, (spec,))
    assert compact.params["enc1"]["w"].dtype == jnp.bfloat16
    assert compact.params["dec2"]["w"].dtype == jnp.bfloat16
    x = jnp.asarray(np.random.default_rng(3).normal(size=(8, 256)),
                    jnp.bfloat16)
    z_d, xh_d = sae_apply(params, x)
    z_c, xh_c = compact.apply(compact.select(x))
    # bf16 accumulation order differs between the two GEMM widths
    np.testing.assert_allclose(np.asarray(z_c, np.float32),
                               np.asarray(z_d, np.float32),
                               rtol=0, atol=5e-2)
    np.testing.assert_allclose(np.asarray(xh_c, np.float32),
                               np.asarray(xh_d, np.float32)[:, compact.sel],
                               rtol=0, atol=5e-2)


def test_stacked_leaf_union_support():
    """ndim > 2 leaves compact by the UNION of their slices' supports."""
    rng = np.random.default_rng(4)
    w = rng.normal(size=(3, 16, 8)).astype(np.float32)    # (L, d, h)
    w[:, 2, :] = 0.0          # dead feature in EVERY slice -> dropped
    w[0, 5, :] = 0.0          # dead in one slice only -> kept (union)
    params = {"enc1": {"w": jnp.asarray(w)}}
    spec = ProjectionSpec(pattern=r"enc1/w", norm="l1inf", radius=1e9,
                          axis=1)
    sup = support_selection(params, (spec,))["enc1/w"]
    assert sup.col_axis == 1 and sup.n_cols == 16
    assert 2 not in sup.sel and 5 in sup.sel
    assert sup.n_selected == 15
    wc = compact_leaf(params["enc1"]["w"], sup)
    assert wc.shape == (3, 15, 8)
    np.testing.assert_array_equal(np.asarray(wc), w[:, sup.sel, :])


def test_support_helpers_roundtrip():
    support = np.array([True, False, True, True, False])
    idx = support_indices(support)
    np.testing.assert_array_equal(idx, [0, 2, 3])
    x = jnp.arange(20, dtype=jnp.float32).reshape(4, 5)
    np.testing.assert_array_equal(np.asarray(compact_columns(x, idx, axis=1)),
                                  np.asarray(x)[:, idx])


def test_hidden_axis_refused():
    params, _ = _projected_params()
    spec = ProjectionSpec(pattern=r"enc1/w", norm="l1inf", radius=0.25,
                          axis=0)   # max over features -> prunes hidden
    with pytest.raises(ValueError, match="hidden"):
        compact_sae(params, (spec,))


def test_no_matching_leaf_refused():
    params, _ = _projected_params()
    spec = ProjectionSpec(pattern=r"nonexistent", norm="l1inf", radius=0.25,
                          axis=1)
    with pytest.raises(ValueError, match="enc1/w"):
        compact_sae(params, (spec,))


def test_train_reports_compaction_ratio():
    """The sae/train.py eval path: per-epoch compaction ratio reaches the
    final serving width and matches what compact_sae actually keeps."""
    X, y, _ = make_classification(n_samples=200, n_features=128,
                                  n_informative=8, class_sep=1.5, seed=7)
    X = (X - X.mean(0)) / (X.std(0) + 1e-6)
    Xtr, ytr, Xte, yte = train_test_split(X, y, 0.25, seed=0)
    spec = ProjectionSpec(pattern=r"enc1/w", norm="l1inf", radius=0.3,
                          axis=1)
    res = train_sae(Xtr, ytr, Xte, yte,
                    SAEConfig(n_features=128, n_hidden=16, n_classes=2),
                    SAETrainConfig(epochs=6, lr=2e-3, projection=spec,
                                   seed=0))
    assert [name for name, _ in res.compaction_history] == \
        ["descent1", "descent2"]
    for _, ratios in res.compaction_history:
        assert len(ratios) == 6
        assert all(0.0 <= r <= 1.0 for r in ratios)
    compact = compact_sae(res.params, (spec,))
    assert res.compaction_ratio == pytest.approx(compact.compaction_ratio)
    assert res.compaction_ratio == pytest.approx(
        res.compaction_history[-1][1][-1])
    # unconstrained baseline reports the trivial ratio
    res0 = train_sae(Xtr, ytr, Xte, yte,
                     SAEConfig(n_features=128, n_hidden=16, n_classes=2),
                     SAETrainConfig(epochs=2, lr=2e-3, projection=None,
                                    seed=0))
    assert res0.compaction_ratio == 1.0


def test_serve_step_follows_refreshed_support():
    """The support rides in the param tree: an old jit'd step fed a
    refreshed CompactSAE with the SAME J but a DIFFERENT surviving set
    serves the refreshed model correctly (no stale-closure gather)."""
    params, spec = _projected_params()
    # a second checkpoint with the support shifted by one feature index:
    # same J, different selected set, identical shapes (no retrace)
    params2 = {
        "enc1": {"w": jnp.roll(params["enc1"]["w"], 1, axis=0),
                 "b": params["enc1"]["b"]},
        "enc2": params["enc2"], "dec1": params["dec1"],
        "dec2": {"w": jnp.roll(params["dec2"]["w"], 1, axis=1),
                 "b": jnp.roll(params["dec2"]["b"], 1)},
    }
    c1 = compact_sae(params, (spec,))
    c2 = compact_sae(params2, (spec,))
    assert c1.n_selected == c2.n_selected
    assert not np.array_equal(c1.sel, c2.sel)
    x = jnp.asarray(np.random.default_rng(6).normal(size=(8, 256)),
                    jnp.float32)
    step = make_serve_step(c1)
    step(c1.params, x)                       # compile against checkpoint 1
    z_c, xh_c = step(c2.params, x)           # refresh: same step, new support
    z_d, xh_d = sae_apply(params2, x)
    np.testing.assert_allclose(np.asarray(z_c), np.asarray(z_d),
                               rtol=0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(xh_c),
                               np.asarray(xh_d)[:, c2.sel],
                               rtol=0, atol=1e-5)
