"""The fused optimizer+projection megakernel (kernels/fused_step, §11).

Covers: Pallas-interpret vs jnp-reference equality of both passes (odd
shapes, transpose, stacked leaves, masks, bf16 params with fp32 moments),
fused-vs-unfused ``projected_update`` equality across constraint families
(bilevel takes the megakernel; plain/weighted fall back bit-exactly),
warm-start theta threading through the fused solve, ``every_k`` gating
falling back to the unfused path, and the per-plan engine counters
distinguishing fused from fallback solves.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import (ProjectionEngine, ProjectionSpec, engine_counters,
                        engine_counters_reset)
from repro.core.constraints import build_packed_plans
from repro.kernels.fused_step import (fused_adam_clip_apply,
                                      fused_adam_colstats)
from repro.optim import AdamConfig, adam_init, adam_update


def _tol(a, b, tol=2e-6):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=tol, rtol=tol)


def _leaf_set(seed, shape, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 5)
    g, m, v, p, mk = [jax.random.normal(k, shape, jnp.float32) for k in ks]
    v = jnp.abs(v)
    mask = (mk > -0.5).astype(jnp.float32)
    return (g.astype(dtype), m, v, p.astype(dtype), mask)


# ---------------------------------------------------------------------------
# kernel vs reference (Pallas interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(48, 200), (33, 130), (3, 17, 96)])
@pytest.mark.parametrize("transpose", [False, True])
def test_pallas_matches_ref_both_passes(shape, transpose):
    g, m, v, p, mask = _leaf_set(0, shape)
    cfg = AdamConfig(lr=1e-2, weight_decay=0.01)
    kw = dict(cfg=cfg, lr_t=jnp.float32(1e-2), b1c=jnp.float32(0.3),
              b2c=jnp.float32(0.05), mask=mask, transpose=transpose)
    r = fused_adam_colstats(g, m, v, p, scale=jnp.float32(0.9),
                            impl="ref", **kw)
    q = fused_adam_colstats(g, m, v, p, scale=jnp.float32(0.9),
                            impl="pallas", interpret=True, **kw)
    for a, b in zip(r, q):
        assert a.shape == b.shape
        _tol(a, b, 2e-6)
    lead, mcols = r[2].shape
    mu = jnp.abs(jax.random.normal(jax.random.PRNGKey(9), (lead, mcols)))
    xr = fused_adam_clip_apply(r[0], r[1], p, mu, impl="ref", **kw)
    xq = fused_adam_clip_apply(r[0], r[1], p, mu, impl="pallas",
                               interpret=True, **kw)
    # interpret mode compiles the kernel body as one fused XLA computation,
    # so FMA contraction can wobble the last ulp vs the eager reference
    _tol(xr, xq, 1e-6)


def test_pallas_matches_ref_bf16_params_fp32_moments():
    g, m, v, p, _ = _leaf_set(1, (32, 160), dtype=jnp.bfloat16)
    cfg = AdamConfig(lr=1e-2, moment_dtype=jnp.float32)
    kw = dict(cfg=cfg, lr_t=jnp.float32(1e-2), b1c=jnp.float32(0.3),
              b2c=jnp.float32(0.05))
    r = fused_adam_colstats(g, m, v, p, impl="ref", **kw)
    q = fused_adam_colstats(g, m, v, p, impl="pallas", interpret=True, **kw)
    assert r[0].dtype == jnp.float32          # moments stay fp32
    for a, b in zip(r, q):
        _tol(a, b, 1e-6)
    mu = jnp.full(r[2].shape, 0.5, jnp.float32)
    xr = fused_adam_clip_apply(r[0], r[1], p, mu, impl="ref", **kw)
    xq = fused_adam_clip_apply(r[0], r[1], p, mu, impl="pallas",
                               interpret=True, **kw)
    assert xr.dtype == jnp.bfloat16           # params written in their dtype
    _tol(np.asarray(xr, np.float32), np.asarray(xq, np.float32), 1e-2)


def test_colstats_describe_the_rounded_update():
    """The statistics are taken on u AFTER rounding through the param dtype
    (the matrix pass 2 actually clips), not on the fp32 intermediate."""
    g, m, v, p, _ = _leaf_set(2, (16, 128), dtype=jnp.bfloat16)
    cfg = AdamConfig(lr=1e-2)
    kw = dict(cfg=cfg, lr_t=jnp.float32(1e-2), b1c=jnp.float32(0.3),
              b2c=jnp.float32(0.05))
    m_st, v_st, colsum, colmax = fused_adam_colstats(g, m, v, p,
                                                     impl="ref", **kw)
    # identity clip: pass 2 reproduces u itself — its stats must equal the
    # pass-1 statistics exactly
    mu = jnp.full(colsum.shape, 1e30, jnp.float32)
    u = fused_adam_clip_apply(m_st, v_st, p, mu, impl="ref", **kw)
    a = jnp.abs(u[None].astype(jnp.float32))
    np.testing.assert_array_equal(np.asarray(jnp.max(a, axis=1)),
                                  np.asarray(colmax))
    _tol(jnp.sum(a, axis=1), colsum, 1e-4)


# ---------------------------------------------------------------------------
# fused projected_update vs the unfused engine
# ---------------------------------------------------------------------------

def _tree(seed=0):
    key = jax.random.PRNGKey(seed)
    return {
        "enc1": {"w": jax.random.normal(jax.random.fold_in(key, 0),
                                        (24, 50)),
                 "b": jnp.zeros((50,))},
        "blocks": {"w": jax.random.normal(jax.random.fold_in(key, 1),
                                          (3, 16, 40))},
    }


def _run(engine, specs, acfg, steps=4, seed=0, mask=None):
    params = _tree(seed)
    grads = jax.tree_util.tree_map(
        lambda p: jax.random.normal(jax.random.PRNGKey(7), p.shape), params)
    opt = adam_init(params, acfg)
    state = engine.init_state(params)
    step = jax.jit(lambda g, o, p, s: engine.projected_update(
        g, o, p, acfg, mask=mask, state=s, with_stats=True))
    for _ in range(steps):
        params, opt, state, stats = step(grads, opt, params, state)
    return params, opt, state, stats


def _assert_same_run(specs, acfg, mask=None, tol=2e-6, seed=0):
    pn, on, sn, stn = _run(ProjectionEngine(specs), specs, acfg,
                           seed=seed, mask=mask)
    pf, of, sf, stf = _run(ProjectionEngine(specs, solver="fused"), specs,
                           acfg, seed=seed, mask=mask)
    for a, b in zip(jax.tree_util.tree_leaves(pn),
                    jax.tree_util.tree_leaves(pf)):
        _tol(a, b, tol)
    for a, b in zip(jax.tree_util.tree_leaves(on.mu),
                    jax.tree_util.tree_leaves(of.mu)):
        _tol(a, b, tol)
    assert set(sn) == set(sf)
    for k in sn:
        _tol(sn[k], sf[k], tol)
    return stn, stf


BILEVEL = (ProjectionSpec(pattern=r"enc1/w", norm="bilevel", radius=4.0),
           ProjectionSpec(pattern=r"blocks/w", norm="bilevel", radius=2.0,
                          axis=1))


def test_fused_equals_newton_bilevel():
    acfg = AdamConfig(lr=1e-2, weight_decay=0.01, clip_norm=1.0)
    engine_counters_reset()
    _assert_same_run(BILEVEL, acfg)
    counts = engine_counters()
    assert counts["bilevel_packed/k1/fused"] > 0
    assert counts["bilevel_packed/k1/newton"] > 0   # the unfused twin's runs
    engine_counters_reset()


def test_fused_equals_newton_with_mask():
    mask = jax.tree_util.tree_map(jnp.ones_like, _tree())
    mask["enc1"]["w"] = mask["enc1"]["w"].at[:, :12].set(0.0)
    acfg = AdamConfig(lr=1e-2, weight_decay=0.05)
    _assert_same_run(BILEVEL, acfg, mask=mask)
    # and the freeze really holds on the fused path
    pf, _, _, _ = _run(ProjectionEngine(BILEVEL, solver="fused"), BILEVEL,
                       acfg, mask=mask)
    np.testing.assert_array_equal(np.asarray(pf["enc1"]["w"][:, :12]), 0.0)


@pytest.mark.parametrize("norm,extra", [
    ("l1inf", {}),
    ("l1inf_weighted", {"weights": tuple(np.linspace(0.5, 2.0, 50))}),
])
def test_fused_falls_back_for_unfusable_families(norm, extra):
    """Plain/weighted need per-column sorted prefix sums — no streaming
    hook, so solver='fused' must replay the unfused path bit-exactly."""
    specs = (ProjectionSpec(pattern=r"enc1/w", norm=norm, radius=4.0,
                            **extra),)
    acfg = AdamConfig(lr=1e-2)
    engine_counters_reset()
    _assert_same_run(specs, acfg, tol=0.0)      # same code path: bit-equal
    counts = engine_counters()
    assert not any(k.endswith("/fused") for k in counts), counts
    engine_counters_reset()


def test_fused_every_k_gating_falls_back():
    """A gated bilevel plan (every_k > 1) cannot fuse (pass 1 must not move
    the params on skipped steps); it solves through the unfused path while
    a k=1 plan in the same spec list still takes the megakernel."""
    specs = (ProjectionSpec(pattern=r"enc1/w", norm="bilevel", radius=4.0),
             ProjectionSpec(pattern=r"blocks/w", norm="bilevel", radius=2.0,
                            axis=1, every_k=3))
    acfg = AdamConfig(lr=1e-2)
    engine_counters_reset()
    stn, stf = _assert_same_run(specs, acfg)
    counts = engine_counters()
    assert counts["bilevel_packed/k1/fused"] > 0
    assert counts["bilevel_packed/k3/newton"] > 0
    assert "bilevel_packed/k3/fused" not in counts
    engine_counters_reset()


def test_fused_warm_start_threads_theta():
    acfg = AdamConfig(lr=1e-3)
    engine = ProjectionEngine(BILEVEL, solver="fused")
    params = _tree(3)
    grads = jax.tree_util.tree_map(
        lambda p: jax.random.normal(jax.random.PRNGKey(5), p.shape) * 0.01,
        params)
    opt = adam_init(params, acfg)
    state = engine.init_state(params)
    step = jax.jit(lambda g, o, p, s: engine.projected_update(
        g, o, p, acfg, state=s, with_stats=True))
    iters = []
    for _ in range(6):
        params, opt, state, stats = step(grads, opt, params, state)
        iters.append(int(stats["bilevel_packed/k1"]))
    assert max(iters[2:]) <= 2, iters           # steady state: bootstrap only
    assert all(float(v.min()) >= 0 for v in state.values())


def test_fused_plan_detection_is_static():
    """Plan qualification happens at trace time on shapes alone."""
    params = _tree(0)
    plans, per_leaf = build_packed_plans(params, BILEVEL)
    assert len(plans) == 1 and not per_leaf
    plan = plans[0]
    sids = plan.virtual_seg_ids()
    assert sids.shape == (plan.virtual_num_cols(),)
    assert sids.shape[0] == 50 + 3 * 16          # no lane padding
    assert sids.max() == plan.num_segments - 1
    # entry order matches the concatenated statistics layout
    spans = np.concatenate([
        np.repeat(np.arange(e.lead) + e.seg_start, e.m)
        for e in plan.entries])
    np.testing.assert_array_equal(sids, spans)
    w = plan.virtual_col_weights()
    np.testing.assert_array_equal(w, np.ones_like(w))


def test_fused_bf16_params_fp32_moments_end_to_end():
    params = jax.tree_util.tree_map(
        lambda p: p.astype(jnp.bfloat16), _tree(4))
    grads = jax.tree_util.tree_map(
        lambda p: jax.random.normal(jax.random.PRNGKey(8), p.shape,
                                    jnp.float32).astype(jnp.bfloat16),
        params)
    acfg = AdamConfig(lr=1e-2, moment_dtype=jnp.float32)
    outs = {}
    for solver in ("newton", "fused"):
        engine = ProjectionEngine(BILEVEL, solver=solver)
        opt = adam_init(params, acfg)
        state = engine.init_state(params)
        p = params
        for _ in range(3):
            p, opt, state = jax.jit(
                lambda g, o, pp, s: engine.projected_update(
                    g, o, pp, acfg, state=s))(grads, opt, p, state)
        outs[solver] = (p, opt)
    for a, b in zip(jax.tree_util.tree_leaves(outs["newton"][0]),
                    jax.tree_util.tree_leaves(outs["fused"][0])):
        assert a.dtype == b.dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    for a, b in zip(jax.tree_util.tree_leaves(outs["newton"][1].mu),
                    jax.tree_util.tree_leaves(outs["fused"][1].mu)):
        assert a.dtype == jnp.float32
        _tol(a, b)


# ---------------------------------------------------------------------------
# the l1,2 family through the megakernel (PR 10: stat="sq", mode="scale")
# ---------------------------------------------------------------------------

L12 = (ProjectionSpec(pattern=r"enc1/w", norm="l12", radius=4.0),
       ProjectionSpec(pattern=r"blocks/w", norm="l12", radius=2.0, axis=1))


def test_fused_equals_newton_l12():
    """l1,2 qualifies for the two-pass megakernel (from_colstats streams
    column energies); the fused step must match the packed Newton to fp
    reduction order, counted under its own fused key."""
    acfg = AdamConfig(lr=1e-2, weight_decay=0.01, clip_norm=1.0)
    engine_counters_reset()
    _assert_same_run(L12, acfg, tol=1e-5)
    counts = engine_counters()
    assert counts["l12_packed/k1/fused"] > 0
    assert counts["l12_packed/k1/newton"] > 0   # the unfused twin's runs
    engine_counters_reset()


def test_fused_l12_bf16_params_fp32_moments():
    params = jax.tree_util.tree_map(
        lambda p: p.astype(jnp.bfloat16), _tree(6))
    grads = jax.tree_util.tree_map(
        lambda p: jax.random.normal(jax.random.PRNGKey(8), p.shape,
                                    jnp.float32).astype(jnp.bfloat16),
        params)
    acfg = AdamConfig(lr=1e-2, moment_dtype=jnp.float32)
    outs = {}
    for solver in ("newton", "fused"):
        engine = ProjectionEngine(L12, solver=solver)
        opt = adam_init(params, acfg)
        state = engine.init_state(params)
        p = params
        for _ in range(3):
            p, opt, state = jax.jit(
                lambda g, o, pp, s: engine.projected_update(
                    g, o, pp, acfg, state=s))(grads, opt, p, state)
        outs[solver] = p
    for a, b in zip(jax.tree_util.tree_leaves(outs["newton"]),
                    jax.tree_util.tree_leaves(outs["fused"])):
        assert a.dtype == b.dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_fused_l12_warm_start_survives_solver_switch():
    """Theta threads under ONE plan key whichever solver runs — switching
    newton -> fused mid-run keeps the warm start: steady-state solves stay
    in the bootstrap pair of Eq.-(19) evaluations."""
    acfg = AdamConfig(lr=1e-3)
    params = _tree(7)
    grads = jax.tree_util.tree_map(
        lambda p: jax.random.normal(jax.random.PRNGKey(5), p.shape) * 0.01,
        params)
    opt = adam_init(params, acfg)
    en = ProjectionEngine(L12)
    ef = ProjectionEngine(L12, solver="fused")
    state = en.init_state(params)
    step_n = jax.jit(lambda g, o, p, s: en.projected_update(
        g, o, p, acfg, state=s, with_stats=True))
    step_f = jax.jit(lambda g, o, p, s: ef.projected_update(
        g, o, p, acfg, state=s, with_stats=True))
    for _ in range(4):
        params, opt, state, stats = step_n(grads, opt, params, state)
    iters = []
    for _ in range(4):
        params, opt, state, stats = step_f(grads, opt, params, state)
        iters.append(int(stats["l12_packed/k1"]))
    assert max(iters[1:]) <= 2, iters
    assert all(float(v.min()) >= 0 for v in state.values())


def test_fused_no_specs_passthrough():
    engine = ProjectionEngine((), solver="fused")
    params = _tree(5)
    grads = jax.tree_util.tree_map(lambda p: 0.01 * jnp.ones_like(p), params)
    acfg = AdamConfig(lr=1e-2)
    opt = adam_init(params, acfg)
    p1, o1, s1 = engine.projected_update(grads, opt, params, acfg, state={})
    p2, o2 = adam_update(grads, opt, params, acfg)
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert s1 == {}
