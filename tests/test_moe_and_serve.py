"""shard_map expert-parallel MoE vs the GSPMD path (numerical equivalence)
and the batched serving loop."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def test_moe_shardmap_matches_gspmd():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    code = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_reduced
        from repro.models.zoo import build, make_batch
        from repro.dist.sharding import default_rules, axis_rules

        cfg = get_reduced("deepseek_v2_236b")
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = make_batch(cfg, 4, 16, kind="train")
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        rules = default_rules(); rules.update(dict(cfg.rules_overrides))
        outs = {}
        for impl in ("gspmd", "shardmap"):
            m2 = dataclasses.replace(
                model, cfg=dataclasses.replace(cfg, moe_impl=impl))
            with mesh, axis_rules(mesh, rules):
                loss, _ = jax.jit(m2.loss)(params, batch)
            outs[impl] = float(loss)
        print(outs)
        assert abs(outs["gspmd"] - outs["shardmap"]) < 2e-2, outs
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=900)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "OK" in out.stdout


def test_batch_server_generates():
    import dataclasses
    from repro.configs import get_reduced
    from repro.models.zoo import build
    from repro.train.serve import BatchServer, ServeConfig

    cfg = dataclasses.replace(get_reduced("gemma_7b"), n_layers=2)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    server = BatchServer(model, batch_slots=3, scfg=ServeConfig(max_seq=32))
    server.load(params)
    prompts = [[1, 2, 3], [4, 5]]
    outs = server.generate(prompts, max_new=6)
    assert len(outs) == 2
    for p, o in zip(prompts, outs):
        assert o[: len(p)] == p
        assert len(o) == len(p) + 6
        assert all(0 <= t < cfg.vocab_padded for t in o)
    # greedy decoding is deterministic
    outs2 = server.generate(prompts, max_new=6)
    assert outs == outs2
