"""Property-based conformance harness over EVERY registered family (PR 10).

Every constraint family in ``core.families`` must pass the same battery:
feasibility after projection, agreement with its independent reference
(the KKT witness — the reference is exact), idempotence, identity inside
the ball, warm-started iteration bounds, and theta equality across the
engine solvers a family can run under. The harness is registry-driven:
``test_registry_coverage_fails_loudly`` walks ``family_names()`` /
``registered_norms()`` and FAILS if a future family registers without a
``CASES`` entry — adding a family forces adding its conformance row.

Inputs are adversarial on purpose: n = 1 and m = 1 matrices, ragged
shapes, exact ties (quantized values), bf16 leaves, all-zero leaves, and
(through the packed/mixed tests) stacked ndim > 2 leaves.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import (ProjectionEngine, ProjectionSpec, apply_constraints,
                        engine_counters, engine_counters_reset,
                        project_segmented_family)
from repro.core.constraints import build_packed_plans
from repro.core.families import (family_names, get_family, packable_norms,
                                 registered_norms)


# ---------------------------------------------------------------------------
# the per-family conformance registry (one row per family — enforced below)
# ---------------------------------------------------------------------------
# norms:      every ProjectionSpec.norm string the family serves (coverage)
# weights:    m -> per-column weight tuple, or None (weight-aware families)
# tie_ref:    reference comparison is valid on exact-tie inputs (hoyer's
#             alternating solve settles degenerate all-equal ties on the
#             hyperplane midpoint — documented in core/hoyer.py)
# ref_metric: "exact" — elementwise agreement with the reference (convex
#             balls: the projection is unique); "distance" — per-column
#             near-optimality ||Y - X|| <= ||Y - X_ref|| (1 + eps) (hoyer:
#             the set is NONCONVEX, the alternating solve may pick a
#             marginally different support than the exact closed form)
# tol:        f32 agreement tolerance vs the reference
# feas:       optional override (Y, X, C, axis, w, loose) -> None asserting
#             the family's OWN feasibility contract, for families whose
#             operator is not a norm-ball projection (l1inf_masked zeroes
#             the dominated support but never clips survivors — Eq. 20)


def _masked_feas(Y, X, C, axis, w, loose):
    from repro.core import l1inf_column_mask, l1inf_norm
    Yf = jnp.asarray(Y, jnp.float32)
    if float(l1inf_norm(Yf, axis=axis)) <= C:
        np.testing.assert_array_equal(_f32(X), _f32(Y))
        return
    alive = np.asarray(l1inf_column_mask(Yf, C, axis=axis))
    bc = alive[None, :] if axis in (0, -2) else alive[:, None]
    np.testing.assert_array_equal(_f32(X), _f32(Y) * bc)


CASES = {
    "l1inf": dict(norms=("l1inf", "l1inf_sorted"), weights=None,
                  tie_ref=True, ref_metric="exact", tol=5e-6),
    "l1inf_weighted": dict(norms=("l1inf_weighted",),
                           weights=lambda m: tuple(
                               float(x) for x in np.linspace(0.5, 2.0, m)),
                           tie_ref=True, ref_metric="exact", tol=5e-6),
    "l1inf_masked": dict(norms=("l1inf_masked",), weights=None,
                         tie_ref=True, ref_metric="exact", tol=5e-6,
                         feas=_masked_feas),
    "bilevel": dict(norms=("bilevel",), weights=None, tie_ref=True,
                    ref_metric="exact", tol=5e-6),
    "l12": dict(norms=("l12",), weights=None, tie_ref=True,
                ref_metric="exact", tol=5e-6),
    "hoyer": dict(norms=("hoyer",), weights=None, tie_ref=False,
                  ref_metric="distance", tol=5e-3),
}

# (shape, max axis, input kind) — n=1, m=1, ragged, ties, bf16, zeros
INPUTS = [
    ((32, 32), 0, "normal"),
    ((8, 200), 0, "normal"),
    ((200, 8), 1, "normal"),
    ((1, 64), 0, "normal"),
    ((50, 1), 0, "normal"),
    ((13, 37), 0, "ties"),
    ((24, 48), 1, "ties"),
    ((24, 48), 0, "bf16"),
    ((16, 24), 0, "zeros"),
]

HOYER_S = 0.75          # hoyer's "radius" is the target sparseness ratio


def _gen(shape, kind, seed):
    rng = np.random.default_rng(seed)
    Y = rng.standard_normal(shape) * 3.0
    if kind == "ties":
        Y = np.round(Y * 2.0) / 2.0          # exact ties, exact zeros
    if kind == "zeros":
        Y = np.zeros(shape)
    dt = jnp.bfloat16 if kind == "bf16" else jnp.float32
    return jnp.asarray(Y, dt)


def _cols(shape, axis):
    return shape[1] if axis in (0, -2) else shape[0]


def _weights(case, m):
    fn = case["weights"]
    return None if fn is None else jnp.asarray(fn(m), jnp.float32)


def _radius(fam, Y, axis, w, frac=0.35):
    if fam.name == "hoyer":
        return HOYER_S
    nv = float(fam.norm_fn(jnp.asarray(Y, jnp.float32), axis, w))
    return max(frac * nv, 1e-3)


def _f32(x):
    return np.asarray(x, np.float32)


# ---------------------------------------------------------------------------
# fail-loudly coverage: registering a family without a CASES row breaks CI
# ---------------------------------------------------------------------------

def test_registry_coverage_fails_loudly():
    missing = set(family_names()) - set(CASES)
    assert not missing, (
        f"families registered without conformance coverage: {sorted(missing)}"
        " — add a CASES row in tests/test_family_conformance.py")
    extra = set(CASES) - set(family_names())
    assert not extra, f"CASES rows for unregistered families: {sorted(extra)}"
    covered = {n for c in CASES.values() for n in c["norms"]}
    missing_norms = registered_norms() - covered
    assert not missing_norms, (
        f"registered norms without conformance coverage: "
        f"{sorted(missing_norms)}")
    for name, case in CASES.items():
        declared = set(get_family(name).norms)
        assert set(case["norms"]) == declared, (
            f"CASES[{name!r}] norms {sorted(case['norms'])} != the family's "
            f"declared norms {sorted(declared)}")


# ---------------------------------------------------------------------------
# per-leaf battery: feasibility, KKT/reference, idempotence, identity inside
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fname", sorted(CASES))
def test_leaf_conformance(fname):
    fam = get_family(fname)
    case = CASES[fname]
    for si, (shape, axis, kind) in enumerate(INPUTS):
        Y = _gen(shape, kind, seed=100 + si)
        w = _weights(case, _cols(shape, axis))
        C = _radius(fam, Y, axis, w)
        X = fam.project_leaf(Y, C, axis, w)
        assert X.shape == Y.shape and X.dtype == Y.dtype, (fname, shape, kind)
        loose = kind == "bf16"
        tol = 5e-2 if loose else case["tol"]
        Xf = jnp.asarray(X, jnp.float32)
        ctx = f"{fname} {shape} axis={axis} {kind}"
        if case.get("feas") is not None:
            case["feas"](Y, X, C, axis, w, loose)
        elif fam.feasible is not None:
            if loose:
                # bf16 rounding of the f32 solution moves the ratio ~1e-2;
                # norm_fn reports hoyer's min column sparseness
                assert float(fam.norm_fn(Xf, axis, w)) >= C - 2e-2, ctx
            else:
                assert bool(fam.feasible(Xf, C, axis, w)), ctx
        else:
            nX = float(fam.norm_fn(Xf, axis, w))
            nY = float(fam.norm_fn(jnp.asarray(Y, jnp.float32), axis, w))
            assert nX <= C * (1 + (3e-2 if loose else 1e-4)), ctx
            if nY > C * 1.01:           # binding: KKT puts X on the sphere
                assert nX >= C * (1 - (3e-2 if loose else 1e-3)), ctx
        if case["tie_ref"] or kind != "ties":
            Xr = fam.reference(Y, C, axis, w)
            if case["ref_metric"] == "distance":
                d = np.sum((_f32(Y) - _f32(X)) ** 2, axis=axis)
                d_ref = np.sum((_f32(Y) - _f32(Xr)) ** 2, axis=axis)
                assert np.all(d <= d_ref * (1 + tol) + 1e-6), (
                    ctx, float(np.max(d - d_ref)))
            else:
                np.testing.assert_allclose(_f32(X), _f32(Xr), atol=tol,
                                           rtol=tol, err_msg=ctx)
        X2 = fam.project_leaf(X, C, axis, w)
        np.testing.assert_allclose(_f32(X2), _f32(X), atol=tol, rtol=tol,
                                   err_msg=ctx + " (idempotence)")
        if kind == "zeros":
            np.testing.assert_array_equal(_f32(X), _f32(Y), err_msg=ctx)


@pytest.mark.parametrize("fname", sorted(CASES))
def test_leaf_identity_inside_ball(fname):
    fam = get_family(fname)
    case = CASES[fname]
    Y = _gen((24, 40), "normal", seed=7)
    w = _weights(case, 40)
    if fname == "hoyer":
        # pre-project to sigma >= s, then ask for a LOWER target: identity
        Y = fam.project_leaf(Y, HOYER_S, 0, w)
        X = fam.project_leaf(Y, HOYER_S - 0.1, 0, w)
    else:
        C = 2.0 * float(fam.norm_fn(Y, 0, w))
        X = fam.project_leaf(Y, C, 0, w)
    np.testing.assert_array_equal(_f32(X), _f32(Y))


# ---------------------------------------------------------------------------
# packed battery: every applicable solver, warm starts, theta equality
# ---------------------------------------------------------------------------

PACKABLE = tuple(f for f in sorted(CASES)
                 if get_family(f).seg_ops is not None)


def _ragged_params(seed=0):
    rng = np.random.default_rng(seed)
    return {"a": jnp.asarray(rng.standard_normal((20, 30)) * 2, jnp.float32),
            "b": jnp.asarray(rng.standard_normal((3, 12, 18)) * 2,
                             jnp.float32),
            "c": jnp.asarray(rng.standard_normal((20, 5)) * 2, jnp.float32)}


def _specs_for(fname, params, frac=0.3):
    fam = get_family(fname)
    case = CASES[fname]
    specs = []
    for k in sorted(params):
        v = params[k]
        m = v.shape[-1]
        wt = case["weights"](m) if case["weights"] is not None else None
        wj = None if wt is None else jnp.asarray(wt, jnp.float32)
        slices = np.asarray(v, np.float32).reshape((-1,) + v.shape[-2:])
        nv = min(float(fam.norm_fn(jnp.asarray(s), 0, wj)) for s in slices)
        kw = {"weights": wt} if wt is not None else {}
        specs.append(ProjectionSpec(pattern=rf"^{k}$", norm=case["norms"][0],
                                    radius=max(frac * nv, 1e-3), **kw))
    return tuple(specs)


@pytest.mark.parametrize("fname", PACKABLE)
def test_packed_solvers_conformance(fname):
    """Every packable family through newton | pallas | sharded: matches the
    per-leaf reference path, warm restarts in the bootstrap pair, and
    produces one theta the solvers agree on (switching solvers mid-run
    keeps the warm start valid)."""
    params = _ragged_params()
    specs = _specs_for(fname, params)
    ref = apply_constraints(params, specs)          # per-leaf project_leaf
    key = f"{fname}_packed/k1"
    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))
    engines = {"newton": ProjectionEngine(specs),
               "pallas": ProjectionEngine(specs, solver="pallas"),
               "sharded": ProjectionEngine(specs, solver="sharded",
                                           mesh=mesh)}
    has_kernel = get_family(fname).pallas_loader is not None
    engine_counters_reset()
    thetas = {}
    for sname, eng in engines.items():
        st0 = eng.init_state(params)
        assert set(st0) == {key}
        out, st, stats = eng.apply(params, state=st0, with_stats=True)
        tol = 5e-4 if (sname == "pallas" and has_kernel) else 5e-6
        for r, o in zip(jax.tree_util.tree_leaves(ref),
                        jax.tree_util.tree_leaves(out)):
            np.testing.assert_allclose(_f32(r), _f32(o), atol=tol, rtol=tol,
                                       err_msg=f"{fname}/{sname}")
        thetas[sname] = st[key]
        # warm restart of the same problem: bootstrap pair only
        _, _, stats2 = eng.apply(params, state=st, with_stats=True)
        if not (sname == "pallas" and has_kernel):   # kernel iters = -1
            assert int(stats2[key]) <= 2, (fname, sname, stats2)
    counts = engine_counters()
    for sname in engines:
        assert counts[f"{key}/{sname}"] == 2, counts
    assert "per_leaf" not in counts, counts
    np.testing.assert_allclose(np.asarray(thetas["newton"]),
                               np.asarray(thetas["sharded"]),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(thetas["newton"]),
                               np.asarray(thetas["pallas"]),
                               atol=1e-3 if has_kernel else 1e-6,
                               rtol=1e-3 if has_kernel else 1e-6)
    # solver SWITCH mid-run: newton's theta warm-starts the sharded solve
    _, _, stats3 = engines["sharded"].apply(
        params, state={key: thetas["newton"]}, with_stats=True)
    assert int(stats3[key]) <= 2, (fname, stats3)


# ---------------------------------------------------------------------------
# per-leaf-only families: the explicit non-packable fallback (hoyer)
# ---------------------------------------------------------------------------

def test_hoyer_is_per_leaf_only_and_unfusable():
    assert "hoyer" in registered_norms()
    assert "hoyer" not in packable_norms()
    with pytest.raises(ValueError, match="per-leaf only"):
        project_segmented_family(jnp.zeros((4, 4)), jnp.zeros((4,), jnp.int32),
                                 jnp.ones((1,)), num_segments=1,
                                 family="hoyer")
    params = {"h": _gen((3, 16, 8), "normal", seed=11)}   # stacked ndim > 2
    specs = (ProjectionSpec(pattern=r"^h$", norm="hoyer", radius=HOYER_S),)
    plans, per_leaf = build_packed_plans(params, specs)
    assert not plans and len(per_leaf) == 1
    # fused engine must replay the per-leaf path bit-exactly (no megakernel)
    engine_counters_reset()
    out_n, _ = ProjectionEngine(specs).apply(params)
    out_f, _ = ProjectionEngine(specs, solver="fused").apply(params)
    counts = engine_counters()
    assert not any(k.endswith("/fused") for k in counts), counts
    np.testing.assert_array_equal(_f32(out_n["h"]), _f32(out_f["h"]))
    from repro.core import hoyer_sparseness
    for sl in np.asarray(out_n["h"], np.float32):
        sig = hoyer_sparseness(jnp.asarray(sl))
        assert float(jnp.min(sig)) >= HOYER_S - 1e-4


# ---------------------------------------------------------------------------
# mixed-family packing: one invocation per family sub-buffer (PR 10 sat. 4)
# ---------------------------------------------------------------------------

def test_mixed_family_packing_through_projected_update():
    """l1inf + bilevel + l12 specs (plus a hoyer per-leaf rider) in ONE
    projected_update: one packed invocation per family sub-buffer, warm
    starts isolated under per-plan keys, every constraint enforced."""
    from repro.optim import AdamConfig, adam_init

    key = jax.random.PRNGKey(0)
    params = {
        "enc": {"w": jax.random.normal(jax.random.fold_in(key, 0), (24, 50))},
        "mlp": {"w": jax.random.normal(jax.random.fold_in(key, 1),
                                       (3, 16, 40))},
        "dec": {"w": jax.random.normal(jax.random.fold_in(key, 2), (30, 20))},
        "hoy": {"w": jax.random.normal(jax.random.fold_in(key, 3), (16, 10))},
    }
    specs = (ProjectionSpec(pattern=r"enc/w", norm="l1inf", radius=4.0),
             ProjectionSpec(pattern=r"mlp/w", norm="bilevel", radius=2.0,
                            axis=1),
             ProjectionSpec(pattern=r"dec/w", norm="l12", radius=3.0),
             ProjectionSpec(pattern=r"hoy/w", norm="hoyer", radius=HOYER_S))
    acfg = AdamConfig(lr=1e-2)
    engine = ProjectionEngine(specs)
    grads = jax.tree_util.tree_map(
        lambda p: jax.random.normal(jax.random.PRNGKey(9), p.shape), params)
    opt = adam_init(params, acfg)
    state = engine.init_state(params)
    assert set(state) == {"l1inf_packed/k1", "bilevel_packed/k1",
                          "l12_packed/k1"}       # hoyer carries no theta
    assert state["bilevel_packed/k1"].shape == (3,)   # stacked leaf: 3 segs
    assert state["l1inf_packed/k1"].shape == (1,)
    assert state["l12_packed/k1"].shape == (1,)
    engine_counters_reset()
    step = jax.jit(lambda g, o, p, s: engine.projected_update(
        g, o, p, acfg, state=s))
    for _ in range(3):
        params, opt, state = step(grads, opt, params, state)
    counts = engine_counters()
    # one invocation per family sub-buffer per trace (jit: traced once)
    assert counts == {"l1inf_packed/k1/newton": 1,
                      "bilevel_packed/k1/newton": 1,
                      "l12_packed/k1/newton": 1,
                      "per_leaf": 1}, counts
    from repro.core import hoyer_sparseness, l12_norm, l1inf_norm
    assert float(l1inf_norm(params["enc"]["w"])) <= 4.0 * (1 + 1e-5)
    for sl in np.asarray(params["mlp"]["w"], np.float32):
        assert float(l1inf_norm(jnp.asarray(sl), axis=1)) <= 2.0 * (1 + 1e-5)
    assert float(l12_norm(params["dec"]["w"])) <= 3.0 * (1 + 1e-5)
    assert float(jnp.min(hoyer_sparseness(params["hoy"]["w"]))) \
        >= HOYER_S - 1e-4
    # warm starts stay isolated per plan key, and re-projecting the
    # (already feasible) updated params is the identity through the engine
    out2, state2 = engine.apply(params, state=state)
    assert set(state2) == set(state)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(out2)):
        np.testing.assert_allclose(_f32(a), _f32(b), atol=1e-5, rtol=1e-5)
