"""SAE framework: model, data generators, Algorithm 3 end-to-end on a
scaled-down version of the paper's synthetic setting."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import ProjectionSpec
from repro.sae import (SAEConfig, SAETrainConfig, sae_init, sae_apply,
                       sae_loss, make_classification, make_lung_surrogate,
                       train_test_split, train_sae)


def test_make_classification_signal():
    X, y, inf_idx = make_classification(n_samples=300, n_features=200,
                                        n_informative=16, seed=1)
    assert X.shape == (300, 200) and y.shape == (300,)
    assert len(inf_idx) == 16
    # informative features separate the classes; noise features don't
    d_inf = np.abs(X[y == 0][:, inf_idx].mean(0) - X[y == 1][:, inf_idx].mean(0))
    noise_idx = np.setdiff1d(np.arange(200), inf_idx)
    d_noise = np.abs(X[y == 0][:, noise_idx].mean(0) - X[y == 1][:, noise_idx].mean(0))
    assert d_inf.mean() > 3 * d_noise.mean()


def test_lung_surrogate_stats():
    X, y, inf_idx = make_lung_surrogate(seed=0)
    assert X.shape == (1005, 2944)
    assert (y == 1).sum() == 469 and (y == 0).sum() == 536
    assert np.all(X > 0)  # intensities; caller log-transforms


def test_sae_shapes_and_grads():
    cfg = SAEConfig(n_features=50, n_hidden=8, n_classes=3)
    params = sae_init(jax.random.PRNGKey(0), cfg)
    x = jnp.ones((4, 50))
    z, xhat = sae_apply(params, x)
    assert z.shape == (4, 3) and xhat.shape == (4, 50)
    (loss, aux), grads = jax.value_and_grad(
        lambda p: sae_loss(p, x, jnp.array([0, 1, 2, 0]), cfg), has_aux=True)(params)
    assert np.isfinite(float(loss))
    assert all(np.all(np.isfinite(np.asarray(g)))
               for g in jax.tree_util.tree_leaves(grads))


@pytest.mark.parametrize("norm", ["l1inf", "l1inf_masked", "bilevel"])
def test_algorithm3_end_to_end(norm):
    """Scaled-down paper setting: projection selects (mostly) the informative
    features and beats chance by a wide margin. ``bilevel`` exercises the
    registry end-to-end through ``sae/train.py``'s unchanged signature (the
    bi-level operator is a drop-in structured-sparsity projection)."""
    X, y, inf_idx = make_classification(n_samples=400, n_features=300,
                                        n_informative=12, class_sep=1.5,
                                        seed=3)
    mu, sd = X.mean(0), X.std(0) + 1e-6
    X = (X - mu) / sd
    Xtr, ytr, Xte, yte = train_test_split(X, y, 0.25, seed=0)
    spec = ProjectionSpec(pattern=r"enc1/w", norm=norm, radius=0.35, axis=1)
    res = train_sae(Xtr, ytr, Xte, yte,
                    SAEConfig(n_features=300, n_hidden=32, n_classes=2),
                    SAETrainConfig(epochs=25, lr=2e-3, projection=spec,
                                   seed=0))
    assert res.test_accuracy > 0.75, res.test_accuracy
    assert res.column_sparsity > 50.0, res.column_sparsity
    # clipped l1,inf recovers a solid fraction of the informative features;
    # the masked variant only claims accuracy parity (paper §6 Overall), so
    # support recall is asserted for the true projection only.
    if norm == "l1inf" and len(res.selected):
        hits = np.intersect1d(res.selected, inf_idx).size
        assert hits / len(inf_idx) > 0.3, (res.selected, inf_idx)


def test_baseline_no_projection_runs():
    X, y, _ = make_classification(n_samples=200, n_features=64,
                                  n_informative=8, class_sep=1.5, seed=5)
    X = (X - X.mean(0)) / (X.std(0) + 1e-6)
    Xtr, ytr, Xte, yte = train_test_split(X, y, 0.25, seed=1)
    res = train_sae(Xtr, ytr, Xte, yte,
                    SAEConfig(n_features=64, n_hidden=16, n_classes=2),
                    SAETrainConfig(epochs=25, lr=2e-3, projection=None, seed=0))
    assert res.column_sparsity == 0.0
    assert res.test_accuracy > 0.6
