"""Model-generic compact serving (serve/compact.py, serve/refresh.py).

Covers the PR-6 contract (DESIGN.md §10): exact forward/decode parity for
MLP hidden-unit compaction and MoE expert compaction, scatter-back
exactness for residual-output (w2) compaction, the BatchServer ragged
prompt regression, hot refresh + live re-compaction with zero retraces,
and re-compaction monotonicity (support never grows; unchanged support is
the identity). Also the satellite-1 shared test: sae's ``compact_leaf``
IS ``core.compact_columns``.
"""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.core.constraints import ProjectionSpec
from repro.core.l1inf import compact_columns
from repro.models.zoo import build, make_batch
from repro.models.transformer import forward, init_cache, decode_step
from repro.models.layers import scatter_residual
from repro.serve import (compact_model, refresh_model, recompact_model,
                         support_selection)
from repro.train.serve import BatchServer, ServeConfig


def _kill_columns(leaf, frac, axis, seed=0):
    """Zero a random fraction of columns — simulated projected training."""
    rng = np.random.default_rng(seed)
    arr = np.array(leaf)
    dead = rng.choice(arr.shape[axis], int(arr.shape[axis] * frac),
                      replace=False)
    idx = [slice(None)] * arr.ndim
    idx[axis] = dead
    arr[tuple(idx)] = 0.0
    return jnp.asarray(arr)


def _mlp_setup(w2_spec=True):
    """Reduced gemma (pure MLP) with sparsified w1 (+ optionally w2)."""
    cfg = dataclasses.replace(get_reduced("gemma_7b"), n_layers=2)
    specs = cfg.projection_specs
    if w2_spec:
        specs = specs + (ProjectionSpec(pattern="blocks/.*/mlp/w2$",
                                        norm="l1inf", radius=64.0, axis=0,
                                        every_k=10),)
    cfg = dataclasses.replace(cfg, projection_specs=specs)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mlp = params["blocks"]["p0_global"]["mlp"]
    mlp["w1"] = _kill_columns(mlp["w1"], 0.75, axis=2, seed=0)
    if w2_spec:
        mlp["w2"] = _kill_columns(mlp["w2"], 0.50, axis=2, seed=1)
    return cfg, model, params


def test_mlp_compact_forward_and_decode_exact():
    """Hidden-unit (w1/w3/w2-rows) + residual-output (w2-cols, scatter-back)
    compaction both reproduce the dense model bit-exactly: dead columns are
    structural zeros, so the gathered GEMMs sum the same nonzero terms."""
    cfg, model, params = _mlp_setup()
    cm = compact_model(params, cfg.projection_specs)
    assert cm.compaction_ratios() == {
        "blocks/p0_global/mlp/w1": 0.25, "blocks/p0_global/mlp/w2": 0.5}
    # coupled gathers: w3 cols and w2 rows follow w1; w2 cols are primary
    mlp = cm.params["blocks"]["p0_global"]["mlp"]
    assert mlp["w1"].shape == (2, 64, 32)
    assert mlp["w3"].shape == (2, 64, 32)
    assert mlp["w2"].shape == (2, 32, 32)
    assert mlp["w2_sel"].shape == (2, 32)

    batch = make_batch(cfg, 2, 16, kind="train")
    dense, _ = forward(params, batch, cfg)
    compact, _ = forward(cm.params, batch, cfg)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(compact))

    cache_d = init_cache(cfg, 2, 16, jnp.float32)
    cache_c = init_cache(cfg, 2, 16, jnp.float32)
    t = jnp.asarray([[3], [5]], jnp.int32)
    for pos in range(4):
        od, cache_d = decode_step(params, cache_d, t, jnp.asarray(pos), cfg)
        oc, cache_c = decode_step(cm.params, cache_c, t, jnp.asarray(pos),
                                  cfg)
    np.testing.assert_array_equal(np.asarray(od), np.asarray(oc))


def test_moe_expert_compact_exact():
    """MoE expert w1/w3/w2 compaction over the stacked expert dim (union
    support across experts) reproduces the dense forward bit-exactly."""
    cfg = get_reduced("mixtral_8x7b")
    specs = cfg.projection_specs + (ProjectionSpec(
        pattern="blocks/.*/moe/w2$", norm="l1inf", radius=64.0, axis=0,
        every_k=10),)
    cfg = dataclasses.replace(cfg, projection_specs=specs)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(1))
    moe = params["blocks"]["p0_local"]["moe"]
    # w1: (cycles, E, d, ff) — kill ff columns; w2: (..., ff, d) — kill d cols
    moe["w1"] = _kill_columns(moe["w1"], 0.75, axis=3, seed=2)
    moe["w2"] = _kill_columns(moe["w2"], 0.50, axis=3, seed=3)
    cm = compact_model(params, cfg.projection_specs)
    assert cm.params["blocks"]["p0_local"]["moe"]["w1"].shape[-1] == 32
    assert cm.params["blocks"]["p0_local"]["moe"]["w2"].shape[-1] == 32

    batch = make_batch(cfg, 2, 16, kind="train")
    dense, _ = forward(params, batch, cfg)
    compact, _ = forward(cm.params, batch, cfg)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(compact))


def test_scatter_residual_matches_dense_gemm():
    """scatter_residual(h @ w2[:, sel], sel, d) == h @ w2 when the killed
    columns are exact zeros — the residual-stream exactness argument."""
    rng = np.random.default_rng(4)
    h = jnp.asarray(rng.normal(size=(3, 16)).astype(np.float32))
    w2 = np.asarray(rng.normal(size=(16, 24)).astype(np.float32))
    w2[:, ::3] = 0.0
    sel = np.flatnonzero(np.any(w2 != 0, axis=0)).astype(np.int32)
    dense = h @ jnp.asarray(w2)
    compact = scatter_residual(h @ jnp.asarray(w2[:, sel]),
                               jnp.asarray(sel), 24)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(compact))


def test_unmatched_spec_leaf_is_skipped_dense():
    """A spec-matched leaf no CompactRule covers (ssm/wx) is left dense and
    reported, not silently mis-compacted."""
    cfg = get_reduced("mamba2_370m")
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cm = compact_model(params, cfg.projection_specs)
    assert any("ssm/wx" in p for p in cm.skipped)
    assert not cm.sels        # nothing compacted, params unchanged
    a = jax.tree_util.tree_leaves(params)
    b = jax.tree_util.tree_leaves(cm.params)
    assert all(x.shape == y.shape for x, y in zip(a, b))


def test_wrong_axis_spec_refused():
    """A spec pruning an axis its rule has no exactness argument for raises
    instead of serving wrong results."""
    cfg = dataclasses.replace(get_reduced("gemma_7b"), n_layers=2)
    bad = (ProjectionSpec(pattern="blocks/.*/mlp/w1$", norm="l1inf",
                          radius=64.0, axis=1, every_k=10),)
    cfg2 = dataclasses.replace(cfg, projection_specs=bad)
    model = build(cfg2)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="exactness"):
        compact_model(params, cfg2.projection_specs)


def test_compact_leaf_is_compact_columns():
    """Satellite 1: sae's compact_leaf is a shim over the ONE core gather
    primitive — identical results on the same LeafSupport."""
    from repro.sae.serve import compact_leaf
    rng = np.random.default_rng(5)
    w = np.asarray(rng.normal(size=(40, 8)).astype(np.float32))
    w[rng.choice(40, 30, replace=False), :] = 0.0
    params = {"enc1": {"w": jnp.asarray(w)}}
    spec = ProjectionSpec(pattern="enc1/w$", norm="l1inf", radius=1.0,
                          axis=1)
    sup = support_selection(params, (spec,))["enc1/w"]
    a = compact_leaf(params["enc1"]["w"], sup)
    b = compact_columns(params["enc1"]["w"], sup.sel, axis=sup.col_axis)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert a.shape == (sup.n_selected, 8)


# --------------------------- BatchServer ------------------------------------


def test_ragged_prompts_match_per_prompt_outputs():
    """Regression (satellite 2): a ragged batch must produce the SAME
    output per row as serving each prompt alone — short rows used to re-feed
    left-aligned pad tokens into their cache."""
    cfg = dataclasses.replace(get_reduced("gemma_7b"), n_layers=2)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    server = BatchServer(model, batch_slots=3, scfg=ServeConfig(max_seq=32))
    server.load(params)
    ragged = server.generate([[1, 2, 3], [4, 5], [7]], max_new=6)
    for i, prompt in enumerate([[1, 2, 3], [4, 5], [7]]):
        alone = server.generate([prompt], max_new=6)
        assert ragged[i] == alone[0], f"row {i} diverges from solo serving"


def test_batch_server_compact_matches_dense():
    """load_compact serves the compacted checkpoint through the generic
    layer and reproduces the dense server's outputs exactly."""
    cfg, model, params = _mlp_setup()
    dense = BatchServer(model, batch_slots=2, scfg=ServeConfig(max_seq=32))
    dense.load(params)
    compact = BatchServer(model, batch_slots=2, scfg=ServeConfig(max_seq=32))
    compact.load_compact(params=params)
    assert compact.compact is not None
    prompts = [[1, 2, 3], [4, 5]]
    assert dense.generate(prompts, max_new=6) == \
        compact.generate(prompts, max_new=6)


def test_hot_refresh_and_recompact_never_retrace():
    """Satellite 3 + tentpole: hot refresh and live re-compaction keep all
    shapes frozen, so the jit'd decode step traces exactly once across
    load -> refresh -> recompact."""
    cfg, model, params = _mlp_setup()
    server = BatchServer(model, batch_slots=2, scfg=ServeConfig(max_seq=32))
    server.load_compact(params=params)
    prompts = [[1, 2, 3], [4, 5]]
    out0 = server.generate(prompts, max_new=4)
    assert server.n_traces == 1

    # hot refresh: new values, same support
    params2 = jax.tree_util.tree_map(lambda a: a * 1.5, params)
    server.refresh(params2)
    server.generate(prompts, max_new=4)
    assert server.n_traces == 1

    # live re-compaction: kill one more live column, support shrinks
    w1_path = "blocks/p0_global/mlp/w1"
    victim = int(server.compact.sels[w1_path][0])
    mlp2 = params2["blocks"]["p0_global"]["mlp"]
    arr = np.array(mlp2["w1"])
    arr[:, :, victim] = 0.0
    mlp2["w1"] = jnp.asarray(arr)
    live_before = server.compact.live[w1_path]
    server.recompact(params2)
    assert server.compact.live[w1_path] == live_before - 1
    assert server.compact.slot_width(w1_path) == live_before  # slot frozen
    out2 = server.generate(prompts, max_new=4)
    assert server.n_traces == 1, "re-compaction must not retrace"

    # recompacted serving still matches the dense model
    dense = BatchServer(model, batch_slots=2, scfg=ServeConfig(max_seq=32))
    dense.load(params2)
    assert out2 == dense.generate(prompts, max_new=4)
    assert out0 is not None


def test_recompact_monotonicity():
    """Satellite 3: support growth across checkpoints raises (frozen-mask
    contract), and recompacting an unchanged support is the identity."""
    cfg, model, params = _mlp_setup(w2_spec=False)
    cm = compact_model(params, cfg.projection_specs)
    w1_path = "blocks/p0_global/mlp/w1"

    # identity: same checkpoint -> same sel array, same compact leaves
    cm_id = recompact_model(cm, params)
    np.testing.assert_array_equal(cm_id.sels[w1_path], cm.sels[w1_path])
    for a, b in zip(jax.tree_util.tree_leaves(cm.params),
                    jax.tree_util.tree_leaves(cm_id.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # growth: revive a dead column -> ValueError, both recompact and refresh
    grown = jax.tree_util.tree_map(lambda a: a, params)
    mlp = grown["blocks"]["p0_global"]["mlp"]
    arr = np.array(mlp["w1"])
    dead_col = next(j for j in range(arr.shape[2])
                    if j not in set(cm.sels[w1_path].tolist()))
    arr[:, :, dead_col] = 1.0
    mlp["w1"] = jnp.asarray(arr)
    with pytest.raises(ValueError, match="monotonicity"):
        recompact_model(cm, grown)
    with pytest.raises(ValueError, match="slot set"):
        refresh_model(cm, grown)
