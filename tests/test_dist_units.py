"""Direct single-device unit tests for the repro.dist substrate.

The subprocess tests (test_multidevice / test_pipeline_compression) validate
the collective semantics on forced multi-device meshes; these cover the
module-level contracts fast and in-process: quantization error bounds, top-k
exactness, error-feedback telescoping, the watchdog EWMA trigger (with an
injected clock — no sleeps), and the sharding-rule plumbing.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.compression import (compressed_psum, ef_step, int8_dequantize,
                                    int8_quantize, topk_compress,
                                    topk_decompress)
from repro.dist.pipeline import build_pipeline_fn
from repro.dist.sharding import (axis_rules, current_rules, default_rules,
                                 logical_spec, shard)
from repro.dist.watchdog import StepWatchdog


# ---------------------------------------------------------------------------
# int8 quantization
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(7,), (64,), (16, 16), (3, 5, 2)])
@pytest.mark.parametrize("scale", [1e-3, 1.0, 1e4])
def test_int8_roundtrip_bound(shape, scale):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)
    q, s = int8_quantize(x)
    xr = int8_dequantize(q, s)
    assert q.dtype == jnp.int8
    assert xr.shape == x.shape
    # symmetric quantization: elementwise error <= scale/2
    np.testing.assert_allclose(np.asarray(xr), np.asarray(x),
                               atol=float(s) * 0.5 + 1e-12)
    # extremes map to +-127 exactly (no clipping loss at the shared scale)
    amax = float(jnp.max(jnp.abs(x)))
    assert int(jnp.max(jnp.abs(q))) == (127 if amax > 0 else 0)


def test_int8_zero_input_no_nan():
    q, s = int8_quantize(jnp.zeros((8,), jnp.float32))
    xr = int8_dequantize(q, s)
    assert np.all(np.isfinite(np.asarray(xr)))
    np.testing.assert_array_equal(np.asarray(xr), np.zeros(8))


def test_int8_shared_scale_matches_explicit():
    x = jnp.asarray([-3.0, 0.5, 2.0], jnp.float32)
    q1, s1 = int8_quantize(x)
    q2, s2 = int8_quantize(x, jnp.max(jnp.abs(x)) / 127.0)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    assert float(s1) == pytest.approx(float(s2))


# ---------------------------------------------------------------------------
# top-k
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,k_frac", [(64, 0.25), (100, 0.05), (7, 0.5),
                                      (5, 1.0)])
def test_topk_exactness(n, k_frac):
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    vals, idx = topk_compress(g, k_frac)
    k = max(1, min(n, int(round(n * k_frac))))
    assert vals.shape == (k,) and idx.shape == (k,)
    rec = np.asarray(topk_decompress(vals, idx, g.shape, g.dtype))
    # the kept entries are exactly the k largest |g| and are bit-identical
    gn = np.asarray(g)
    keep = np.argsort(-np.abs(gn))[:k]
    expect = np.zeros_like(gn)
    expect[keep] = gn[keep]
    np.testing.assert_array_equal(rec, expect)


def test_topk_2d_uses_flat_indices():
    g = jnp.asarray([[0.0, 5.0], [-7.0, 1.0]], jnp.float32)
    vals, idx = topk_compress(g, 0.5)
    rec = np.asarray(topk_decompress(vals, idx, g.shape, g.dtype))
    np.testing.assert_array_equal(rec, np.array([[0.0, 5.0], [-7.0, 0.0]]))


# ---------------------------------------------------------------------------
# error feedback
# ---------------------------------------------------------------------------

def test_ef_residual_telescopes():
    """After T rounds, transmitted + residual == sum of raw gradients: EF
    delays gradient mass but never loses it."""
    rng = np.random.default_rng(2)
    T, n = 10, 64
    gs = [jnp.asarray(rng.normal(size=(n,)), jnp.float32) for _ in range(T)]
    err = jnp.zeros((n,), jnp.float32)
    sent = jnp.zeros((n,), jnp.float32)
    for g in gs:
        sparse, err = ef_step(g, err, k_frac=0.125)
        assert int(jnp.sum(sparse != 0)) == 8
        sent = sent + sparse
    total = np.sum(np.asarray(gs), axis=0)
    np.testing.assert_allclose(np.asarray(sent + err), total, atol=1e-4)


def test_ef_step_exact_split():
    g = jnp.asarray([4.0, -1.0, 0.5, 3.0], jnp.float32)
    err0 = jnp.asarray([0.0, 2.5, 0.0, 0.0], jnp.float32)
    sparse, err = ef_step(g, err0, k_frac=0.5)
    # corrected = [4, 1.5, 0.5, 3] -> top-2 = indices 0, 3
    np.testing.assert_array_equal(np.asarray(sparse), [4.0, 0.0, 0.0, 3.0])
    np.testing.assert_allclose(np.asarray(sparse + err),
                               np.asarray(g + err0), atol=1e-7)


# ---------------------------------------------------------------------------
# watchdog (injected clock: deterministic, no sleeps)
# ---------------------------------------------------------------------------

class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _run_steps(w, clock, durations):
    for i, d in enumerate(durations):
        w.start()
        clock.t += d
        w.stop(i)


def test_watchdog_ewma_trigger_and_grace():
    clock = _FakeClock()
    fired = []
    w = StepWatchdog(threshold=2.0, grace_steps=2, alpha=0.5,
                     on_straggler=lambda s, dt, ew: fired.append(s),
                     clock=clock)
    # grace window: a slow step among the first grace_steps must NOT fire
    _run_steps(w, clock, [1.0, 10.0, 1.0, 1.0])
    assert fired == []
    # EWMA is now O(1s); a 3x step fires
    w.start(); clock.t += 50.0; w.stop(99)
    assert fired == [99]
    assert len(w.events) == 1
    step, dt, ewma = w.events[0]
    assert step == 99 and dt == pytest.approx(50.0) and dt > 2.0 * ewma


def test_watchdog_straggler_not_folded_into_ewma():
    clock = _FakeClock()
    w = StepWatchdog(threshold=2.0, grace_steps=0, alpha=0.5, clock=clock)
    _run_steps(w, clock, [1.0, 1.0, 100.0, 1.0, 100.0])
    # both 100s steps fire: the first did not inflate the baseline
    assert [e[0] for e in w.events] == [2, 4]
    assert w.ewma == pytest.approx(1.0)


def test_watchdog_stop_returns_duration_and_requires_start():
    clock = _FakeClock()
    w = StepWatchdog(clock=clock)
    w.start()
    clock.t += 0.25
    assert w.stop(0) == pytest.approx(0.25)
    with pytest.raises(RuntimeError):
        w.stop(1)


def test_watchdog_metrics_snapshot():
    clock = _FakeClock()
    w = StepWatchdog(threshold=2.0, grace_steps=0, alpha=0.5, clock=clock)
    # before any step: sentinel step, zeros everywhere
    m = w.metrics()
    assert m["step"] == -1.0 and m["step_time_s"] == 0.0
    assert m["step_time_ewma_s"] == 0.0 and m["straggler"] == 0.0
    assert m["straggler_events_total"] == 0.0

    _run_steps(w, clock, [1.0, 1.0])
    m = w.metrics()
    assert m["step"] == 1.0
    assert m["step_time_s"] == pytest.approx(1.0)
    assert m["step_time_ewma_s"] == pytest.approx(1.0)
    assert m["straggler"] == 0.0 and m["straggler_events_total"] == 0.0

    # a straggler step flags itself but leaves the EWMA baseline alone
    w.start(); clock.t += 100.0; w.stop(2)
    m = w.metrics()
    assert m["step"] == 2.0 and m["step_time_s"] == pytest.approx(100.0)
    assert m["step_time_ewma_s"] == pytest.approx(1.0)
    assert m["straggler"] == 1.0 and m["straggler_events_total"] == 1.0

    # the next normal step clears the flag; the total is cumulative
    w.start(); clock.t += 1.0; w.stop(3)
    m = w.metrics()
    assert m["straggler"] == 0.0 and m["straggler_events_total"] == 1.0
    # every value is a plain float so the dict drops into a metrics stream
    assert all(isinstance(v, float) for v in m.values())


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_default_rules_layout():
    r = default_rules()
    assert r["fsdp"] == "data" and r["mlp"] == "model"
    assert r["batch"] == "data" and r["layers"] is None
    rp = default_rules(multi_pod=True)
    assert rp["batch"] == ("pod", "data")
    assert rp["cache_batch"] == ("pod", "data")
    assert rp["fsdp"] == "data"  # FSDP stays within-pod


def test_logical_spec_and_context():
    rules = default_rules()
    assert logical_spec(("batch", "vocab"), rules) == P("data", "model")
    assert logical_spec(("nope", None), rules) == P(None, None)
    assert current_rules() is None
    mesh = jax.make_mesh((1,), ("data",))
    with axis_rules(mesh, rules):
        assert current_rules() == (mesh, rules)
        with axis_rules(None, None):  # nesting: innermost wins
            assert current_rules() == (None, None)
        assert current_rules() == (mesh, rules)
    assert current_rules() is None


def test_shard_noop_outside_context_and_on_none_mesh():
    x = jnp.ones((4, 8))
    assert shard(x, "batch", "embed") is x
    with axis_rules(None, None):
        assert shard(x, "batch", "embed") is x


def test_shard_constrains_and_drops_nondivisible():
    mesh = jax.make_mesh((1,), ("data",))
    rules = {"batch": "data", "ghost": "absent_axis"}
    x = jnp.arange(12, dtype=jnp.float32).reshape(3, 4)
    with axis_rules(mesh, rules):
        y = jax.jit(lambda a: shard(a, "batch", "ghost"))(x)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
        with pytest.raises(ValueError):
            shard(x, "batch")  # rank mismatch


# ---------------------------------------------------------------------------
# 1-device pipeline / compressed_psum (in-process smoke; multi-device
# semantics live in the subprocess tests)
# ---------------------------------------------------------------------------

def test_pipeline_single_stage_identity_schedule():
    mesh = jax.make_mesh((1,), ("pp",))
    rng = np.random.default_rng(3)
    W = jnp.asarray(rng.normal(size=(1, 6, 6)) * 0.3, jnp.float32)
    x = jnp.asarray(rng.normal(size=(5, 2, 6)), jnp.float32)
    pipe = build_pipeline_fn(lambda w, h: jnp.tanh(h @ w), 1, 5, mesh, "pp")
    with mesh:
        y = jax.jit(pipe)(W, x)
    np.testing.assert_allclose(np.asarray(y), np.tanh(np.asarray(x) @
                                                      np.asarray(W[0])),
                               atol=1e-6)


def test_pipeline_rejects_wrong_mesh():
    mesh = jax.make_mesh((1,), ("pp",))
    with pytest.raises(ValueError):
        build_pipeline_fn(lambda w, h: h, 4, 8, mesh, "pp")


@pytest.mark.parametrize("mode", ["none", "int8", "topk"])
def test_compressed_psum_single_device(mode):
    from jax.experimental.shard_map import shard_map
    mesh = jax.make_mesh((1,), ("pod",))
    g = jnp.asarray(np.random.default_rng(4).normal(size=(32,)), jnp.float32)

    def body(gs):
        return compressed_psum({"g": gs}, "pod", mode=mode, k_frac=1.0)["g"]

    fn = shard_map(body, mesh=mesh, in_specs=(P(),), out_specs=P(),
                   check_rep=False)
    with mesh:
        out = np.asarray(fn(g))
    atol = (float(jnp.max(jnp.abs(g))) / 127.0 * 0.51 + 1e-6
            if mode == "int8" else 1e-6)
    np.testing.assert_allclose(out, np.asarray(g), atol=atol)


def test_compressed_psum_unknown_mode():
    with pytest.raises(ValueError):
        compressed_psum({"g": jnp.ones(4)}, "pod", mode="fp4")
