"""Weighted l1,inf projection + variational-inequality optimality
certificates for the whole projection family.

The VI certificate: X* = P_B(Y) iff <Y - X*, Z - X*> <= 0 for every
feasible Z. We sample many random feasible Z per instance — a projection
bug (wrong theta, wrong support, wrong clipping) shows up as a positive
inner product.
"""
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import (project_l1inf_weighted, l1inf_weighted_norm,
                        project_l1inf_newton, project_l1inf_masked,
                        project_l1_ball, project_l12_ball, l1inf_norm)


def _random_feasible_l1inf_w(rng, n, m, w, C, count):
    """Random points with sum_j w_j max_i |Z_ij| <= C."""
    out = []
    for _ in range(count):
        Z = rng.normal(size=(n, m))
        nrm = float((w * np.abs(Z).max(axis=0)).sum())
        Z *= rng.uniform(0, 1) * C / max(nrm, 1e-12)
        out.append(Z)
    return out


def _vi_holds(Y, X, feasible, tol=1e-4):
    Y = np.asarray(Y, np.float64)
    X = np.asarray(X, np.float64)
    scale = max(np.abs(Y).max(), 1.0) ** 2
    return all(np.sum((Y - X) * (Z - X)) <= tol * scale * Y.size ** 0.5
               for Z in feasible)


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("Cfrac", [0.05, 0.4, 0.9])
def test_weighted_l1inf_vi_certificate(seed, Cfrac):
    rng = np.random.default_rng(seed)
    n, m = 12, 9
    Y = rng.normal(size=(n, m))
    w = rng.uniform(0.2, 3.0, size=m)
    C = float(Cfrac * (w * np.abs(Y).max(axis=0)).sum())
    X = np.asarray(project_l1inf_weighted(jnp.asarray(Y, jnp.float32),
                                          jnp.asarray(w, jnp.float32), C))
    # feasibility (tight when projecting from outside)
    assert float((w * np.abs(X).max(axis=0)).sum()) <= C * (1 + 1e-4)
    feas = _random_feasible_l1inf_w(rng, n, m, w, C, 50) + [X, np.zeros_like(X)]
    assert _vi_holds(Y, X, feas)


def test_weighted_equals_unweighted_at_w1():
    rng = np.random.default_rng(3)
    Y = rng.normal(size=(20, 15)).astype(np.float32)
    C = 4.0
    Xw = np.asarray(project_l1inf_weighted(jnp.asarray(Y),
                                           jnp.ones(15, np.float32), C))
    Xu = np.asarray(project_l1inf_newton(jnp.asarray(Y), C))
    np.testing.assert_allclose(Xw, Xu, atol=1e-5)


def test_weighted_prunes_heavy_columns_first():
    """Columns with larger weights are more expensive to keep."""
    rng = np.random.default_rng(4)
    Y = np.abs(rng.normal(size=(10, 6))).astype(np.float32) + 0.5
    w = np.array([1, 1, 1, 20, 20, 20], np.float32)
    C = 0.25 * float((w * np.abs(Y).max(axis=0)).sum())
    X = np.asarray(project_l1inf_weighted(jnp.asarray(Y), jnp.asarray(w), C))
    live = np.abs(X).max(axis=0) > 1e-7
    assert live[:3].sum() >= live[3:].sum(), live


def test_weighted_inside_identity_and_zero_radius():
    rng = np.random.default_rng(5)
    Y = (rng.normal(size=(6, 4)) * 0.01).astype(np.float32)
    w = np.ones(4, np.float32)
    X = np.asarray(project_l1inf_weighted(jnp.asarray(Y), jnp.asarray(w),
                                          1e6))
    np.testing.assert_array_equal(X, Y)
    X0 = np.asarray(project_l1inf_weighted(jnp.asarray(Y), jnp.asarray(w),
                                           0.0))
    np.testing.assert_array_equal(X0, np.zeros_like(Y))


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 10), m=st.integers(2, 10),
       seed=st.integers(0, 2**31 - 1), cfrac=st.floats(0.05, 1.2))
def test_property_weighted_vi(n, m, seed, cfrac):
    rng = np.random.default_rng(seed)
    Y = rng.normal(size=(n, m))
    w = rng.uniform(0.3, 2.0, size=m)
    nrm = float((w * np.abs(Y).max(axis=0)).sum())
    if nrm <= 0:
        return
    C = float(cfrac * nrm)
    X = np.asarray(project_l1inf_weighted(jnp.asarray(Y, jnp.float32),
                                          jnp.asarray(w, jnp.float32), C))
    assert float((w * np.abs(X).max(axis=0)).sum()) <= C * (1 + 1e-3) + 1e-6
    feas = _random_feasible_l1inf_w(rng, n, m, w, C, 25) + [np.zeros_like(X)]
    assert _vi_holds(Y, X, feas)


# ---- VI certificates for the rest of the family ---------------------------

def test_vi_unweighted_family():
    rng = np.random.default_rng(7)
    Y = rng.normal(size=(15, 10))
    Yj = jnp.asarray(Y, jnp.float32)
    C = 0.3 * float(np.abs(Y).max(axis=0).sum())
    X = np.asarray(project_l1inf_newton(Yj, C))
    feas = []
    for _ in range(40):
        Z = rng.normal(size=Y.shape)
        Z *= rng.uniform(0, 1) * C / max(float(np.abs(Z).max(0).sum()), 1e-9)
        feas.append(Z)
    assert _vi_holds(Y, X, feas + [np.zeros_like(Y)])

    # l1 ball
    C1 = 0.3 * float(np.abs(Y).sum())
    X1 = np.asarray(project_l1_ball(Yj, C1))
    feas1 = []
    for _ in range(40):
        Z = rng.normal(size=Y.shape)
        Z *= rng.uniform(0, 1) * C1 / max(float(np.abs(Z).sum()), 1e-9)
        feas1.append(Z)
    assert _vi_holds(Y, X1, feas1 + [np.zeros_like(Y)])

    # l1,2 group ball
    C2 = 0.3 * float(np.sqrt((Y ** 2).sum(0)).sum())
    X2 = np.asarray(project_l12_ball(Yj, C2))
    feas2 = []
    for _ in range(40):
        Z = rng.normal(size=Y.shape)
        Z *= rng.uniform(0, 1) * C2 / max(
            float(np.sqrt((Z ** 2).sum(0)).sum()), 1e-9)
        feas2.append(Z)
    assert _vi_holds(Y, X2, feas2 + [np.zeros_like(Y)])
