"""Pallas l1,inf kernels vs the pure-jnp oracle (interpret mode, CPU).

Shape/dtype sweeps per kernel + full-projection equivalence against both the
ref oracle and the faithful heap algorithm.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.l1inf import ref
from repro.kernels.l1inf.kernel import colstats, mu_solve, clip_apply
from repro.kernels.l1inf.ops import project_l1inf_pallas
from repro.core import project_l1inf_heap, project_l1inf_newton


@pytest.mark.parametrize("shape", [(8, 128), (512, 128), (1024, 256), (64, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_colstats(shape, dtype):
    rng = np.random.default_rng(0)
    Y = jnp.asarray(rng.normal(size=shape), dtype)
    bn = shape[0] if shape[0] <= 512 else 512
    s, mx = colstats(Y, block_m=128, block_n=bn, interpret=True)
    s_ref, mx_ref = ref.colstats_ref(Y)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5)
    np.testing.assert_allclose(np.asarray(mx), np.asarray(mx_ref), rtol=1e-6)


@pytest.mark.parametrize("shape", [(16, 128), (256, 128), (777, 128), (96, 256)])
@pytest.mark.parametrize("theta_frac", [0.01, 0.3, 0.9])
def test_mu_solve(shape, theta_frac):
    rng = np.random.default_rng(1)
    Y = jnp.asarray(rng.uniform(0, 1, size=shape), jnp.float32)
    colsum = jnp.sum(Y, axis=0)
    theta = jnp.asarray(theta_frac * float(jnp.median(colsum)), jnp.float32)
    mu, k, S, act = mu_solve(Y, theta, block_m=128, interpret=True)
    mu_r, k_r, S_r, act_r = ref.mu_solve_ref(Y, theta)
    np.testing.assert_array_equal(np.asarray(act), np.asarray(act_r))
    np.testing.assert_allclose(np.asarray(mu), np.asarray(mu_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(k), np.asarray(k_r))
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_r), rtol=1e-5, atol=1e-5)
    # defining property: removed mass == theta on active columns
    removed = np.sum(np.maximum(np.asarray(Y) - np.asarray(mu)[None, :], 0), axis=0)
    np.testing.assert_allclose(removed[np.asarray(act)], float(theta), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_clip_apply(dtype):
    rng = np.random.default_rng(2)
    Y = jnp.asarray(rng.normal(size=(256, 128)), dtype)
    mu = jnp.asarray(np.abs(rng.normal(size=128)), jnp.float32)
    X = clip_apply(Y, mu, block_m=128, block_n=256, interpret=True)
    X_ref = ref.clip_apply_ref(Y, mu)
    np.testing.assert_allclose(np.asarray(X, np.float32), np.asarray(X_ref, np.float32), atol=1e-6)


@pytest.mark.parametrize("shape", [(7, 5), (100, 100), (33, 257), (1000, 64), (2, 1000)])
@pytest.mark.parametrize("Cfrac", [0.02, 0.25, 0.8, 1.3])
def test_full_projection_vs_heap(shape, Cfrac):
    rng = np.random.default_rng(hash(shape) % 2**31)
    Y = rng.normal(size=shape)
    norm = np.abs(Y).max(axis=0).sum()
    C = float(Cfrac * norm)
    X = np.asarray(project_l1inf_pallas(jnp.asarray(Y, jnp.float32), C, interpret=True))
    Xh = project_l1inf_heap(Y, C)
    scale = max(np.abs(Y).max(), 1.0)
    np.testing.assert_allclose(X, Xh, atol=3e-4 * scale, rtol=3e-3)
    assert np.abs(X).max(axis=0).sum() <= C * (1 + 1e-3) + 1e-6


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_full_projection_dtypes(dtype):
    rng = np.random.default_rng(5)
    Y = jnp.asarray(rng.normal(size=(96, 200)), dtype)
    C = 10.0
    X = project_l1inf_pallas(Y, C, interpret=True)
    assert X.dtype == dtype
    Xn = project_l1inf_newton(jnp.asarray(Y, jnp.float32), C)
    tol = 3e-2 if dtype == jnp.bfloat16 else 5e-4
    np.testing.assert_allclose(np.asarray(X, np.float32), np.asarray(Xn),
                               atol=tol, rtol=tol)


def test_inside_ball_identity():
    rng = np.random.default_rng(6)
    Y = jnp.asarray(rng.normal(size=(32, 48)) * 0.01, jnp.float32)
    C = 1e6
    X = project_l1inf_pallas(Y, C, interpret=True)
    np.testing.assert_array_equal(np.asarray(X), np.asarray(Y))


def test_ref_oracle_matches_heap():
    rng = np.random.default_rng(7)
    Y = rng.uniform(-1, 1, size=(60, 80))
    for Cfrac in (0.05, 0.5):
        C = float(Cfrac * np.abs(Y).max(axis=0).sum())
        Xr = np.asarray(ref.project_l1inf_ref(jnp.asarray(Y, jnp.float32), C))
        Xh = project_l1inf_heap(Y, C)
        np.testing.assert_allclose(Xr, Xh, atol=1e-4, rtol=1e-3)
