"""Pallas l1,inf kernels vs the pure-jnp oracle (interpret mode, CPU).

Shape/dtype sweeps per kernel + full-projection equivalence against both the
ref oracle and the faithful heap algorithm, plus the sparsity-adaptive
engine features: active-column shrinking, warm start, the packed segmented
path, and adversarial shapes (non-multiples of the tile dims, n=1, m=1,
tie-heavy inputs, inside-ball, bf16).
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels.l1inf import ref
from repro.kernels.l1inf.kernel import colstats, mu_solve, clip_apply
from repro.kernels.l1inf.ops import (project_l1inf_pallas,
                                     project_l1inf_pallas_segmented,
                                     _pick_block_n)
from repro.core import (project_l1inf_heap, project_l1inf_newton,
                        project_l1inf_sorted)


@pytest.mark.parametrize("shape", [(8, 128), (512, 128), (1024, 256), (64, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_colstats(shape, dtype):
    rng = np.random.default_rng(0)
    Y = jnp.asarray(rng.normal(size=shape), dtype)
    bn = shape[0] if shape[0] <= 512 else 512
    s, mx = colstats(Y, block_m=128, block_n=bn, interpret=True)
    s_ref, mx_ref = ref.colstats_ref(Y)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5)
    np.testing.assert_allclose(np.asarray(mx), np.asarray(mx_ref), rtol=1e-6)


@pytest.mark.parametrize("shape", [(16, 128), (256, 128), (777, 128), (96, 256)])
@pytest.mark.parametrize("theta_frac", [0.01, 0.3, 0.9])
def test_mu_solve(shape, theta_frac):
    rng = np.random.default_rng(1)
    Y = jnp.asarray(rng.uniform(0, 1, size=shape), jnp.float32)
    colsum = jnp.sum(Y, axis=0)
    theta = jnp.asarray(theta_frac * float(jnp.median(colsum)), jnp.float32)
    mu, k, S, act = mu_solve(Y, theta, block_m=128, interpret=True)
    mu_r, k_r, S_r, act_r = ref.mu_solve_ref(Y, theta)
    np.testing.assert_array_equal(np.asarray(act), np.asarray(act_r))
    np.testing.assert_allclose(np.asarray(mu), np.asarray(mu_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(k), np.asarray(k_r))
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_r), rtol=1e-5, atol=1e-5)
    # defining property: removed mass == theta on active columns
    removed = np.sum(np.maximum(np.asarray(Y) - np.asarray(mu)[None, :], 0), axis=0)
    np.testing.assert_allclose(removed[np.asarray(act)], float(theta), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_clip_apply(dtype):
    rng = np.random.default_rng(2)
    Y = jnp.asarray(rng.normal(size=(256, 128)), dtype)
    mu = jnp.asarray(np.abs(rng.normal(size=128)), jnp.float32)
    X = clip_apply(Y, mu, block_m=128, block_n=256, interpret=True)
    X_ref = ref.clip_apply_ref(Y, mu)
    np.testing.assert_allclose(np.asarray(X, np.float32), np.asarray(X_ref, np.float32), atol=1e-6)


@pytest.mark.parametrize("shape", [(7, 5), (100, 100), (33, 257), (1000, 64), (2, 1000)])
@pytest.mark.parametrize("Cfrac", [0.02, 0.25, 0.8, 1.3])
def test_full_projection_vs_heap(shape, Cfrac):
    rng = np.random.default_rng(hash(shape) % 2**31)
    Y = rng.normal(size=shape)
    norm = np.abs(Y).max(axis=0).sum()
    C = float(Cfrac * norm)
    X = np.asarray(project_l1inf_pallas(jnp.asarray(Y, jnp.float32), C, interpret=True))
    Xh = project_l1inf_heap(Y, C)
    scale = max(np.abs(Y).max(), 1.0)
    np.testing.assert_allclose(X, Xh, atol=3e-4 * scale, rtol=3e-3)
    assert np.abs(X).max(axis=0).sum() <= C * (1 + 1e-3) + 1e-6


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_full_projection_dtypes(dtype):
    rng = np.random.default_rng(5)
    Y = jnp.asarray(rng.normal(size=(96, 200)), dtype)
    C = 10.0
    X = project_l1inf_pallas(Y, C, interpret=True)
    assert X.dtype == dtype
    Xn = project_l1inf_newton(jnp.asarray(Y, jnp.float32), C)
    tol = 3e-2 if dtype == jnp.bfloat16 else 5e-4
    np.testing.assert_allclose(np.asarray(X, np.float32), np.asarray(Xn),
                               atol=tol, rtol=tol)


def test_inside_ball_identity():
    rng = np.random.default_rng(6)
    Y = jnp.asarray(rng.normal(size=(32, 48)) * 0.01, jnp.float32)
    C = 1e6
    X = project_l1inf_pallas(Y, C, interpret=True)
    np.testing.assert_array_equal(np.asarray(X), np.asarray(Y))


def test_pick_block_n():
    # largest divisor <= 512 that is a multiple of 8 — never the old
    # silent 8-row fallback for awkward n_pad
    assert _pick_block_n(512) == 512
    assert _pick_block_n(8) == 8
    assert _pick_block_n(1024) == 512
    assert _pick_block_n(520) == 104     # old rule collapsed this to 8
    assert _pick_block_n(136) == 136
    assert _pick_block_n(8 * 127) == 8   # prime sublane count: 8 is correct
    for n_pad in range(8, 2048, 8):
        bn = _pick_block_n(n_pad)
        assert n_pad % bn == 0 and bn % 8 == 0 and bn <= 512
    with pytest.raises(ValueError):
        _pick_block_n(12)


# ----------------------------- adversarial shapes ---------------------------

@pytest.mark.parametrize("shape", [
    (1, 300),        # n=1: every column is its own max
    (50, 1),         # m=1: simplex-style water filling
    (1, 1),
    (130, 257),      # non-multiples of 8 / 128
    (520, 130),      # n_pad=520 exercises the block_n divisor fallback
    (9, 129),        # one past the tile boundary in both dims
])
@pytest.mark.parametrize("Cfrac", [0.05, 0.6])
def test_pallas_adversarial_shapes(shape, Cfrac):
    rng = np.random.default_rng(hash(shape) % 2**31)
    Y = rng.normal(size=shape)
    C = float(Cfrac * np.abs(Y).max(axis=0).sum())
    if C <= 0:
        return
    X = np.asarray(project_l1inf_pallas(jnp.asarray(Y, jnp.float32), C,
                                        interpret=True))
    Xh = project_l1inf_heap(Y, C)
    Xs = np.asarray(project_l1inf_sorted(jnp.asarray(Y, jnp.float32), C))
    scale = max(np.abs(Y).max(), 1.0)
    np.testing.assert_allclose(X, Xh, atol=3e-4 * scale, rtol=3e-3)
    np.testing.assert_allclose(X, Xs, atol=3e-4 * scale, rtol=3e-3)
    assert np.abs(X).max(axis=0).sum() <= C * (1 + 1e-3) + 1e-6


def test_pallas_tie_heavy():
    """Many equal |Y| values straddling mu (degenerate breakpoints)."""
    rng = np.random.default_rng(11)
    Y = rng.choice([0.0, 1.0, -1.0, 2.0, 2.0], size=(40, 96))
    norm = np.abs(Y).max(axis=0).sum()
    for Cfrac in (0.1, 0.45, 0.9):
        C = float(Cfrac * norm)
        X = np.asarray(project_l1inf_pallas(jnp.asarray(Y, jnp.float32), C,
                                            interpret=True))
        Xh = project_l1inf_heap(Y, C)
        np.testing.assert_allclose(X, Xh, atol=5e-4, rtol=3e-3)


def test_pallas_inside_ball_and_bf16_adversarial():
    rng = np.random.default_rng(12)
    # inside-ball on a non-tile-aligned shape: exact identity
    Y = jnp.asarray(rng.normal(size=(33, 77)) * 0.01, jnp.float32)
    X = project_l1inf_pallas(Y, 1e5, interpret=True)
    np.testing.assert_array_equal(np.asarray(X), np.asarray(Y))
    # bf16 on a ragged shape, vs the f32 newton reference
    Yb = jnp.asarray(rng.normal(size=(37, 131)), jnp.bfloat16)
    C = 8.0
    Xb = project_l1inf_pallas(Yb, C, interpret=True)
    assert Xb.dtype == jnp.bfloat16
    Xn = project_l1inf_newton(jnp.asarray(Yb, jnp.float32), C)
    np.testing.assert_allclose(np.asarray(Xb, np.float32), np.asarray(Xn),
                               atol=3e-2, rtol=3e-2)
    Xhb = project_l1inf_heap(np.asarray(Yb, np.float32), C)
    np.testing.assert_allclose(np.asarray(Xb, np.float32), Xhb,
                               atol=3e-2, rtol=3e-2)


# ----------------------- sparsity-adaptive engine ---------------------------

def test_shrink_matches_no_shrink():
    """Active-column shrinking is a layout optimization: identical results
    with the engine's compaction on or off, up to the fp accumulation-order
    wobble of the permuted Eq.-(19) reductions."""
    rng = np.random.default_rng(13)
    scale = np.exp(rng.normal(size=(1, 300)))
    Y = jnp.asarray(rng.normal(size=(60, 300)) * scale, jnp.float32)
    for Cfrac in (0.02, 0.3):
        C = float(Cfrac * np.abs(np.asarray(Y)).max(axis=0).sum())
        X1 = np.asarray(project_l1inf_pallas(Y, C, interpret=True,
                                             shrink=True))
        X0 = np.asarray(project_l1inf_pallas(Y, C, interpret=True,
                                             shrink=False))
        tol = 1e-6 * float(np.abs(np.asarray(Y)).max())
        np.testing.assert_allclose(X1, X0, atol=tol)
        # and both agree with the heap oracle
        Xh = project_l1inf_heap(np.asarray(Y, np.float64), C)
        np.testing.assert_allclose(X1, Xh, atol=3e-4 * scale.max(), rtol=3e-3)


def test_work_counter_j_proportional():
    """The per-step work counter must shrink with column sparsity."""
    rng = np.random.default_rng(14)
    scale = np.exp(rng.normal(size=(1, 512)))
    Y = jnp.asarray(rng.uniform(0, 1, size=(40, 512)) * scale, jnp.float32)
    norm = float(np.abs(np.asarray(Y)).max(axis=0).sum())
    X, st = project_l1inf_pallas(Y, 0.01 * norm, interpret=True,
                                 return_stats=True)
    _, st0 = project_l1inf_pallas(Y, 0.01 * norm, interpret=True,
                                  shrink=False, return_stats=True)
    colsp = float((np.abs(np.asarray(X)).max(axis=0) <= 1e-12).mean())
    assert colsp > 0.5                       # high-sparsity regime
    # strictly less work than the non-shrinking engine, and the final
    # Newton step touches only the surviving prefix
    assert int(st["work_cols"]) < int(st0["work_cols"])
    assert int(st["active_cols_per_step"]) < int(st["full_cols"])


def test_pallas_warm_start():
    rng = np.random.default_rng(15)
    Y = jnp.asarray(rng.normal(size=(48, 200)), jnp.float32)
    C = float(0.2 * np.abs(np.asarray(Y)).max(axis=0).sum())
    X, st = project_l1inf_pallas(Y, C, interpret=True, return_stats=True)
    Xw, stw = project_l1inf_pallas(Y, C, theta0=st["theta"], interpret=True,
                                   return_stats=True)
    np.testing.assert_allclose(np.asarray(Xw), np.asarray(X), atol=1e-6)
    # exact restart: the bootstrap pair (+ at most one fp-wobble step from
    # the bisection-approximate payloads), well below a cold solve
    assert int(stw["newton_iters"]) <= 3
    assert int(stw["newton_iters"]) < int(st["newton_iters"])
    # overshooting warm start is repaired, result unchanged
    Xo = project_l1inf_pallas(Y, C, theta0=st["theta"] * 7.0, interpret=True)
    np.testing.assert_allclose(np.asarray(Xo), np.asarray(X), atol=1e-5)


def test_pallas_segmented_vs_per_matrix():
    rng = np.random.default_rng(16)
    sizes = [(40, 50), (64, 130), (24, 33)]
    n_max = max(n for n, _ in sizes)
    cols, sids, Cs, mats = [], [], [], []
    for g, (n, m) in enumerate(sizes):
        Yg = rng.normal(size=(n, m)) * rng.choice([0.3, 1.0, 4.0])
        pad = np.zeros((n_max, m), np.float32)
        pad[:n] = Yg
        cols.append(pad)
        sids += [g] * m
        Cs.append(float(0.2 * np.abs(Yg).max(axis=0).sum()))
        mats.append(Yg)
    Yp = jnp.asarray(np.concatenate(cols, axis=1))
    sids = jnp.asarray(np.array(sids, np.int32))
    X, theta = project_l1inf_pallas_segmented(
        Yp, sids, jnp.asarray(np.array(Cs, np.float32)), num_segments=3,
        interpret=True)
    Xref = ref.project_l1inf_segmented_ref(np.asarray(Yp), np.asarray(sids),
                                           np.array(Cs, np.float32), 3)
    np.testing.assert_allclose(np.asarray(X), Xref, atol=3e-4, rtol=3e-3)
    # segment thetas match the scalar engine's
    for g, (n, m) in enumerate(sizes):
        Xh = project_l1inf_heap(mats[g], Cs[g])
        cols_g = np.asarray(sids) == g
        np.testing.assert_allclose(np.asarray(X)[:n, cols_g], Xh,
                                   atol=3e-4, rtol=3e-3)


def test_mu_solve_vector_theta_and_nact():
    rng = np.random.default_rng(17)
    Y = jnp.asarray(rng.uniform(0, 1, size=(64, 256)), jnp.float32)
    colsum = jnp.sum(Y, axis=0)
    th_scalar = jnp.asarray(0.3 * float(jnp.median(colsum)), jnp.float32)
    mu_s, k_s, S_s, a_s = mu_solve(Y, th_scalar, block_m=128, interpret=True)
    # vector theta equal everywhere == scalar theta
    th_vec = jnp.full((256,), th_scalar, jnp.float32)
    mu_v, k_v, S_v, a_v = mu_solve(Y, th_vec, block_m=128, interpret=True)
    np.testing.assert_array_equal(np.asarray(mu_s), np.asarray(mu_v))
    np.testing.assert_array_equal(np.asarray(k_s), np.asarray(k_v))
    # nact_blocks=1: second block (cols 128+) emits inactive defaults
    mu_1, k_1, S_1, a_1 = mu_solve(Y, th_scalar, block_m=128, interpret=True,
                                   nact_blocks=jnp.asarray(1, jnp.int32))
    np.testing.assert_array_equal(np.asarray(mu_1)[:128],
                                  np.asarray(mu_s)[:128])
    assert not np.asarray(a_1)[128:].any()
    assert (np.asarray(mu_1)[128:] == 0).all()
    assert (np.asarray(k_1)[128:] == 1).all()


def test_ref_oracle_matches_heap():
    rng = np.random.default_rng(7)
    Y = rng.uniform(-1, 1, size=(60, 80))
    for Cfrac in (0.05, 0.5):
        C = float(Cfrac * np.abs(Y).max(axis=0).sum())
        Xr = np.asarray(ref.project_l1inf_ref(jnp.asarray(Y, jnp.float32), C))
        Xh = project_l1inf_heap(Y, C)
        np.testing.assert_allclose(Xr, Xh, atol=1e-4, rtol=1e-3)
