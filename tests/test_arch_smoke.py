"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train step + one decode step on CPU; shapes and finiteness asserted.

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models.zoo import build, make_batch
from repro.models.transformer import init_cache, decode_step
from repro.optim import AdamConfig, adam_init, adam_update
from repro.core import apply_constraints

B, S = 2, 32


def _finite(tree):
    return all(np.all(np.isfinite(np.asarray(l, np.float32)))
               for l in jax.tree_util.tree_leaves(tree))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, B, S, kind="train")

    logits, aux = model.forward(params, batch)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert _finite(logits)
    # padded vocab columns are masked off
    if cfg.vocab_padded > cfg.vocab:
        assert float(np.max(np.asarray(logits)[..., cfg.vocab:])) < -1e20

    (loss, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
        params, batch)
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert _finite(grads), arch

    acfg = AdamConfig(lr=1e-3)
    opt = adam_init(params, acfg)
    new_params, opt = adam_update(grads, opt, params, acfg)
    assert _finite(new_params)
    # the paper's technique as a first-class feature: constraint application
    if cfg.projection_specs:
        projected = apply_constraints(new_params, cfg.projection_specs)
        assert _finite(projected)
        changed = any(
            not np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree_util.tree_leaves(new_params),
                            jax.tree_util.tree_leaves(projected)))
        assert changed, f"{arch}: projection specs matched no parameters"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_reduced(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(1))
    cache = init_cache(cfg, B, S, jnp.float32)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, new_cache = decode_step(params, cache, tok, jnp.asarray(3), cfg)
    assert logits.shape == (B, 1, cfg.vocab_padded)
    assert _finite(logits), arch
    # cache structure preserved
    assert (jax.tree_util.tree_structure(cache)
            == jax.tree_util.tree_structure(new_cache))
    # another step at the next position must differ (state advanced). Exact
    # comparison: with a repeated token the softmax can saturate on the
    # current position (gemma_7b), leaving only eps-level differences — but a
    # decode that ignored pos/cache entirely would be bit-identical.
    logits2, _ = decode_step(params, new_cache, tok, jnp.asarray(4), cfg)
    assert not np.array_equal(np.asarray(logits), np.asarray(logits2)), arch


def test_full_configs_match_assignment():
    """Exact assigned numbers (spot the critical dims)."""
    expect = {
        "gemma_7b": (28, 3072, 16, 16, 24576, 256000),
        "qwen25_32b": (64, 5120, 40, 8, 27648, 152064),
        "gemma3_4b": (34, 2560, 8, 4, 10240, 262144),
        "stablelm_3b": (32, 2560, 32, 32, 6912, 50304),
        "hymba_15b": (32, 1600, 25, 5, 5504, 32001),
        "llama32_vision_90b": (100, 8192, 64, 8, 28672, 128256),
        "whisper_small": (12, 768, 12, 12, 3072, 51865),
        "mamba2_370m": (48, 1024, 1, 1, 0, 50280),
        "mixtral_8x7b": (32, 4096, 32, 8, 14336, 32000),
        "deepseek_v2_236b": (60, 5120, 128, 128, 1536, 102400),
    }
    for arch, (L, d, H, KV, ff, V) in expect.items():
        cfg = get_config(arch)
        got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
               cfg.d_ff, cfg.vocab)
        assert got == (L, d, H, KV, ff, V), (arch, got)
    assert get_config("mixtral_8x7b").n_experts == 8
    assert get_config("deepseek_v2_236b").n_experts == 160
    assert get_config("deepseek_v2_236b").kv_lora == 512
    assert get_config("mamba2_370m").ssm_state == 128
    assert get_config("hymba_15b").ssm_state == 16


def test_param_counts_plausible():
    """Total parameter counts from the layouts are in the advertised range."""
    from repro.models.zoo import build
    expects = {  # (low, high) in billions
        "gemma_7b": (7, 10),
        "qwen25_32b": (25, 36),
        "gemma3_4b": (3, 6),
        "stablelm_3b": (2, 4),
        "hymba_15b": (1, 2.5),
        "llama32_vision_90b": (70, 100),
        "whisper_small": (0.08, 0.35),
        "mamba2_370m": (0.25, 0.55),
        "mixtral_8x7b": (40, 52),
        "deepseek_v2_236b": (200, 260),
    }
    for arch, (lo, hi) in expects.items():
        n = build(get_config(arch)).n_params() / 1e9
        assert lo <= n <= hi, (arch, n)
