"""Pipeline-parallel stage handoff + compressed cross-pod psum, validated on
host-device meshes in subprocesses."""
import os
import subprocess
import sys
import textwrap

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


def test_pipeline_matches_sequential():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.dist.pipeline import build_pipeline_fn

        N_STAGES, N_MICRO, D = 4, 8, 16
        mesh = jax.make_mesh((4,), ("pod",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        rng = np.random.default_rng(0)
        Ws = jnp.asarray(rng.normal(size=(N_STAGES, D, D)) * 0.3, jnp.float32)
        x = jnp.asarray(rng.normal(size=(N_MICRO, 2, D)), jnp.float32)

        stage = lambda W, h: jnp.tanh(h @ W)
        pipe = build_pipeline_fn(stage, N_STAGES, N_MICRO, mesh, "pod")
        with mesh:
            y = jax.jit(pipe)(Ws, x)

        # sequential reference
        ref = x
        for s in range(N_STAGES):
            ref = jnp.tanh(ref @ Ws[s])
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   atol=1e-5)
        print("OK")
    """)
    assert "OK" in out


def test_compressed_psum_int8():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.dist.compression import compressed_psum

        mesh = jax.make_mesh((4,), ("pod",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        rng = np.random.default_rng(1)
        g = jnp.asarray(rng.normal(size=(4, 256)), jnp.float32)

        def body(gs):
            return compressed_psum({"g": gs[0]}, "pod", mode="int8")["g"]

        fn = shard_map(body, mesh=mesh, in_specs=(P("pod"),),
                       out_specs=P(), check_rep=False)
        with mesh:
            total_c = fn(g)
        total = np.asarray(g).sum(0)
        err = np.abs(np.asarray(total_c) - total).max()
        scale = np.abs(np.asarray(g)).max() / 127
        assert err <= 4 * scale + 1e-5, (err, scale)
        print("OK")
    """)
    assert "OK" in out
