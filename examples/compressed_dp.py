"""Cross-pod data parallelism with compressed gradient reduction.

Demonstrates the dist/compression primitives in an explicit shard_map DP
step: within-pod math stays exact; the cross-pod gradient combine uses the
int8 shared-scale psum (4x DCI traffic cut) or EF top-k. Runs on host
devices standing in for pods:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/compressed_dp.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.dist.compression import compressed_psum

PODS = 4
D = 256

mesh = jax.make_mesh((PODS,), ("pod",),
                     axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(0)
W = jnp.asarray(rng.normal(size=(D, D)) * 0.1, jnp.float32)
X = jnp.asarray(rng.normal(size=(PODS * 8, D)), jnp.float32)
Y = jnp.asarray(rng.normal(size=(PODS * 8, D)), jnp.float32)


def local_grad(W, x, y):
    def loss(W):
        return jnp.mean((x @ W - y) ** 2)
    return jax.grad(loss)(W)


def dp_step(mode):
    def body(x, y):
        g = local_grad(W, x, y)                       # per-pod gradient
        g = compressed_psum({"g": g}, "pod", mode=mode)["g"] / PODS
        return g

    fn = shard_map(body, mesh=mesh, in_specs=(P("pod"), P("pod")),
                   out_specs=P(), check_rep=False)
    with mesh:
        return jax.jit(fn)(X, Y)


g_exact = dp_step("none")
g_int8 = dp_step("int8")
err = float(jnp.max(jnp.abs(g_exact - g_int8)))
rel = err / float(jnp.max(jnp.abs(g_exact)))
print(f"exact-vs-int8 grad max err: {err:.3e} (rel {rel:.3%}) — "
      f"4x cross-pod traffic cut")
assert rel < 0.02
print("compressed cross-pod DP OK")
