"""Paper reproduction at example scale: supervised autoencoder + l1,inf
double descent for biomarker-style feature selection (paper §5-6).

    PYTHONPATH=src python examples/sae_feature_selection.py
"""
import numpy as np

from repro.core import ProjectionSpec
from repro.sae import (SAEConfig, SAETrainConfig, make_classification,
                       train_test_split, train_sae)

D, INFORMATIVE = 2000, 32
X, y, inf_idx = make_classification(
    n_samples=800, n_features=D, n_informative=INFORMATIVE,
    class_sep=1.0, seed=0)
X = (X - X.mean(0)) / (X.std(0) + 1e-6)
Xtr, ytr, Xte, yte = train_test_split(X, y, 0.2, seed=0)

for name, spec in [
    ("baseline (no projection)", None),
    ("l1,inf projected (Algorithm 3)",
     ProjectionSpec(pattern=r"enc1/w", norm="l1inf", radius=0.2, axis=1)),
]:
    res = train_sae(Xtr, ytr, Xte, yte,
                    SAEConfig(n_features=D, n_hidden=96, n_classes=2),
                    SAETrainConfig(epochs=25, lr=2e-3, projection=spec,
                                   seed=0))
    sel = res.selected
    hits = np.intersect1d(sel, inf_idx).size if len(sel) else 0
    print(f"{name:35s} acc={res.test_accuracy*100:5.2f}%  "
          f"colsp={res.column_sparsity:5.1f}%  "
          f"selected={len(sel):4d}  informative-recovered={hits}/{INFORMATIVE}")
