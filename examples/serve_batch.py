"""Batched COMPACT serving example: a zoo checkpoint with its structural
zeros compiled out (DESIGN.md §10), driven by the BatchServer slot manager
on CPU — ragged prompts, hot checkpoint refresh, and one live
re-compaction, all through a single compiled decode step.

    PYTHONPATH=src python examples/serve_batch.py
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.core import apply_constraints
from repro.core.constraints import ProjectionSpec
from repro.models.zoo import build
from repro.train.serve import BatchServer, ServeConfig

# a reduced zoo config whose mlp/w1 carries the paper's l1,inf constraint
cfg = dataclasses.replace(get_reduced("gemma_7b"), n_layers=4)
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))

# stand-in for projected training: one hard projection at a tight radius
# leaves most hidden units as structural zeros (exact, not approximate)
spec = dataclasses.replace(cfg.projection_specs[0], radius=0.15)
cfg = dataclasses.replace(cfg, projection_specs=(spec,))
model = dataclasses.replace(model, cfg=cfg)
params = apply_constraints(params, cfg.projection_specs)

server = BatchServer(model, batch_slots=4, scfg=ServeConfig(max_seq=64))
server.load_compact(params=params)
ratios = server.compact.compaction_ratios()
for path, r in ratios.items():
    print(f"{path}: serving {r:.1%} of the trained width")

prompts = [[1, 5, 9], [2, 4], [7, 7, 7, 7]]   # ragged: rows run per-position
outs = server.generate(prompts, max_new=8)
for p, o in zip(prompts, outs):
    print(f"prompt {p} -> {o}")

# hot refresh: a new checkpoint's values flow through the frozen gather —
# same shapes, so the compiled step is reused, never retraced
params2 = jax.tree_util.tree_map(lambda a: a * 1.01, params)
server.refresh(params2)
server.generate(prompts, max_new=8)

# live re-compaction: kill one more hidden unit, support shrinks INSIDE
# the frozen slot width (pad slots re-gather a dead column -> exact zeros)
w1_path = next(iter(server.compact.sels))
victim = int(server.compact.sels[w1_path][0])
mlp = params2["blocks"]["p0_global"]["mlp"]
arr = np.array(mlp["w1"])
arr[..., victim] = 0.0
mlp["w1"] = jnp.asarray(arr)
server.recompact(params2)
server.generate(prompts, max_new=8)

print(f"live support now {server.compact.live[w1_path]} / "
      f"slot {server.compact.slot_width(w1_path)}")
print(f"served {len(prompts)} ragged requests + refresh + re-compaction "
      f"with {server.n_traces} compile(s)")
assert server.n_traces == 1
