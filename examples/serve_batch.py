"""Batched serving example: the same decode_step the 512-chip dry-run
lowers, driven by the BatchServer slot manager on CPU.

    PYTHONPATH=src python examples/serve_batch.py
"""
import dataclasses

import jax

from repro.configs import get_reduced
from repro.models.zoo import build
from repro.train.serve import BatchServer, ServeConfig

cfg = dataclasses.replace(get_reduced("mamba2_370m"), n_layers=4)
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))

server = BatchServer(model, batch_slots=4, scfg=ServeConfig(max_seq=64))
server.load(params)

prompts = [[1, 5, 9], [2, 4], [7, 7, 7, 7]]
outs = server.generate(prompts, max_new=8)
for p, o in zip(prompts, outs):
    print(f"prompt {p} -> {o}")
print("served", len(prompts), "requests in one fixed-shape batch")
