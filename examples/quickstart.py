"""Quickstart: the l1,inf projection family in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (
    l1inf_norm, project_l1inf, project_l1inf_heap, project_l1inf_masked,
    prox_linf1, theta_l1inf, ProjectionSpec, apply_constraints,
    sparsity_report,
)

rng = np.random.default_rng(0)
Y = rng.normal(size=(64, 256)).astype(np.float32)
C = 8.0

print(f"||Y||_1,inf = {float(l1inf_norm(jnp.asarray(Y))):.2f}, projecting to C={C}")

# 1) the TPU-native production path (jit-safe semismooth Newton)
X = project_l1inf(jnp.asarray(Y), C)                # method="newton"
print(f"newton : ||X|| = {float(l1inf_norm(X)):.4f}, "
      f"zero columns = {int((np.abs(np.asarray(X)).max(0) == 0).sum())}/256")

# 2) the paper's own near-linear heap algorithm (CPU oracle)
Xh = project_l1inf_heap(Y, C)
print(f"heap   : max |diff| vs newton = "
      f"{np.abs(np.asarray(X) - Xh).max():.2e}")
print(f"theta* = {float(theta_l1inf(jnp.asarray(Y), C)):.4f}")

# 3) masked projection (Eq. 20): same support, unclipped magnitudes
Xm = project_l1inf_masked(jnp.asarray(Y), C)
print(f"masked : kept columns match projection support: "
      f"{bool(((np.asarray(Xm) != 0).any(0) == (np.asarray(X) != 0).any(0)).all())}")

# 4) prox of the dual norm via Moreau (Eq. 16)
p = prox_linf1(jnp.asarray(Y), C)
print(f"moreau : ||prox + proj - Y|| = "
      f"{np.abs(np.asarray(p + X) - Y).max():.2e}")

# 5) as a training constraint on a parameter pytree
params = {"layer": {"w": jnp.asarray(Y)}, "bias": jnp.zeros(4)}
spec = ProjectionSpec(pattern=r"layer/w", norm="l1inf", radius=C, axis=0)
params = apply_constraints(params, (spec,))
print(f"pytree : column sparsity report = "
      f"{sparsity_report(params, (spec,))}")
