"""Continuous-batching fleet serving example: the FleetEngine keeps ONE
compiled decode step hot while requests arrive, finish, and free their
slots mid-flight (DESIGN.md §13) — no cohort barrier, no retrace. A
checkpoint refresh and a live re-compaction land between steps through
the same compiled step, and the engine reports per-request TTFT and
inter-token latency percentiles at the end.

    PYTHONPATH=src python examples/serve_fleet.py
"""
import dataclasses

import jax

from repro.configs import get_reduced
from repro.core import apply_constraints
from repro.models.zoo import build
from repro.serve import EngineConfig, FleetEngine, RecompactScheduler

# a reduced zoo config whose mlp/w1 carries the paper's l1,inf constraint
cfg = dataclasses.replace(get_reduced("gemma_7b"), n_layers=2)
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))

# stand-in for projected training: one hard projection at a tight radius
# leaves most hidden units as structural zeros (exact, not approximate)
spec = dataclasses.replace(cfg.projection_specs[0], radius=0.15)
cfg = dataclasses.replace(cfg, projection_specs=(spec,))
model = dataclasses.replace(model, cfg=cfg)
params = apply_constraints(params, cfg.projection_specs)

engine = FleetEngine(model, batch_slots=2, cfg=EngineConfig(max_seq=32),
                     scheduler=RecompactScheduler(threshold=0.9))
engine.load_compact(params=params)

# open-loop arrivals: more requests than slots, heavy-tailed budgets —
# short rows finish and their slots re-admit from the queue mid-flight
requests = [([1, 5, 9], 3), ([2, 4], 10), ([7, 7, 7], 3), ([3, 8], 3)]
rids = [engine.submit(prompt, max_new=budget)
        for prompt, budget in requests]

outs = {}
step = 0
while engine.stats()["busy_slots"] or engine.stats()["queue"]:
    for comp in engine.step():
        outs[comp.rid] = comp.tokens
    step += 1
    if step == 4:                       # hot checkpoint swap, mid-flight
        engine.refresh(params)
    if step == 6:                       # live re-compaction, mid-flight
        engine.recompact(params)
for comp in engine.flush():
    outs[comp.rid] = comp.tokens

for (prompt, budget), rid in zip(requests, rids):
    print(f"prompt {prompt} (budget {budget}) -> {outs[rid]}")

lat = engine.latency_report()
print(f"TTFT p50 {lat['ttft']['p50'] * 1e3:.2f} ms, per-token p50 "
      f"{lat['per_token']['p50'] * 1e3:.2f} ms over "
      f"{lat['per_token']['count']} gaps")

st = engine.stats()
print(f"served {len(requests)} requests over {st['steps']} steps "
      f"(+ refresh + re-compaction) with {st['n_traces']} compile(s)")
assert st["n_traces"] == 1
assert all(len(outs[rid]) == len(prompt) + budget
           for (prompt, budget), rid in zip(requests, rids))
