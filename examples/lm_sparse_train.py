"""End-to-end driver: train an LM with the paper's l1,inf structured
sparsity applied to the MLP in-projections during training — the framework's
first-class integration of the projection.

Default is a CPU-scale model (~5M params, 200 steps, a few minutes).
``--hundred-m`` selects a ~100M-param config (same code path; budget
permitting). On the production mesh the identical step is what the dry-run
lowers at 512 chips.

    PYTHONPATH=src python examples/lm_sparse_train.py
    PYTHONPATH=src python examples/lm_sparse_train.py --steps 300 --hundred-m
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.core import ProjectionSpec
from repro.models.zoo import build, reduce_config
from repro.data.pipeline import SyntheticLM, LMBatcher
from repro.train.loop import TrainConfig, train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--hundred-m", action="store_true")
ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
args = ap.parse_args()

base = get_config("gemma_7b")
if args.hundred_m:
    cfg = dataclasses.replace(
        reduce_config(base), n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=8, head_dim=64, d_ff=2048, vocab=32000,
        q_chunk=128, kv_chunk=128)
    batch, seq = 8, 256
else:
    cfg = dataclasses.replace(
        reduce_config(base), n_layers=4, d_model=256, n_heads=4,
        n_kv_heads=4, head_dim=64, d_ff=1024, vocab=8192,
        q_chunk=64, kv_chunk=64)
    batch, seq = 8, 128
cfg = dataclasses.replace(
    cfg,
    projection_specs=(ProjectionSpec(pattern=r"blocks/.*/mlp/w1$",
                                     norm="l1inf", radius=12.0, axis=0,
                                     every_k=10),))

model = build(cfg)
print(f"model: {model.n_params()/1e6:.1f}M params on {jax.devices()[0].platform}")

batcher = LMBatcher(SyntheticLM(cfg.vocab, seed=0), batch, seq)
out = train(model, batcher,
            TrainConfig(steps=args.steps, log_every=20, ckpt_every=100,
                        ckpt_dir=args.ckpt_dir, lr=1e-3,
                        with_projection=True))
if out["losses"]:
    print(f"loss: {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")
else:
    print(f"no steps to run: resumed at the final checkpoint in "
          f"{args.ckpt_dir} (delete it or raise --steps to train further)")
for k, v in out["sparsity"].items():
    print(f"column sparsity {k}: {v:.1f}%")
