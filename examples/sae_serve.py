"""End-to-end serving scenario: train a projected SAE (Algorithm 3),
compact the structurally-zero encoder columns out, and serve the compact
model — the paper's feature-selection payoff at inference time.

    PYTHONPATH=src python examples/sae_serve.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import ProjectionSpec
from repro.sae import (SAEConfig, SAETrainConfig, compact_sae,
                       make_classification, make_serve_step, sae_apply,
                       train_test_split, train_sae)

D, INFORMATIVE = 1000, 16

# 1) train under the l1,inf projection (double descent)
X, y, inf_idx = make_classification(
    n_samples=600, n_features=D, n_informative=INFORMATIVE,
    class_sep=1.2, seed=0)
X = (X - X.mean(0)) / (X.std(0) + 1e-6)
Xtr, ytr, Xte, yte = train_test_split(X, y, 0.2, seed=0)
spec = ProjectionSpec(pattern=r"enc1/w", norm="l1inf", radius=0.15, axis=1)
res = train_sae(Xtr, ytr, Xte, yte,
                SAEConfig(n_features=D, n_hidden=64, n_classes=2),
                SAETrainConfig(epochs=15, lr=2e-3, projection=spec, seed=0))
print(f"trained: acc={res.test_accuracy*100:.2f}%  "
      f"colsp={res.column_sparsity:.1f}%  "
      f"epoch compaction ratios (descent2): "
      f"{[f'{r:.3f}' for r in res.compaction_history[-1][1][-3:]]}")

# 2) compact: gather surviving encoder rows + co-compact the decoder output
compact = compact_sae(res.params, (spec,))
print(f"compacted: {compact.n_selected}/{compact.n_features} features kept "
      f"-> encoder GEMM at {compact.compaction_ratio:.4f}x dense FLOPs")

# 3) serve: batched jit step on full-width inputs (one static gather inside)
step = make_serve_step(compact)
xb = jnp.asarray(Xte[:64], jnp.float32)
z_c, xh_c = step(compact.params, xb)
z_d, xh_d = sae_apply(res.params, xb)
print(f"serve parity: max|z - z_dense| = "
      f"{float(jnp.abs(z_c - z_d).max()):.2e}, "
      f"max|xhat - xhat_dense[:, sel]| = "
      f"{float(jnp.abs(xh_c - xh_d[:, compact.sel]).max()):.2e}")

hits = np.intersect1d(compact.sel, inf_idx).size
print(f"selected features recover {hits}/{INFORMATIVE} informative ones")
